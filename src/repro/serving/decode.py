"""Batched single-token decode with explicit caches — the ``serve_step``.

Cache layouts (leading axis = layer, so the decode loop is a lax.scan that
consumes cache slices as xs and emits updated slices as ys):

  dense/moe : KV ring buffers  k,v (L, B, W, KH, hd) + per-seq positions (B,)
  ssm       : SSD states (L, B, H, N, P) + conv rings (L, B, K-1, C)
  hybrid    : SWA ring buffers (W = sliding_window) for the scanned segments,
              full-context caches for the 3 global layers, SSM state for all
  vlm       : self-KV rings per superblock + precomputed cross-KV from the
              (stub) patch embeddings
  audio     : decoder self-KV rings + precomputed cross-KV from the (stub)
              encoder output

Positions are per-sequence (B,), so continuous batching (sequences at
different offsets) works; ring slots are ``pos % W`` and keys are stored
post-RoPE, making slot order irrelevant to the softmax.

``decode_32k`` lowers these functions with a full 32k cache; ``long_500k``
(ssm/hybrid only) carries O(1) state + O(W) window — that is the point.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..models.layers import attn_project_qkv, decode_attention, rms_norm, swiglu_mlp
from ..models.moe import moe_ffn
from ..models.ssm import init_ssm_state, ssm_decode
from ..models.transformer import _lm_head, hymba_layout

Params = dict[str, Any]


def _cache_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _kv_cache(n_layers: int, batch: int, window: int, cfg, dtype=None):
    dtype = dtype or _cache_dtype(cfg)
    shape = (n_layers, batch, window, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _ssm_states(n_layers: int, batch: int, cfg):
    one = init_ssm_state(cfg, batch)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_layers,) + a.shape), one)


def init_cache(cfg, batch: int, context: int) -> Params:
    """Cache pytree for ``context`` max tokens (ShapeDtypeStruct-able)."""
    cache: Params = {"pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        cache["ssm"] = _ssm_states(cfg.n_layers, batch, cfg)
    elif cfg.hybrid:
        mid, na, nb = hymba_layout(cfg)
        w = min(cfg.sliding_window, context)
        cache["seg_a"] = _kv_cache(na, batch, w, cfg)
        cache["seg_b"] = _kv_cache(nb, batch, w, cfg)
        cache["glb"] = _kv_cache(3, batch, context, cfg)
        cache["ssm_a"] = _ssm_states(na, batch, cfg)
        cache["ssm_b"] = _ssm_states(nb, batch, cfg)
        cache["ssm_g"] = _ssm_states(3, batch, cfg)
    elif cfg.family == "vlm":
        dt = _cache_dtype(cfg)
        k = cfg.cross_attn_every
        nsb = cfg.n_layers // (k + 1)
        shape = (nsb, k, batch, context, cfg.n_kv_heads, cfg.hd)
        cache["self_k"] = jnp.zeros(shape, dt)
        cache["self_v"] = jnp.zeros(shape, dt)
        xshape = (nsb, batch, cfg.vision_tokens, cfg.n_kv_heads, cfg.hd)
        cache["cross_k"] = jnp.zeros(xshape, dt)
        cache["cross_v"] = jnp.zeros(xshape, dt)
    elif cfg.is_encdec:
        dt = _cache_dtype(cfg)
        cache.update(_kv_cache(cfg.n_layers, batch, context, cfg))
        xshape = (cfg.n_layers, batch, cfg.audio_frames, cfg.n_kv_heads, cfg.hd)
        cache["cross_k"] = jnp.zeros(xshape, dt)
        cache["cross_v"] = jnp.zeros(xshape, dt)
    else:
        cache.update(_kv_cache(cfg.n_layers, batch, context, cfg))
    return cache


# ---------------------------------------------------------------------------
# Single-layer decode helpers
# ---------------------------------------------------------------------------


def _attn_decode(lp, x, cfg, k_l, v_l, pos):
    """x (B,1,D); k_l/v_l (B,W,KH,hd); pos (B,) absolute positions."""
    b = x.shape[0]
    w = k_l.shape[1]
    q, k, v = attn_project_qkv(lp, x, cfg, pos[:, None])
    slot = pos % w
    k_l = k_l.at[jnp.arange(b), slot].set(k[:, 0].astype(k_l.dtype))
    v_l = v_l.at[jnp.arange(b), slot].set(v[:, 0].astype(v_l.dtype))
    valid = jnp.minimum(pos + 1, w)
    o = decode_attention(q, k_l, v_l, valid)
    return o.reshape(b, 1, -1) @ lp["wo"], k_l, v_l


def _dense_decode_layer(lp, x, cfg, k_l, v_l, pos, *, moe: bool):
    a, k_l, v_l = _attn_decode(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, k_l, v_l, pos)
    h = x + a
    hin = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if moe:
        out, _ = moe_ffn(lp["moe"], hin, cfg)
    else:
        out = swiglu_mlp(lp["mlp"], hin)
    return h + out, k_l, v_l


def _cross_decode(lp, x, cfg, ck, cv):
    b = x.shape[0]
    q = (x @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
    o = decode_attention(q, ck, cv, ck.shape[1])
    return o.reshape(b, 1, -1) @ lp["wo"]


def _hybrid_decode_layer(lp, x, cfg, k_l, v_l, sst, pos):
    xin = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, k_l, v_l = _attn_decode(lp["attn"], xin, cfg, k_l, v_l, pos)
    s, sst = ssm_decode(lp["ssm"], sst, xin, cfg)
    mixed = 0.5 * (
        rms_norm(a, lp["attn_norm"], cfg.norm_eps) + rms_norm(s, lp["ssm_norm"], cfg.norm_eps)
    )
    h = x + mixed
    h = h + swiglu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
    return h, k_l, v_l, sst


# ---------------------------------------------------------------------------
# decode_step per family
# ---------------------------------------------------------------------------


def decode_step(params: Params, cfg, cache: Params, tokens: jax.Array):
    """tokens (B,1) int32 -> (logits (B,V) f32, new cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]
    x = params["embed"][tokens]

    if cfg.family == "ssm":
        def body(h, xs):
            lp, st = xs
            xin = rms_norm(h, lp["ln1"], cfg.norm_eps)
            y, st2 = ssm_decode(lp["ssm"], st, xin, cfg)
            return h + y, st2
        x, new_states = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache = {**cache, "ssm": new_states, "pos": pos + 1}

    elif cfg.hybrid:
        gl = params["global_layers"]
        g = lambda i: jax.tree.map(lambda a: a[i], gl)
        gk, gv = cache["glb"]["k"], cache["glb"]["v"]
        gs = cache["ssm_g"]
        gsel = lambda t, i: jax.tree.map(lambda a: a[i], t)
        new_gk, new_gv, new_gs = [], [], []

        def seg(x, layers, kv, states):
            def body(h, xs):
                lp, k_l, v_l, st = xs
                h, k2, v2, st2 = _hybrid_decode_layer(lp, h, cfg, k_l, v_l, st, pos)
                return h, (k2, v2, st2)
            x, (k2, v2, st2) = jax.lax.scan(body, x, (layers, kv["k"], kv["v"], states))
            return x, {"k": k2, "v": v2}, st2

        x, gk0, gv0, gs0 = _hybrid_decode_layer(g(0), x, cfg, gk[0], gv[0], gsel(gs, 0), pos)
        x, kv_a, st_a = seg(x, params["seg_a"], cache["seg_a"], cache["ssm_a"])
        x, gk1, gv1, gs1 = _hybrid_decode_layer(g(1), x, cfg, gk[1], gv[1], gsel(gs, 1), pos)
        x, kv_b, st_b = seg(x, params["seg_b"], cache["seg_b"], cache["ssm_b"])
        x, gk2, gv2, gs2 = _hybrid_decode_layer(g(2), x, cfg, gk[2], gv[2], gsel(gs, 2), pos)
        new_cache = {
            "pos": pos + 1,
            "seg_a": kv_a, "seg_b": kv_b,
            "ssm_a": st_a, "ssm_b": st_b,
            "glb": {"k": jnp.stack([gk0, gk1, gk2]), "v": jnp.stack([gv0, gv1, gv2])},
            "ssm_g": jax.tree.map(lambda a, b_, c: jnp.stack([a, b_, c]), gs0, gs1, gs2),
        }

    elif cfg.family == "vlm":
        k = cfg.cross_attn_every

        def sb_body(h, xs):
            sb, sk, sv, ck, cv = xs
            new_k, new_v = [], []
            for i in range(k):
                lp = jax.tree.map(lambda a: a[i], sb["self"])
                h, k2, v2 = _dense_decode_layer(lp, h, cfg, sk[i], sv[i], pos, moe=False)
                new_k.append(k2)
                new_v.append(v2)
            cl = sb["cross"]
            hin = rms_norm(h, cl["ln1"], cfg.norm_eps)
            h = h + _cross_decode(cl["attn"], hin, cfg, ck, cv)
            h = h + swiglu_mlp(cl["mlp"], rms_norm(h, cl["ln2"], cfg.norm_eps))
            return h, (jnp.stack(new_k), jnp.stack(new_v))

        x, (nsk, nsv) = jax.lax.scan(
            sb_body, x,
            (params["superblocks"], cache["self_k"], cache["self_v"],
             cache["cross_k"], cache["cross_v"]),
        )
        new_cache = {**cache, "self_k": nsk, "self_v": nsv, "pos": pos + 1}

    elif cfg.is_encdec:
        def body(h, xs):
            lp, k_l, v_l, ck, cv = xs
            a, k2, v2 = _attn_decode(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, k_l, v_l, pos)
            h = h + a
            h = h + _cross_decode(lp["cross"], rms_norm(h, lp["ln3"], cfg.norm_eps), cfg, ck, cv)
            h = h + swiglu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
            return h, (k2, v2)
        x, (nk, nv) = jax.lax.scan(
            body, x,
            (params["layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        )
        new_cache = {**cache, "k": nk, "v": nv, "pos": pos + 1}

    else:  # dense / moe
        is_moe = cfg.family == "moe"

        def body(h, xs):
            lp, k_l, v_l = xs
            h, k2, v2 = _dense_decode_layer(lp, h, cfg, k_l, v_l, pos, moe=is_moe)
            return h, (k2, v2)
        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {**cache, "k": nk, "v": nv, "pos": pos + 1}

    logits = _lm_head(params, cfg, x)[:, 0]
    return logits, new_cache
