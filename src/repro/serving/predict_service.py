"""Micro-batched block-prediction service: the traffic-bearing §VI path.

The paper's block-access result (§VI, Figure 9) is that scoring a *set* of
test instances costs one grouped count query + one matmul per family,
instead of one restricted count pipeline per instance.  This module turns
that batch observation into a serving loop:

* **Resident model state.**  At construction the service runs each
  family's grouped count query ONCE (the expensive, data-touching part)
  and keeps the per-entity count matrix and the log-CPT matrix
  device-resident.  A request for entities ``[e1..ek]`` is then a gather +
  the ``block_predict`` contraction — no count pipeline on the hot path.

* **Micro-batching on the bucket ladder.**  Requests land in a bounded
  queue; a worker thread drains it and flushes a batch when it has
  ``max_batch`` rows or the oldest request has waited ``flush_ms``.  The
  gathered batch is padded up to the geometric bucket-ladder rung
  (:func:`~repro.kernels.bucketing.bucket_rows`, min 2 rows), so arbitrary
  traffic shapes hit O(#rungs) compiled programs: after
  :meth:`PredictService.warmup`, the serving path compiles **zero** new
  XLA programs — the ``bench_serve`` CI gate.

* **Bit-identity.**  Scoring rides
  :func:`~repro.core.predict.family_row_scores` — the same rung-padded
  contraction ``predict_single_loop`` uses — and the same family order and
  normalization, so served posteriors are *bitwise* equal to the
  single-instance oracle on the same model (the ``serve_equal`` gate),
  not merely close.

* **Accounting.**  Per-request latency quantiles are tracked in-service;
  compiles and kernel launches ride the existing global counters in
  :mod:`repro.kernels.ops` / :mod:`repro.kernels.bucketing`, snapshotted
  at warmup so :meth:`PredictService.stats` reports warm-path deltas.

Responses are host numpy arrays (the device->host copy is part of serving
a request and is transfer-accounted through ``ops.to_host``).
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import resolve as _resolve_config
from ..core.counts import GROUP_AXIS, contingency_table
from ..core.model_store import LearnedModel
from ..core.predict import _families_with, _log_factor_matrix, family_row_scores
from ..core.sparse_counts import SparseCT
from ..kernels import bucketing, ops
from ..kernels.bucketing import bucket_rows

__all__ = ["PredictService", "ServedPrediction", "ServiceOverloaded"]


class ServiceOverloaded(RuntimeError):
    """The bounded request queue is full — backpressure, not silent queuing."""


@dataclass(frozen=True)
class ServedPrediction:
    """One answered request: posteriors for the requested entities."""

    target: str
    entity_ids: np.ndarray    # (k,) int32 — the ids as requested
    log_scores: np.ndarray    # (k, |Y|) unnormalized, float32
    probs: np.ndarray         # (k, |Y|) normalized (Eq. 2), float32
    latency_ms: float         # enqueue -> response


@dataclass(frozen=True)
class _Request:
    ids: np.ndarray
    future: Future
    enqueued: float


_SHUTDOWN = object()


class PredictService:
    """Answer batched ``P(y | x)`` queries for one (db, model, target).

    Parameters
    ----------
    db:
        The evidence database (its schema must equal ``model.schema``).
    model:
        A :class:`~repro.core.model_store.LearnedModel` — typically
        ``load_model(path)`` output, CPTs device-resident.
    target:
        The class par-RV, an entity attribute (paper §VII).
    max_batch:
        Flush a micro-batch once it holds this many rows.
    flush_ms:
        Flush once the oldest queued request has waited this long.
    queue_size:
        Bound of the request queue; :meth:`submit` raises
        :class:`ServiceOverloaded` when it is full.
    impl:
        Kernel dispatch policy for the resident build and the hot path
        (``auto`` honors ``engine_config(kernel_impl=...)`` as usual).
    """

    def __init__(
        self,
        db,
        model: LearnedModel,
        target: str,
        *,
        max_batch: int = 64,
        flush_ms: float = 2.0,
        queue_size: int = 1024,
        impl: str = "auto",
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if flush_ms < 0:
            raise ValueError(f"flush_ms must be >= 0, got {flush_ms}")
        if queue_size < 1:
            raise ValueError(f"queue_size must be >= 1, got {queue_size}")
        if model.schema != db.schema:
            raise ValueError(
                "model/database schema mismatch: the artifact was learned "
                "on a different relational schema than the serving database"
            )

        cat = db.catalog
        target_rv = cat[target]
        if target_rv.kind != "entity_attr":
            raise ValueError(
                f"serving targets are entity attributes (paper §VII), "
                f"got {target!r} of kind {target_rv.kind!r}"
            )

        self.target = target
        self.max_batch = int(max_batch)
        self.flush_s = float(flush_ms) / 1e3
        self._impl = impl
        self._kimpl = ops.kernel_impl(impl)
        self.n_entities = db.entities[target_rv.table].n_rows
        self.n_y = target_rv.cardinality

        # Resident model state: one grouped count query per family, run
        # once, then (counts, log-CPT) stay on device for the hot path.
        fovar = target_rv.fovars[0].fid
        self._fams: list[tuple[jnp.ndarray | None, jnp.ndarray]] = []
        for child in _families_with(model.bn, target):
            rest, logmat = _log_factor_matrix(model.factors[child], target)
            logmat = logmat.reshape(-1, self.n_y)
            if rest:
                gct = contingency_table(db, rest, impl=impl, group_fovar=fovar)
                gct = gct.transpose((GROUP_AXIS,) + rest)
                if isinstance(gct, SparseCT):
                    # densify once at build time (counts are exact ints) so
                    # the hot path is a uniform gather + dense contraction
                    gct = gct.to_dense(
                        budget=_resolve_config("dense_cell_budget")
                    )
                counts = ops.to_device(
                    np.asarray(ops.to_host(gct.table), np.float32).reshape(
                        self.n_entities, -1
                    )
                )
            else:
                counts = None  # family is {Y} alone: one grounding per entity
            self._fams.append((counts, ops.to_device(np.asarray(logmat))))

        self._queue: queue.Queue = queue.Queue(maxsize=int(queue_size))
        self._lock = threading.Lock()
        self._latencies_ms: list[float] = []
        self._batch_rows: list[int] = []
        self._n_requests = 0
        self._launches0 = ops.total_launches()
        self._compiles0 = bucketing.total_compiles()
        self._closed = False
        self._worker = threading.Thread(
            target=self._serve_loop, name="repro-predict-service", daemon=True
        )
        self._worker.start()

    # -- scoring ------------------------------------------------------------

    def _score_batch(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(log_scores, probs) host arrays for ``ids`` — the §VI block path.

        Every device op runs at the bucket rung of ``len(ids)`` (padding
        gathers entity 0; its rows are sliced off host-side so result
        shapes never leak data-dependent sizes into compiled programs).
        """
        n = len(ids)
        pad = max(bucket_rows(max(n, 1)), 2)
        idx = np.zeros((pad,), np.int32)
        idx[:n] = ids
        idx = jnp.asarray(idx)

        scores = jnp.zeros((pad, self.n_y), jnp.float32)
        for counts, logmat in self._fams:
            if counts is not None:
                rows = jnp.take(counts, idx, axis=0)
            else:
                rows = jnp.ones((pad, 1), jnp.float32)
            scores = scores + family_row_scores(rows, logmat, impl=self._kimpl)
        logz = jax.scipy.special.logsumexp(scores, axis=1, keepdims=True)
        probs = jnp.exp(scores - logz)
        log_host = ops.to_host(scores)[:n]
        prob_host = ops.to_host(probs)[:n]
        return log_host, prob_host

    # -- the micro-batching loop -------------------------------------------

    def _serve_loop(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=0.05)
            except queue.Empty:
                if self._closed:
                    return
                continue
            if first is _SHUTDOWN:
                return
            batch = [first]
            total = len(first.ids)
            deadline = first.enqueued + self.flush_s
            while total < self.max_batch:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    break
                try:
                    req = self._queue.get(timeout=wait)
                except queue.Empty:
                    break
                if req is _SHUTDOWN:
                    self._flush(batch)
                    return
                batch.append(req)
                total += len(req.ids)
            self._flush(batch)

    def _flush(self, batch: list[_Request]) -> None:
        ids = np.concatenate([req.ids for req in batch])
        try:
            log_scores, probs = self._score_batch(ids)
        except BaseException as e:  # surface failures to every waiter
            for req in batch:
                if not req.future.cancelled():
                    req.future.set_exception(e)
            return
        done = time.perf_counter()
        offset = 0
        with self._lock:
            self._batch_rows.append(len(ids))
        for req in batch:
            k = len(req.ids)
            latency_ms = (done - req.enqueued) * 1e3
            result = ServedPrediction(
                target=self.target,
                entity_ids=req.ids,
                log_scores=log_scores[offset:offset + k],
                probs=probs[offset:offset + k],
                latency_ms=latency_ms,
            )
            offset += k
            with self._lock:
                self._latencies_ms.append(latency_ms)
            if not req.future.cancelled():
                req.future.set_result(result)

    # -- public API ---------------------------------------------------------

    def submit(self, entity_ids) -> Future:
        """Enqueue one request; resolves to a :class:`ServedPrediction`."""
        if self._closed:
            raise RuntimeError("PredictService is closed")
        ids = np.atleast_1d(np.asarray(entity_ids, np.int32))
        if ids.ndim != 1 or ids.size == 0:
            raise ValueError(f"entity_ids must be a non-empty 1-d list, got {entity_ids!r}")
        if ids.min() < 0 or ids.max() >= self.n_entities:
            raise ValueError(
                f"entity ids must be in [0, {self.n_entities}), "
                f"got range [{ids.min()}, {ids.max()}]"
            )
        fut: Future = Future()
        req = _Request(ids=ids, future=fut, enqueued=time.perf_counter())
        with self._lock:
            self._n_requests += 1
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            raise ServiceOverloaded(
                f"request queue is full ({self._queue.maxsize} pending); "
                "shed load or raise queue_size"
            ) from None
        return fut

    def predict(self, entity_ids, timeout: float | None = 30.0) -> ServedPrediction:
        """Synchronous convenience: submit one request and wait for it."""
        return self.submit(entity_ids).result(timeout=timeout)

    def warmup(self, batch_sizes=None) -> dict:
        """Compile the serving programs for every rung up to ``max_batch``.

        Returns ``{"rungs": [...], "compiles": n}``.  After warmup the hot
        path compiles nothing: :meth:`stats` reports ``warm_compiles``
        relative to this point.
        """
        if batch_sizes is None:
            rungs: list[int] = []
            n = 1
            while True:
                rung = max(bucket_rows(n), 2)
                if rung not in rungs:
                    rungs.append(rung)
                if rung >= max(self.max_batch, 1):
                    break
                n = rung + 1
        else:
            rungs = sorted({max(bucket_rows(max(int(b), 1)), 2) for b in batch_sizes})
        before = bucketing.total_compiles()
        for rung in rungs:
            self._score_batch(np.zeros((rung,), np.int32))
        self._launches0 = ops.total_launches()
        self._compiles0 = bucketing.total_compiles()
        with self._lock:
            self._latencies_ms.clear()
            self._batch_rows.clear()
            self._n_requests = 0
        return {"rungs": rungs, "compiles": bucketing.total_compiles() - before}

    def stats(self) -> dict:
        """Serving counters: latency quantiles + warm-path compile/launch deltas.

        ``warm_compiles`` / ``launches`` ride the existing global
        accounting in :mod:`repro.kernels` (deltas since the last
        :meth:`warmup`, or construction), so other activity on the same
        process shows up here — bracket measurements accordingly.
        """
        with self._lock:
            lat = np.asarray(self._latencies_ms, np.float64)
            rows = list(self._batch_rows)
            n_requests = self._n_requests
        return {
            "requests": n_requests,
            "answered": int(lat.size),
            "batches": len(rows),
            "rows_per_batch": (float(np.mean(rows)) if rows else 0.0),
            "p50_ms": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "p99_ms": float(np.percentile(lat, 99)) if lat.size else 0.0,
            "warm_compiles": bucketing.total_compiles() - self._compiles0,
            "launches": ops.total_launches() - self._launches0,
        }

    def close(self) -> None:
        """Stop the worker after draining already-queued requests."""
        if self._closed:
            return
        self._closed = True
        self._queue.put(_SHUTDOWN)
        self._worker.join(timeout=10.0)

    def __enter__(self) -> "PredictService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
