"""Production meshes.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first init.

Topology: TPU v5e pods; single-pod = 256 chips as (data=16, model=16),
multi-pod = 2 pods x 256 as (pod=2, data=16, model=16).  DP/FSDP runs over
(pod, data); TP/EP over model; ICI within a pod, DCI across pods — the
``pod`` axis only ever carries data-parallel all-reduces.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_from_shape(shape: tuple[int, ...]):
    """Arbitrary test meshes, e.g. (2,2,2) on 8 host devices."""
    axes = ("pod", "data", "model")[-len(shape):] if len(shape) == 3 else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))
