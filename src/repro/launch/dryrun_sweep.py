"""Sweep driver: one subprocess per dry-run cell (isolation + fresh XLA).

Runs every (arch x shape) cell for the requested meshes, skipping cells whose
JSON already exists (resume semantics — delete results/dryrun to redo).  A
cell crash (OOM, sharding bug) is recorded and the sweep continues.

No jax import here: the child (repro.launch.dryrun) sets XLA_FLAGS itself.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ARCH_IDS = (
    "mamba2_130m", "llama32_vision_90b", "hymba_1_5b", "qwen3_4b",
    "granite_8b", "qwen15_32b", "minicpm_2b", "whisper_medium",
    "phi35_moe", "arctic_480b",
)
SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--meshes", nargs="*", default=["single", "multi"])
    p.add_argument("--archs", nargs="*", default=list(ARCH_IDS))
    p.add_argument("--shapes", nargs="*", default=list(SHAPE_IDS))
    p.add_argument("--timeout", type=int, default=3000)
    p.add_argument("--force", action="store_true")
    a = p.parse_args(argv)

    out = Path(a.out)
    out.mkdir(parents=True, exist_ok=True)
    cells = [
        (arch, s, m)
        for m in a.meshes  # mesh-major: all single-pod (roofline) first
        for arch in a.archs
        for s in a.shapes
    ]
    t0 = time.time()
    n_ok = n_skip = n_err = n_cached = 0
    for i, (arch, s, m) in enumerate(cells):
        path = out / f"{arch}--{s}--{m}.json"
        if path.exists() and not a.force:
            try:
                st = json.loads(path.read_text()).get("status")
            except Exception:
                st = None
            if st in ("ok", "skip"):
                n_cached += 1
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", s, "--mesh", m, "--out", str(out)]
        print(f"[{i+1}/{len(cells)}] {arch} x {s} x {m} (t={time.time()-t0:.0f}s)",
              flush=True)
        try:
            r = subprocess.run(cmd, timeout=a.timeout, capture_output=True, text=True)
            if r.returncode != 0 and not path.exists():
                path.write_text(json.dumps({
                    "arch": arch, "shape": s, "mesh": m, "status": "error",
                    "traceback": (r.stderr or "")[-8000:],
                }, indent=1))
        except subprocess.TimeoutExpired:
            path.write_text(json.dumps({
                "arch": arch, "shape": s, "mesh": m, "status": "error",
                "traceback": f"timeout after {a.timeout}s",
            }, indent=1))
        st = json.loads(path.read_text()).get("status") if path.exists() else "error"
        n_ok += st == "ok"
        n_skip += st == "skip"
        n_err += st == "error"
        print(f"    -> {st}", flush=True)
    print(f"done: ok={n_ok} skip={n_skip} err={n_err} cached={n_cached} "
          f"wall={time.time()-t0:.0f}s", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
