import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first init.  REPRO_DRYRUN_DEVICES overrides for mechanism tests
# on small fake-device counts (still before the jax import below).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the *real* step function (train / prefill / decode
— the same builders the trainer and server jit) against ShapeDtypeStruct
inputs with production shardings, compiles it for the 256-chip single-pod
mesh and/or the 512-chip two-pod mesh, and records:

  * compiled.memory_analysis()  — per-device bytes (proves it fits HBM)
  * compiled.cost_analysis()    — per-device FLOPs / bytes for the roofline
  * collective schedule         — parsed from the optimized HLO

into results/dryrun/{arch}--{shape}--{mesh}.json.  Sharding bugs, OOM-at-
compile and unsupported collectives all fail here — that is the point.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both   (hours on 1 CPU)
"""

import argparse
import json
import time
import traceback
from pathlib import Path


def _cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path, opt_level: str = "default") -> dict:
    import jax
    import jax.numpy as jnp

    from ..configs.base import SHAPES, get_config, shape_applicable
    from ..parallel import sharding as sh
    from ..roofline import analysis as ra
    from ..serving.decode import init_cache
    from ..training.step import (
        make_decode_step,
        make_prefill_step,
        make_train_step,
    )
    from ..training.optimizer import adamw_init
    from ..models.transformer import init_params
    from .mesh import make_production_mesh

    from ..configs.base import pad_heads
    from .mesh import make_mesh_from_shape

    cfg_true = get_config(arch)
    cfg = cfg_true
    if os.environ.get("REPRO_PAD_HEADS"):
        # §Perf "pad-heads": MHA archs pad to a model-axis multiple so
        # attention shards instead of replicating.  MODEL_FLOPS stays on the
        # true config (padded heads are not useful work).
        cfg = pad_heads(cfg_true, int(os.environ["REPRO_PAD_HEADS"]))
    shape = SHAPES[shape_name]
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "opt_level": opt_level,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    runs, why = shape_applicable(cfg, shape)
    if not runs:
        record.update(status="skip", reason=why)
        return record

    # Mechanism-test override (small fake-device counts); production default
    # is the spec mesh: (16,16) single-pod, (2,16,16) multi-pod.
    env_mesh = os.environ.get(
        "REPRO_DRYRUN_MESH_MULTI" if mesh_kind == "multi" else "REPRO_DRYRUN_MESH"
    )
    if env_mesh:
        mesh = make_mesh_from_shape(tuple(int(x) for x in env_mesh.split(",")))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    record["n_chips"] = int(n_chips)
    dp = sh.dp_axes(mesh)

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda: init_params(cfg, key))
    p_shard = sh.param_shardings(mesh, params_shapes)
    record["replication_notes"] = sh.explain(mesh, params_shapes)

    gb, seq = shape.global_batch, shape.seq_len
    tok_dtype = jnp.int32

    def batch_shapes_train():
        b = {
            "tokens": jax.ShapeDtypeStruct((gb, seq), tok_dtype),
            "labels": jax.ShapeDtypeStruct((gb, seq), tok_dtype),
        }
        if cfg.family == "vlm":
            b["memory"] = jax.ShapeDtypeStruct((gb, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            b["memory"] = jax.ShapeDtypeStruct((gb, cfg.audio_frames, cfg.d_model), jnp.bfloat16)
        return b

    import contextlib

    # ambient mesh so the model's with_sharding_constraint activations bind
    stack = contextlib.ExitStack()
    if hasattr(jax, "set_mesh"):
        stack.enter_context(jax.set_mesh(mesh))

    t0 = time.perf_counter()
    if shape.kind == "train":
        accum = int(os.environ.get("REPRO_ACCUM_STEPS", "4"))
        record["accum_steps"] = accum
        step = make_train_step(cfg, accum_steps=accum)
        opt_shapes = jax.eval_shape(lambda: adamw_init(params_shapes))
        o_shard = {
            "m": sh.param_shardings(mesh, params_shapes),
            "v": sh.param_shardings(mesh, params_shapes),
            "step": sh.replicated(mesh),
        }
        bshapes = batch_shapes_train()
        b_shard = sh.batch_shardings(mesh, bshapes)
        metrics_shard = jax.tree.map(lambda _: sh.replicated(mesh),
                                     jax.eval_shape(step, params_shapes, opt_shapes, bshapes)[2])
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, metrics_shard),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_shapes, opt_shapes, bshapes)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        bshapes = batch_shapes_train()
        bshapes.pop("labels")
        b_shard = sh.batch_shardings(mesh, bshapes)
        from jax.sharding import NamedSharding, PartitionSpec as P
        out_shard = NamedSharding(mesh, P(sh.div(mesh, gb, dp), None))
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard), out_shardings=out_shard)
        lowered = jitted.lower(params_shapes, bshapes)
    else:  # decode
        step = make_decode_step(cfg)
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, gb, seq))
        c_shard = sh.cache_shardings(mesh, cache_shapes)
        tshape = {"tokens": jax.ShapeDtypeStruct((gb, 1), tok_dtype)}
        t_shard = sh.batch_shardings(mesh, tshape)
        from jax.sharding import NamedSharding, PartitionSpec as P
        logits_shard = NamedSharding(mesh, P(sh.div(mesh, gb, dp), None))
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, t_shard["tokens"]),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_shapes, cache_shapes,
                               jax.ShapeDtypeStruct((gb, 1), tok_dtype))
    record["lower_s"] = time.perf_counter() - t0

    t1 = time.perf_counter()
    compiled = lowered.compile()
    record["compile_s"] = time.perf_counter() - t1
    stack.close()

    ca = compiled.cost_analysis() or {}
    record["cost_analysis"] = {
        k: float(v) for k, v in ca.items()
        if k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
    }
    try:
        ma = compiled.memory_analysis()
        record["memory_analysis"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }
    except Exception as e:  # pragma: no cover - backend-specific
        record["memory_analysis"] = {"error": str(e)}

    # Trip-count-aware accounting from the partitioned HLO text.  XLA's
    # module-level cost_analysis counts scan bodies once (verified:
    # tests/test_roofline.py), so the roofline terms come from the analyzer.
    from ..roofline import hlo as rh

    txt = compiled.as_text()
    stats = rh.analyze(txt)
    record["collectives"] = stats.collective_bytes
    record["hlo_bytes"] = len(txt)
    record["trip_counts"] = {k: int(v) for k, v in stats.trip_counts.items()}
    record["hlo_flops_per_device"] = stats.flops
    record["hlo_bytes_per_device"] = stats.bytes

    terms = ra.compute_terms(
        stats.flops, stats.bytes, stats.total_collective_bytes,
        n_chips=int(n_chips),
        model_flops=ra.model_flops_for(cfg_true, shape),
    )
    record["roofline"] = ra.terms_dict(terms)
    record["status"] = "ok"
    return record


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--opt-level", default="default",
                   help="tag recorded in the JSON (perf-iteration bookkeeping)")
    a = p.parse_args(argv)

    out_dir = Path(a.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    from ..configs.base import ARCH_IDS, SHAPES

    cells = []
    meshes = ["single", "multi"] if a.mesh == "both" else [a.mesh]
    if a.all:
        for arch in ARCH_IDS:
            for s in SHAPES:
                for m in meshes:
                    cells.append((arch, s, m))
    else:
        assert a.arch and a.shape, "--arch/--shape or --all"
        for m in meshes:
            cells.append((a.arch, a.shape, m))

    failures = 0
    for arch, s, m in cells:
        path = out_dir / f"{arch}--{s}--{m}.json"
        print(f"[dryrun] {arch} x {s} x {m} ...", flush=True)
        try:
            rec = _cell(arch, s, m, out_dir, a.opt_level)
        except Exception:
            rec = {"arch": arch, "shape": s, "mesh": m, "status": "error",
                   "traceback": traceback.format_exc()}
            failures += 1
        path.write_text(json.dumps(rec, indent=1))
        status = rec.get("status")
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec['compile_s']:.1f}s flops/dev={r['flops_per_device']:.3g}"
                     f" bottleneck={r['bottleneck']} roofline_frac={r['roofline_fraction']:.3f}")
        elif status == "skip":
            extra = f" ({rec['reason']})"
        else:
            extra = " ERROR (see json)"
        print(f"[dryrun] {arch} x {s} x {m}: {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
