import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Dry-run cell for the paper's own technique: distributed pre-counting.

Lowers the sharded count-manager pipeline (Figure-6 metaquery + Möbius
virtual join, rows sharded over the data axes, entity dimension tables
replicated) for the production meshes at an IMDb-scale workload
(10^7 fact rows — one order beyond the paper's largest database), plus the
§VI block-prediction scoring matmul.  This is the hillclimb cell "most
representative of the paper's technique" (EXPERIMENTS.md §Perf).

Workload model (paper-faithful): one relationship table with two entity
attributes per side + one relationship attribute -> CT over
(R, a1, a2, b1, b2, ra) with Möbius F-block, i.e. the exact Fig. 3(c)
object at production scale.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_factorbase --mesh single
  REPRO_FB_OPT=fused  ...   # hillclimbed variant (see §Perf)
"""

import argparse
import json
import time
from pathlib import Path


def _cell(mesh_kind: str, n_rows: int, n_entities: int, opt: str) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..kernels import ops
    from ..roofline import analysis as ra, hlo as rh
    from .mesh import make_mesh_from_shape, make_production_mesh

    env_mesh = os.environ.get(
        "REPRO_DRYRUN_MESH_MULTI" if mesh_kind == "multi" else "REPRO_DRYRUN_MESH"
    )
    if env_mesh:
        mesh = make_mesh_from_shape(tuple(int(x) for x in env_mesh.split(",")))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(mesh.devices.size)
    # §Perf iteration "fb-all-axes": counting has no tensor-parallel
    # structure, so fact rows shard over EVERY mesh axis (model included) —
    # the data-axes-only layout left 16/16ths of each pod idle (measured
    # 16x flops/bytes redundancy per device).
    if opt in ("all-axes", "fused"):
        dp = tuple(mesh.axis_names)
    else:
        dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))

    # domains: 2 entity attrs x card 3 per side, rel attr card 4 (n/a+3)
    cards = [3, 3, 3, 3, 4]
    nbins = int(np.prod(cards))
    rows = -(-n_rows // dp_n) * dp_n

    def count_pipeline(keys, weights, e1_attr_keys, e2_attr_keys):
        """Distributed Fig.3(c): T-block histogram + Möbius F-block."""
        if opt == "fused":
            # one fused local pass: histogram T-keys AND both entity
            # histograms locally, single psum of the concatenated stats
            def local(k_shard, w_shard, e1_shard, e2_shard):
                t_part = ops.ct_count(k_shard, nbins, w_shard, impl="matmul")
                h1 = ops.ct_count(e1_shard, 9, impl="matmul").astype(jnp.float32)
                h2 = ops.ct_count(e2_shard, 9, impl="matmul").astype(jnp.float32)
                packed = jnp.concatenate([t_part, h1, h2])
                return jax.lax.psum(packed, dp)

            packed = jax.shard_map(
                local, mesh=mesh,
                in_specs=(P(dp), P(dp), P(dp), P(dp)),
                out_specs=P(),
            )(keys, weights, e1_attr_keys, e2_attr_keys)
            t_flat = packed[:nbins]
            h1 = packed[nbins:nbins + 9].reshape(3, 3)
            h2 = packed[nbins + 9:].reshape(3, 3)
        else:
            def local(k_shard, w_shard):
                part = ops.ct_count(k_shard, nbins, w_shard, impl="matmul")
                return jax.lax.psum(part.astype(jnp.float32), dp)

            t_flat = jax.shard_map(
                local, mesh=mesh, in_specs=(P(dp), P(dp)), out_specs=P()
            )(keys, weights)

            def ent_local(e_shard):
                return jax.lax.psum(
                    ops.ct_count(e_shard, 9, impl="matmul").astype(jnp.float32), dp
                )

            h1 = jax.shard_map(ent_local, mesh=mesh, in_specs=(P(dp),), out_specs=P())(
                e1_attr_keys).reshape(3, 3)
            h2 = jax.shard_map(ent_local, mesh=mesh, in_specs=(P(dp),), out_specs=P())(
                e2_attr_keys).reshape(3, 3)

        t_block = t_flat.reshape(3, 3, 3, 3, 4)
        star = jnp.einsum("ab,cd->abcd", h1, h2)
        f_count = star - t_block.sum(axis=-1)
        f_block = jnp.zeros_like(t_block).at[..., 0].set(f_count)
        ct = jnp.stack([f_block, t_block], axis=0)  # (2,3,3,3,3,4)

        # §VI block scoring: entities sharded over dp, CPT replicated
        return ct

    def predict_pipeline(counts, log_cpt):
        def local(c_shard, l_rep):
            return ops.block_predict(c_shard, l_rep, impl="auto")

        return jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, None), P(None, None)), out_specs=P(dp, None),
        )(counts, log_cpt)

    record = {
        "arch": "factorbase_count", "shape": f"imdb10x_{n_rows}rows",
        "mesh": mesh_kind, "kind": "count", "n_chips": n_chips, "opt_level": opt,
    }

    keys = jax.ShapeDtypeStruct((rows,), jnp.int32)
    w = jax.ShapeDtypeStruct((rows,), jnp.float32)
    ek = jax.ShapeDtypeStruct((-(-n_entities // dp_n) * dp_n,), jnp.int32)
    NS = lambda spec: NamedSharding(mesh, spec)

    t0 = time.perf_counter()
    lowered = jax.jit(
        count_pipeline,
        in_shardings=(NS(P(dp)), NS(P(dp)), NS(P(dp)), NS(P(dp))),
        out_shardings=NS(P()),
    ).lower(keys, w, ek, ek)
    compiled = lowered.compile()
    record["compile_s"] = time.perf_counter() - t0

    ents = -(-n_entities // dp_n) * dp_n
    cshape = jax.ShapeDtypeStruct((ents, nbins * 2), jnp.float32)
    lshape = jax.ShapeDtypeStruct((nbins * 2, 3), jnp.float32)
    lowered_p = jax.jit(
        predict_pipeline,
        in_shardings=(NS(P(dp, None)), NS(P(None, None))),
        out_shardings=NS(P(dp, None)),
    ).lower(cshape, lshape)
    compiled_p = lowered_p.compile()

    stats = rh.analyze(compiled.as_text())
    stats_p = rh.analyze(compiled_p.as_text())
    record["collectives"] = {
        k: stats.collective_bytes.get(k, 0) + stats_p.collective_bytes.get(k, 0)
        for k in set(stats.collective_bytes) | set(stats_p.collective_bytes)
    }
    try:
        ma = compiled.memory_analysis()
        record["memory_analysis"] = {
            "peak_bytes_est": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        }
    except Exception as e:
        record["memory_analysis"] = {"error": str(e)}

    # model flops: the "useful work" of GROUP BY COUNT is one multiply-add
    # per (row x bin-tile lane) in the MXU formulation; the information-
    # theoretic minimum is 1 update/row, so we report both
    flops = stats.flops + stats_p.flops
    bytes_ = stats.bytes + stats_p.bytes
    coll = sum(record["collectives"].values())
    useful = 2.0 * n_rows  # 1 MAC per row (scatter-equivalent work)
    terms = ra.compute_terms(flops, bytes_, coll, n_chips=n_chips, model_flops=useful)
    record["roofline"] = ra.terms_dict(terms)
    # counting is a streaming workload: its roof is HBM bandwidth (read every
    # row once), so report the bandwidth-roofline fraction as the headline
    ideal_bw_s = n_rows * 8.0 / (n_chips * ra.HBM_BW)  # key + weight bytes
    record["roofline"]["ideal_s"] = ideal_bw_s
    record["roofline"]["roofline_fraction"] = ideal_bw_s / max(terms.roofline_s, 1e-12)
    record["hlo_flops_per_device"] = stats.flops
    record["hlo_bytes_per_device"] = stats.bytes
    record["status"] = "ok"
    return record


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--rows", type=int, default=10_000_000_000)
    p.add_argument("--entities", type=int, default=1_000_000)
    p.add_argument("--out", default="results/dryrun_fb")
    a = p.parse_args(argv)
    out = Path(a.out)
    out.mkdir(parents=True, exist_ok=True)
    opt = os.environ.get("REPRO_FB_OPT", "default")
    meshes = ["single", "multi"] if a.mesh == "both" else [a.mesh]
    for m in meshes:
        rec = _cell(m, a.rows, a.entities, opt)
        path = out / f"factorbase_count--{m}--{opt}.json"
        path.write_text(json.dumps(rec, indent=1))
        rf = rec["roofline"]
        print(f"[fb-dryrun] {m}/{opt}: compile={rec['compile_s']:.1f}s "
              f"compute={rf['compute_s']:.4g}s memory={rf['memory_s']:.4g}s "
              f"collective={rf['collective_s']:.4g}s bottleneck={rf['bottleneck']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
