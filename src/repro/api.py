"""High-level one-call API: learn → save/load → predict → serve.

The paper's workflow (§I, Figure 4) is *pipeline-shaped* — analyze the
schema, count, learn structure, estimate parameters, then answer queries —
but the engine modules expose each stage separately so benchmarks and
tests can probe them in isolation.  This module is the assembled
pipeline: :func:`learn` runs schema → counts → LAJ structure search →
parameter estimation and hands back one durable
:class:`~repro.core.model_store.LearnedModel`, which
:func:`~repro.core.model_store.save_model` /
:func:`~repro.core.model_store.load_model` round-trip bit-identically and
:func:`predict` / :class:`~repro.serving.predict_service.PredictService`
consume without re-learning anything.

Everything here is re-exported from the :mod:`repro` package root —
``repro.learn(db)`` is the intended spelling.
"""

from __future__ import annotations

from .core.cpt import learn_parameters
from .core.database import RelationalDatabase
from .core.model_store import LearnedModel
from .core.predict import PredictionResult, predict_block
from .core.structure import CountCache, learn_and_join

__all__ = ["learn", "predict"]


def learn(
    db: RelationalDatabase,
    *,
    score: str = "aic",
    alpha: float = 0.1,
    max_parents: int = 3,
    max_chain: int = 2,
    mode: str = "precount",
    impl: str = "auto",
    meta: dict | None = None,
) -> LearnedModel:
    """Learn a full model from a relational database, end to end.

    Runs the paper's pipeline in one call — pre-count (or on-demand count,
    per ``mode``), learn-and-join structure search, Dirichlet-smoothed
    parameter estimation — and returns a :class:`LearnedModel` carrying
    the schema contract, the BN, every family CPT, and a provenance
    ``meta`` block (hyperparameters used, plus anything passed in
    ``meta``) that travels with the saved artifact.

    Engine knobs (kernel impl, bucket ladder, incremental mode, …) come
    from the active :func:`repro.engine_config` context.
    """
    cache = CountCache(db, mode=mode, impl=impl)
    result = learn_and_join(
        db,
        cache,
        score=score,
        alpha=alpha,
        max_parents=max_parents,
        max_chain=max_chain,
        impl=impl,
    )
    factors = learn_parameters(result.bn, cache, alpha=alpha, impl=impl)
    provenance = {
        "score": score,
        "alpha": alpha,
        "max_parents": max_parents,
        "max_chain": max_chain,
        "count_mode": mode,
        "n_candidates_scored": result.n_candidates_scored,
        "learn_seconds": result.seconds,
    }
    if meta:
        provenance.update(meta)
    model = LearnedModel(
        schema=db.schema, bn=result.bn, factors=factors, meta=provenance
    )
    model.validate()
    return model


def predict(
    db: RelationalDatabase,
    model: LearnedModel,
    target: str,
    *,
    impl: str = "auto",
) -> PredictionResult:
    """Score every test entity's ``P(target | rest)`` with the §VI block path.

    One grouped count query + one matmul per family touching ``target`` —
    the paper's block-access optimization.  ``model`` may come straight
    from :func:`learn` or from :func:`repro.load_model`; ``db`` must match
    the model's schema (the same check the serving tier enforces).
    """
    if model.schema != db.schema:
        raise ValueError(
            "database schema does not match the model's schema; "
            "a model only answers queries against the catalog it was "
            "learned from"
        )
    return predict_block(db, model.bn, model.factors, target, impl=impl)
