"""Optimized-HLO text analyzer: trip-count-aware FLOPs / bytes / collectives.

XLA's ``compiled.cost_analysis()`` visits every computation once — a
``lax.scan`` body (our layer stacks) is counted once instead of
trip-count times, making the module-level numbers useless for scanned
models.  This analyzer re-derives the three roofline inputs directly from
``compiled.as_text()``:

  1. parse the module into computations and ops;
  2. build the call graph (while body/condition, fusions via calls=/to_apply,
     conditionals) and a *execution-multiplier* for every computation:
     mult[entry] = 1, while bodies multiply by their trip count (parsed from
     the loop-condition constant), nested loops compose;
  3. FLOPs  = sum over dot/convolution ops of 2 * prod(result) * prod(contracted)
              * mult[computation]  (MXU work; elementwise is ignored);
  4. bytes  = sum over *top-level* ops (entry/while/call computations, not
              fusion internals) of (result + resolvable operand) bytes
              * mult — fusion-internal traffic stays in registers/VMEM on a
              real TPU, so only fusion boundaries count as HBM traffic;
  5. collective bytes by kind, * mult (all-reduce counted x2: RS + AG).

All numbers are per-device (the text is the partitioned module).  Validated
against analytic 6*N*D in tests/test_roofline.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\("
)
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALL_ATTR = re.compile(r"(calls|to_apply|condition|body)=([%\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) over all array shapes inside a type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line.strip()) if "{" in line else None
        if m and "->" in line:
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_LINE.match(line)
        if om:
            cur.ops.append(Op(om.group(1), om.group(2), om.group(3), line))
    return comps


@dataclass
class HloStats:
    flops: float
    bytes: float
    collective_bytes: dict[str, float]
    trip_counts: dict[str, int]
    dot_flops_by_comp: dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def analyze(text: str) -> HloStats:
    comps = parse_module(text)

    # result-type symbol table (module-wide; optimized HLO names are unique
    # enough in practice — collisions fall back to result-only accounting)
    sym: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            sym[op.name] = op.type_str

    # call edges and fusion-ness
    called_as_fusion: set[str] = set()
    edges: dict[str, list[tuple[str, str]]] = {c: [] for c in comps}
    trip_counts: dict[str, int] = {}
    for c in comps.values():
        for op in c.ops:
            attrs = dict()
            for kind, target in _CALL_ATTR.findall(op.line):
                edges[c.name].append((kind, target))
                if kind in ("calls", "to_apply") and op.opcode == "fusion":
                    called_as_fusion.add(target)
                elif kind == "to_apply":
                    called_as_fusion.add(target)  # reducers: internal
            bm = _BRANCHES.search(op.line)
            if bm:
                for t in bm.group(1).split(","):
                    t = t.strip()
                    if t:
                        edges[c.name].append(("branch", t))

    # trip counts: for each while op, parse its condition computation
    for c in comps.values():
        for op in c.ops:
            if op.opcode != "while":
                continue
            cond = body = None
            for kind, target in _CALL_ATTR.findall(op.line):
                if kind == "condition":
                    cond = target
                elif kind == "body":
                    body = target
            trip = 1
            if cond and cond in comps:
                consts = [int(x) for x in _CONSTANT_S32.findall(
                    "\n".join(o.line for o in comps[cond].ops)
                ) if int(x) < 10**9]
                if consts:
                    trip = max(consts)
            if body:
                trip_counts[body] = max(trip_counts.get(body, 1), trip)

    # execution multipliers (DAG DP from the entry computation)
    callers: dict[str, list[tuple[str, str]]] = {c: [] for c in comps}
    for src, es in edges.items():
        for kind, dst in es:
            if dst in callers:
                callers[dst].append((kind, src))
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+(%[\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        all_called = {dst for es in edges.values() for _, dst in es}
        roots = [c for c in comps if c not in all_called]
        entry = roots[0] if roots else next(iter(comps))

    mult: dict[str, float] = {}

    def get_mult(name: str, stack=()) -> float:
        if name == entry:
            return 1.0
        if name in mult:
            return mult[name]
        if name in stack:  # recursion guard
            return 0.0
        total = 0.0
        for kind, src in callers.get(name, ()):
            m = get_mult(src, stack + (name,))
            if kind == "body":
                m *= trip_counts.get(name, 1)
            elif kind == "condition":
                m *= trip_counts.get(name.replace("condition", "body"), 1)
            total += m
        mult[name] = total if total > 0 else 1.0
        return mult[name]

    # flops: dots everywhere (including fusion internals)
    flops = 0.0
    dot_by_comp: dict[str, float] = {}
    for c in comps.values():
        m = get_mult(c.name)
        comp_flops = 0.0
        for op in c.ops:
            if op.opcode not in ("dot", "convolution"):
                continue
            res_elems, _ = _shape_elems_bytes(op.type_str)
            contract = 1
            cm = _CONTRACT.search(op.line)
            if cm is not None:
                idxs = [int(i) for i in cm.group(1).split(",") if i]
                # lhs operand shape: first %ref in the parens
                args = re.search(r"\(([^)]*)\)", op.line.split(op.opcode, 1)[1])
                if args:
                    first = args.group(1).split(",")[0].strip()
                    lhs_type = sym.get(first, "")
                    st = _SHAPE_TOKEN.search(lhs_type)
                    if st:
                        dims = [int(d) for d in st.group(2).split(",") if d]
                        for i in idxs:
                            if i < len(dims):
                                contract *= dims[i]
            comp_flops += 2.0 * res_elems * contract
        if comp_flops:
            dot_by_comp[c.name] = comp_flops * m
            flops += comp_flops * m

    # bytes: top-level ops of non-fusion computations.  HBM-traffic proxy:
    # each op's RESULT is written once and read ~once downstream (x2);
    # operands are NOT added (fusions read slices, not whole buffers, and
    # every buffer is already counted at its producer).  dynamic-update-slice
    # writes only its slice in place, so DUS(-fusion) ops inside a loop are
    # charged the full buffer once per *loop*, not per iteration.
    skip_opcodes = {"parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all", "partition-id", "while",
                    "conditional", "call"}
    total_bytes = 0.0
    for c in comps.values():
        if c.name in called_as_fusion:
            continue
        m = get_mult(c.name)
        trip = trip_counts.get(c.name, 1)
        for op in c.ops:
            if op.opcode in skip_opcodes:
                continue
            _, res_b = _shape_elems_bytes(op.type_str)
            m_eff = m
            if "dynamic-update-slice" in op.name or op.opcode == "dynamic-update-slice":
                m_eff = m / max(trip, 1)
            total_bytes += 2.0 * res_b * m_eff

    # collectives
    coll: dict[str, float] = {}
    for c in comps.values():
        m = get_mult(c.name)
        for op in c.ops:
            base = op.opcode.replace("-start", "")
            if base not in _COLLECTIVES:
                continue
            if op.opcode.endswith("-done"):
                continue
            _, b = _shape_elems_bytes(op.type_str)
            if base == "all-reduce":
                b *= 2
            coll[base] = coll.get(base, 0.0) + b * m

    return HloStats(
        flops=flops,
        bytes=total_bytes,
        collective_bytes=coll,
        trip_counts=trip_counts,
        dot_flops_by_comp=dot_by_comp,
    )
