"""Roofline terms from compiled dry-run artifacts (no hardware required).

Per (arch x shape x mesh) cell, from the post-SPMD compiled module:

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak, v5e)
    memory     = HLO_bytes_per_device / 819e9         (HBM BW, v5e)
    collective = collective_bytes_per_device / 50e9   (~per-link ICI BW)

``cost_analysis()`` is per-partition under SPMD (verified empirically), so
all three terms are per-device seconds; the bottleneck is the max term.
Collective bytes are parsed from the optimized HLO text: the result-buffer
size of every all-gather / reduce-scatter / all-to-all / collective-permute,
with all-reduce counted twice (its ring cost is RS + AG).  This is a
schedule-level estimate — it ignores overlap (XLA hides collectives behind
compute inside scans), so the collective term is an upper bound on exposed
communication.

MODEL_FLOPS uses the 6*N*D train / 2*N*D inference convention with N =
active parameters; the ratio MODEL_FLOPS / (chips * HLO_FLOPs) shows how
much compiled compute is "useful" (remat recompute, attention FLOPs and
dead padding all push it below 1).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass

PEAK_FLOPS = 197e12     # bf16 per chip (v5e)
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link (approx)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device collective bytes by op kind (result-buffer sizes)."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind, _start = m.group(1), m.group(2), m.group(3)
        b = _type_bytes(type_str)
        if kind == "all-reduce":
            b *= 2  # ring AR = reduce-scatter + all-gather
        out[kind] = out.get(kind, 0.0) + float(b)
    return out


@dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float     # MODEL_FLOPS / (chips * HLO_FLOPs)
    roofline_s: float       # max(term)
    ideal_s: float          # MODEL_FLOPS / (chips * peak)
    roofline_fraction: float  # ideal_s / roofline_s  (1.0 == at compute roof)


def compute_terms(
    flops_per_device: float,
    bytes_per_device: float,
    coll_bytes_per_device: float,
    *,
    n_chips: int,
    model_flops: float,
) -> RooflineTerms:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = coll_bytes_per_device / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    hlo_total = flops_per_device * n_chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    roofline_s = max(terms.values())
    ideal_s = model_flops / (n_chips * PEAK_FLOPS)
    return RooflineTerms(
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes_per_device,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=useful,
        roofline_s=roofline_s,
        ideal_s=ideal_s,
        roofline_fraction=(ideal_s / roofline_s) if roofline_s > 0 else 0.0,
    )


def model_flops_for(cfg, shape) -> float:
    """6*N*D train, 2*N*D prefill, 2*N*B decode (N = active params)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # one decode step


def terms_dict(t: RooflineTerms) -> dict:
    return asdict(t)
