"""Activation sharding constraints driven by the ambient (abstract) mesh.

Model code calls ``act(x, ("dp", None, "model", None))`` at layer
boundaries; under ``jax.sharding.use_mesh`` (the launcher/dry-run wraps
lowering in it) this pins the activation layout so GSPMD propagation cannot
fall back to replication — without a mesh it is a no-op, so the same model
code runs untouched on a single CPU device (smoke tests).

Dim tags: "dp" -> (pod, data) data-parallel axes, "model" -> tensor/expert
axis.  A tag is silently dropped when the dim is not divisible by the axis
size (e.g. 25 heads on a 16-way model axis) — the divisible dims still get
pinned, which is what keeps the not-quite-regular archs (hymba, qwen1.5,
arctic attention) from replicating *everything*.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def _mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if m is None or not m.axis_names:
        return None
    return m


def dp_size() -> int:
    """Total data-parallel way count of the ambient mesh (1 without a mesh)."""
    m = _mesh()
    if m is None:
        return 1
    sizes = dict(zip(m.axis_names, m.axis_sizes))
    return math.prod(sizes[a] for a in ("pod", "data") if a in sizes)


def act(x: jax.Array, dims: tuple) -> jax.Array:
    """Constrain activation ``x`` along logical dim tags (see module doc)."""
    m = _mesh()
    if m is None:
        return x
    axis_sizes = dict(zip(m.axis_names, m.axis_sizes))
    dp = tuple(a for a in ("pod", "data") if a in axis_sizes)
    dp_size = math.prod(axis_sizes[a] for a in dp) if dp else 1
    model_size = axis_sizes.get("model", 1)

    spec = []
    for size, d in zip(x.shape, dims):
        if d == "dp" and dp and size % dp_size == 0:
            spec.append(dp)
        elif d == "model" and "model" in axis_sizes and size % model_size == 0:
            spec.append("model")
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
