"""Sharding rules: parameter/optimizer/activation/cache PartitionSpecs.

Scheme (GSPMD auto-prop from these anchors):
  * DP/FSDP over the ``pod`` x ``data`` axes: the batch shards over them, and
    every large parameter also shards one of its *non-model* dims over them
    (ZeRO-3-style fully-sharded parameters + optimizer state; XLA inserts the
    per-layer all-gathers inside the scan and overlaps them).
  * TP over ``model``: attention/MLP inner dims, vocab where divisible.
  * EP over ``model``: MoE expert axis.
  * Decode KV caches shard their *sequence* axis over ``model`` (the cache is
    the dominant decode-time buffer, and kv-head counts like 8 do not divide
    the 16-way model axis; sequence does) — attention's softmax then reduces
    over a sharded axis, which XLA turns into the expected all-reduce.

Every rule degrades to replication when a dim is not divisible by the mesh
axis (recorded by ``explain()``; e.g. 25 q-heads / hymba, 40 kv-heads /
qwen1.5, odd vocabs).  This module is pure metadata — no device state.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    return int(np.prod([mesh.shape[a] for a in axes]))


def div(mesh: Mesh, dim: int, axes):
    """axes if they divide dim, else None (replicate)."""
    return axes if dim % max(_axsize(mesh, axes), 1) == 0 else None


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        else:
            out.append(str(p))
    return "/".join(out)


def param_spec(mesh: Mesh, path: str, shape: tuple[int, ...]) -> P:
    """PartitionSpec for one parameter leaf (leading stack axes -> None)."""
    dp = dp_axes(mesh)
    name = path.split("/")[-1]
    nd = len(shape)

    def lead(n_mat: int) -> tuple:
        return (None,) * (nd - n_mat)

    if name in ("embed",):
        v, d = shape
        return P(div(mesh, v, "model"), div(mesh, d, dp))
    if name == "lm_head":
        d, v = shape
        return P(div(mesh, d, dp), div(mesh, v, "model"))
    if nd >= 1 and (name.startswith("ln") or "norm" in name or name in (
            "a_log", "d_skip", "dt_bias", "conv_b", "bq", "bk", "bv")):
        return P(*(None,) * nd)
    if "moe" in path and name in ("w_gate", "w_up"):
        e, d, f = shape[-3:]
        return P(*lead(3), div(mesh, e, "model"), div(mesh, d, dp), None)
    if "moe" in path and name == "w_down":
        e, f, d = shape[-3:]
        return P(*lead(3), div(mesh, e, "model"), None, div(mesh, d, dp))
    if name == "w_router":
        d, e = shape[-2:]
        return P(*lead(2), div(mesh, d, dp), None)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_in"):
        a, b = shape[-2:]
        return P(*lead(2), div(mesh, a, dp), div(mesh, b, "model"))
    if name in ("wo", "w_down", "w_out"):
        a, b = shape[-2:]
        return P(*lead(2), div(mesh, a, "model"), div(mesh, b, dp))
    if name == "conv_w":
        return P(*(None,) * nd)
    # fallback: replicate
    return P(*(None,) * nd)


def param_shardings(mesh: Mesh, params_shapes: Params) -> Params:
    """NamedSharding pytree for a params(-shaped) pytree of ShapeDtypeStructs."""

    def leaf(path, x):
        return NamedSharding(mesh, param_spec(mesh, _path_str(path), x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params_shapes)


def explain(mesh: Mesh, params_shapes: Params) -> list[str]:
    """Human-readable report of replicated-by-indivisibility decisions."""
    notes = []

    def leaf(path, x):
        spec = param_spec(mesh, _path_str(path), x.shape)
        if all(s is None for s in spec) and x.size * 2 > 1 << 20:
            notes.append(f"replicated: {_path_str(path)} {x.shape}")
        return None

    jax.tree_util.tree_map_with_path(leaf, params_shapes)
    return notes


# ---------------------------------------------------------------------------
# Batch / cache / optimizer shardings
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_shapes: dict) -> dict:
    dp = dp_axes(mesh)

    def leaf(path, x):
        b = x.shape[0]
        spec = (div(mesh, b, dp),) + (None,) * (len(x.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, batch_shapes)


def cache_shardings(mesh: Mesh, cache_shapes: dict) -> dict:
    """Decode caches: batch over dp; KV sequence axis over model.

    KV leaves are identified by shape convention (.., B, W, KH, hd) — axis -3
    is the ring length.  SSM states shard batch only.
    """
    dp = dp_axes(mesh)

    def leaf(path, x):
        name = _path_str(path)
        nd = len(x.shape)
        if name.endswith("pos"):
            return NamedSharding(mesh, P(div(mesh, x.shape[0], dp)))
        spec = [None] * nd
        # find the batch axis: first axis after leading layer-stack axes whose
        # position matches the known layouts
        if "ssm" in name or name.endswith(("/h", "/conv")):
            # (L, B, ...) states
            spec[1] = div(mesh, x.shape[1], dp)
        elif nd >= 4:
            # (L[, k], B, W, KH, hd) KV caches: batch at -4, seq at -3
            spec[nd - 4] = div(mesh, x.shape[nd - 4], dp)
            spec[nd - 3] = div(mesh, x.shape[nd - 3], "model")
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
