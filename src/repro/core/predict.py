"""Test-set prediction (paper §VI): single-instance and block access.

Class probabilities follow the log-linear conditional (Eq. 2):

    log P(y | X_-Y) =  Σ_{families f containing Y}  Σ_{cfg}
                       target_CT_f[e, cfg] * log cp_f[cfg, y]   + const

Only families containing the target par-RV matter, and only groundings that
match the target entity contribute (the paper's key observation).  The
**block** path adds the target-entity id to the GROUP BY — here a leading
tensor axis — and scores the whole test set with one matmul per family
(Pallas ``block_predict``).  The **single** path re-runs the count pipeline
per test instance with a ``WHERE <target> = e`` restriction, reproducing the
cost profile of the paper's single-access baseline (Figure 9's red bars).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.bucketing import bucket_rows
from .bn import BayesNet
from .counts import GROUP_AXIS, contingency_table
from .cpt import FactorTable
from .database import RelationalDatabase
from .sparse_counts import SparseCT, sparse_block_scores

_LOG_TINY = 1e-30


@dataclass(frozen=True)
class PredictionResult:
    target: str
    log_scores: jax.Array     # (n_entities, |Y|) unnormalized
    probs: jax.Array          # (n_entities, |Y|) normalized (Eq. 2)
    seconds: float

    def accuracy(self, true_codes: jax.Array) -> float:
        pred = jnp.argmax(self.log_scores, axis=1)
        return float(jnp.mean((pred == true_codes).astype(jnp.float32)))

    def conditional_loglik(self, true_codes: jax.Array) -> float:
        """The paper's CLL metric: mean log P(true label | X_-Y)."""
        p = jnp.take_along_axis(self.probs, true_codes[:, None].astype(jnp.int32), axis=1)
        return float(jnp.mean(jnp.log(jnp.maximum(p, _LOG_TINY))))


def family_row_scores(
    counts: jax.Array, logmat: jax.Array, *, impl: str = "auto"
) -> jax.Array:
    """One family's contribution for a batch of rows: ``counts @ logmat``.

    ``counts`` is ``(B, C)`` per-row family counts, ``logmat`` is ``(C,
    |Y|)`` log-CPT columns; the result is ``(B, |Y|)``.  Rows are padded to
    the bucket-ladder rung (zero rows are identity for the contraction)
    before the ``block_predict`` kernel runs, then sliced back.

    This is the **bit-identity seam** shared by :func:`predict_single_loop`
    and the serving tier's micro-batcher: because both sides launch the
    same rung-shaped programs, a row's float32 dot reduces identically
    whether it arrived alone or inside a batch — which is what lets the
    ``bench_serve`` gate demand served posteriors *bitwise* equal to the
    single-instance oracle rather than "close".  (The rung is clamped to
    >= 2 rows: XLA:CPU lowers 1-row dots through a different GEMV path
    whose reduction order differs from the batched GEMM's.)
    """
    n = counts.shape[0]
    pad = max(bucket_rows(max(n, 1)), 2)
    if pad != n:
        counts = jnp.concatenate(
            [counts, jnp.zeros((pad - n,) + counts.shape[1:], counts.dtype)]
        )
    out = ops.block_predict(counts, logmat, impl=impl)
    return out[:n] if pad != n else out


def _families_with(bn: BayesNet, target: str) -> list[str]:
    """Children whose family par-factor contains the target par-RV."""
    out = []
    for child in bn.rvs:
        if child == target or target in bn.parents[child]:
            out.append(child)
    return out


def _log_factor_matrix(factor: FactorTable, target: str) -> tuple[tuple[str, ...], jax.Array]:
    """Rearrange log cp with the target axis last: (family-minus-Y..., |Y|)."""
    order = tuple(v for v in factor.rvs if v != target) + (target,)
    perm = tuple(factor.rvs.index(v) for v in order)
    logs = jnp.log(jnp.maximum(jnp.transpose(factor.table, perm), _LOG_TINY))
    return order[:-1], logs


def predict_block(
    db: RelationalDatabase,
    bn: BayesNet,
    factors: dict[str, FactorTable],
    target: str,
    *,
    impl: str = "auto",
) -> PredictionResult:
    """Score all test entities with one grouped query per family (§VI block)."""
    t0 = time.perf_counter()
    cat = db.catalog
    target_rv = cat[target]
    assert target_rv.kind == "entity_attr", "targets are entity attributes (paper §VII)"
    fovar = target_rv.fovars[0].fid
    n_entities = db.entities[target_rv.table].n_rows
    n_y = target_rv.cardinality

    scores = jnp.zeros((n_entities, n_y), jnp.float32)
    kimpl = ops.kernel_impl(impl)
    for child in _families_with(bn, target):
        factor = factors[child]
        rest, logmat = _log_factor_matrix(factor, target)
        if rest:
            gct = contingency_table(db, rest, impl=impl, group_fovar=fovar)
            gct = gct.transpose((GROUP_AXIS,) + rest)
            if isinstance(gct, SparseCT):
                # realized-cells-only scatter instead of the dense matmul
                contrib = sparse_block_scores(
                    gct, np.asarray(logmat, np.float32).reshape(-1, n_y), n_entities
                )
                scores = scores + jnp.asarray(contrib)
                continue
            counts = gct.table.reshape(n_entities, -1)
        else:
            # family is {Y} alone: every entity contributes exactly one grounding
            counts = jnp.ones((n_entities, 1), jnp.float32)
        scores = scores + ops.block_predict(counts, logmat.reshape(-1, n_y), impl=kimpl)

    logz = jax.scipy.special.logsumexp(scores, axis=1, keepdims=True)
    probs = jnp.exp(scores - logz)
    return PredictionResult(target, scores, probs, time.perf_counter() - t0)


def predict_single_loop(
    db: RelationalDatabase,
    bn: BayesNet,
    factors: dict[str, FactorTable],
    target: str,
    *,
    impl: str = "auto",
    max_instances: int | None = None,
) -> PredictionResult:
    """Per-instance loop: one restricted count query per test entity (§VI single).

    Reproduces the baseline of Figure 9 — each instance re-scans the data
    with a ``WHERE <fovar> = e`` restriction, so cost grows as
    O(#instances x data) instead of the block path's O(data).
    """
    t0 = time.perf_counter()
    cat = db.catalog
    target_rv = cat[target]
    fovar = target_rv.fovars[0].fid
    n_entities = db.entities[target_rv.table].n_rows
    n = n_entities if max_instances is None else min(n_entities, max_instances)
    n_y = target_rv.cardinality

    fams = []
    for child in _families_with(bn, target):
        rest, logmat = _log_factor_matrix(factors[child], target)
        fams.append((rest, logmat.reshape(-1, n_y)))

    rows = []
    kimpl = ops.kernel_impl(impl)
    for e in range(n):
        s = jnp.zeros((n_y,), jnp.float32)
        for rest, logmat in fams:
            if rest:
                ct = contingency_table(db, rest, impl=impl, restrict={fovar: e})
                if isinstance(ct, SparseCT):
                    # densify the restricted row (counts are exact integers,
                    # so this is lossless) and ride the same contraction as
                    # the dense branch — one reduction order everywhere
                    ct = ct.transpose(rest)
                    row = np.zeros((logmat.shape[0],), np.float32)
                    np.add.at(row, np.asarray(ct.codes), np.asarray(ct.counts))
                    counts = jnp.asarray(row).reshape(1, -1)
                else:
                    counts = ct.transpose(rest).table.reshape(1, -1)
            else:
                counts = jnp.ones((1, 1), jnp.float32)
            s = s + family_row_scores(counts, logmat, impl=kimpl)[0]
        rows.append(s)
    scores = jnp.stack(rows, axis=0)
    logz = jax.scipy.special.logsumexp(scores, axis=1, keepdims=True)
    probs = jnp.exp(scores - logz)
    return PredictionResult(target, scores, probs, time.perf_counter() - t0)
