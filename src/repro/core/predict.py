"""Test-set prediction (paper §VI): single-instance and block access.

Class probabilities follow the log-linear conditional (Eq. 2):

    log P(y | X_-Y) =  Σ_{families f containing Y}  Σ_{cfg}
                       target_CT_f[e, cfg] * log cp_f[cfg, y]   + const

Only families containing the target par-RV matter, and only groundings that
match the target entity contribute (the paper's key observation).  The
**block** path adds the target-entity id to the GROUP BY — here a leading
tensor axis — and scores the whole test set with one matmul per family
(Pallas ``block_predict``).  The **single** path re-runs the count pipeline
per test instance with a ``WHERE <target> = e`` restriction, reproducing the
cost profile of the paper's single-access baseline (Figure 9's red bars).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .bn import BayesNet
from .counts import GROUP_AXIS, contingency_table
from .cpt import FactorTable
from .database import RelationalDatabase
from .sparse_counts import SparseCT, sparse_block_scores

_LOG_TINY = 1e-30


@dataclass(frozen=True)
class PredictionResult:
    target: str
    log_scores: jax.Array     # (n_entities, |Y|) unnormalized
    probs: jax.Array          # (n_entities, |Y|) normalized (Eq. 2)
    seconds: float

    def accuracy(self, true_codes: jax.Array) -> float:
        pred = jnp.argmax(self.log_scores, axis=1)
        return float(jnp.mean((pred == true_codes).astype(jnp.float32)))

    def conditional_loglik(self, true_codes: jax.Array) -> float:
        """The paper's CLL metric: mean log P(true label | X_-Y)."""
        p = jnp.take_along_axis(self.probs, true_codes[:, None].astype(jnp.int32), axis=1)
        return float(jnp.mean(jnp.log(jnp.maximum(p, _LOG_TINY))))


def _families_with(bn: BayesNet, target: str) -> list[str]:
    """Children whose family par-factor contains the target par-RV."""
    out = []
    for child in bn.rvs:
        if child == target or target in bn.parents[child]:
            out.append(child)
    return out


def _log_factor_matrix(factor: FactorTable, target: str) -> tuple[tuple[str, ...], jax.Array]:
    """Rearrange log cp with the target axis last: (family-minus-Y..., |Y|)."""
    order = tuple(v for v in factor.rvs if v != target) + (target,)
    perm = tuple(factor.rvs.index(v) for v in order)
    logs = jnp.log(jnp.maximum(jnp.transpose(factor.table, perm), _LOG_TINY))
    return order[:-1], logs


def predict_block(
    db: RelationalDatabase,
    bn: BayesNet,
    factors: dict[str, FactorTable],
    target: str,
    *,
    impl: str = "auto",
) -> PredictionResult:
    """Score all test entities with one grouped query per family (§VI block)."""
    t0 = time.perf_counter()
    cat = db.catalog
    target_rv = cat[target]
    assert target_rv.kind == "entity_attr", "targets are entity attributes (paper §VII)"
    fovar = target_rv.fovars[0].fid
    n_entities = db.entities[target_rv.table].n_rows
    n_y = target_rv.cardinality

    scores = jnp.zeros((n_entities, n_y), jnp.float32)
    kimpl = ops.kernel_impl(impl)
    for child in _families_with(bn, target):
        factor = factors[child]
        rest, logmat = _log_factor_matrix(factor, target)
        if rest:
            gct = contingency_table(db, rest, impl=impl, group_fovar=fovar)
            gct = gct.transpose((GROUP_AXIS,) + rest)
            if isinstance(gct, SparseCT):
                # realized-cells-only scatter instead of the dense matmul
                contrib = sparse_block_scores(
                    gct, np.asarray(logmat, np.float32).reshape(-1, n_y), n_entities
                )
                scores = scores + jnp.asarray(contrib)
                continue
            counts = gct.table.reshape(n_entities, -1)
        else:
            # family is {Y} alone: every entity contributes exactly one grounding
            counts = jnp.ones((n_entities, 1), jnp.float32)
        scores = scores + ops.block_predict(counts, logmat.reshape(-1, n_y), impl=kimpl)

    logz = jax.scipy.special.logsumexp(scores, axis=1, keepdims=True)
    probs = jnp.exp(scores - logz)
    return PredictionResult(target, scores, probs, time.perf_counter() - t0)


def predict_single_loop(
    db: RelationalDatabase,
    bn: BayesNet,
    factors: dict[str, FactorTable],
    target: str,
    *,
    impl: str = "auto",
    max_instances: int | None = None,
) -> PredictionResult:
    """Per-instance loop: one restricted count query per test entity (§VI single).

    Reproduces the baseline of Figure 9 — each instance re-scans the data
    with a ``WHERE <fovar> = e`` restriction, so cost grows as
    O(#instances x data) instead of the block path's O(data).
    """
    t0 = time.perf_counter()
    cat = db.catalog
    target_rv = cat[target]
    fovar = target_rv.fovars[0].fid
    n_entities = db.entities[target_rv.table].n_rows
    n = n_entities if max_instances is None else min(n_entities, max_instances)
    n_y = target_rv.cardinality

    fams = []
    for child in _families_with(bn, target):
        rest, logmat = _log_factor_matrix(factors[child], target)
        fams.append((rest, logmat.reshape(-1, n_y)))

    rows = []
    kimpl = ops.kernel_impl(impl)
    for e in range(n):
        s = jnp.zeros((n_y,), jnp.float32)
        for rest, logmat in fams:
            if rest:
                ct = contingency_table(db, rest, impl=impl, restrict={fovar: e})
                if isinstance(ct, SparseCT):
                    ct = ct.transpose(rest)
                    lm = np.asarray(logmat, np.float32)
                    s = s + jnp.asarray(
                        (ct.counts[:, None] * lm[ct.codes]).sum(0, dtype=np.float32)
                    )
                    continue
                counts = ct.transpose(rest).table.reshape(1, -1)
            else:
                counts = jnp.ones((1, 1), jnp.float32)
            s = s + ops.block_predict(counts, logmat, impl=kimpl)[0]
        rows.append(s)
    scores = jnp.stack(rows, axis=0)
    logz = jax.scipy.special.logsumexp(scores, axis=1, keepdims=True)
    probs = jnp.exp(scores - logz)
    return PredictionResult(target, scores, probs, time.perf_counter() - t0)
