"""Int-encoded relational database instances.

The RDBMS stores tables of labelled values; the TPU adaptation stores every
column as a dense ``int32`` code array (codes defined by the par-RV domains in
the :class:`~repro.core.schema.VariableCatalog`).  Entity tables use their row
index as the implicit primary key, so a relationship table's foreign-key
columns are simply row indices into the referenced entity tables — a join is a
``jnp.take``.

This module is deliberately framework-light: plain pytrees of arrays so that
tables can be donated to jitted count kernels and sharded with pjit/shard_map.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

import jax.numpy as jnp
import numpy as np

from .schema import RelationalSchema, VariableCatalog, analyze_schema


@dataclass(frozen=True)
class EntityTable:
    """One entity population: ``attrs[name]`` is an int32 code array (n_rows,)."""

    name: str
    n_rows: int
    attrs: Mapping[str, jnp.ndarray]

    def column(self, attr: str) -> jnp.ndarray:
        return self.attrs[attr]


@dataclass(frozen=True)
class RelationshipTable:
    """One relationship instance table.

    ``fk1``/``fk2`` are row indices into the two referenced entity tables
    (ordered as in the :class:`RelationshipDecl`).  Only *true* groundings are
    stored (as in the SQL tables); the count manager derives the ``F`` counts
    with the Möbius virtual join.
    """

    name: str
    n_rows: int
    fk1: jnp.ndarray
    fk2: jnp.ndarray
    attrs: Mapping[str, jnp.ndarray]  # codes in the n/a-augmented domain (so >= 1)

    def column(self, attr: str) -> jnp.ndarray:
        return self.attrs[attr]


@dataclass(frozen=True)
class RelationalDatabase:
    """A full database instance = one joint assignment X = x (paper §II-A)."""

    schema: RelationalSchema
    catalog: VariableCatalog
    entities: Mapping[str, EntityTable]
    relationships: Mapping[str, RelationshipTable]

    @property
    def total_tuples(self) -> int:
        return int(
            sum(t.n_rows for t in self.entities.values())
            + sum(t.n_rows for t in self.relationships.values())
        )

    def entity(self, name: str) -> EntityTable:
        return self.entities[name]

    def relationship(self, name: str) -> RelationshipTable:
        return self.relationships[name]

    def validate(self) -> None:
        """Cheap invariant checks (used by property tests)."""
        for decl in self.schema.entities:
            t = self.entities[decl.name]
            for attr, dom in decl.attributes:
                col = np.asarray(t.attrs[attr])
                assert col.shape == (t.n_rows,), (decl.name, attr, col.shape)
                assert col.min(initial=0) >= 0 and col.max(initial=0) < len(dom)
        for decl in self.schema.relationships:
            t = self.relationships[decl.name]
            n1 = self.entities[decl.entities[0]].n_rows
            n2 = self.entities[decl.entities[1]].n_rows
            fk1, fk2 = np.asarray(t.fk1), np.asarray(t.fk2)
            assert fk1.shape == fk2.shape == (t.n_rows,)
            if t.n_rows:
                assert fk1.min() >= 0 and fk1.max() < n1, decl.name
                assert fk2.min() >= 0 and fk2.max() < n2, decl.name
            for attr, dom in decl.attributes:
                col = np.asarray(t.attrs[attr])
                # stored groundings are true, so codes are in the declared
                # domain shifted by one (0 is reserved for n/a)
                assert col.shape == (t.n_rows,)
                if t.n_rows:
                    assert col.min() >= 1 and col.max() <= len(dom), (decl.name, attr)


def from_labels(
    schema: RelationalSchema,
    entity_rows: Mapping[str, Mapping[str, list]],
    relationship_rows: Mapping[str, dict],
) -> RelationalDatabase:
    """Build a database from labelled (string-valued) rows.

    ``entity_rows[table][attr]`` is a list of labels (one per entity row).
    ``relationship_rows[table]`` is a dict with keys ``fk1``, ``fk2`` (lists of
    row indices) and ``attrs`` (mapping attr -> list of labels).
    """
    catalog = analyze_schema(schema)
    entities = {}
    for decl in schema.entities:
        cols = entity_rows[decl.name]
        n = len(next(iter(cols.values()))) if cols else 0
        attrs = {}
        for attr, dom in decl.attributes:
            codes = np.array([dom.index(v) for v in cols[attr]], dtype=np.int32)
            attrs[attr] = jnp.asarray(codes)
            n = len(codes)
        entities[decl.name] = EntityTable(decl.name, n, attrs)

    relationships = {}
    for decl in schema.relationships:
        spec = relationship_rows.get(decl.name, {"fk1": [], "fk2": [], "attrs": {}})
        fk1 = jnp.asarray(np.array(spec["fk1"], dtype=np.int32))
        fk2 = jnp.asarray(np.array(spec["fk2"], dtype=np.int32))
        attrs = {}
        for attr, dom in decl.attributes:
            labels = spec["attrs"][attr]
            codes = np.array([dom.index(v) + 1 for v in labels], dtype=np.int32)  # +1: n/a==0
            attrs[attr] = jnp.asarray(codes)
        relationships[decl.name] = RelationshipTable(
            decl.name, int(fk1.shape[0]), fk1, fk2, attrs
        )

    db = RelationalDatabase(schema, catalog, entities, relationships)
    db.validate()
    return db


def university_db() -> RelationalDatabase:
    """The paper's running example (Figure 2): Student, Professor, RA."""
    from .schema import make_schema

    schema = make_schema(
        entities={
            "student": {
                "intelligence": ("1", "2", "3"),
                "ranking": ("1", "2"),
            },
            "prof": {
                "popularity": ("1", "2", "3"),
                "teachingability": ("1", "2"),
            },
        },
        relationships={
            "RA": (
                ("prof", "student"),
                {
                    "salary": ("low", "med", "high"),
                    "capability": ("1", "2", "3"),
                },
            ),
        },
    )
    # Figure 2 instances.  Students: jack, kim, paul.  Profs: jim, oliver, david.
    students = {"intelligence": ["2", "3", "1"], "ranking": ["1", "1", "2"]}
    profs = {"popularity": ["2", "3", "2"], "teachingability": ["1", "1", "2"]}
    # RA rows: (jack,oliver,high,3), (kim,oliver,low,1), (paul,jim,med,2),
    #          (kim,david,high,2).  fk1 indexes prof, fk2 indexes student.
    ra = {
        "fk1": [1, 1, 0, 2],   # oliver, oliver, jim, david
        "fk2": [0, 1, 2, 1],   # jack, kim, paul, kim
        "attrs": {
            "salary": ["high", "low", "med", "high"],
            "capability": ["3", "1", "2", "2"],
        },
    }
    return from_labels(
        schema,
        entity_rows={"student": students, "prof": profs},
        relationship_rows={"RA": ra},
    )
