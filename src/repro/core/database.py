"""Int-encoded relational database instances.

The RDBMS stores tables of labelled values; the TPU adaptation stores every
column as a dense ``int32`` code array (codes defined by the par-RV domains in
the :class:`~repro.core.schema.VariableCatalog`).  Entity tables use their row
index as the implicit primary key, so a relationship table's foreign-key
columns are simply row indices into the referenced entity tables — a join is a
``jnp.take``.

This module is deliberately framework-light: plain pytrees of arrays so that
tables can be donated to jitted count kernels and sharded with pjit/shard_map.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

import jax.numpy as jnp
import numpy as np

from .schema import RelationalSchema, VariableCatalog, analyze_schema


@dataclass(frozen=True)
class EntityTable:
    """One entity population: ``attrs[name]`` is an int32 code array (n_rows,)."""

    name: str
    n_rows: int
    attrs: Mapping[str, jnp.ndarray]

    def column(self, attr: str) -> jnp.ndarray:
        return self.attrs[attr]


@dataclass(frozen=True)
class RelationshipTable:
    """One relationship instance table.

    ``fk1``/``fk2`` are row indices into the two referenced entity tables
    (ordered as in the :class:`RelationshipDecl`).  Only *true* groundings are
    stored (as in the SQL tables); the count manager derives the ``F`` counts
    with the Möbius virtual join.
    """

    name: str
    n_rows: int
    fk1: jnp.ndarray
    fk2: jnp.ndarray
    attrs: Mapping[str, jnp.ndarray]  # codes in the n/a-augmented domain (so >= 1)

    def column(self, attr: str) -> jnp.ndarray:
        return self.attrs[attr]


@dataclass(frozen=True)
class RelationalDatabase:
    """A full database instance = one joint assignment X = x (paper §II-A)."""

    schema: RelationalSchema
    catalog: VariableCatalog
    entities: Mapping[str, EntityTable]
    relationships: Mapping[str, RelationshipTable]

    @property
    def total_tuples(self) -> int:
        return int(
            sum(t.n_rows for t in self.entities.values())
            + sum(t.n_rows for t in self.relationships.values())
        )

    def entity(self, name: str) -> EntityTable:
        return self.entities[name]

    def relationship(self, name: str) -> RelationshipTable:
        return self.relationships[name]

    def validate(self) -> None:
        """Cheap invariant checks (used by property tests)."""
        for decl in self.schema.entities:
            t = self.entities[decl.name]
            for attr, dom in decl.attributes:
                col = np.asarray(t.attrs[attr])
                assert col.shape == (t.n_rows,), (decl.name, attr, col.shape)
                assert col.min(initial=0) >= 0 and col.max(initial=0) < len(dom)
        for decl in self.schema.relationships:
            t = self.relationships[decl.name]
            n1 = self.entities[decl.entities[0]].n_rows
            n2 = self.entities[decl.entities[1]].n_rows
            fk1, fk2 = np.asarray(t.fk1), np.asarray(t.fk2)
            assert fk1.shape == fk2.shape == (t.n_rows,)
            if t.n_rows:
                assert fk1.min() >= 0 and fk1.max() < n1, decl.name
                assert fk2.min() >= 0 and fk2.max() < n2, decl.name
            for attr, dom in decl.attributes:
                col = np.asarray(t.attrs[attr])
                # stored groundings are true, so codes are in the declared
                # domain shifted by one (0 is reserved for n/a)
                assert col.shape == (t.n_rows,)
                if t.n_rows:
                    assert col.min() >= 1 and col.max() <= len(dom), (decl.name, attr)


@dataclass(frozen=True)
class TableDelta:
    """A signed per-table COO delta stream (the unit of incremental maintenance).

    ``inserted`` carries the rows that entered the table (weight ``+1``) and
    ``deleted`` the rows that left it (weight ``-1``), both as ordinary
    :class:`RelationshipTable` instances so the count manager can run the
    *same* join-tree contraction over a delta view that it runs over a full
    table.  Because every count statistic is linear in each relationship's
    row multiset, ``ΔCT = CT(inserted view) − CT(deleted view)`` exactly
    (see ``sparse_counts.sparse_ct_delta``).
    """

    table: str
    inserted: RelationshipTable
    deleted: RelationshipTable

    @property
    def n_rows(self) -> int:
        return self.inserted.n_rows + self.deleted.n_rows


def _delta_rows_table(
    decl, name: str, spec: Mapping[str, object] | None
) -> RelationshipTable:
    """Validate and int-encode one signed half of a delta spec."""
    if spec is None:
        spec = {"fk1": [], "fk2": [], "attrs": {}}
    fk1 = np.asarray(spec.get("fk1", []), dtype=np.int32)
    fk2 = np.asarray(spec.get("fk2", []), dtype=np.int32)
    if fk1.shape != fk2.shape or fk1.ndim != 1:
        raise ValueError(f"{name}: fk1/fk2 must be equal-length 1-D, "
                         f"got {fk1.shape} vs {fk2.shape}")
    n = int(fk1.shape[0])
    spec_attrs = dict(spec.get("attrs", {}))
    attrs = {}
    for attr, dom in decl.attributes:
        col = np.asarray(spec_attrs.pop(attr, [] if n == 0 else None),
                         dtype=np.int32)
        if col.shape != (n,):
            raise ValueError(f"{name}.{attr}: expected {n} codes, got {col.shape}")
        # stored groundings are true: codes live in the n/a-augmented domain
        if n and (col.min() < 1 or col.max() > len(dom)):
            raise ValueError(f"{name}.{attr}: codes must be in [1, {len(dom)}]")
        attrs[attr] = jnp.asarray(col)
    if spec_attrs:
        raise ValueError(f"{name}: unknown attrs {sorted(spec_attrs)}")
    return RelationshipTable(name, n, jnp.asarray(fk1), jnp.asarray(fk2), attrs)


def apply_delta(
    db: RelationalDatabase,
    table: str,
    inserted_rows: Mapping[str, object] | None = None,
    deleted_rows=None,
) -> tuple[RelationalDatabase, TableDelta]:
    """Functionally apply a relationship-row delta; emit its signed stream.

    ``inserted_rows`` is a dict with keys ``fk1``, ``fk2`` (entity row
    indices) and ``attrs`` (mapping attr -> codes in the stored, n/a-augmented
    convention: true groundings carry codes ``>= 1``).  ``deleted_rows`` is a
    sequence of row *indices* into the current table (unambiguous under
    duplicate rows).  Returns ``(new_db, delta)`` — the input database is
    never mutated (all tables are frozen), so live caches keyed on the old
    instance stay valid while the delta propagates.

    Entity-table deltas are rejected: inserting or deleting an entity row
    changes the grounding population itself, which invalidates *every*
    contingency table — there is no O(Δ) update, only a rebuild.

    Precondition (shared with construction, not checked here or by
    ``validate()`` — a scan of the live table would cost O(n), defeating the
    O(Δ) contract): each ``(fk1, fk2)`` pair grounds the relationship at
    most once, so an inserted pair must not already have a surviving row.
    A duplicate makes the true/false grounding split inconsistent (counts
    can go negative) in the rebuilt and delta-maintained CT alike.
    """
    if table in db.entities:
        raise NotImplementedError(
            f"entity-table deltas are not incremental ({table!r}): a "
            "population change touches every CT; rebuild instead"
        )
    if table not in db.relationships:
        raise KeyError(f"unknown relationship table {table!r}")
    decl = next(d for d in db.schema.relationships if d.name == table)
    rel = db.relationships[table]
    n1 = db.entities[decl.entities[0]].n_rows
    n2 = db.entities[decl.entities[1]].n_rows

    ins = _delta_rows_table(decl, table, inserted_rows)
    if ins.n_rows:
        f1, f2 = np.asarray(ins.fk1), np.asarray(ins.fk2)
        if f1.min() < 0 or f1.max() >= n1 or f2.min() < 0 or f2.max() >= n2:
            raise ValueError(f"{table}: inserted foreign keys out of range")

    idx = np.asarray([] if deleted_rows is None else deleted_rows, dtype=np.int64)
    if idx.size:
        if idx.min() < 0 or idx.max() >= rel.n_rows:
            raise IndexError(f"{table}: deleted row index out of range "
                             f"[0, {rel.n_rows})")
        if np.unique(idx).size != idx.size:
            raise ValueError(f"{table}: duplicate deleted row indices")
    dele = RelationshipTable(
        table, int(idx.size),
        jnp.asarray(np.asarray(rel.fk1)[idx]),
        jnp.asarray(np.asarray(rel.fk2)[idx]),
        {a: jnp.asarray(np.asarray(c)[idx]) for a, c in rel.attrs.items()},
    )

    keep = np.ones(rel.n_rows, dtype=bool)
    keep[idx] = False

    def _cat(col, ins_col):
        # numpy concat + ONE device_put per column: jnp.concatenate would
        # compile a fresh (and never-reused) program for every distinct
        # table length, taxing each delta application with an XLA compile
        return jnp.asarray(np.concatenate([np.asarray(col)[keep],
                                           np.asarray(ins_col)]))

    new_rel = RelationshipTable(
        table,
        rel.n_rows - int(idx.size) + ins.n_rows,
        _cat(rel.fk1, ins.fk1),
        _cat(rel.fk2, ins.fk2),
        {a: _cat(c, ins.attrs[a]) for a, c in rel.attrs.items()},
    )
    new_db = RelationalDatabase(
        db.schema, db.catalog, db.entities,
        {**db.relationships, table: new_rel},
    )
    return new_db, TableDelta(table, ins, dele)


def from_labels(
    schema: RelationalSchema,
    entity_rows: Mapping[str, Mapping[str, list]],
    relationship_rows: Mapping[str, dict],
    entity_sizes: Mapping[str, int] | None = None,
) -> RelationalDatabase:
    """Build a database from labelled (string-valued) rows.

    ``entity_rows[table][attr]`` is a list of labels (one per entity row).
    ``relationship_rows[table]`` is a dict with keys ``fk1``, ``fk2`` (lists of
    row indices) and ``attrs`` (mapping attr -> list of labels).
    ``entity_sizes[table]`` supplies the population of an entity with no
    attribute columns (otherwise row counts come from the columns).
    """
    catalog = analyze_schema(schema)
    entities = {}
    for decl in schema.entities:
        cols = entity_rows[decl.name]
        n = (entity_sizes or {}).get(decl.name, 0)
        if cols:
            n = len(next(iter(cols.values())))
        attrs = {}
        for attr, dom in decl.attributes:
            codes = np.array([dom.index(v) for v in cols[attr]], dtype=np.int32)
            attrs[attr] = jnp.asarray(codes)
            n = len(codes)
        entities[decl.name] = EntityTable(decl.name, n, attrs)

    relationships = {}
    for decl in schema.relationships:
        spec = relationship_rows.get(decl.name, {"fk1": [], "fk2": [], "attrs": {}})
        fk1 = jnp.asarray(np.array(spec["fk1"], dtype=np.int32))
        fk2 = jnp.asarray(np.array(spec["fk2"], dtype=np.int32))
        attrs = {}
        for attr, dom in decl.attributes:
            labels = spec["attrs"][attr]
            codes = np.array([dom.index(v) + 1 for v in labels], dtype=np.int32)  # +1: n/a==0
            attrs[attr] = jnp.asarray(codes)
        relationships[decl.name] = RelationshipTable(
            decl.name, int(fk1.shape[0]), fk1, fk2, attrs
        )

    db = RelationalDatabase(schema, catalog, entities, relationships)
    db.validate()
    return db


def university_db() -> RelationalDatabase:
    """The paper's running example (Figure 2): Student, Professor, RA."""
    from .schema import make_schema

    schema = make_schema(
        entities={
            "student": {
                "intelligence": ("1", "2", "3"),
                "ranking": ("1", "2"),
            },
            "prof": {
                "popularity": ("1", "2", "3"),
                "teachingability": ("1", "2"),
            },
        },
        relationships={
            "RA": (
                ("prof", "student"),
                {
                    "salary": ("low", "med", "high"),
                    "capability": ("1", "2", "3"),
                },
            ),
        },
    )
    # Figure 2 instances.  Students: jack, kim, paul.  Profs: jim, oliver, david.
    students = {"intelligence": ["2", "3", "1"], "ranking": ["1", "1", "2"]}
    profs = {"popularity": ["2", "3", "2"], "teachingability": ["1", "1", "2"]}
    # RA rows: (jack,oliver,high,3), (kim,oliver,low,1), (paul,jim,med,2),
    #          (kim,david,high,2).  fk1 indexes prof, fk2 indexes student.
    ra = {
        "fk1": [1, 1, 0, 2],   # oliver, oliver, jim, david
        "fk2": [0, 1, 2, 1],   # jack, kim, paul, kim
        "attrs": {
            "salary": ["high", "low", "med", "high"],
            "capability": ["3", "1", "2", "2"],
        },
    }
    return from_labels(
        schema,
        entity_rows={"student": students, "prof": profs},
        relationship_rows={"RA": ra},
    )
