"""FactorBase core: the paper's contribution as composable JAX modules.

Pipeline (paper Figure 4):

    RelationalSchema --analyze_schema--> VariableCatalog          (VDB)
    RelationalDatabase --contingency_table/joint_--> CTs          (CDB)
    CTs --mle_factor/score_*--> FactorTables, ScoreTables         (MDB)
    learn_and_join / hill_climb --> BayesNet                      (structure)
    predict_block / predict_single_loop --> class probabilities   (§VI)
    distributed.* --> the same, sharded over a TPU mesh
"""

from .bn import BayesNet
from .counts import (
    CTLike,
    ContingencyTable,
    DENSE_CELL_BUDGET,
    contingency_table,
    ct_conditional,
    joint_contingency_table,
    set_dense_cell_budget,
)
from .cpt import FactorTable, learn_parameters, mle_factor
from .sparse_counts import (
    DeviceSparseCT,
    LeafMessageCache,
    SparseCT,
    apply_ct_delta,
    as_host,
    sparse_ct_delta,
)
from .database import (
    EntityTable,
    RelationalDatabase,
    RelationshipTable,
    TableDelta,
    apply_delta,
    from_labels,
    university_db,
)
from .predict import PredictionResult, predict_block, predict_single_loop
from .schema import (
    EntityDecl,
    ParRV,
    RelationalSchema,
    RelationshipDecl,
    VariableCatalog,
    analyze_schema,
    make_schema,
)
from .score_manager import ScoreManager
from .scores import ScoreTable, score_family, score_structure
from .structure import (
    CountCache,
    LearnAndJoinResult,
    hill_climb,
    learn_and_join,
    warm_hill_climb,
)

__all__ = [
    "BayesNet", "CTLike", "ContingencyTable", "DENSE_CELL_BUDGET",
    "DeviceSparseCT", "LeafMessageCache", "SparseCT", "apply_ct_delta",
    "as_host", "sparse_ct_delta",
    "set_dense_cell_budget", "contingency_table", "ct_conditional",
    "joint_contingency_table", "FactorTable", "learn_parameters", "mle_factor",
    "EntityTable", "RelationalDatabase", "RelationshipTable", "TableDelta",
    "apply_delta", "from_labels",
    "university_db", "PredictionResult", "predict_block", "predict_single_loop",
    "EntityDecl", "ParRV", "RelationalSchema", "RelationshipDecl",
    "VariableCatalog", "analyze_schema", "make_schema", "ScoreTable",
    "score_family", "score_structure", "CountCache", "ScoreManager",
    "LearnAndJoinResult", "hill_climb", "learn_and_join", "warm_hill_climb",
]
