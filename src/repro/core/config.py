"""Unified engine configuration: one resolution order for every knob.

FactorBase's BayesStore stance — models (and the engine serving them) are
first-class, managed objects — is incompatible with configuration smeared
across ~18 ``REPRO_*`` environment variables and per-module ``set_*()``
setters: a service embedding the engine cannot scope a knob to one request,
cannot snapshot what it is actually running with, and cannot trust that an
env var read at *import* time still reflects the environment at *call*
time.  This module is the single owner of all of that state:

* :class:`EngineConfig` — a frozen dataclass snapshot of every knob, fully
  resolved (:func:`current_config` returns one).
* :func:`engine_config` — a context manager applying scoped overrides::

      with engine_config(coo_shards=4, device_min_rows=0):
          learn(db)                      # sharded, device-forced
      # previous behavior restored, even on exception

  Contexts nest (innermost wins per field) and are **thread-safe**: the
  override stack lives in a :mod:`contextvars` variable, so a context
  entered in one thread is invisible to every other thread.
* :func:`resolve` — the precedence engine every internal call site uses:

      explicit per-call kwarg  >  innermost active ``engine_config`` context
      >  module ``set_*()`` setter (process-global)  >  ``REPRO_*`` env var
      >  built-in default

Environment variables are re-read on every resolution (they are the
*fallback* layer, kept for shell/CI ergonomics) and keep their historical
fail-loud contract: a malformed value raises ``ValueError`` naming the
variable rather than silently running with the default.  The legacy
``set_*()`` setters in :mod:`~repro.kernels.bucketing`,
:mod:`~repro.kernels.ops`, :mod:`~repro.core.counts`,
:mod:`~repro.core.score_manager` and :mod:`~repro.core.sparse_counts` are
retained as deprecated shims that delegate to :func:`set_override` — same
behavior, one source of truth.

This module deliberately imports nothing from the rest of the package (and
imports :mod:`jax` only lazily, for the persistent-cache side effect), so
both the ``core`` and ``kernels`` layers can depend on it without cycles.
"""

from __future__ import annotations

import contextlib
import contextvars
import os
from dataclasses import dataclass, fields as dataclass_fields
from typing import Any, Callable, Iterator, Mapping

__all__ = [
    "EngineConfig",
    "current_config",
    "engine_config",
    "resolve",
    "set_override",
]


# ---------------------------------------------------------------------------
# Field specs: default, env var, env parser, value validator
# ---------------------------------------------------------------------------


def _parse_int(env: str, raw: str, *, minimum: int | None = None,
               style: str = "an integer") -> int:
    try:
        n = int(raw)
    except ValueError as e:
        bound = f" >= {minimum}" if minimum is not None else ""
        raise ValueError(f"{env} must be {style}{bound}, got {raw!r}") from e
    if minimum is not None and n < minimum:
        raise ValueError(f"{env} must be >= {minimum}, got {n}")
    return n


def _check_int(name: str, value: Any, *, minimum: int | None = None) -> int:
    if isinstance(value, bool) or not isinstance(value, int):
        raise ValueError(f"{name} must be an int, got {value!r}")
    if minimum is not None and value < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {value}")
    return value


def _check_bool(name: str, value: Any) -> bool:
    if not isinstance(value, bool):
        raise ValueError(f"{name} must be a bool, got {value!r}")
    return value


def _check_choice(name: str, value: Any, choices: tuple[str, ...]) -> str:
    if value not in choices:
        raise ValueError(f"{name} must be one of {choices}, got {value!r}")
    return value


@dataclass(frozen=True)
class _Field:
    default: Any
    env: str | None                              # None: no env fallback
    parse_env: Callable[[str], Any] | None       # raw env string -> value
    validate: Callable[[str, Any], Any]          # (field name, value) -> value


def _kernel_impl_env(raw: str) -> str:
    v = raw.strip().lower()
    if v not in ("", "pallas", "ref"):
        # fail loudly: a typo'd value would silently fall back to the
        # oracles and defeat the CI leg whose purpose is dispatch coverage
        raise ValueError(
            f"REPRO_KERNEL_IMPL must be 'pallas' or 'ref' (or unset), got {v!r}"
        )
    return v


_SORT_IMPLS = ("auto", "xla", "pallas")
_DONATE_MODES = ("auto", "0", "1")


def _sort_impl_env(raw: str) -> str:
    v = raw.strip().lower() or "auto"
    if v not in _SORT_IMPLS:
        raise ValueError(f"REPRO_SORT_IMPL must be one of {_SORT_IMPLS}, got {v!r}")
    return v


def _donate_env(raw: str) -> str:
    v = raw.strip().lower() or "auto"
    if v not in _DONATE_MODES:
        raise ValueError(f"REPRO_DONATE must be one of {_DONATE_MODES}, got {v!r}")
    return v


def _bool01_env(env: str) -> Callable[[str], bool]:
    def parse(raw: str) -> bool:
        v = raw.strip()
        if v not in ("0", "1"):
            raise ValueError(f"{env} must be 0 or 1, got {v!r}")
        return v == "1"
    return parse


def _bucket_base_env(raw: str) -> int:
    try:
        base = int(raw)
    except ValueError as e:
        raise ValueError(
            f"REPRO_BUCKET_BASE / REPRO_BUCKET_GROWTH must parse as int / "
            f"float, got {raw!r}"
        ) from e
    return _check_int("bucket base", base, minimum=1)


def _bucket_growth_env(raw: str) -> float:
    try:
        growth = float(raw)
    except ValueError as e:
        raise ValueError(
            f"REPRO_BUCKET_BASE / REPRO_BUCKET_GROWTH must parse as int / "
            f"float, got {raw!r}"
        ) from e
    return _validate_growth("bucket growth", growth)


def _validate_growth(name: str, value: Any) -> float:
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise ValueError(f"{name} must be a number, got {value!r}")
    if value <= 1.0:
        # growth == 1 would make every row count its own "bucket" and
        # silently bring the per-shape recompile tax back
        raise ValueError(f"{name} must be > 1, got {value}")
    return float(value)


_FIELDS: dict[str, _Field] = {
    # kernel dispatch ------------------------------------------------------
    "kernel_impl": _Field(
        default="",
        env="REPRO_KERNEL_IMPL",
        parse_env=_kernel_impl_env,
        validate=lambda n, v: _check_choice(n, v, ("", "pallas", "ref")),
    ),
    "sort_impl": _Field(
        default="auto",
        env="REPRO_SORT_IMPL",
        parse_env=_sort_impl_env,
        validate=lambda n, v: _check_choice(n, v, _SORT_IMPLS),
    ),
    "coo_hist_bins": _Field(
        default=1 << 22,
        env="REPRO_COO_HIST_BINS",
        parse_env=lambda raw: _parse_int("REPRO_COO_HIST_BINS", raw),
        validate=lambda n, v: _check_int(n, v),
    ),
    # count-manager routing ------------------------------------------------
    "device_min_rows": _Field(
        default=1 << 18,
        env="REPRO_DEVICE_MIN_ROWS",
        parse_env=lambda raw: _device_min_rows_env(raw),
        validate=lambda n, v: _check_int(n, v, minimum=0),
    ),
    "dense_cell_budget": _Field(
        default=1 << 26,
        env=None,
        parse_env=None,
        validate=lambda n, v: _check_int(n, v, minimum=1),
    ),
    "coo_shards": _Field(
        default=1,
        env="REPRO_COO_SHARDS",
        parse_env=lambda raw: _parse_int("REPRO_COO_SHARDS", raw, minimum=1),
        validate=lambda n, v: _check_int(n, v, minimum=1),
    ),
    # score-manager routing ------------------------------------------------
    "batch_min_candidates": _Field(
        default=8,
        env="REPRO_BATCH_MIN_CANDIDATES",
        parse_env=lambda raw: _parse_int(
            "REPRO_BATCH_MIN_CANDIDATES", raw, minimum=0
        ),
        validate=lambda n, v: _check_int(n, v, minimum=0),
    ),
    "incremental": _Field(
        default=True,
        env="REPRO_INCREMENTAL",
        parse_env=_bool01_env("REPRO_INCREMENTAL"),
        validate=lambda n, v: _check_bool(n, v),
    ),
    "msg_cache": _Field(
        default=128,
        env="REPRO_MSG_CACHE",
        parse_env=lambda raw: _parse_int("REPRO_MSG_CACHE", raw, minimum=0),
        validate=lambda n, v: _check_int(n, v, minimum=0),
    ),
    "fused_build": _Field(
        default=True,
        env="REPRO_FUSED_BUILD",
        parse_env=_bool01_env("REPRO_FUSED_BUILD"),
        validate=lambda n, v: _check_bool(n, v),
    ),
    # bucket ladder / compile warmth ---------------------------------------
    "bucket_base": _Field(
        default=128,
        env="REPRO_BUCKET_BASE",
        parse_env=_bucket_base_env,
        validate=lambda n, v: _check_int(n, v, minimum=1),
    ),
    "bucket_growth": _Field(
        default=2.0,
        env="REPRO_BUCKET_GROWTH",
        parse_env=_bucket_growth_env,
        validate=_validate_growth,
    ),
    "donation": _Field(
        default="auto",
        env="REPRO_DONATE",
        parse_env=_donate_env,
        validate=lambda n, v: _check_choice(n, v, _DONATE_MODES),
    ),
    "jax_cache_dir": _Field(
        default="",
        env="REPRO_JAX_CACHE_DIR",
        parse_env=lambda raw: raw.strip(),
        validate=lambda n, v: _check_path(n, v),
    ),
}


def _check_path(name: str, value: Any) -> str:
    if not isinstance(value, (str, os.PathLike)):
        raise ValueError(f"{name} must be a path string, got {value!r}")
    return str(value)


def _device_min_rows_env(raw: str) -> int:
    try:
        rows = int(raw)
    except ValueError as e:
        # fail loudly, like REPRO_BUCKET_BASE: a typo'd value would silently
        # fall back to the default and defeat the knob
        raise ValueError(
            f"REPRO_DEVICE_MIN_ROWS must parse as int, got {raw!r}"
        ) from e
    if rows < 0:
        raise ValueError(f"REPRO_DEVICE_MIN_ROWS must be >= 0, got {rows}")
    return rows


# ---------------------------------------------------------------------------
# The three mutable layers: context stack, global setter overrides, env
# ---------------------------------------------------------------------------

#: Innermost-last stack of validated {field: value} override mappings.  A
#: ContextVar gives the thread-safety contract for free: each thread (and
#: each asyncio task) sees only the contexts it entered itself.
_CONTEXT_STACK: contextvars.ContextVar[tuple[Mapping[str, Any], ...]] = (
    contextvars.ContextVar("repro_engine_config_stack", default=())
)

#: Process-global overrides written by the legacy ``set_*()`` setters (and
#: :func:`set_override`).  Sits *below* the context stack — a scoped
#: ``engine_config`` always wins over ambient module-level mutation — and
#: *above* the environment, matching the setters' historical behavior of
#: replacing the env-initialized module global.
_GLOBAL_OVERRIDES: dict[str, Any] = {}

_UNSET = object()


def _field(name: str) -> _Field:
    try:
        return _FIELDS[name]
    except KeyError:
        raise ValueError(
            f"unknown engine-config field {name!r}; known fields: "
            f"{tuple(sorted(_FIELDS))}"
        ) from None


def resolve(name: str, kwarg: Any = _UNSET) -> Any:
    """Resolve one field: kwarg > context > setter override > env > default.

    ``kwarg`` is the per-call override an API accepted explicitly (pass
    nothing — not ``None`` — when the caller did not supply one).  The env
    layer is re-read from ``os.environ`` on every call and keeps the
    fail-loud parse contract of the historical per-module readers.
    """
    spec = _field(name)
    if kwarg is not _UNSET and kwarg is not None:
        return spec.validate(name, kwarg)
    for overrides in reversed(_CONTEXT_STACK.get()):
        if name in overrides:
            return overrides[name]
    if name in _GLOBAL_OVERRIDES:
        return _GLOBAL_OVERRIDES[name]
    if spec.env is not None:
        raw = os.environ.get(spec.env, "")
        if raw.strip():
            return spec.parse_env(raw)
    return spec.default


def set_override(name: str, value: Any) -> Any:
    """Set (or with ``None``, clear) the process-global override for a field.

    Returns the field's previous *resolved* value — the legacy setters'
    return convention, so ``set_x(set_x(new))`` round-trips.  This is the
    delegation target of every deprecated per-module ``set_*()`` setter.
    """
    old = resolve(name)
    if value is None:
        _GLOBAL_OVERRIDES.pop(name, None)
    else:
        _GLOBAL_OVERRIDES[name] = _field(name).validate(name, value)
    return old


# ---------------------------------------------------------------------------
# EngineConfig snapshots + the scoped context manager
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EngineConfig:
    """A frozen, fully-resolved snapshot of every engine knob.

    Field defaults are the engine's built-in defaults; :func:`current_config`
    returns a snapshot with the full precedence chain applied.  Instances
    are plain data — apply one with ``engine_config(**asdict(cfg))``.
    """

    kernel_impl: str = ""
    sort_impl: str = "auto"
    coo_hist_bins: int = 1 << 22
    device_min_rows: int = 1 << 18
    dense_cell_budget: int = 1 << 26
    coo_shards: int = 1
    batch_min_candidates: int = 8
    incremental: bool = True
    msg_cache: int = 128
    fused_build: bool = True
    bucket_base: int = 128
    bucket_growth: float = 2.0
    donation: str = "auto"
    jax_cache_dir: str = ""


# keep the dataclass and the field-spec table in lockstep
assert {f.name for f in dataclass_fields(EngineConfig)} == set(_FIELDS), (
    "EngineConfig fields and _FIELDS spec table diverged"
)
assert all(
    getattr(EngineConfig(), n) == s.default for n, s in _FIELDS.items()
), "EngineConfig defaults and _FIELDS defaults diverged"


def current_config() -> EngineConfig:
    """Snapshot the active configuration (all layers applied)."""
    return EngineConfig(**{name: resolve(name) for name in _FIELDS})


def _wire_cache_dir(cache_dir: str) -> None:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Thresholds are zeroed so the small bucketed programs qualify (by
    default JAX only persists compiles >1s).  jax is imported lazily so
    merely importing this module stays dependency-free.
    """
    import jax

    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


@contextlib.contextmanager
def engine_config(**overrides: Any) -> Iterator[EngineConfig]:
    """Scoped engine configuration: apply ``overrides`` until exit.

    Only fields passed explicitly are overridden; everything else keeps
    resolving through the outer layers.  Contexts nest (innermost wins per
    field) and are isolated per thread / per asyncio task.  Yields the
    resolved :class:`EngineConfig` in effect inside the block.

    ``jax_cache_dir`` is side-effectful: entering a context that sets it
    wires JAX's persistent compilation cache immediately (JAX offers no
    un-wire, so that one setting survives context exit).
    """
    validated = {
        name: _field(name).validate(name, value)
        for name, value in overrides.items()
    }
    token = _CONTEXT_STACK.set(_CONTEXT_STACK.get() + (validated,))
    try:
        if validated.get("jax_cache_dir"):
            _wire_cache_dir(validated["jax_cache_dir"])
        yield current_config()
    finally:
        _CONTEXT_STACK.reset(token)


# Importing the engine with REPRO_JAX_CACHE_DIR set wires the persistent
# compilation cache up front (the warm-start contract predating this
# module): the env var is the startup form of the knob.
_startup_cache_dir = resolve("jax_cache_dir")
if _startup_cache_dir:
    _wire_cache_dir(_startup_cache_dir)
