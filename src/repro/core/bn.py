"""Bayesian-network structure objects — the Models Database (MDB) schema.

Paper §V-A stores the graph in a ``BayesNet(child, parent)`` table, one
``@par-RVID@_CPT`` factor table per node, and a ``Scores`` table.  Here the
structure is a frozen mapping child -> parents over par-RV ids, with the
factor/score tables managed by :mod:`repro.core.cpt` / :mod:`repro.core.scores`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping


@dataclass(frozen=True)
class BayesNet:
    """A parametrized BN structure: DAG over par-RV ids."""

    rvs: tuple[str, ...]
    parents: Mapping[str, tuple[str, ...]]

    def __post_init__(self):
        for child, ps in self.parents.items():
            assert child in self.rvs, child
            for p in ps:
                assert p in self.rvs, (child, p)
            assert len(set(ps)) == len(ps), f"duplicate parents for {child}"

    @staticmethod
    def empty(rvs: Iterable[str]) -> "BayesNet":
        rvs = tuple(rvs)
        return BayesNet(rvs, {r: () for r in rvs})

    def family(self, child: str) -> tuple[str, ...]:
        """child + parents — the par-factor of this node (paper §II-B)."""
        return (child,) + tuple(self.parents[child])

    def edges(self) -> tuple[tuple[str, str], ...]:
        return tuple(
            (p, c) for c in self.rvs for p in self.parents.get(c, ())
        )

    @property
    def n_edges(self) -> int:
        return sum(len(self.parents.get(c, ())) for c in self.rvs)

    def with_edge(self, parent: str, child: str) -> "BayesNet":
        ps = self.parents[child]
        assert parent not in ps
        new = dict(self.parents)
        new[child] = ps + (parent,)
        return BayesNet(self.rvs, new)

    def without_edge(self, parent: str, child: str) -> "BayesNet":
        new = dict(self.parents)
        new[child] = tuple(p for p in self.parents[child] if p != parent)
        return BayesNet(self.rvs, new)

    def reversed_edge(self, parent: str, child: str) -> "BayesNet":
        return self.without_edge(parent, child).with_edge(child, parent)

    def has_edge(self, parent: str, child: str) -> bool:
        return parent in self.parents.get(child, ())

    def is_acyclic(self) -> bool:
        return self.topological_order() is not None

    def topological_order(self) -> tuple[str, ...] | None:
        """Kahn's algorithm; None if cyclic."""
        indeg = {r: len(self.parents.get(r, ())) for r in self.rvs}
        children: dict[str, list[str]] = {r: [] for r in self.rvs}
        for c in self.rvs:
            for p in self.parents.get(c, ()):
                children[p].append(c)
        queue = sorted(r for r, d in indeg.items() if d == 0)
        order: list[str] = []
        while queue:
            n = queue.pop(0)
            order.append(n)
            for c in children[n]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    queue.append(c)
            queue.sort()
        return tuple(order) if len(order) == len(self.rvs) else None

    def union(self, other: "BayesNet") -> "BayesNet":
        """Edge union over the union of node sets (used by learn-and-join)."""
        rvs = tuple(dict.fromkeys(self.rvs + other.rvs))
        parents = {}
        for r in rvs:
            ps = tuple(dict.fromkeys(
                self.parents.get(r, ()) + other.parents.get(r, ())
            ))
            parents[r] = ps
        return BayesNet(rvs, parents)
