"""The Count Manager (paper §IV): dense and sparse contingency tables.

The contingency-table problem: given par-RVs **V** and a database instance,
produce the table of counts of every joint value assignment, where the count
ranges over the *cross product of the first-order variables' populations*
(so relationship par-RVs take value F for unlinked tuples, and relationship
attributes take ``n/a`` there — paper Fig. 3(c)).

TPU-native construction (replaces the SQL metaquery pipeline):

  * a **query conditioned on relationships = True** is a join-tree
    contraction: relationship tables are factors over entity indices, entity
    attributes are code columns, and GROUP BY COUNT is a mixed-radix encode +
    histogram (``kernels.ct_count``).  Eliminating a leaf first-order
    variable through a relationship is a *weighted histogram* — the tensor
    analogue of a foreign-key join.
  * the **Möbius virtual join** (paper §IV, citing Qian et al. CIKM'14)
    recovers the R = False blocks without ever materializing a cross join:
    ``CT[F] = CT[*] - CT[T]`` axis group by axis group, where the
    "don't-care" table of an untouched population is just an outer product
    of entity-attribute histograms.

Two storage backends implement the same :class:`CTLike` interface:

  * :class:`ContingencyTable` — one dense float32 tensor cell per joint
    value; the Pallas ``ct_count`` histogram is the fast path.  Cell count
    is the full domain cross product, so it only fits small bounded domains.
  * :class:`~repro.core.sparse_counts.SparseCT` — COO over mixed-radix
    composite codes storing only *realized* sufficient statistics (the
    paper's #SS, vastly smaller than the cross product; §IV).  Built by
    sort-then-segment-sum; ``impl="sparse"`` selects it explicitly.  With
    ``device_resident=True`` the sparse build itself runs on device
    (:func:`~repro.core.sparse_counts.device_sparse_contingency_table`):
    the join-tree contraction and Möbius recursion execute as COO code
    algebra over ``jax.Array``s and the result is a
    :class:`~repro.core.sparse_counts.DeviceSparseCT` that never existed
    on host.

**Auto-switch heuristic:** with ``impl="auto"`` the dense/Pallas path is used
while the dense cell count (domain cross product, times the group-entity
population for §VI block queries) stays within :data:`DENSE_CELL_BUDGET`
(default ``2**26`` cells ≈ 256 MiB of float32); beyond it the query silently
switches to the sparse backend.  The knob is configurable per call
(``dense_cell_budget=...``) or globally (:func:`set_dense_cell_budget`).

Counts are float32 (exact for cells < 2**24; tests cross-check an int64
numpy brute force on small instances).  Every public function is
metadata-driven via the :class:`VariableCatalog` — the analogue of the
paper's metaqueries reading the VDB.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.bucketing import bucket_rows
from . import config
from .database import RelationalDatabase
from .schema import (
    KIND_ENTITY_ATTR,
    KIND_REL,
    KIND_REL_ATTR,
    ParRV,
)


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------

#: Max dense cells ``impl="auto"`` will materialize before switching to the
#: sparse COO backend (2**26 float32 cells = 256 MiB) — the built-in
#: default of the ``dense_cell_budget`` engine-config field.  The live
#: value resolves through :mod:`repro.core.config` (see module docstring).
DENSE_CELL_BUDGET: int = 1 << 26


def set_dense_cell_budget(n_cells: int) -> int:
    """Set the global dense/sparse auto-switch budget; returns the old value.

    .. deprecated:: delegates to :mod:`repro.core.config`; prefer
       ``engine_config(dense_cell_budget=...)`` for scoped use.
    """
    return config.set_override("dense_cell_budget", int(n_cells))


#: Minimum ``db.total_tuples`` for ``device_resident=True`` to actually run
#: the device build.  Below it the host COO builder (numpy lexsort +
#: reduceat) wins outright — ``bench_scale`` measures synth-smoke (54k
#: tuples) at <1x device-vs-host while synth-1m is >2x — so small requests
#: fall back to :func:`~repro.core.sparse_counts.sparse_contingency_table`
#: with identical cells.  The default is calibrated from the committed
#: ``bench_scale`` numbers: the log-log interpolated host/device crossover
#: lands in the 2-4 * 10^5 tuple range run-to-run, so the default sits at
#: the power of two inside it (the bench JSON records the re-measured
#: crossover under ``bench_scale._routing`` on every refresh).
_DEVICE_MIN_ROWS_DEFAULT = 1 << 18


def device_min_rows() -> int:
    """Current device-build row threshold (``0`` = always honor the flag).

    Resolves through :mod:`repro.core.config` (``REPRO_DEVICE_MIN_ROWS``
    env fallback, ``engine_config(device_min_rows=...)`` for scoped use).
    """
    return config.resolve("device_min_rows")


def set_device_min_rows(rows: int) -> int:
    """Set the device-build row threshold; returns the previous value.

    Benchmarks and device tests pass ``0`` to force the device path on
    small databases; production tuning moves the crossover measured by
    ``bench_scale``.

    .. deprecated:: delegates to :mod:`repro.core.config`; prefer
       ``engine_config(device_min_rows=...)`` for scoped use.
    """
    rows = int(rows)
    if rows < 0:
        raise ValueError(f"device min rows must be >= 0, got {rows}")
    return config.set_override("device_min_rows", rows)


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n.

    Shared by every batched code path that pads a data-dependent *logical*
    dimension (batch size, stacked parent/child extents, sparse code
    spaces) so jitted launch shapes stabilize across hill-climb sweeps —
    and so the chunking guards and the padding they protect can never
    disagree about a bucket boundary.  Data-dependent *row counts* use the
    configurable geometric ladder in :mod:`repro.kernels.bucketing`
    instead (:func:`~repro.kernels.bucketing.bucket_rows`), which the ops
    wrappers apply to every device COO stream.
    """
    return 1 << max(0, n - 1).bit_length()


@runtime_checkable
class CTLike(Protocol):
    """What score/structure/prediction layers require of a contingency table.

    Both the dense :class:`ContingencyTable` and the COO
    :class:`~repro.core.sparse_counts.SparseCT` satisfy this protocol, so
    every consumer (``scores.py``, ``structure.py``, ``predict.py``) works
    with either backend unchanged.
    """

    @property
    def rvs(self) -> tuple[str, ...]: ...

    @property
    def n_cells(self) -> int: ...

    def total(self): ...

    def n_nonzero(self) -> int: ...

    def marginal(self, keep: tuple[str, ...]) -> "CTLike": ...

    def transpose(self, order: tuple[str, ...]) -> "CTLike": ...


# ---------------------------------------------------------------------------
# Contingency tables
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ContingencyTable:
    """Dense sufficient-statistics tensor: one axis per par-RV (by vid)."""

    rvs: tuple[str, ...]
    table: jax.Array  # float32, shape = tuple(cardinality of each rv)

    def __post_init__(self):
        assert self.table.ndim == len(self.rvs), (self.rvs, self.table.shape)

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.table.shape)) if self.table.ndim else 1

    def total(self) -> jax.Array:
        return jnp.sum(self.table)

    def n_nonzero(self) -> int:
        """Number of realized sufficient statistics (the paper's #SS)."""
        return int(jnp.sum(self.table > 0))

    def marginal(self, keep: tuple[str, ...]) -> "ContingencyTable":
        """GROUP BY a subset of the par-RVs (sum out the rest)."""
        missing = [v for v in keep if v not in self.rvs]
        if missing:
            raise KeyError(f"par-RVs {missing} not in this CT {self.rvs}")
        drop_axes = tuple(i for i, v in enumerate(self.rvs) if v not in keep)
        t = jnp.sum(self.table, axis=drop_axes) if drop_axes else self.table
        kept = tuple(v for v in self.rvs if v in keep)
        ct = ContingencyTable(kept, t)
        return ct.transpose(keep)

    def transpose(self, order: tuple[str, ...]) -> "ContingencyTable":
        if tuple(order) == self.rvs:
            return self
        perm = tuple(self.rvs.index(v) for v in order)
        return ContingencyTable(tuple(order), jnp.transpose(self.table, perm))


# ---------------------------------------------------------------------------
# Batched family marginalization (set-oriented §V-C counts)
# ---------------------------------------------------------------------------


def stacked_family_tables(
    digits: "dict[str, jax.Array | np.ndarray]",
    cell_counts: "jax.Array | np.ndarray",
    cards: dict[str, int],
    families: list[tuple[str, tuple[str, ...]]],
    *,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array, list[tuple[str, int, int]]]:
    """Marginalize a whole batch of families out of one joint CT in one pass.

    The joint CT is given in realized-cell form: ``digits[rv]`` is the
    decoded value column of par-RV ``rv`` over the joint's nonzero cells and
    ``cell_counts`` their counts (either backend produces this — the COO
    codes of a :class:`~repro.core.sparse_counts.SparseCT` or the
    ``flatnonzero`` cells of a dense tensor).  For each requested family
    ``(child, parents)`` the target cell is

        ``bin = family_index * S + parent_code * C_max + child_value``

    so the *entire batch* of family CTs is one weighted GROUP BY — a single
    ``ops.ct_count`` launch (the stacked take/einsum pass; ``impl="matmul"``
    lowers it as one-hot MXU contractions) instead of one marginalization
    per family.  Padding is sized by the batch maxima ``P_max x C_max``;
    family domains are bounded by ``max_parents``, so the padded stack stays
    small even for mixed-arity batches.

    Arrays may live on device (jnp) or host (numpy); device-resident digit
    caches keep the whole remap on device (see ``ScoreManager``).

    All padded dimensions (batch, parent configs, child lanes, scatter rows)
    are rounded up to powers of two so the jitted launch shapes stabilize
    across sweeps — otherwise every hill-climb sweep's slightly different
    batch would recompile.  Padding rows/lanes carry count 0 (scatter keys
    ``-1`` are dropped by ``ct_count``) and an all-zero child mask, so they
    score to exactly nothing downstream.

    Returns ``(stacked, child_mask, metas)``: a ``(B_pad, P_max, C_max)``
    float32 stack of padded family CTs (axes ``(*sorted parents, child)``,
    rows ``len(families):`` all-zero padding), the ``(B_pad, C_max)``
    valid-child-lane mask for the batched kernels, and one
    ``(child, n_parent_configs, child_card)`` meta per *requested* family.
    """
    if not families:
        raise ValueError("empty family batch")
    bucket = pow2_bucket

    metas: list[tuple[str, int, int]] = []
    p_max = c_max = 1
    for child, parents in families:
        p_i = math.prod((cards[p] for p in parents), start=1)
        c_i = cards[child]
        metas.append((child, p_i, c_i))
        p_max, c_max = max(p_max, p_i), max(c_max, c_i)
    p_max, c_max = bucket(p_max), bucket(c_max)
    b_pad = bucket(len(families))
    stride = p_max * c_max
    n_bins = b_pad * stride
    if n_bins > 2**31 - 1:
        raise OverflowError(
            f"stacked family batch needs {n_bins:.3g} bins; split the batch"
        )

    host = isinstance(cell_counts, np.ndarray)
    xp = np if host else jnp
    nnz = int(cell_counts.shape[0])
    if nnz == 0:
        stacked = jnp.zeros((b_pad, p_max, c_max), jnp.float32)
    else:
        chunks = []
        for i, (child, parents) in enumerate(families):
            p_cards = [cards[p] for p in parents]
            code = digits[child] + i * stride
            for p, s in zip(parents, radix_strides(p_cards)):
                code = code + digits[p] * (s * c_max)
            chunks.append(code)
        bins = xp.concatenate(chunks).astype(xp.int32)
        weights = xp.tile(cell_counts, len(families))
        # scatter rows ride the kernels' geometric row ladder (not pow2):
        # the padded histogram input shares compiled programs with every
        # other bucketed stream of the run
        row_pad = bucket_rows(int(bins.shape[0])) - int(bins.shape[0])
        # -1 keys are dropped by ct_count: row padding is free of mass
        bins = xp.pad(bins, (0, row_pad), constant_values=-1)
        weights = xp.pad(weights, (0, row_pad))
        flat = ops.ct_count(
            jnp.asarray(bins), n_bins, weights=jnp.asarray(weights),
            impl=ops.kernel_impl(impl),
        )
        stacked = flat.reshape(b_pad, p_max, c_max)

    mask = np.zeros((b_pad, c_max), np.float32)
    for i, (_, _, c_i) in enumerate(metas):
        mask[i, :c_i] = 1.0
    return stacked, jnp.asarray(mask), metas


# ---------------------------------------------------------------------------
# Mixed-radix code helpers
# ---------------------------------------------------------------------------


def radix_strides(cards: list[int]) -> list[int]:
    """Row-major strides so that code = sum_i digit_i * stride_i."""
    strides = [1] * len(cards)
    for i in range(len(cards) - 2, -1, -1):
        strides[i] = strides[i + 1] * cards[i + 1]
    return strides


def encode_columns(cols: list[jax.Array], cards: list[int]) -> jax.Array:
    """Mixed-radix composite key over int32 code columns."""
    if not cols:
        raise ValueError("need at least one column")
    strides = radix_strides(cards)
    key = cols[0] * strides[0]
    for c, s in zip(cols[1:], strides[1:]):
        key = key + c * s
    return key


# ---------------------------------------------------------------------------
# Column access (the SELECT list of the metaquery)
# ---------------------------------------------------------------------------


def _entity_attr_column(db: RelationalDatabase, rv: ParRV) -> jax.Array:
    return db.entities[rv.table].attrs[rv.column]


def _rel_attr_column(db: RelationalDatabase, rv: ParRV) -> jax.Array:
    return db.relationships[rv.table].attrs[rv.column]


def _rel_fk(db: RelationalDatabase, rel_name: str, fovar_id: str) -> jax.Array:
    """Foreign-key column of a relationship table for a given first-order var."""
    decl = db.schema.relationship(rel_name)
    t = db.relationships[rel_name]
    cat = db.catalog
    rel_rv = cat.rel_var_of(rel_name)
    f1, f2 = rel_rv.fovars
    if fovar_id == f1.fid:
        return t.fk1
    if fovar_id == f2.fid:
        return t.fk2
    raise KeyError(f"{fovar_id} is not a first-order variable of {rel_name} ({decl.entities})")


# ---------------------------------------------------------------------------
# Query planning (shared by the dense and sparse backends)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QueryPlan:
    """Validated join-tree plan for one conditional count query.

    Produced by :func:`plan_conditional` and consumed by both the dense
    join-tree contraction below and the sparse builder in
    :mod:`repro.core.sparse_counts` — the two backends share universe
    resolution, attribute grouping, join-graph construction and all input
    validation, and differ only in how messages are materialized.
    """

    universe: tuple[str, ...]                       # first-order variables
    ent_attrs: dict[str, list[ParRV]]               # fovar id -> its attr rvs
    rel_attrs: dict[str, list[ParRV]]               # rel name -> its attr rvs
    adj: dict[str, list[tuple[str, str]]]           # fovar -> [(rel, other)]
    comps: tuple[tuple[str, ...], ...]              # connected components
    comp_of: dict[str, int]
    restrict: dict[str, int] = field(default_factory=dict)
    group_fovar: str | None = None
    #: component indices whose join graph is NOT a tree (parallel
    #: relationships between one fovar pair, rings, diamonds, dual
    #: self-relationships).  Leaf elimination cannot contract them; the
    #: sparse backend routes them to the explicit ground join
    #: (``sparse_counts._ground_join_component``) and the dense backend
    #: delegates the whole query to sparse + ``to_dense``.
    cyclic: frozenset[int] = frozenset()


def plan_conditional(
    db: RelationalDatabase,
    attr_rvs: tuple[str, ...],
    cond_true: tuple[str, ...],
    fovar_universe: tuple[str, ...] | None = None,
    *,
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
) -> QueryPlan:
    """Validate a conditional count query and plan its join-tree contraction."""
    cat = db.catalog
    rvs = [cat[v] for v in attr_rvs]
    for rv in rvs:
        if rv.kind == KIND_REL:
            raise ValueError(
                f"{rv.vid} is a relationship par-RV; use contingency_table() "
                "for queries with relationship variables"
            )
    for rv in rvs:
        if rv.kind == KIND_REL_ATTR and rv.table not in cond_true:
            raise ValueError(
                f"{rv.vid}: relationship attribute requires {rv.table} in cond_true"
            )

    # First-order variable universe.
    q_fovars: list[str] = []
    for rv in rvs:
        for f in rv.fovars:
            if f.fid not in q_fovars:
                q_fovars.append(f.fid)
    for rname in cond_true:
        for f in cat.rel_var_of(rname).fovars:
            if f.fid not in q_fovars:
                q_fovars.append(f.fid)
    restrict = dict(restrict or {})
    if group_fovar is not None and group_fovar not in q_fovars:
        q_fovars.append(group_fovar)
    for f in restrict:
        if f not in q_fovars:
            q_fovars.append(f)
    universe = list(fovar_universe) if fovar_universe is not None else q_fovars
    for f in (group_fovar,) if group_fovar is not None else ():
        if f not in universe:
            universe.append(f)
    for f in restrict:
        if f not in universe:
            universe.append(f)
    for f in q_fovars:
        if f not in universe:
            raise ValueError(f"query fovar {f} outside universe {universe}")

    # Group attribute rvs.
    ent_attrs: dict[str, list[ParRV]] = {f: [] for f in universe}
    rel_attrs: dict[str, list[ParRV]] = {r: [] for r in cond_true}
    for rv in rvs:
        if rv.kind == KIND_ENTITY_ATTR:
            ent_attrs[rv.fovars[0].fid].append(rv)
        else:
            rel_attrs[rv.table].append(rv)

    # Join graph over first-order variables.
    adj: dict[str, list[tuple[str, str]]] = {f: [] for f in universe}
    for rname in cond_true:
        # a self-relationship never aliases its two roles: analyze_schema
        # emits distinct index-0/index-1 fovars (e.g. "a0"/"a1"), so every
        # edge connects two distinct join-graph nodes
        f1, f2 = (f.fid for f in cat.rel_var_of(rname).fovars)
        assert f1 != f2, (rname, f1)
        adj[f1].append((rname, f2))
        adj[f2].append((rname, f1))

    # Connected components over the universe.
    comp_of: dict[str, int] = {}
    comps: list[tuple[str, ...]] = []
    for f in universe:
        if f in comp_of:
            continue
        stack, comp = [f], []
        comp_of[f] = len(comps)
        while stack:
            g = stack.pop()
            comp.append(g)
            for _, h in adj[g]:
                if h not in comp_of:
                    comp_of[h] = len(comps)
                    stack.append(h)
        comps.append(tuple(comp))

    # Components with more edges than a spanning tree (parallel
    # relationships, rings, diamonds, dual self-relationships) cannot be
    # contracted by leaf elimination; mark them for the ground-join path.
    n_edges_by_comp = [0] * len(comps)
    for rname in cond_true:
        f1 = cat.rel_var_of(rname).fovars[0].fid
        n_edges_by_comp[comp_of[f1]] += 1
    cyclic = frozenset(
        ci for ci, comp in enumerate(comps)
        if n_edges_by_comp[ci] > len(comp) - 1
    )

    return QueryPlan(
        universe=tuple(universe),
        ent_attrs=ent_attrs,
        rel_attrs=rel_attrs,
        adj=adj,
        comps=tuple(comps),
        comp_of=comp_of,
        restrict=restrict,
        group_fovar=group_fovar,
        cyclic=cyclic,
    )


# ---------------------------------------------------------------------------
# Join-tree contraction: CT conditioned on relationships = True
# ---------------------------------------------------------------------------


def _fold_codes(
    msg: jax.Array, cards: list[int], col: jax.Array, card: int
) -> tuple[jax.Array, list[int]]:
    """Fold a per-row code column into a (rows, C) message -> (rows, C * card).

    message'[r, c * card + col[r]] = message[r, c] — the tensor analogue of
    adding a column to the GROUP BY list.
    """
    onehot = jax.nn.one_hot(col, card, dtype=msg.dtype)  # (rows, card)
    out = msg[:, :, None] * onehot[:, None, :]
    return out.reshape(msg.shape[0], -1), cards + [card]


def _combine_messages(
    a: jax.Array, a_cards: list[int], b: jax.Array, b_cards: list[int]
) -> tuple[jax.Array, list[int]]:
    """Pointwise product over shared entity rows, code spaces concatenated."""
    out = a[:, :, None] * b[:, None, :]
    return out.reshape(a.shape[0], -1), a_cards + b_cards


GROUP_AXIS = "__group__"  # pseudo par-RV id for the target-entity axis (§VI)


def ct_conditional(
    db: RelationalDatabase,
    attr_rvs: tuple[str, ...],
    cond_true: tuple[str, ...],
    fovar_universe: tuple[str, ...] | None = None,
    *,
    impl: str = "auto",
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
) -> ContingencyTable:
    """CT over attribute par-RVs, conditioned on ``cond_true`` relationships.

    This is the paper's Figure-6 metaquery generalized to relationship
    chains/trees: the count of each joint attribute assignment among tuples
    of the first-order-variable cross product for which *all* relationships
    in ``cond_true`` hold.

    ``fovar_universe`` fixes the population cross product (needed by the
    Möbius recursion so that T- and don't-care branches count over the same
    tuple space); it defaults to the first-order variables referenced by the
    query itself.

    ``group_fovar`` implements the paper's §VI *block access*: the entity id
    of that first-order variable is added to the GROUP BY, appearing as a
    leading pseudo-axis named ``__group__`` in the result.  ``restrict``
    maps first-order variables to a single entity row (the single-instance
    ``WHERE S.s_id = jack`` baseline) — counting is restricted to groundings
    using exactly that entity.

    ``impl="sparse"`` delegates to the COO backend and returns a
    :class:`~repro.core.sparse_counts.SparseCT` (same cells, sparse storage).
    """
    if impl == "sparse":
        from .sparse_counts import sparse_ct_conditional

        return sparse_ct_conditional(
            db, attr_rvs, cond_true, fovar_universe,
            group_fovar=group_fovar, restrict=restrict,
        )

    cat = db.catalog
    plan = plan_conditional(
        db, attr_rvs, cond_true, fovar_universe,
        group_fovar=group_fovar, restrict=restrict,
    )
    if plan.cyclic:
        # Cyclic join graphs (parallel relationships, rings, diamonds) have
        # no leaf-elimination order; the sparse backend's ground join is the
        # one mechanism for them, so delegate and densify (identical cells).
        from .sparse_counts import sparse_ct_conditional

        return sparse_ct_conditional(
            db, attr_rvs, cond_true, fovar_universe,
            group_fovar=group_fovar, restrict=restrict,
        ).to_dense()
    ent_attrs, rel_attrs = plan.ent_attrs, plan.rel_attrs
    adj, comps, comp_of = plan.adj, plan.comps, plan.comp_of
    restrict = plan.restrict

    def fovar_n_rows(fid: str) -> int:
        return db.entities[cat.fovar(fid).entity].n_rows

    def initial_message(fid: str) -> tuple[jax.Array, list[int], list[str]]:
        """(n_rows, C) message with this fovar's own attribute codes folded in."""
        n = fovar_n_rows(fid)
        msg = jnp.ones((n, 1), jnp.float32)
        if fid in restrict:
            ind = (jnp.arange(n, dtype=jnp.int32) == restrict[fid]).astype(jnp.float32)
            msg = msg * ind[:, None]
        cards: list[int] = []
        folded: list[str] = []
        for rv in ent_attrs[fid]:
            msg, cards = _fold_codes(msg, cards, _entity_attr_column(db, rv), rv.cardinality)
            folded.append(rv.vid)
        return msg, cards, folded

    def finish_root(
        fid: str, msgs: list[tuple[jax.Array, list[int], list[str]]]
    ) -> tuple[jax.Array, list[int], list[str]]:
        """Contract the root's message list over its entity rows.

        For k messages M_i (n, C_i) the result is
        ``out[c_1..c_k] = sum_n prod_i M_i[n, c_i]``.  Materializing the full
        (n, prod C_i) product first is the hub blow-up (IMDb-scale joins);
        instead the messages are split into two balanced groups A, B and the
        row sum becomes one matmul A^T @ B — the MXU-native join reduction.
        For the §VI *block* path the per-entity product IS the result, so the
        group fovar keeps its row axis (families are small, so no blow-up).
        """
        msgs = [m for m in msgs if m is not None]
        if fid == group_fovar:
            msg, cards, folded = msgs[0]
            for m2, c2, f2 in msgs[1:]:
                msg, _ = _combine_messages(msg, cards, m2, c2)
                cards, folded = cards + c2, folded + f2
            return msg.reshape(-1), [msg.shape[0]] + cards, [GROUP_AXIS] + folded

        # Greedy balanced partition by code-space size.
        sizes = [int(np.prod(c)) if c else 1 for _, c, _ in msgs]
        order = np.argsort(sizes)[::-1]
        ga: list[int] = []
        gb: list[int] = []
        pa = pb = 1
        for i in order:
            if pa <= pb:
                ga.append(int(i))
                pa *= sizes[int(i)]
            else:
                gb.append(int(i))
                pb *= sizes[int(i)]

        def fold_group(idxs: list[int]):
            if not idxs:
                return None
            msg, cards, folded = msgs[idxs[0]]
            for i in idxs[1:]:
                m2, c2, f2 = msgs[i]
                msg, _ = _combine_messages(msg, cards, m2, c2)
                cards, folded = cards + c2, folded + f2
            return msg, cards, folded

        a = fold_group(ga)
        b = fold_group(gb)
        if b is None:
            msg, cards, folded = a
            return jnp.sum(msg, axis=0), cards, folded
        (ma, ca, fa), (mb, cb, fb) = a, b
        out = jnp.einsum("na,nb->ab", ma, mb).reshape(-1)
        return out, ca + cb, fa + fb

    def contract_component(comp: list[str]) -> tuple[jax.Array, list[int], list[str]]:
        """Eliminate the component down to a flat (C,) count vector."""
        if len(comp) == 1 and not adj[comp[0]]:
            msg, cards, folded = initial_message(comp[0])
            return finish_root(comp[0], [(msg, cards, folded)])

        # Per-fovar state: list of pending messages (own attrs + subtree
        # contributions).  Messages are only *combined* when a fovar is
        # eliminated through a relationship (interior nodes of chains) or at
        # the root via the balanced matmul contraction.
        state: dict[str, list[tuple[jax.Array, list[int], list[str]]]] = {
            f: [initial_message(f)] for f in comp
        }
        remaining_edges = {
            rname: tuple(f.fid for f in cat.rel_var_of(rname).fovars)
            for rname in cond_true
            if comp_of[cat.rel_var_of(rname).fovars[0].fid] == comp_of[comp[0]]
        }
        degree = {f: len(adj[f]) for f in comp}
        alive = set(comp)
        # Root choice: the group fovar if present (its rows must survive),
        # else the max-degree hub so interior combines stay small and the
        # final contraction uses the balanced matmul.
        if group_fovar in comp:
            root = group_fovar
        else:
            root = max(comp, key=lambda f: (degree[f], f))

        while len(alive) > 1:
            # pick a leaf of the join tree (tree guaranteed above)
            leaf = min(f for f in alive if degree[f] <= 1 and f != root)
            # its single remaining edge
            edge = next(
                (rn, fs) for rn, fs in remaining_edges.items() if leaf in fs
            )
            rname, (f1, f2) = edge
            other = f2 if leaf == f1 else f1
            # fold the leaf's pending messages into one (leaf-side combine)
            msg, cards, folded = state[leaf][0]
            for m2, c2, f2_ in state[leaf][1:]:
                msg, _ = _combine_messages(msg, cards, m2, c2)
                cards, folded = cards + c2, folded + f2_
            c_leaf = int(np.prod(cards)) if cards else 1
            if msg.shape[0] * c_leaf > 2**31:
                raise MemoryError(
                    f"message for {leaf} through {rname} has {msg.shape[0]}x{c_leaf} "
                    "cells; reorder the join tree or marginalize attributes earlier"
                )

            # relationship attribute codes (n/a-augmented domains; stored codes >= 1)
            r_cols: list[jax.Array] = []
            r_cards: list[int] = []
            r_names: list[str] = []
            for rv in rel_attrs[rname]:
                r_cols.append(_rel_attr_column(db, rv))
                r_cards.append(rv.cardinality)
                r_names.append(rv.vid)
            d_r = int(np.prod(r_cards)) if r_cards else 1

            fk_leaf = _rel_fk(db, rname, leaf)
            fk_other = _rel_fk(db, rname, other)
            n_other = fovar_n_rows(other)
            n_rows = int(fk_leaf.shape[0])

            out_card = c_leaf * d_r
            if n_rows == 0:
                new_msg = jnp.zeros((n_other, out_card), jnp.float32)
            else:
                # weights: leaf message gathered per relationship row
                w = msg[fk_leaf]  # (rows, c_leaf)
                # key base: other-entity row index, then leaf codes, then rel codes
                if r_cols:
                    rcode = encode_columns(r_cols, r_cards)
                else:
                    rcode = jnp.zeros((n_rows,), jnp.int32)
                base = fk_other.astype(jnp.int32) * out_card + rcode
                keys2d = base[:, None] + (
                    jnp.arange(c_leaf, dtype=jnp.int32) * d_r
                )[None, :]
                flat = ops.ct_count(
                    keys2d.reshape(-1),
                    n_other * out_card,
                    weights=w.reshape(-1),
                    impl=impl,
                )
                new_msg = flat.reshape(n_other, out_card)

            new_cards = cards + r_cards
            new_folded = folded + r_names

            state[other].append((new_msg, new_cards, new_folded))
            alive.discard(leaf)
            degree[other] -= 1
            degree[leaf] -= 1
            del remaining_edges[rname]

        assert next(iter(alive)) == root
        return finish_root(root, state[root])

    # Contract each component; combine with outer products (cross product).
    vec = jnp.ones((1,), jnp.float32)
    all_cards: list[int] = []
    all_folded: list[str] = []
    for comp in comps:
        cvec, cards, folded = contract_component(comp)
        vec = (vec[:, None] * cvec[None, :]).reshape(-1)
        all_cards += cards if cards else [1]
        all_folded += folded if folded else ["__scalar__"]

    shape = tuple(c for c in all_cards)
    tensor = vec.reshape(shape) if shape else vec.reshape(())
    # Drop the placeholder axes of attribute-less components (size 1).
    keep_axes = [i for i, v in enumerate(all_folded) if v != "__scalar__"]
    tensor = jnp.squeeze(
        tensor, axis=tuple(i for i, v in enumerate(all_folded) if v == "__scalar__")
    ) if len(keep_axes) != len(all_folded) else tensor
    folded_order = tuple(v for v in all_folded if v != "__scalar__")
    ct = ContingencyTable(folded_order, tensor)
    out_order = tuple(attr_rvs)
    if group_fovar is not None:
        out_order = (GROUP_AXIS,) + out_order
    return ct.transpose(out_order)


# ---------------------------------------------------------------------------
# Möbius virtual join: full CTs with relationship variables
# ---------------------------------------------------------------------------


def mobius_setup(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    fovar_universe: tuple[str, ...] | None,
) -> tuple[list[ParRV], list[str], list[str], tuple[str, ...], tuple[str, ...]]:
    """Shared pre-work of the Möbius recursion (dense and sparse backends).

    Returns ``(want, rel_names, added, attr_rvs, universe)``: the resolved
    par-RVs, the relationships whose indicator must be recursed over (with
    ``added`` naming the ones injected only to support their attributes), the
    non-indicator query rvs, and the fixed first-order-variable universe so
    every branch of the recursion counts over the same grounding space.
    """
    cat = db.catalog
    want = [cat[v] for v in rvs]

    rel_names: list[str] = []
    for rv in want:
        if rv.kind == KIND_REL and rv.table not in rel_names:
            rel_names.append(rv.table)
    added: list[str] = []
    for rv in want:
        if rv.kind == KIND_REL_ATTR and rv.table not in rel_names:
            rel_names.append(rv.table)
            added.append(rv.table)

    attr_rvs = tuple(v.vid for v in want if v.kind != KIND_REL)

    # Fixed population cross product for all branches of the recursion.
    # An explicit ``fovar_universe`` (e.g. *all* catalog fovars) reproduces
    # the paper's pre-counting semantics: every count is over the full
    # grounding space, so scores from different families are commensurable.
    universe: list[str] = list(fovar_universe) if fovar_universe else []
    for rv in want:
        for f in rv.fovars:
            if f.fid not in universe:
                universe.append(f.fid)
    for rname in rel_names:
        for f in cat.rel_var_of(rname).fovars:
            if f.fid not in universe:
                universe.append(f.fid)
    return want, rel_names, added, attr_rvs, tuple(universe)


def dense_cells_of(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    group_fovar: str | None = None,
) -> int:
    """Dense cell count a query would materialize (exact Python int)."""
    cat = db.catalog
    cells = math.prod(cat[v].cardinality for v in rvs) if rvs else 1
    if group_fovar is not None:
        cells *= db.entities[cat.fovar(group_fovar).entity].n_rows
    return cells


_VALID_IMPLS = ("auto", "pallas", "ref", "matmul", "sparse")


def _pick_backend(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    impl: str,
    group_fovar: str | None,
    dense_cell_budget: int | None,
) -> str:
    """"dense" or "sparse" — the auto-switch heuristic (module docstring)."""
    if impl not in _VALID_IMPLS:
        raise ValueError(f"impl must be one of {_VALID_IMPLS}, got {impl!r}")
    if impl == "sparse":
        return "sparse"
    budget = config.resolve("dense_cell_budget", dense_cell_budget)
    if impl == "auto" and dense_cells_of(db, rvs, group_fovar) > budget:
        return "sparse"
    return "dense"


def contingency_table(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    *,
    impl: str = "auto",
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
    fovar_universe: tuple[str, ...] | None = None,
    dense_cell_budget: int | None = None,
    device_resident: bool = False,
    shards: int | None = None,
) -> CTLike:
    """Full contingency table for any par-RV set (paper Fig. 3(c)).

    Relationship par-RVs become F/T axes; their attributes get ``n/a`` rows.
    Internally, any relationship whose attributes appear without its
    indicator is temporarily added, and summed out at the end.

    With ``group_fovar``, the result carries a leading ``__group__`` axis
    indexed by that entity's rows (§VI block access); with ``restrict``,
    counts cover only groundings through the given entity rows (§VI single
    access).

    Returns a dense :class:`ContingencyTable` or, when ``impl="sparse"`` is
    forced or ``impl="auto"`` finds the dense cell count above
    ``dense_cell_budget`` (default :data:`DENSE_CELL_BUDGET`), a COO
    :class:`~repro.core.sparse_counts.SparseCT` with identical cells.
    ``device_resident=True`` selects the *device-side* sparse build: the
    whole construction runs as COO code algebra on device and returns a
    :class:`~repro.core.sparse_counts.DeviceSparseCT` (bit-identical cells,
    zero host-side COO materialization — all subsequent CT algebra runs
    through ``jax.lax.sort``-based device aggregation); dense tables are
    jax arrays already, so the flag is a no-op for them.  Databases with
    fewer than :func:`device_min_rows` total tuples (``REPRO_DEVICE_MIN_ROWS``)
    ignore the flag and use the host builder — below the measured crossover
    the device build's launch overhead loses to numpy outright, and the
    cells are identical either way.  ``shards``
    row-shards the device build's fact-table scans (default: the
    ``REPRO_COO_SHARDS`` env knob) — bit-identical result, only relevant
    with ``device_resident=True``.
    """
    if _pick_backend(db, rvs, impl, group_fovar, dense_cell_budget) == "sparse":
        if device_resident and db.total_tuples >= device_min_rows():
            # Device-side build: the join-tree contraction and Möbius
            # recursion run as COO code algebra over jax.Arrays — no host
            # COO column is ever materialized, so there is no bulk h2d copy
            # of the result (ROADMAP "device-side builds").  Databases below
            # the REPRO_DEVICE_MIN_ROWS crossover skip it: at small N the
            # host lexsort build beats device launch + compile overhead
            # (bench_scale's measured crossover), so they fall through to
            # the host builder with identical cells.
            from .sparse_counts import device_sparse_contingency_table

            return device_sparse_contingency_table(
                db, rvs,
                group_fovar=group_fovar, restrict=restrict,
                fovar_universe=fovar_universe, shards=shards,
            )
        from .sparse_counts import sparse_contingency_table

        return sparse_contingency_table(
            db, rvs,
            group_fovar=group_fovar, restrict=restrict,
            fovar_universe=fovar_universe,
        )

    cat = db.catalog
    want, rel_names, added, attr_rvs, universe_t = mobius_setup(db, rvs, fovar_universe)

    g_prefix: tuple[str, ...] = (GROUP_AXIS,) if group_fovar is not None else ()

    def recurse(
        remaining: tuple[str, ...], fixed_true: tuple[str, ...], attrs: tuple[str, ...]
    ) -> ContingencyTable:
        if not remaining:
            return ct_conditional(
                db, attrs, fixed_true, universe_t, impl=impl,
                group_fovar=group_fovar, restrict=restrict,
            )
        r, rest = remaining[0], remaining[1:]
        r_attr_vids = tuple(
            v.vid for v in want if v.kind == KIND_REL_ATTR and v.table == r
        )
        t_branch = recurse(rest, fixed_true + (r,), attrs)
        star_attrs = tuple(v for v in attrs if v not in r_attr_vids)
        star_branch = recurse(rest, fixed_true, star_attrs)

        # Align on all shared axes (deeper indicators, group axis, star
        # attributes), with this relationship's attribute axes last.
        shared = tuple(v for v in t_branch.rvs if v not in r_attr_vids)
        t_ct = t_branch.transpose(shared + r_attr_vids)
        n_r_axes = len(r_attr_vids)
        t_tab = t_ct.table
        if n_r_axes:
            t_sum = jnp.sum(t_tab, axis=tuple(range(t_tab.ndim - n_r_axes, t_tab.ndim)))
        else:
            t_sum = t_tab
        star_tab = star_branch.transpose(shared).table
        f_count = star_tab - t_sum  # counts with r = False

        # Assemble: new leading axis for the relationship indicator (F=0, T=1),
        # with r-attr axes present in both branches (F-branch mass at n/a=0).
        if n_r_axes:
            r_cards = tuple(cat[v].cardinality for v in r_attr_vids)
            f_block = jnp.zeros(f_count.shape + r_cards, jnp.float32)
            idx = (Ellipsis,) + (0,) * n_r_axes
            f_block = f_block.at[idx].set(f_count)
            t_block = t_tab
            # In the T branch, n/a codes (0) are structurally impossible; the
            # histogram already returns zero there.
        else:
            f_block = f_count
            t_block = t_tab
        stacked = jnp.stack([f_block, t_block], axis=0)
        rel_vid = cat.rel_var_of(r).vid
        return ContingencyTable((rel_vid,) + shared + r_attr_vids, stacked)

    full = recurse(tuple(rel_names), (), attr_rvs)
    # Sum out indicators that were added only to support their attributes.
    if added:
        keep = g_prefix + tuple(v.vid for v in want)
        full = full.marginal(keep)
    return full.transpose(g_prefix + tuple(rvs))


def joint_contingency_table(
    db: RelationalDatabase,
    *,
    impl: str = "auto",
    dense_cell_budget: int | None = None,
    device_resident: bool = False,
    shards: int | None = None,
) -> CTLike:
    """The pre-counting joint CT over *all* par-RVs (paper §VII-B).

    This is the maximally-challenging count-manager workload: every entity
    attribute, relationship indicator and relationship attribute of the
    catalog in one table.  Local family CTs are then GROUP BY marginals
    (``.marginal`` on either backend), which is why pre-counting makes
    structure search fast.

    With ``impl="auto"`` the joint switches to the sparse COO backend once
    its dense cell count exceeds the budget — pre-counting then scales with
    the *realized* sufficient statistics (#SS) instead of the domain cross
    product.  A forced dense ``impl`` keeps the historical hard cap.

    ``device_resident=True`` *builds* a sparse joint on the device — the
    join-tree contraction and Möbius virtual join run as COO code algebra
    over ``jax.Array``s with no host-side COO materialization and no bulk
    h2d copy — after which structure search can marginalize and score it
    without any host round-trip (the ROADMAP's "device-resident COO" and
    "device-side builds" items).
    """
    vids = tuple(v.vid for v in db.catalog.par_rvs)
    if _pick_backend(db, vids, impl, None, dense_cell_budget) == "sparse":
        return contingency_table(
            db, vids, impl="sparse", device_resident=device_resident,
            shards=shards,
        )
    cells = dense_cells_of(db, vids)
    if cells > 2**28:
        raise MemoryError(
            f"joint CT would have {cells:.3g} dense cells; use impl='sparse' "
            "(COO sufficient statistics) or factored/on-demand counting "
            "(ct_conditional + contingency_table on family subsets)"
        )
    return contingency_table(db, vids, impl=impl)
