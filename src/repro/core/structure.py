"""SRL structure learning (paper §II-C Algorithm 1 + the learn-and-join search).

The generic loop — REFINECANDIDATES / LEARNPARAMETERS / argmax score — is
instantiated as greedy hill-climbing over BN edges with decomposable scores,
exactly what makes the paper's *store+score* design effective: every local
score touches only one family CT, served by the count manager from the
pre-counted joint CT (or on demand).

Scoring is **set-oriented** (§V-C): instead of scoring one candidate family
per call, each sweep enumerates every legal ADD/REMOVE/REVERSE move up
front, dedupes the touched families against the score memo, and requests
them all in one :meth:`~repro.core.score_manager.ScoreManager.score_batch`
pass — a few large device launches per sweep rather than two per candidate.
Pass a plain callable (or ``batch=False``) to fall back to serial
per-family scoring; both paths enumerate moves in the same order and apply
the same improvement threshold, so they walk the same move sequence.

``LearnAndJoin`` implements the lattice search of Schulte & Khosravi (2012)
as used in the paper's case study (§VII-B): an iterative-deepening search
over longer and longer relationship chains, where edges decided on shorter
chains are inherited as hard constraints on longer ones.  Unlike the original
implementation posted with the paper (limited to two relationship par-RVs per
par-factor), the count manager here joins arbitrary chains/trees, so the
lattice depth is a config knob — the FACTORBASE claim this reproduces.
Independent lattice nodes of a level (disjoint par-RV sets) additionally
have their opening sweeps prefetched through the same batched service.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Callable

from .bn import BayesNet
from .counts import CTLike
from .database import RelationalDatabase
from .schema import VariableCatalog
from .score_manager import CountCache, ScoreManager
from .scores import FamilyScore, score_family

__all__ = [
    "CountCache",
    "ScoreManager",
    "SearchConstraints",
    "HillClimbResult",
    "LearnAndJoinResult",
    "hill_climb",
    "learn_and_join",
    "warm_hill_climb",
]


# ---------------------------------------------------------------------------
# Hill-climbing over one node set (the single-table learner inside LAJ)
# ---------------------------------------------------------------------------


@dataclass
class SearchConstraints:
    """Edge inheritance: required edges are frozen in, forbidden edges out.

    ``decided`` pairs (unordered) were adjudicated at a lower lattice level:
    their orientation/absence is inherited and the climber must not revisit
    them (the learn-and-join constraint system).
    """

    required: frozenset[tuple[str, str]] = frozenset()
    forbidden: frozenset[tuple[str, str]] = frozenset()
    decided: frozenset[frozenset[str]] = frozenset()

    def may_add(self, p: str, c: str) -> bool:
        if (p, c) in self.forbidden:
            return False
        if frozenset((p, c)) in self.decided and (p, c) not in self.required:
            return False
        return True

    def may_remove(self, p: str, c: str) -> bool:
        return (p, c) not in self.required


@dataclass
class HillClimbResult:
    bn: BayesNet
    score: float
    n_candidates_scored: int
    seconds: float
    n_sweeps: int = 0


#: A family is ``(child, parents)``; a move is the candidate structure plus
#: the families whose local scores enter its delta (new minus old).
Family = tuple[str, tuple[str, ...]]


def _enumerate_moves(
    bn: BayesNet,
    rvs: tuple[str, ...],
    cons: SearchConstraints,
    max_parents: int,
) -> list[tuple[BayesNet, tuple[Family, ...], tuple[Family, ...]]]:
    """All legal ADD / REMOVE / REVERSE moves of one sweep, in a fixed order.

    Returns ``(candidate, new_families, old_families)`` triples; the move's
    score delta is ``sum local(new) - sum local(old)``.  Both the batched
    and the serial climber iterate this same list, so tie-breaking (first
    best move wins) is identical across scoring paths.
    """
    moves: list[tuple[BayesNet, tuple[Family, ...], tuple[Family, ...]]] = []
    # ADD
    for p, c in itertools.permutations(rvs, 2):
        if bn.has_edge(p, c) or bn.has_edge(c, p):
            continue
        if not cons.may_add(p, c):
            continue
        if len(bn.parents[c]) >= max_parents:
            continue
        cand = bn.with_edge(p, c)
        if not cand.is_acyclic():
            continue
        moves.append(
            (cand, ((c, tuple(cand.parents[c])),), ((c, tuple(bn.parents[c])),))
        )
    # REMOVE
    for p, c in bn.edges():
        if not cons.may_remove(p, c):
            continue
        cand = bn.without_edge(p, c)
        moves.append(
            (cand, ((c, tuple(cand.parents[c])),), ((c, tuple(bn.parents[c])),))
        )
    # REVERSE
    for p, c in bn.edges():
        if not cons.may_remove(p, c) or not cons.may_add(c, p):
            continue
        if len(bn.parents[p]) >= max_parents:
            continue
        cand = bn.reversed_edge(p, c)
        if not cand.is_acyclic():
            continue
        moves.append(
            (
                cand,
                ((c, tuple(cand.parents[c])), (p, tuple(cand.parents[p]))),
                ((c, tuple(bn.parents[c])), (p, tuple(bn.parents[p]))),
            )
        )
    return moves


#: Relative score margin a move must win by — against the current structure
#: to be applied at all, and against the incumbent best move to displace it.
#: Scaled by the magnitude of the *local* family scores entering the move's
#: delta (NOT the global structure score, which grows with the number of
#: par-RVs while per-move deltas do not) and set above float32
#: kernel-reduction noise, so the greedy walk is invariant to *how* a family
#: was scored (batched stack vs single-family kernel differ only in
#: summation order): analytic ties (e.g. the two orientations of a first
#: edge) fall to the first-enumerated move on every scoring path instead of
#: to whichever accumulated the luckier rounding.
_MIN_DELTA_REL = 1e-6


def hill_climb(
    rvs: tuple[str, ...],
    counts_of: Callable[[tuple[str, ...]], CTLike],
    *,
    score: str = "aic",
    alpha: float = 0.0,
    max_parents: int = 3,
    constraints: SearchConstraints | None = None,
    n_groundings: float | None = None,
    impl: str = "auto",
    init: BayesNet | None = None,
    batch: bool = True,
) -> HillClimbResult:
    """Greedy add/delete/reverse edge search with decomposable local scores.

    Only the one or two families touched by a move are re-scored; local
    scores are memoized by (child, sorted parents) — the paper's store+score
    design.  When ``counts_of`` is a :class:`ScoreManager` (and ``batch``
    is left on), every sweep's candidate families are scored in ONE
    set-oriented :meth:`~ScoreManager.score_batch` pass; otherwise each
    family is scored on demand through :func:`~repro.core.scores.
    score_family` (the serial baseline).
    """
    if score not in ("aic", "bic", "loglik"):
        raise ValueError(f"score must be one of aic|bic|loglik, got {score!r}")
    if score == "bic" and n_groundings is None:
        raise ValueError(
            "score='bic' requires n_groundings (the grounding count N in the "
            "-0.5 * #params * ln N penalty); learn_and_join passes "
            "db.total_tuples automatically"
        )
    t0 = time.perf_counter()
    cons = constraints or SearchConstraints()
    bn = init if init is not None else BayesNet.empty(rvs)
    for p, c in cons.required:
        if not bn.has_edge(p, c):
            bn = bn.with_edge(p, c)
    assert bn.is_acyclic(), "required edges form a cycle"

    mgr = counts_of if (batch and isinstance(counts_of, ScoreManager)) else None
    local_memo: dict[tuple[str, tuple[str, ...]], FamilyScore] = {}
    n_scored = 0
    mgr_scored0 = mgr.n_scored_families if mgr is not None else 0

    def family_score(child: str, parents: tuple[str, ...]) -> FamilyScore:
        nonlocal n_scored
        key = (child, tuple(sorted(parents)))
        if mgr is not None:
            return mgr.score_one(child, key[1], alpha, impl=impl)
        if key not in local_memo:
            local_memo[key] = score_family(counts_of, child, key[1], alpha, impl=impl)
            n_scored += 1
        return local_memo[key]

    def local(child: str, parents: tuple[str, ...]) -> float:
        fs = family_score(child, parents)
        if score == "aic":
            return fs.aic()
        if score == "bic":
            return fs.bic(n_groundings)
        return fs.loglik

    init_fams = [(c, tuple(bn.parents[c])) for c in rvs]
    if mgr is not None and init_fams:
        mgr.score_batch(init_fams, alpha, impl=impl)
    cur_score = sum(local(c, ps) for c, ps in init_fams)

    n_sweeps = 0
    while True:
        n_sweeps += 1
        moves = _enumerate_moves(bn, rvs, cons, max_parents)
        if mgr is not None and moves:
            # the set-oriented pass: every family any move of this sweep
            # touches, deduped against the memo, scored in one batch
            mgr.score_batch(
                [f for _, new, old in moves for f in new + old], alpha, impl=impl
            )
        best_delta, best_bn, best_margin = 0.0, None, 1e-9
        for cand, new_fams, old_fams in moves:
            vals_new = [local(c, ps) for c, ps in new_fams]
            vals_old = [local(c, ps) for c, ps in old_fams]
            delta = sum(vals_new) - sum(vals_old)
            margin = max(
                1e-9,
                _MIN_DELTA_REL * max(abs(v) for v in vals_new + vals_old),
            )
            if delta > best_delta + max(margin, best_margin):
                best_delta, best_bn, best_margin = delta, cand, margin
        if best_bn is None:
            break
        bn = best_bn
        cur_score += best_delta

    if mgr is not None:
        n_scored = mgr.n_scored_families - mgr_scored0
    return HillClimbResult(bn, cur_score, n_scored, time.perf_counter() - t0, n_sweeps)


def warm_hill_climb(
    prev: BayesNet,
    counts_of: Callable[[tuple[str, ...]], CTLike],
    *,
    score: str = "aic",
    alpha: float = 0.0,
    max_parents: int = 3,
    constraints: SearchConstraints | None = None,
    n_groundings: float | None = None,
    impl: str = "auto",
    batch: bool = True,
) -> HillClimbResult:
    """Re-search after a delta: restart hill-climb from the previous graph.

    The incremental-maintenance companion of :meth:`~repro.core.
    score_manager.ScoreManager.apply_delta`: pass the manager whose memo the
    dirty-set refresh just pruned and the previously learned network.  A
    small delta leaves the score landscape almost unchanged, so the climb
    starting at ``prev`` (instead of the empty graph) re-scores only the
    dirty families plus the moves around them and typically converges in a
    sweep or two — the greedy walk itself is unchanged, so if the optimum
    moved, the search still follows the score gradient to the new one.
    Equivalent to ``hill_climb(prev.rvs, ..., init=prev)``.
    """
    return hill_climb(
        tuple(prev.rvs), counts_of, score=score, alpha=alpha,
        max_parents=max_parents, constraints=constraints,
        n_groundings=n_groundings, impl=impl, init=prev, batch=batch,
    )


# ---------------------------------------------------------------------------
# Learn-and-join lattice search
# ---------------------------------------------------------------------------


@dataclass
class LatticeNode:
    rels: tuple[str, ...]          # relationship chain (sorted)
    rvs: tuple[str, ...]           # par-RVs visible at this node
    level: int


def _rel_chains(cat: VariableCatalog, max_len: int) -> list[list[str]]:
    """Connected relationship subsets (chains/trees in the FO-var graph)."""
    rels = [v.table for v in cat.rel_vars]
    chains: list[list[str]] = [[r] for r in rels]
    seen = {frozenset((r,)) for r in rels}
    frontier = [[r] for r in rels]
    for _ in range(2, max_len + 1):
        nxt = []
        for chain in frontier:
            fovars = set()
            for r in chain:
                fovars |= {f.fid for f in cat.rel_var_of(r).fovars}
            for r in rels:
                if r in chain:
                    continue
                rf = {f.fid for f in cat.rel_var_of(r).fovars}
                if not (rf & fovars):
                    continue
                key = frozenset(chain + [r])
                if key in seen:
                    continue
                seen.add(key)
                ext = sorted(chain + [r])
                nxt.append(ext)
                chains.append(ext)
        frontier = nxt
        if not frontier:
            break
    return chains


@dataclass
class LearnAndJoinResult:
    bn: BayesNet
    per_level_seconds: dict[int, float]
    n_candidates_scored: int
    n_lattice_nodes: int
    seconds: float
    n_sweeps: int = 0


def _prefetch_level(
    mgr: ScoreManager,
    nodes: list[tuple[tuple[str, ...], set[tuple[str, str]]]],
    required: set[tuple[str, str]],
    decided: set[frozenset[str]],
    alpha: float,
    impl: str,
    max_parents: int,
) -> None:
    """Batch the opening sweeps of one lattice level through the service.

    Family scores are context-free (counts range over the full catalog
    universe), so prefetching is always sound; what varies per node is
    *which* families its sweeps request.  Initial families are requested
    for every node.  First-sweep move families are prefetched only for
    nodes whose par-RV set is disjoint from all earlier nodes of the same
    level — those are the independent lattice nodes: same-level
    adjudication cannot constrain their move set, so the prefetch is exact
    (level 0's per-entity-table nodes always qualify).
    """
    fams: list[Family] = []
    prev_rvs: list[set[str]] = []
    for rvs, extra_req in nodes:
        req = {(p, c) for (p, c) in required | extra_req if p in rvs and c in rvs}
        bn = BayesNet.empty(rvs)
        for p, c in req:
            if not bn.has_edge(p, c):
                bn = bn.with_edge(p, c)
        if not bn.is_acyclic():
            prev_rvs.append(set(rvs))
            continue
        fams.extend((c, tuple(bn.parents[c])) for c in rvs)
        if all(not (set(rvs) & s) for s in prev_rvs):
            cons = SearchConstraints(
                required=frozenset(req),
                decided=frozenset(
                    {pc for pc in decided if all(v in rvs for v in pc)}
                ),
            )
            for _, new, old in _enumerate_moves(bn, rvs, cons, max_parents):
                fams.extend(new)
                fams.extend(old)
        prev_rvs.append(set(rvs))
    if fams:
        mgr.score_batch(fams, alpha, impl=impl)


def learn_and_join(
    db: RelationalDatabase,
    counts_of: Callable[[tuple[str, ...]], CTLike],
    *,
    score: str = "aic",
    alpha: float = 0.0,
    max_parents: int = 3,
    max_chain: int = 2,
    impl: str = "auto",
    batch: bool = True,
) -> LearnAndJoinResult:
    """The LAJ algorithm (§VII-B): iterative deepening over relationship chains.

    Level 0: one BN per entity table over its attribute par-RVs.
    Level k: one BN per connected relationship chain of length k, over the
    entity attributes of the chain's first-order variables plus the chain's
    relationship indicators and attributes.  Edges adjudicated at lower
    levels are inherited (required if present, forbidden if absent between
    already-seen node pairs).  The final model is the union of the maximal
    chains' BNs.

    With a :class:`ScoreManager` (and ``batch`` on), each level's
    independent nodes have their opening sweeps scored in one batched pass
    before any node runs, and the manager's score memo is shared across
    nodes — families recurring between lattice nodes are never re-scored.

    Standard LAJ constraints enforced here:
      * a relationship indicator is a required parent of each of its
        descriptive attributes (the n/a pattern is deterministic given R=F);
      * entity attributes may not be children of relationship attributes
        across levels unless the edge was learned at this level (we keep the
        simpler inherited-edge rule, which subsumes the common cases).
    """
    t0 = time.perf_counter()
    cat = db.catalog
    per_level: dict[int, float] = {}
    n_scored = 0
    n_sweeps = 0

    required: set[tuple[str, str]] = set()
    decided: set[frozenset[str]] = set()
    mgr = counts_of if (batch and isinstance(counts_of, ScoreManager)) else None

    def run_node(rvs: tuple[str, ...], extra_required: set[tuple[str, str]]) -> BayesNet:
        nonlocal n_scored, n_sweeps
        cons = SearchConstraints(
            required=frozenset(
                {(p, c) for (p, c) in required | extra_required if p in rvs and c in rvs}
            ),
            forbidden=frozenset(),
            decided=frozenset(
                {pc for pc in decided if all(v in rvs for v in pc)}
            ),
        )
        res = hill_climb(
            rvs,
            counts_of,
            score=score,
            alpha=alpha,
            max_parents=max_parents,
            constraints=cons,
            n_groundings=float(db.total_tuples),
            impl=impl,
            batch=batch,
        )
        n_scored += res.n_candidates_scored
        n_sweeps += res.n_sweeps
        return res.bn

    def adjudicate(bn: BayesNet) -> None:
        """Freeze this node's decisions for higher lattice levels."""
        for p, c in bn.edges():
            required.add((p, c))
        for a, b in itertools.combinations(bn.rvs, 2):
            decided.add(frozenset((a, b)))

    # ---- level 0: entity tables --------------------------------------------
    lvl_t = time.perf_counter()
    level_bns: list[BayesNet] = []
    nodes0: list[tuple[tuple[str, ...], set[tuple[str, str]]]] = []
    for fovar in cat.fovars:
        rvs = tuple(v.vid for v in cat.attrs_of_fovar(fovar.fid))
        if len(rvs) < 1:
            continue
        nodes0.append((rvs, set()))
    if mgr is not None:
        before = mgr.n_scored_families
        _prefetch_level(mgr, nodes0, required, decided, alpha, impl, max_parents)
        n_scored += mgr.n_scored_families - before
    for rvs, extra_req in nodes0:
        bn = run_node(rvs, extra_req)
        adjudicate(bn)
        level_bns.append(bn)
    per_level[0] = time.perf_counter() - lvl_t

    # ---- levels 1..max_chain: relationship chains --------------------------
    chains = _rel_chains(cat, max_chain)
    n_nodes = len(chains) + len(level_bns)
    final_bns: dict[frozenset[str], BayesNet] = {}
    for level in range(1, max_chain + 1):
        lvl_t = time.perf_counter()
        level_nodes: list[tuple[list[str], tuple[str, ...], set[tuple[str, str]]]] = []
        for chain in [c for c in chains if len(c) == level]:
            rvs: list[str] = []
            extra_req: set[tuple[str, str]] = set()
            fovars: list[str] = []
            for r in chain:
                rv = cat.rel_var_of(r)
                rvs.append(rv.vid)
                for f in rv.fovars:
                    if f.fid not in fovars:
                        fovars.append(f.fid)
                for a in cat.attrs_of_rel(r):
                    rvs.append(a.vid)
                    extra_req.add((rv.vid, a.vid))  # R -> its attributes
            for f in fovars:
                rvs.extend(v.vid for v in cat.attrs_of_fovar(f))
            level_nodes.append((chain, tuple(dict.fromkeys(rvs)), extra_req))
        if mgr is not None:
            before = mgr.n_scored_families
            _prefetch_level(
                mgr,
                [(rvs_t, extra_req) for _, rvs_t, extra_req in level_nodes],
                required, decided, alpha, impl, max_parents,
            )
            n_scored += mgr.n_scored_families - before
        for chain, rvs_t, extra_req in level_nodes:
            bn = run_node(rvs_t, extra_req)
            adjudicate(bn)
            final_bns[frozenset(chain)] = bn
        per_level[level] = time.perf_counter() - lvl_t

    # ---- union of maximal-chain BNs (+ entity BNs for isolated attributes) --
    maximal = [
        key for key in final_bns
        if not any(key < other for other in final_bns)
    ]
    model = BayesNet.empty(())
    for bn in level_bns:
        model = model.union(bn)
    for key in maximal:
        model = model.union(final_bns[key])
    assert model.is_acyclic(), "learn-and-join union must stay acyclic"

    return LearnAndJoinResult(
        bn=model,
        per_level_seconds=per_level,
        n_candidates_scored=n_scored,
        n_lattice_nodes=n_nodes,
        seconds=time.perf_counter() - t0,
        n_sweeps=n_sweeps,
    )
