"""SRL structure learning (paper §II-C Algorithm 1 + the learn-and-join search).

The generic loop — REFINECANDIDATES / LEARNPARAMETERS / argmax score — is
instantiated as greedy hill-climbing over BN edges with decomposable scores,
exactly what makes the paper's *store+score* design effective: every local
score touches only one family CT, served by the count manager from the
pre-counted joint CT (or on demand).

``LearnAndJoin`` implements the lattice search of Schulte & Khosravi (2012)
as used in the paper's case study (§VII-B): an iterative-deepening search
over longer and longer relationship chains, where edges decided on shorter
chains are inherited as hard constraints on longer ones.  Unlike the original
implementation posted with the paper (limited to two relationship par-RVs per
par-factor), the count manager here joins arbitrary chains/trees, so the
lattice depth is a config knob — the FACTORBASE claim this reproduces.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable

from .bn import BayesNet
from .counts import CTLike, ContingencyTable, contingency_table, joint_contingency_table
from .database import RelationalDatabase
from .schema import KIND_ENTITY_ATTR, KIND_REL, KIND_REL_ATTR, VariableCatalog
from .scores import FamilyScore, score_family


# ---------------------------------------------------------------------------
# Count cache — the CDB service used by the search
# ---------------------------------------------------------------------------


class CountCache:
    """Serves family CTs, either from a pre-counted joint CT or on demand.

    ``mode="precount"`` reproduces the paper's evaluation choice (§VII-B):
    one maximally-hard joint CT build, then every family CT is a cheap
    GROUP BY marginal.  ``mode="ondemand"`` counts each distinct family once
    (memoized) — the alternative the paper contrasts with.  The
    ``instance-loop`` baseline in the benchmarks disables the memo.
    ``mode="sparse"`` is pre-counting on the COO backend: the joint is a
    :class:`~repro.core.sparse_counts.SparseCT` (no dense-cell cap — storage
    is #SS), and every served family CT is a sparse marginal.  Passing
    ``impl="sparse"`` to the other modes routes their queries through the
    sparse backend as well.

    Bookkeeping counters: ``n_queries`` increments on every call;
    ``n_materializations`` increments each time a CT is actually *built*
    from the database (the pre-counted joint counts as one; memo hits and
    joint marginals are not materializations).
    """

    def __init__(
        self,
        db: RelationalDatabase,
        mode: str = "precount",
        *,
        impl: str = "auto",
        memoize: bool = True,
    ):
        assert mode in ("precount", "ondemand", "sparse")
        self.db = db
        self.mode = mode
        self.impl = "sparse" if mode == "sparse" else impl
        self.memoize = memoize
        self._memo: dict[tuple[str, ...], CTLike] = {}
        self.n_queries = 0
        self.n_materializations = 0
        self.joint: CTLike | None = None
        if mode in ("precount", "sparse"):
            self.joint = joint_contingency_table(db, impl=self.impl)
            self.n_materializations += 1

    def __call__(self, rvs: tuple[str, ...]) -> CTLike:
        self.n_queries += 1
        key = tuple(sorted(rvs))
        if self.memoize and key in self._memo:
            return self._memo[key].transpose(tuple(rvs))
        if self.joint is not None:
            ct = self.joint.marginal(tuple(rvs))
        else:
            # count over the FULL catalog universe so on-demand counts are
            # cell-identical to pre-counted joint-CT marginals
            universe = tuple(f.fid for f in self.db.catalog.fovars)
            ct = contingency_table(
                self.db, tuple(rvs), impl=self.impl, fovar_universe=universe
            )
            self.n_materializations += 1
        if self.memoize:
            self._memo[key] = ct
        return ct


# ---------------------------------------------------------------------------
# Hill-climbing over one node set (the single-table learner inside LAJ)
# ---------------------------------------------------------------------------


@dataclass
class SearchConstraints:
    """Edge inheritance: required edges are frozen in, forbidden edges out.

    ``decided`` pairs (unordered) were adjudicated at a lower lattice level:
    their orientation/absence is inherited and the climber must not revisit
    them (the learn-and-join constraint system).
    """

    required: frozenset[tuple[str, str]] = frozenset()
    forbidden: frozenset[tuple[str, str]] = frozenset()
    decided: frozenset[frozenset[str]] = frozenset()

    def may_add(self, p: str, c: str) -> bool:
        if (p, c) in self.forbidden:
            return False
        if frozenset((p, c)) in self.decided and (p, c) not in self.required:
            return False
        return True

    def may_remove(self, p: str, c: str) -> bool:
        return (p, c) not in self.required


@dataclass
class HillClimbResult:
    bn: BayesNet
    score: float
    n_candidates_scored: int
    seconds: float


def hill_climb(
    rvs: tuple[str, ...],
    counts_of: Callable[[tuple[str, ...]], CTLike],
    *,
    score: str = "aic",
    alpha: float = 0.0,
    max_parents: int = 3,
    constraints: SearchConstraints | None = None,
    n_groundings: float | None = None,
    impl: str = "auto",
    init: BayesNet | None = None,
) -> HillClimbResult:
    """Greedy add/delete/reverse edge search with decomposable local scores.

    Only the one or two families touched by a move are re-scored; local
    scores are memoized by (child, parents) — the paper's store+score design.
    """
    t0 = time.perf_counter()
    cons = constraints or SearchConstraints()
    bn = init if init is not None else BayesNet.empty(rvs)
    for p, c in cons.required:
        if not bn.has_edge(p, c):
            bn = bn.with_edge(p, c)
    assert bn.is_acyclic(), "required edges form a cycle"

    local_memo: dict[tuple[str, tuple[str, ...]], FamilyScore] = {}
    n_scored = 0

    def local(child: str, parents: tuple[str, ...]) -> float:
        nonlocal n_scored
        key = (child, tuple(sorted(parents)))
        if key not in local_memo:
            fs = score_family(counts_of, child, parents, alpha, impl=impl)
            local_memo[key] = fs
            n_scored += 1
        fs = local_memo[key]
        if score == "aic":
            return fs.aic()
        if score == "bic":
            assert n_groundings is not None
            return fs.bic(n_groundings)
        if score == "loglik":
            return fs.loglik
        raise ValueError(score)

    def total(b: BayesNet) -> float:
        return sum(local(c, tuple(b.parents[c])) for c in b.rvs)

    cur_score = total(bn)

    while True:
        best_delta = 1e-9
        best_bn = None
        # ADD
        for p, c in itertools.permutations(rvs, 2):
            if bn.has_edge(p, c) or bn.has_edge(c, p):
                continue
            if not cons.may_add(p, c):
                continue
            if len(bn.parents[c]) >= max_parents:
                continue
            cand = bn.with_edge(p, c)
            if not cand.is_acyclic():
                continue
            delta = local(c, tuple(cand.parents[c])) - local(c, tuple(bn.parents[c]))
            if delta > best_delta:
                best_delta, best_bn = delta, cand
        # REMOVE
        for p, c in bn.edges():
            if not cons.may_remove(p, c):
                continue
            cand = bn.without_edge(p, c)
            delta = local(c, tuple(cand.parents[c])) - local(c, tuple(bn.parents[c]))
            if delta > best_delta:
                best_delta, best_bn = delta, cand
        # REVERSE
        for p, c in bn.edges():
            if not cons.may_remove(p, c) or not cons.may_add(c, p):
                continue
            if len(bn.parents[p]) >= max_parents:
                continue
            cand = bn.reversed_edge(p, c)
            if not cand.is_acyclic():
                continue
            delta = (
                local(c, tuple(cand.parents[c]))
                + local(p, tuple(cand.parents[p]))
                - local(c, tuple(bn.parents[c]))
                - local(p, tuple(bn.parents[p]))
            )
            if delta > best_delta:
                best_delta, best_bn = delta, cand

        if best_bn is None:
            break
        bn = best_bn
        cur_score += best_delta

    return HillClimbResult(bn, cur_score, n_scored, time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Learn-and-join lattice search
# ---------------------------------------------------------------------------


@dataclass
class LatticeNode:
    rels: tuple[str, ...]          # relationship chain (sorted)
    rvs: tuple[str, ...]           # par-RVs visible at this node
    level: int


def _rel_chains(cat: VariableCatalog, max_len: int) -> list[list[str]]:
    """Connected relationship subsets (chains/trees in the FO-var graph)."""
    rels = [v.table for v in cat.rel_vars]
    chains: list[list[str]] = [[r] for r in rels]
    seen = {frozenset((r,)) for r in rels}
    frontier = [[r] for r in rels]
    for _ in range(2, max_len + 1):
        nxt = []
        for chain in frontier:
            fovars = set()
            for r in chain:
                fovars |= {f.fid for f in cat.rel_var_of(r).fovars}
            for r in rels:
                if r in chain:
                    continue
                rf = {f.fid for f in cat.rel_var_of(r).fovars}
                if not (rf & fovars):
                    continue
                key = frozenset(chain + [r])
                if key in seen:
                    continue
                seen.add(key)
                ext = sorted(chain + [r])
                nxt.append(ext)
                chains.append(ext)
        frontier = nxt
        if not frontier:
            break
    return chains


@dataclass
class LearnAndJoinResult:
    bn: BayesNet
    per_level_seconds: dict[int, float]
    n_candidates_scored: int
    n_lattice_nodes: int
    seconds: float


def learn_and_join(
    db: RelationalDatabase,
    counts_of: Callable[[tuple[str, ...]], CTLike],
    *,
    score: str = "aic",
    alpha: float = 0.0,
    max_parents: int = 3,
    max_chain: int = 2,
    impl: str = "auto",
) -> LearnAndJoinResult:
    """The LAJ algorithm (§VII-B): iterative deepening over relationship chains.

    Level 0: one BN per entity table over its attribute par-RVs.
    Level k: one BN per connected relationship chain of length k, over the
    entity attributes of the chain's first-order variables plus the chain's
    relationship indicators and attributes.  Edges adjudicated at lower
    levels are inherited (required if present, forbidden if absent between
    already-seen node pairs).  The final model is the union of the maximal
    chains' BNs.

    Standard LAJ constraints enforced here:
      * a relationship indicator is a required parent of each of its
        descriptive attributes (the n/a pattern is deterministic given R=F);
      * entity attributes may not be children of relationship attributes
        across levels unless the edge was learned at this level (we keep the
        simpler inherited-edge rule, which subsumes the common cases).
    """
    t0 = time.perf_counter()
    cat = db.catalog
    per_level: dict[int, float] = {}
    n_scored = 0

    required: set[tuple[str, str]] = set()
    decided: set[frozenset[str]] = set()

    def run_node(rvs: tuple[str, ...], extra_required: set[tuple[str, str]]) -> BayesNet:
        nonlocal n_scored
        cons = SearchConstraints(
            required=frozenset(
                {(p, c) for (p, c) in required | extra_required if p in rvs and c in rvs}
            ),
            forbidden=frozenset(),
            decided=frozenset(
                {pc for pc in decided if all(v in rvs for v in pc)}
            ),
        )
        res = hill_climb(
            rvs,
            counts_of,
            score=score,
            alpha=alpha,
            max_parents=max_parents,
            constraints=cons,
            n_groundings=float(db.total_tuples),
            impl=impl,
        )
        n_scored += res.n_candidates_scored
        return res.bn

    def adjudicate(bn: BayesNet) -> None:
        """Freeze this node's decisions for higher lattice levels."""
        for p, c in bn.edges():
            required.add((p, c))
        for a, b in itertools.combinations(bn.rvs, 2):
            decided.add(frozenset((a, b)))

    # ---- level 0: entity tables --------------------------------------------
    lvl_t = time.perf_counter()
    level_bns: list[BayesNet] = []
    for fovar in cat.fovars:
        rvs = tuple(v.vid for v in cat.attrs_of_fovar(fovar.fid))
        if len(rvs) < 1:
            continue
        bn = run_node(rvs, set())
        adjudicate(bn)
        level_bns.append(bn)
    per_level[0] = time.perf_counter() - lvl_t

    # ---- levels 1..max_chain: relationship chains --------------------------
    chains = _rel_chains(cat, max_chain)
    n_nodes = len(chains) + len(level_bns)
    final_bns: dict[frozenset[str], BayesNet] = {}
    for level in range(1, max_chain + 1):
        lvl_t = time.perf_counter()
        for chain in [c for c in chains if len(c) == level]:
            rvs: list[str] = []
            extra_req: set[tuple[str, str]] = set()
            fovars: list[str] = []
            for r in chain:
                rv = cat.rel_var_of(r)
                rvs.append(rv.vid)
                for f in rv.fovars:
                    if f.fid not in fovars:
                        fovars.append(f.fid)
                for a in cat.attrs_of_rel(r):
                    rvs.append(a.vid)
                    extra_req.add((rv.vid, a.vid))  # R -> its attributes
            for f in fovars:
                rvs.extend(v.vid for v in cat.attrs_of_fovar(f))
            bn = run_node(tuple(dict.fromkeys(rvs)), extra_req)
            adjudicate(bn)
            final_bns[frozenset(chain)] = bn
        per_level[level] = time.perf_counter() - lvl_t

    # ---- union of maximal-chain BNs (+ entity BNs for isolated attributes) --
    maximal = [
        key for key in final_bns
        if not any(key < other for other in final_bns)
    ]
    model = BayesNet.empty(())
    for bn in level_bns:
        model = model.union(bn)
    for key in maximal:
        model = model.union(final_bns[key])
    assert model.is_acyclic(), "learn-and-join union must stay acyclic"

    return LearnAndJoinResult(
        bn=model,
        per_level_seconds=per_level,
        n_candidates_scored=n_scored,
        n_lattice_nodes=n_nodes,
        seconds=time.perf_counter() - t0,
    )
