"""Relational schema + par-RV catalog — the paper's Random Variable Database (VDB).

FactorBase §III: the *schema analyzer* reads key constraints from the system
catalog and automatically produces metadata about the parametrized random
variables (par-RVs) of the statistical model:

    Entity set            ->  first-order variable       (``S``, ``P``)
    Entity attribute      ->  unary par-RV               (``Intelligence(S)``)
    Relationship set      ->  boolean par-RV              (``RA(P,S)``)
    Relationship attribute->  binary par-RV               (``Salary(P,S)``)

In the RDBMS this metadata lives in tables (``Relationship``, ``AttributeColumns``,
``Domain``, ...).  Here it lives in :class:`VariableCatalog`, a frozen, hashable
object that plays the same role: every downstream module (count manager, model
manager, structure search, prediction) is *driven by this metadata*, never by
hard-coded table knowledge — the JAX analogue of the paper's metaqueries.

Only finite domains are supported (as in the paper).  Relationship attributes
get the distinguished value ``N_A`` at code 0, used when the relationship does
not hold (paper §III, following Milch et al.'s BLOG convention).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

N_A = "n/a"  # distinguished "undefined" value for relationship attributes


# ---------------------------------------------------------------------------
# Schema declarations (the analogue of CREATE TABLE + key constraints)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EntityDecl:
    """An entity table: implicit primary key = row index, finite-domain attributes."""

    name: str
    attributes: tuple[tuple[str, tuple[str, ...]], ...]  # (attr_name, domain values)

    def domain(self, attr: str) -> tuple[str, ...]:
        for a, dom in self.attributes:
            if a == attr:
                return dom
        raise KeyError(f"entity {self.name!r} has no attribute {attr!r}")


@dataclass(frozen=True)
class RelationshipDecl:
    """A binary relationship table (paper footnote 2: relationships are binary).

    ``entities`` names the two referenced entity tables; a *self-relationship*
    (e.g. ``Borders(Country, Country)``) repeats the same name and yields two
    first-order variables over the same population.
    """

    name: str
    entities: tuple[str, str]
    attributes: tuple[tuple[str, tuple[str, ...]], ...] = ()

    @property
    def is_self(self) -> bool:
        return self.entities[0] == self.entities[1]

    def domain(self, attr: str) -> tuple[str, ...]:
        for a, dom in self.attributes:
            if a == attr:
                return dom
        raise KeyError(f"relationship {self.name!r} has no attribute {attr!r}")


@dataclass(frozen=True)
class RelationalSchema:
    entities: tuple[EntityDecl, ...]
    relationships: tuple[RelationshipDecl, ...]

    def entity(self, name: str) -> EntityDecl:
        for e in self.entities:
            if e.name == name:
                return e
        raise KeyError(f"no entity table {name!r}")

    def relationship(self, name: str) -> RelationshipDecl:
        for r in self.relationships:
            if r.name == name:
                return r
        raise KeyError(f"no relationship table {name!r}")

    def validate(self) -> None:
        names = [e.name for e in self.entities] + [r.name for r in self.relationships]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate table names in schema: {names}")
        for r in self.relationships:
            for en in r.entities:
                self.entity(en)  # raises if missing
        for e in self.entities:
            for _, dom in e.attributes:
                if len(dom) < 2:
                    raise ValueError(f"attribute domains need >=2 values, got {dom}")
        for r in self.relationships:
            for a, dom in r.attributes:
                if N_A in dom:
                    raise ValueError(
                        f"{r.name}.{a}: do not declare {N_A!r}; it is added automatically"
                    )


def make_schema(
    entities: Mapping[str, Mapping[str, Sequence[str]]],
    relationships: Mapping[str, tuple[tuple[str, str], Mapping[str, Sequence[str]]]],
) -> RelationalSchema:
    """Convenience constructor from plain dicts (used by tests and generators)."""
    ents = tuple(
        EntityDecl(name, tuple((a, tuple(dom)) for a, dom in attrs.items()))
        for name, attrs in entities.items()
    )
    rels = tuple(
        RelationshipDecl(name, ents_pair, tuple((a, tuple(dom)) for a, dom in attrs.items()))
        for name, (ents_pair, attrs) in relationships.items()
    )
    schema = RelationalSchema(ents, rels)
    schema.validate()
    return schema


# ---------------------------------------------------------------------------
# par-RVs (the VDB rows)
# ---------------------------------------------------------------------------

KIND_ENTITY_ATTR = "entity_attr"  # 1Variables in the paper's VDB schema
KIND_REL = "rel"                  # Relationship
KIND_REL_ATTR = "rel_attr"        # 2Variables


@dataclass(frozen=True)
class FirstOrderVar:
    """A typed first-order variable, e.g. ``S0`` ranging over students."""

    fid: str          # "student0"
    entity: str       # "student"
    index: int        # 0 normally; 1 for the second copy in a self-relationship


@dataclass(frozen=True)
class ParRV:
    """One parametrized random variable with its finite domain.

    ``domain[i]`` is the value with integer code ``i``; all tensor layers work
    in codes and only the catalog can decode back to labels.
    """

    vid: str                         # e.g. "intelligence(student0)"
    kind: str                        # one of the KIND_* constants
    domain: tuple[str, ...]
    fovars: tuple[FirstOrderVar, ...]
    table: str                       # source table name
    column: str | None = None        # source column (None for relationship par-RVs)

    @property
    def arity(self) -> int:
        return len(self.fovars)

    @property
    def cardinality(self) -> int:
        return len(self.domain)

    def code(self, value: str) -> int:
        return self.domain.index(value)

    def __repr__(self) -> str:  # keep test output readable
        return f"ParRV({self.vid})"


@dataclass(frozen=True)
class VariableCatalog:
    """The Random Variable Database: all par-RVs derived from a schema."""

    schema: RelationalSchema
    par_rvs: tuple[ParRV, ...]
    fovars: tuple[FirstOrderVar, ...]

    def __getitem__(self, vid: str) -> ParRV:
        for v in self.par_rvs:
            if v.vid == vid:
                return v
        raise KeyError(f"no par-RV {vid!r}")

    def of_kind(self, kind: str) -> tuple[ParRV, ...]:
        return tuple(v for v in self.par_rvs if v.kind == kind)

    @property
    def entity_attrs(self) -> tuple[ParRV, ...]:
        return self.of_kind(KIND_ENTITY_ATTR)

    @property
    def rel_vars(self) -> tuple[ParRV, ...]:
        return self.of_kind(KIND_REL)

    @property
    def rel_attrs(self) -> tuple[ParRV, ...]:
        return self.of_kind(KIND_REL_ATTR)

    def rel_var_of(self, rel_name: str) -> ParRV:
        for v in self.rel_vars:
            if v.table == rel_name:
                return v
        raise KeyError(f"no relationship par-RV for table {rel_name!r}")

    def attrs_of_rel(self, rel_name: str) -> tuple[ParRV, ...]:
        return tuple(v for v in self.rel_attrs if v.table == rel_name)

    def attrs_of_fovar(self, fid: str) -> tuple[ParRV, ...]:
        return tuple(
            v for v in self.entity_attrs if v.fovars[0].fid == fid
        )

    def fovar(self, fid: str) -> FirstOrderVar:
        for f in self.fovars:
            if f.fid == fid:
                return f
        raise KeyError(f"no first-order variable {fid!r}")


def _fovar_id(entity: str, index: int) -> str:
    return f"{entity}{index}"


def analyze_schema(schema: RelationalSchema) -> VariableCatalog:
    """The schema analyzer (paper §III + Appendix): schema -> VDB.

    Mirrors the MySQL ``AchemaAnalyzer.sql`` pipeline: discover first-order
    variables from entity tables (two copies for populations that appear on
    both sides of a self-relationship), then emit 1Variables (entity
    attributes), Relationship par-RVs, and 2Variables (relationship
    attributes) with the ``n/a``-augmented domains.
    """
    schema.validate()

    # Which entity populations need a second first-order variable?
    needs_second = {r.entities[0] for r in schema.relationships if r.is_self}

    fovars: list[FirstOrderVar] = []
    for ent in schema.entities:
        fovars.append(FirstOrderVar(_fovar_id(ent.name, 0), ent.name, 0))
        if ent.name in needs_second:
            fovars.append(FirstOrderVar(_fovar_id(ent.name, 1), ent.name, 1))
    fov_by_id = {f.fid: f for f in fovars}

    par_rvs: list[ParRV] = []

    # 1Variables — entity attributes.  For entities with two first-order
    # variables the attribute par-RV is emitted for each copy (paper's
    # PVariables construction with index_number 0/1).
    for ent in schema.entities:
        copies = [0, 1] if ent.name in needs_second else [0]
        for attr, dom in ent.attributes:
            for idx in copies:
                fid = _fovar_id(ent.name, idx)
                par_rvs.append(
                    ParRV(
                        vid=f"{attr}({fid})",
                        kind=KIND_ENTITY_ATTR,
                        domain=tuple(dom),
                        fovars=(fov_by_id[fid],),
                        table=ent.name,
                        column=attr,
                    )
                )

    # Relationship par-RVs (boolean: F=0, T=1) and 2Variables.
    for rel in schema.relationships:
        e1, e2 = rel.entities
        idx2 = 1 if rel.is_self else 0
        f1, f2 = fov_by_id[_fovar_id(e1, 0)], fov_by_id[_fovar_id(e2, idx2)]
        par_rvs.append(
            ParRV(
                vid=f"{rel.name}({f1.fid},{f2.fid})",
                kind=KIND_REL,
                domain=("F", "T"),
                fovars=(f1, f2),
                table=rel.name,
                column=None,
            )
        )
        for attr, dom in rel.attributes:
            par_rvs.append(
                ParRV(
                    vid=f"{attr}({f1.fid},{f2.fid})",
                    kind=KIND_REL_ATTR,
                    domain=(N_A,) + tuple(dom),  # code 0 == n/a
                    fovars=(f1, f2),
                    table=rel.name,
                    column=attr,
                )
            )

    return VariableCatalog(schema=schema, par_rvs=tuple(par_rvs), fovars=tuple(fovars))
