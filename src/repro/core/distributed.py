"""Distributed counting and prediction — FactorBase pushed onto a TPU mesh.

The paper runs on single-node MySQL; the scalability story at 512+ chips is
the classic star-schema split: *fact* (relationship) tables are sharded by
row over the data axes of the mesh, *dimension* (entity) tables are
replicated.  Each device histograms its row shard with the Pallas
``ct_count`` kernel and a ``psum`` over the data axes yields the global
contingency table — GROUP BY COUNT as an all-reduce of partial aggregates,
which is exactly how a distributed RDBMS executes the same query plan.

Block prediction shards the *test entities* instead: the grouped target CT
rows live on the device that owns the entity, the (small) factor tables are
replicated, and scoring is a local matmul with no collective at all.

Everything here is shard_map-first so the same code lowers on the production
meshes (``launch/mesh.py``) for the multi-pod dry-run.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels import ops
from .counts import ContingencyTable, encode_columns
from .database import RelationalDatabase

try:
    # jax >= 0.6 spelling; on older versions the attribute access raises
    # through jax's deprecation shim
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes except 'model' carry data shards for counting."""
    return tuple(a for a in mesh.axis_names if a != "model")


def sharded_ct_count(
    keys: jax.Array,
    num_bins: int,
    mesh: Mesh,
    *,
    weights: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    """GROUP BY COUNT with rows sharded over the mesh's data axes.

    ``keys`` must be padded (with -1) to a multiple of the data-axis device
    count; the result is a replicated (num_bins,) count vector.
    """
    axes = _data_axes(mesh)

    def local(keys_shard, w_shard):
        part = ops.ct_count(keys_shard, num_bins, w_shard, impl=impl)
        return jax.lax.psum(part.astype(jnp.float32), axes)

    w = jnp.ones(keys.shape, jnp.float32) if weights is None else weights
    fn = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes), P(axes)),
        out_specs=P(),
    )
    return fn(keys, w)


def pad_rows(arr: jax.Array, multiple: int, fill) -> jax.Array:
    n = arr.shape[0]
    pad = -n % multiple
    if pad == 0:
        return arr
    return jnp.concatenate([arr, jnp.full((pad,) + arr.shape[1:], fill, arr.dtype)])


def single_rel_ct_sharded(
    db: RelationalDatabase,
    rel_name: str,
    rvs: Sequence[str],
    mesh: Mesh,
    *,
    impl: str = "auto",
) -> ContingencyTable:
    """Distributed Figure-6 metaquery + Möbius virtual join for one relationship.

    ``rvs`` must consist of: the relationship indicator (optional), its
    attributes, and entity attributes of its two first-order variables.
    The relationship rows are sharded; entity tables are replicated (they
    are the small dimension tables).  Validated cell-exactly against the
    single-device :func:`repro.core.counts.contingency_table` in tests.
    """
    cat = db.catalog
    rel_rv = cat.rel_var_of(rel_name)
    f1, f2 = (f.fid for f in rel_rv.fovars)
    rel_t = db.relationships[rel_name]

    want = [cat[v] for v in rvs]
    ent1 = [v for v in want if v.kind == "entity_attr" and v.fovars[0].fid == f1]
    ent2 = [v for v in want if v.kind == "entity_attr" and v.fovars[0].fid == f2]
    rattrs = [v for v in want if v.kind == "rel_attr"]
    has_indicator = any(v.kind == "rel" for v in want)
    for v in rattrs:
        assert v.table == rel_name, (v.vid, rel_name)

    # --- T-part: histogram over sharded relationship rows ------------------
    cols: list[jax.Array] = []
    cards: list[int] = []
    order: list[str] = []
    e1t, e2t = db.entities[cat.fovar(f1).entity], db.entities[cat.fovar(f2).entity]
    for v in ent1:
        cols.append(e1t.attrs[v.column][rel_t.fk1])
        cards.append(v.cardinality)
        order.append(v.vid)
    for v in ent2:
        cols.append(e2t.attrs[v.column][rel_t.fk2])
        cards.append(v.cardinality)
        order.append(v.vid)
    for v in rattrs:
        cols.append(rel_t.attrs[v.column])
        cards.append(v.cardinality)
        order.append(v.vid)

    n_dev = int(np.prod([mesh.shape[a] for a in _data_axes(mesh)]))
    nbins = int(np.prod(cards)) if cards else 1
    if cols:
        keys = encode_columns(cols, cards)
    else:
        keys = jnp.zeros((rel_t.n_rows,), jnp.int32)
    keys = pad_rows(keys, max(n_dev, 1), -1)
    t_flat = sharded_ct_count(keys, nbins, mesh, impl=impl)
    t_block = t_flat.reshape(tuple(cards) if cards else ())

    # --- don't-care part: outer product of replicated entity histograms ----
    def ent_hist(et, attrs_):
        if not attrs_:
            return jnp.asarray(float(et.n_rows)), []
        cs = [et.attrs[v.column] for v in attrs_]
        cds = [v.cardinality for v in attrs_]
        h = ops.ct_count(encode_columns(cs, cds), int(np.prod(cds)), impl=impl)
        return h.astype(jnp.float32).reshape(tuple(cds)), cds

    # ent_hist returns a scalar population size when the query has no
    # attributes of that side — the outer product then degenerates to a
    # broadcast multiply, which is exactly the cross-product count.
    h1, _ = ent_hist(e1t, ent1)
    h2, _ = ent_hist(e2t, ent2)
    star = jnp.tensordot(jnp.atleast_1d(h1), jnp.atleast_1d(h2), axes=0)
    star = star.reshape(tuple(v.cardinality for v in ent1 + ent2))

    # --- Möbius: F-block = star - sum_over_rel_attrs(T) ---------------------
    n_r = len(rattrs)
    t_sum = t_block.sum(axis=tuple(range(t_block.ndim - n_r, t_block.ndim))) if n_r else t_block
    f_count = star - t_sum
    if n_r:
        r_cards = tuple(v.cardinality for v in rattrs)
        f_block = jnp.zeros(f_count.shape + r_cards, jnp.float32)
        f_block = f_block.at[(Ellipsis,) + (0,) * n_r].set(f_count)
    else:
        f_block = f_count

    if has_indicator:
        table = jnp.stack([f_block, t_block.astype(jnp.float32)], axis=0)
        names = (rel_rv.vid,) + tuple(order)
    else:
        table = f_block + t_block.astype(jnp.float32)
        names = tuple(order)
    ct = ContingencyTable(names, table)
    return ct.transpose(tuple(rvs))


def sharded_coo_aggregate(
    codes: jax.Array,
    weights: jax.Array,
    mesh: Mesh,
) -> tuple[jax.Array, jax.Array]:
    """COO canonicalization with the stream sharded over the mesh's data axes.

    The sparse twin of :func:`sharded_ct_count`: each device sorts and
    segment-sums its row shard of the ``(codes, weights)`` stream locally
    (``ops._coo_aggregate_impl`` — float64 accumulation, one float32
    rounding per partial), the per-device partials are all-gathered, and
    one replicated global :func:`ops.coo_aggregate` merges them.  Because
    per-shard partial counts are integer-valued float32 (each bounded by
    its merged cell, inside the 2**24 precision contract) and the merge
    re-accumulates in float64, the result is bit-identical to the
    single-device aggregation of the whole stream.

    ``codes`` must be padded to a multiple of the data-axis device count
    with the int-max sentinel (weight 0) — :func:`pad_rows` — the same
    identity padding every COO consumer already ignores.
    """
    axes = _data_axes(mesh)

    def local(c_shard, w_shard):
        u, s = ops._coo_aggregate_impl(c_shard, w_shard)
        u = jax.lax.all_gather(u, axes, tiled=True)
        s = jax.lax.all_gather(s, axes, tiled=True)
        return u, s

    fn = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes), P(axes)),
        out_specs=(P(), P()),
        # the all_gather makes both outputs replicated; the static
        # replication checker cannot infer that through the gather
        check_rep=False,
    )
    with enable_x64():
        u, s = fn(codes, weights)
    return ops.coo_aggregate(u, s)


def sharded_sparse_contingency_table(
    db: RelationalDatabase,
    rvs: Sequence[str],
    mesh: Mesh,
    *,
    group_fovar: str | None = None,
    restrict: dict | None = None,
):
    """The sparse COO joint/family CT, row-sharded by the mesh's data size.

    The star-schema split of :func:`single_rel_ct_sharded` applied to the
    *sparse* build: the shard count is the product of the mesh's data-axis
    sizes, and the actual slicing/merging runs through
    :func:`repro.core.sparse_counts.device_sparse_ct_conditional`'s pivot
    sharding (per-shard contraction, one signed-aggregate merge).  On a
    single-device mesh this degenerates to the plain device build.
    Bit-identical to the unsharded table by the partial-merge argument
    documented there.
    """
    from .sparse_counts import device_sparse_contingency_table

    n_dev = int(np.prod([mesh.shape[a] for a in _data_axes(mesh)]))
    return device_sparse_contingency_table(
        db, tuple(rvs),
        group_fovar=group_fovar, restrict=restrict,
        shards=max(n_dev, 1),
    )


def sharded_block_predict(
    counts: jax.Array,
    log_cpt: jax.Array,
    mesh: Mesh,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Block scoring with test entities sharded over the data axes.

    counts (E, C) is sharded on E; log_cpt (C, Y) is replicated; the output
    (E, Y) stays sharded — zero collectives, which is the §VI point at scale.
    """
    axes = _data_axes(mesh)

    def local(c_shard, l_rep):
        return ops.block_predict(c_shard, l_rep, impl=impl)

    fn = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=P(axes, None),
    )
    return fn(counts, log_cpt)
