"""Set-oriented ScoreManager: the MDB ``Scores`` service, batched (§V-C).

FactorBase computes the ``Scores`` table with ONE set-oriented SQL query over
all families at once; the companion position paper (*SQL for SRL*, arXiv
1507.00646) argues that set-at-a-time relational operations — not per-family
loops — are what make in-database structure learning fast.  This module is
that design on the tensor stack:

  * :class:`CountCache` (the CDB service) serves single family CTs, either as
    marginals of a pre-counted joint CT or on demand — the store side of the
    paper's store+score design.
  * :class:`ScoreManager` extends it with :meth:`ScoreManager.score_batch`:
    a *batch* of candidate families ``(child, parents)`` goes in, all
    :class:`~repro.core.scores.FamilyScore` rows come out of one
    set-oriented pass —

      - **dense joint**: the joint's realized cells are decoded once into
        per-RV digit columns (cached, optionally device-resident), every
        family of the batch is remapped to a slot of one padded
        ``(B, P_max, C_max)`` stack by a single ``ops.ct_count`` launch
        (:func:`~repro.core.counts.stacked_family_tables`), and the whole
        stack is scored by one ``mle_cpt_batched`` + one
        ``factor_loglik_batched`` launch
        (:func:`~repro.core.scores.stacked_family_scores`);
      - **sparse joint**: all families are concatenated into a single
        sort-then-segment-sum code remap
        (:meth:`~repro.core.sparse_counts.SparseCT.marginal_batch`, one
        ``ops.sorted_segment_sum`` launch) and scored over realized cells
        only (float64 host math, bit-identical to the serial sparse path);
      - **on-demand mode** (no joint) degrades gracefully to memoized
        per-family counting.

    Scores are memoized by ``(child, sorted parents, alpha)`` — family
    counts always range over the full catalog universe, so a family's score
    is context-free and the memo is shared across hill-climb sweeps *and*
    across lattice nodes of a learn-and-join run.

``device_resident=True`` keeps the dense joint's decoded digit columns and
cell counts on device, so the whole batched remap + scoring pipeline runs as
a few device launches per sweep with no host round-trip of the joint CT.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .counts import (
    CTLike,
    contingency_table,
    joint_contingency_table,
    radix_strides,
    stacked_family_tables,
)
from .database import RelationalDatabase
from .scores import FamilyScore, score_family, stacked_family_scores
from .sparse_counts import SparseCT, sparse_family_stats


class CountCache:
    """Serves family CTs, either from a pre-counted joint CT or on demand.

    ``mode="precount"`` reproduces the paper's evaluation choice (§VII-B):
    one maximally-hard joint CT build, then every family CT is a cheap
    GROUP BY marginal.  ``mode="ondemand"`` counts each distinct family once
    (memoized) — the alternative the paper contrasts with.  The
    ``instance-loop`` baseline in the benchmarks disables the memo.
    ``mode="sparse"`` is pre-counting on the COO backend: the joint is a
    :class:`~repro.core.sparse_counts.SparseCT` (no dense-cell cap — storage
    is #SS), and every served family CT is a sparse marginal.  Passing
    ``impl="sparse"`` to the other modes routes their queries through the
    sparse backend as well.

    Bookkeeping counters: ``n_queries`` increments on every call;
    ``n_materializations`` increments each time a CT is actually *built*
    from the database (the pre-counted joint counts as one; memo hits and
    joint marginals are not materializations).
    """

    def __init__(
        self,
        db: RelationalDatabase,
        mode: str = "precount",
        *,
        impl: str = "auto",
        memoize: bool = True,
    ):
        assert mode in ("precount", "ondemand", "sparse")
        self.db = db
        self.mode = mode
        self.impl = "sparse" if mode == "sparse" else impl
        self.memoize = memoize
        self._memo: dict[tuple[str, ...], CTLike] = {}
        self.n_queries = 0
        self.n_materializations = 0
        self.joint: CTLike | None = None
        if mode in ("precount", "sparse"):
            self.joint = joint_contingency_table(db, impl=self.impl)
            self.n_materializations += 1

    def __call__(self, rvs: tuple[str, ...]) -> CTLike:
        self.n_queries += 1
        key = tuple(sorted(rvs))
        if self.memoize and key in self._memo:
            return self._memo[key].transpose(tuple(rvs))
        if self.joint is not None:
            ct = self.joint.marginal(tuple(rvs))
        else:
            # count over the FULL catalog universe so on-demand counts are
            # cell-identical to pre-counted joint-CT marginals
            universe = tuple(f.fid for f in self.db.catalog.fovars)
            ct = contingency_table(
                self.db, tuple(rvs), impl=self.impl, fovar_universe=universe
            )
            self.n_materializations += 1
        if self.memoize:
            self._memo[key] = ct
        return ct


class ScoreManager(CountCache):
    """Batched family-scoring service — see the module docstring.

    Counters (on top of :class:`CountCache`'s): ``n_score_batches`` is the
    number of set-oriented passes actually executed (memo-complete batches
    are free); ``n_scored_families`` the number of distinct families scored
    through them.
    """

    def __init__(
        self,
        db: RelationalDatabase,
        mode: str = "precount",
        *,
        impl: str = "auto",
        memoize: bool = True,
        device_resident: bool = False,
    ):
        super().__init__(db, mode, impl=impl, memoize=memoize)
        self.device_resident = bool(device_resident)
        self._score_memo: dict[tuple, FamilyScore] = {}
        self._cards: dict[str, int] | None = None
        self._joint_rvs: tuple[str, ...] | None = None
        self._cell_codes: np.ndarray | None = None
        self._cell_counts = None
        self._digit_cache: dict[str, object] = {}
        self.n_score_batches = 0
        self.n_scored_families = 0

    # -- joint-CT cell cache (counts layer plumbing) -------------------------

    def _ensure_cells(self) -> None:
        """Decode the dense joint's realized cells once (COO view)."""
        if self._cell_counts is not None:
            return
        flat = np.asarray(self.joint.table, np.float32).reshape(-1)
        codes = np.flatnonzero(flat).astype(np.int64)
        counts = flat[codes]
        self._cell_codes = codes
        self._joint_rvs = self.joint.rvs
        self._cards = dict(zip(self.joint.rvs, self.joint.table.shape))
        self._cell_counts = jnp.asarray(counts) if self.device_resident else counts

    def _digit(self, rv: str):
        """Cached decoded value column of one par-RV over the joint's cells."""
        if rv not in self._digit_cache:
            cards = [self._cards[v] for v in self._joint_rvs]
            stride = radix_strides(cards)[self._joint_rvs.index(rv)]
            d = ((self._cell_codes // stride) % self._cards[rv]).astype(np.int32)
            self._digit_cache[rv] = jnp.asarray(d) if self.device_resident else d
        return self._digit_cache[rv]

    # -- public scoring API --------------------------------------------------

    def score_batch(
        self,
        families: "list[tuple[str, tuple[str, ...]]]",
        alpha: float = 0.0,
        *,
        impl: str | None = None,
    ) -> list[FamilyScore]:
        """Score a batch of candidate families in one set-oriented pass.

        ``families`` is a list of ``(child, parents)``; parents are
        canonicalized to sorted order (scores are order-invariant), results
        come back in request order, and every computed row lands in the
        score memo, so only memo misses cost anything.  The memo key
        excludes ``impl`` — use one manager per kernel dispatch policy.
        """
        impl = self.impl if impl is None else impl
        canon = [(child, tuple(sorted(parents))) for child, parents in families]
        todo: list[tuple[str, tuple[str, ...]]] = []
        seen: set[tuple] = set()
        for key in canon:
            if key in seen or (key + (float(alpha),)) in self._score_memo:
                continue
            seen.add(key)
            todo.append(key)

        if todo:
            self.n_score_batches += 1
            self.n_scored_families += len(todo)
            if self.joint is None:
                # on-demand mode: no joint to remap; memoized per-family CTs
                for child, parents in todo:
                    fs = score_family(self, child, parents, alpha, impl=impl)
                    self._score_memo[(child, parents, float(alpha))] = fs
            elif isinstance(self.joint, SparseCT):
                keeps = [parents + (child,) for child, parents in todo]
                fcts = self.joint.marginal_batch(keeps)
                for (child, parents), fct in zip(todo, fcts):
                    ll, n_params = sparse_family_stats(fct, child, parents, alpha)
                    self._score_memo[(child, parents, float(alpha))] = FamilyScore(
                        child, ll, n_params
                    )
                    if self.memoize:
                        self._memo.setdefault(tuple(sorted(fct.rvs)), fct)
            else:
                self._ensure_cells()
                for group in self._shape_groups(todo):
                    stacked, mask, metas = stacked_family_tables(
                        {v: self._digit(v) for f in group for v in (f[0],) + f[1]},
                        self._cell_counts, self._cards, group, impl=impl,
                    )
                    scores = stacked_family_scores(
                        stacked, mask, metas, alpha, impl=impl
                    )
                    for (child, parents), fs in zip(group, scores):
                        self._score_memo[(child, parents, float(alpha))] = fs

        return [self._score_memo[key + (float(alpha),)] for key in canon]

    def _shape_groups(
        self, todo: "list[tuple[str, tuple[str, ...]]]"
    ) -> "list[list[tuple[str, tuple[str, ...]]]]":
        """Chunk a batch so its padded stack stays under the cell budget.

        ``stacked_family_tables`` pads every slot to the batch maxima, so a
        single high-cardinality family must not inflate hundreds of tiny
        slots, and a chunk's total padded cells ``B_pad * P_max * C_max``
        must stay under :data:`~repro.core.counts.DENSE_CELL_BUDGET` — the
        same cap the serial path's dense family tables respect (beyond it
        the stacked histogram could also overflow its int32 bin space).
        Families are greedily packed largest-slot-first, so a typical sweep
        batch (bounded family domains) stays ONE launch group and a
        pathological batch degrades to a few, never to one per family.
        """
        self._ensure_cells()
        # read at call time so set_dense_cell_budget() is honored
        from .counts import DENSE_CELL_BUDGET

        def bucket(n: int) -> int:
            return 1 << max(0, n - 1).bit_length()

        dims = {
            fam: (
                bucket(math.prod((self._cards[p] for p in fam[1]), start=1)),
                bucket(self._cards[fam[0]]),
            )
            for fam in todo
        }
        order = sorted(todo, key=lambda f: dims[f][0] * dims[f][1], reverse=True)
        out: list[list[tuple[str, tuple[str, ...]]]] = []
        cur: list[tuple[str, tuple[str, ...]]] = []
        cur_p = cur_c = 1
        for fam in order:
            p_b, c_b = dims[fam]
            cand_p, cand_c = max(cur_p, p_b), max(cur_c, c_b)
            if not cur or bucket(len(cur) + 1) * cand_p * cand_c <= DENSE_CELL_BUDGET:
                cur.append(fam)
                cur_p, cur_c = cand_p, cand_c
            else:
                out.append(cur)
                cur, cur_p, cur_c = [fam], p_b, c_b
        if cur:
            out.append(cur)
        return out

    def score_one(
        self,
        child: str,
        parents: tuple[str, ...],
        alpha: float = 0.0,
        *,
        impl: str | None = None,
    ) -> FamilyScore:
        """Memoized single-family score (a batch of one)."""
        return self.score_batch([(child, parents)], alpha, impl=impl)[0]
