"""Set-oriented ScoreManager: the MDB ``Scores`` service, batched (§V-C).

FactorBase computes the ``Scores`` table with ONE set-oriented SQL query over
all families at once; the companion position paper (*SQL for SRL*, arXiv
1507.00646) argues that set-at-a-time relational operations — not per-family
loops — are what make in-database structure learning fast.  This module is
that design on the tensor stack:

  * :class:`CountCache` (the CDB service) serves single family CTs, either as
    marginals of a pre-counted joint CT or on demand — the store side of the
    paper's store+score design.
  * :class:`ScoreManager` extends it with :meth:`ScoreManager.score_batch`:
    a *batch* of candidate families ``(child, parents)`` goes in, all
    :class:`~repro.core.scores.FamilyScore` rows come out of one
    set-oriented pass —

      - **dense joint**: the joint's realized cells are decoded once into
        per-RV digit columns (cached, optionally device-resident), every
        family of the batch is remapped to a slot of one padded
        ``(B, P_max, C_max)`` stack by a single ``ops.ct_count`` launch
        (:func:`~repro.core.counts.stacked_family_tables`), and the whole
        stack is scored by one ``mle_cpt_batched`` + one
        ``factor_loglik_batched`` launch
        (:func:`~repro.core.scores.stacked_family_scores`);
      - **sparse joint, host** (:class:`~repro.core.sparse_counts.SparseCT`):
        all families are concatenated into a single sort-then-segment-sum
        code remap (:meth:`~repro.core.sparse_counts.SparseCT.
        marginal_batch`) and scored over realized cells only (float64 host
        math, bit-identical to the serial sparse path) — the small-N fast
        path and the oracle for the device path;
      - **sparse joint, device** (:class:`~repro.core.sparse_counts.
        DeviceSparseCT`, via ``device_resident=True``): the joint's decoded
        digit columns live on device, every family of the batch is
        re-encoded into a disjoint slot of one concatenated int32 code
        space, and a single fused ``ops.sparse_family_score`` launch sorts
        the stream, derives cell/parent-run totals, and contracts each
        family's ``SUM(count * log cp)`` — replacing the old
        marginalize -> ``mle_cpt_batched`` -> ``factor_loglik_batched``
        three-hop with ~1 launch per sweep and no host sort;
      - **on-demand mode** (no joint) degrades gracefully to memoized
        per-family counting.

    Scores are memoized by ``(child, sorted parents, alpha)`` — family
    counts always range over the full catalog universe, so a family's score
    is context-free and the memo is shared across hill-climb sweeps *and*
    across lattice nodes of a learn-and-join run.

``device_resident=True`` keeps the joint's decoded digit columns and cell
counts on device — for dense joints the batched remap + scoring pipeline,
and for sparse joints the fused COO scorer, both run as a couple of device
launches per sweep with no host round-trip of the joint CT.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..kernels import bucketing, ops
from ..kernels.sparse_score import MAX_FAMILIES
from . import config
from . import database as _database
from .counts import (
    CTLike,
    contingency_table,
    joint_contingency_table,
    pow2_bucket,
    radix_strides,
    stacked_family_tables,
)
from .database import RelationalDatabase
from .scores import FamilyScore, score_family, stacked_family_scores
from .sparse_counts import (
    DeviceSparseCT,
    LeafMessageCache,
    SparseCT,
    apply_ct_delta,
    sparse_ct_delta,
    sparse_family_stats,
)


#: Default routing threshold of the adaptive batch/serial scorer: sweeps
#: with fewer memo-missing candidates than this go through the serial
#: per-family path.  Every set-oriented engine pays per-batch fixed costs
#: (stream assembly, kernel launch, the host sync of its result) that a
#: handful of tiny family scorings undercut — the movielens regression,
#: where hill-climb sweeps average ~2-3 fresh candidates and the batched
#: leg measured *slower* than serial.  Large sweeps keep the batched path,
#: which wins by amortizing exactly those costs.  (The built-in default of
#: the ``batch_min_candidates`` engine-config field.)
_BATCH_MIN_DEFAULT = 8


def batch_min_candidates() -> int:
    """The router threshold (``REPRO_BATCH_MIN_CANDIDATES``, fail-loud).

    ``0`` disables the serial route entirely (every memo-missing batch is
    set-oriented, the pre-router behavior); large values effectively force
    serial scoring.  Resolves through :mod:`repro.core.config`
    (``engine_config(batch_min_candidates=...)`` for scoped use).
    """
    return config.resolve("batch_min_candidates")


def incremental_enabled() -> bool:
    """Incremental joint maintenance switch (``REPRO_INCREMENTAL``, fail-loud).

    On by default.  ``0`` makes :meth:`CountCache.apply_delta` rebuild the
    pre-counted joint from scratch on every delta instead of propagating a
    signed ΔCT — the bisection aid for suspected delta-propagation bugs
    (results are bit-identical either way; only latency differs).
    """
    return config.resolve("incremental")


class CountCache:
    """Serves family CTs, either from a pre-counted joint CT or on demand.

    ``mode="precount"`` reproduces the paper's evaluation choice (§VII-B):
    one maximally-hard joint CT build, then every family CT is a cheap
    GROUP BY marginal.  ``mode="ondemand"`` counts each distinct family once
    (memoized) — the alternative the paper contrasts with.  The
    ``instance-loop`` baseline in the benchmarks disables the memo.
    ``mode="sparse"`` is pre-counting on the COO backend: the joint is a
    :class:`~repro.core.sparse_counts.SparseCT` (no dense-cell cap — storage
    is #SS), and every served family CT is a sparse marginal.  Passing
    ``impl="sparse"`` to the other modes routes their queries through the
    sparse backend as well.

    Bookkeeping counters: ``n_queries`` increments on every call;
    ``n_materializations`` increments each time a CT is actually *built*
    from the database (the pre-counted joint counts as one; memo hits and
    joint marginals are not materializations).

    ``device_resident=True`` makes the sparse pre-counted joint device
    end-to-end: it is *built* on device (the join-tree contraction and
    Möbius join as COO code algebra — see
    :func:`~repro.core.sparse_counts.device_sparse_contingency_table`; no
    host COO, no bulk h2d copy) and served marginals are computed by device
    sort+segment-sum and returned as device tables (host consumers coerce
    via :func:`~repro.core.sparse_counts.as_host`).  Device-built joints
    may carry interior zero-count cells (exact Möbius cancellations);
    every consumer here treats them as absent — they re-encode to
    zero-weight stream elements that contribute nothing.
    """

    def __init__(
        self,
        db: RelationalDatabase,
        mode: str = "precount",
        *,
        impl: str = "auto",
        memoize: bool = True,
        device_resident: bool = False,
        shards: int | None = None,
    ):
        assert mode in ("precount", "ondemand", "sparse")
        self.db = db
        self.mode = mode
        self.impl = "sparse" if mode == "sparse" else impl
        self.memoize = memoize
        self.device_resident = bool(device_resident)
        self._shards = shards
        self._memo: dict[tuple[str, ...], CTLike] = {}
        self._msg_cache: LeafMessageCache | None = None
        self.n_queries = 0
        self.n_materializations = 0
        self.n_delta_applies = 0
        self.joint: CTLike | None = None
        if mode in ("precount", "sparse"):
            # shards row-shards the device build's fact-table scans
            # (default: the REPRO_COO_SHARDS env knob); bit-identical joint
            self.joint = joint_contingency_table(
                db, impl=self.impl, device_resident=device_resident,
                shards=shards,
            )
            self.n_materializations += 1

    def _dirty_rvs(self, table: str) -> set[str]:
        """Par-RVs a delta to ``table`` can change: its indicator + attrs.

        Everything else is provably untouched — entity populations are
        fixed (``database.apply_delta`` rejects entity deltas), so any CT
        marginal over axes disjoint from this set sums the touched
        relationship out entirely and never reads its rows.
        """
        cat = self.db.catalog
        return {cat.rel_var_of(table).vid} | {
            a.vid for a in cat.attrs_of_rel(table)
        }

    def apply_delta(
        self, table: str, inserted_rows=None, deleted_rows=None
    ) -> dict:
        """Apply a relationship-row delta and maintain the caches in O(Δ).

        Wraps :func:`repro.core.database.apply_delta` (same arguments), then:

        * the pre-counted **sparse** joint is updated by signed ΔCT
          propagation + one merge (:func:`~repro.core.sparse_counts.
          sparse_ct_delta` / :func:`~repro.core.sparse_counts.
          apply_ct_delta`) — bit-identical in canonical host form to a
          rebuild, at delta cost.  Leaf messages are served from a
          per-manager :class:`~repro.core.sparse_counts.LeafMessageCache`.
          Dense joints (and ``REPRO_INCREMENTAL=0``) rebuild instead.
        * the CT memo drops exactly the entries whose RV sets intersect the
          **dirty set** (the touched relationship's indicator + attributes);
          disjoint marginals are provably unchanged and stay served.

        Returns a stats dict (``delta``, ``dirty_rvs``, ``incremental``).
        """
        new_db, delta = _database.apply_delta(
            self.db, table, inserted_rows, deleted_rows
        )
        dirty = self._dirty_rvs(table)
        self.db = new_db
        self.n_delta_applies += 1
        for key in [k for k in self._memo if dirty.intersection(k)]:
            del self._memo[key]
        incremental = False
        if isinstance(self.joint, (SparseCT, DeviceSparseCT)) and incremental_enabled():
            if self._msg_cache is None:
                self._msg_cache = LeafMessageCache()
            dct = sparse_ct_delta(
                new_db, delta, self.joint.rvs, shards=self._shards,
                msg_cache=self._msg_cache,
            )
            self.joint = apply_ct_delta(self.joint, dct)
            incremental = True
        elif self.joint is not None:
            self.joint = joint_contingency_table(
                new_db, impl=self.impl, device_resident=self.device_resident,
                shards=self._shards,
            )
            self.n_materializations += 1
        return {"delta": delta, "dirty_rvs": dirty, "incremental": incremental}

    def __call__(self, rvs: tuple[str, ...]) -> CTLike:
        self.n_queries += 1
        key = tuple(sorted(rvs))
        if self.memoize and key in self._memo:
            return self._memo[key].transpose(tuple(rvs))
        if self.joint is not None:
            ct = self.joint.marginal(tuple(rvs))
        else:
            # count over the FULL catalog universe so on-demand counts are
            # cell-identical to pre-counted joint-CT marginals
            universe = tuple(f.fid for f in self.db.catalog.fovars)
            ct = contingency_table(
                self.db, tuple(rvs), impl=self.impl, fovar_universe=universe
            )
            self.n_materializations += 1
        if self.memoize:
            self._memo[key] = ct
        return ct


class ScoreManager(CountCache):
    """Batched family-scoring service — see the module docstring.

    Counters (on top of :class:`CountCache`'s): ``n_score_batches`` is the
    number of set-oriented passes actually executed (memo-complete batches
    are free); ``n_scored_families`` the number of distinct families scored
    through them.
    """

    def __init__(
        self,
        db: RelationalDatabase,
        mode: str = "precount",
        *,
        impl: str = "auto",
        memoize: bool = True,
        device_resident: bool = False,
        shards: int | None = None,
    ):
        super().__init__(
            db, mode, impl=impl, memoize=memoize,
            device_resident=device_resident, shards=shards,
        )
        self._score_memo: dict[tuple, FamilyScore] = {}
        self._cards: dict[str, int] | None = None
        self._joint_rvs: tuple[str, ...] | None = None
        self._cell_codes = None
        self._cell_counts = None
        self._digit_cache: dict[str, object] = {}
        self._digit_mat = None
        self.n_score_batches = 0
        self.n_scored_families = 0
        #: adaptive batch/serial router (see :func:`batch_min_candidates`):
        #: memo-missing batches below the threshold score serially.
        self.batch_min_candidates = batch_min_candidates()
        self.n_serial_routed = 0
        self.n_batched_routed = 0
        self.n_dirty_families = 0
        self.n_preserved_families = 0

    def apply_delta(
        self, table: str, inserted_rows=None, deleted_rows=None
    ) -> dict:
        """Delta-apply + **dirty-set score refresh** (see the base method).

        Only families whose RV set intersects the dirty set (the touched
        relationship's indicator + attributes) are evicted from the score
        memo and re-scored on next request; every other family's score is
        *provably* unchanged — its CT marginalizes the touched relationship
        out and family scores are context-free — so it keeps serving from
        the memo.  The split is counted in ``n_dirty_families`` /
        ``n_preserved_families`` (cumulative) and returned per call.
        """
        stats = super().apply_delta(table, inserted_rows, deleted_rows)
        dirty = stats["dirty_rvs"]
        n_dirty = n_preserved = 0
        for key in list(self._score_memo):
            child, parents, _alpha = key
            if dirty.intersection((child,) + parents):
                del self._score_memo[key]
                n_dirty += 1
            else:
                n_preserved += 1
        self.n_dirty_families += n_dirty
        self.n_preserved_families += n_preserved
        # the joint's cells changed: decoded digit/cell caches rebuild lazily
        self._cards = None
        self._joint_rvs = None
        self._cell_codes = None
        self._cell_counts = None
        self._digit_cache = {}
        self._digit_mat = None
        stats["n_dirty_families"] = n_dirty
        stats["n_preserved_families"] = n_preserved
        return stats

    # -- joint-CT cell cache (counts layer plumbing) -------------------------

    def _ensure_cells(self) -> None:
        """Expose the joint's realized cells as (codes, counts) columns.

        Dense joints are decoded once (``flatnonzero``); sparse joints — on
        either side of the PCIe — already *are* this COO view.  With
        ``device_resident`` the counts column lives on device.
        """
        if self._cell_counts is not None:
            return
        joint = self.joint
        if isinstance(joint, (SparseCT, DeviceSparseCT)):
            self._cell_codes = joint.codes
            self._cards = dict(zip(joint.rvs, joint.cards))
            counts = joint.counts
        else:
            flat = np.asarray(joint.table, np.float32).reshape(-1)
            self._cell_codes = np.flatnonzero(flat).astype(np.int64)
            self._cards = dict(zip(joint.rvs, joint.table.shape))
            counts = flat[self._cell_codes]
        self._joint_rvs = joint.rvs
        if self.device_resident and isinstance(counts, np.ndarray):
            counts = ops.to_device(counts)
        self._cell_counts = counts

    def _digit(self, rv: str):
        """Cached decoded value column of one par-RV over the joint's cells."""
        if rv not in self._digit_cache:
            cards = [self._cards[v] for v in self._joint_rvs]
            stride = radix_strides(cards)[self._joint_rvs.index(rv)]
            codes = self._cell_codes
            if isinstance(codes, jax.Array):
                # int64 composite codes decode under a local x64 scope; the
                # digit column itself always fits int32
                with enable_x64():
                    d = ((codes // stride) % self._cards[rv]).astype(jnp.int32)
            else:
                d = ((codes // stride) % self._cards[rv]).astype(np.int32)
                if self.device_resident:
                    d = ops.to_device(d)
            self._digit_cache[rv] = d
        return self._digit_cache[rv]

    def _digit_matrix(self):
        """All joint par-RVs' digit columns as one cached (R, nnz) matrix.

        Stacked once for the joint's lifetime (the columns are immutable),
        so the per-chunk family re-encode is pure row gathers — no O(R x
        nnz) restack per sweep.  Row ``i`` is ``self._joint_rvs[i]``.
        """
        if self._digit_mat is None:
            self._digit_mat = jnp.stack([self._digit(v) for v in self._joint_rvs])
            if isinstance(self._cell_codes, jax.Array):
                # device-sparse scoring reads only the matrix; don't keep a
                # second full copy of every column alive in the cache
                self._digit_cache.clear()
        return self._digit_mat

    # -- public scoring API --------------------------------------------------

    def score_batch(
        self,
        families: "list[tuple[str, tuple[str, ...]]]",
        alpha: float = 0.0,
        *,
        impl: str | None = None,
    ) -> list[FamilyScore]:
        """Score a batch of candidate families in one set-oriented pass.

        ``families`` is a list of ``(child, parents)``; parents are
        canonicalized to sorted order (scores are order-invariant), results
        come back in request order, and every computed row lands in the
        score memo, so only memo misses cost anything.  The memo key
        excludes ``impl`` — use one manager per kernel dispatch policy.

        An adaptive router picks the engine per call: batches with fewer
        than :attr:`batch_min_candidates` memo-missing families score
        through the serial per-family path (identical scores, no batched
        fixed costs), larger ones through the set-oriented engines.  The
        split is counted in ``n_serial_routed`` / ``n_batched_routed``.
        """
        impl = self.impl if impl is None else impl
        canon = [(child, tuple(sorted(parents))) for child, parents in families]
        todo: list[tuple[str, tuple[str, ...]]] = []
        seen: set[tuple] = set()
        for key in canon:
            if key in seen or (key + (float(alpha),)) in self._score_memo:
                continue
            seen.add(key)
            todo.append(key)

        if todo:
            self.n_score_batches += 1
            self.n_scored_families += len(todo)
            serial = self.joint is None or len(todo) < self.batch_min_candidates
            if not serial:
                self.n_batched_routed += len(todo)
            if serial:
                # on-demand mode (no joint to remap), or the adaptive
                # router: a handful of memo misses — typical of late
                # hill-climb sweeps, where most families are memo hits —
                # cannot amortize the batched engines' per-pass fixed costs
                # (stream assembly, launch, result sync), so score them
                # through the per-family path.  Same scores either way:
                # both routes reduce to identical family CT cells.
                if self.joint is not None:
                    self.n_serial_routed += len(todo)
                for child, parents in todo:
                    fs = score_family(self, child, parents, alpha, impl=impl)
                    self._score_memo[(child, parents, float(alpha))] = fs
            elif isinstance(self.joint, DeviceSparseCT):
                # the fused device path: no marginal CTs are materialized —
                # one sparse_family_score launch per (chunked) batch
                self._score_sparse_device(todo, alpha, impl)
            elif isinstance(self.joint, SparseCT):
                keeps = [parents + (child,) for child, parents in todo]
                fcts = self.joint.marginal_batch(keeps)
                for (child, parents), fct in zip(todo, fcts):
                    ll, n_params = sparse_family_stats(fct, child, parents, alpha)
                    self._score_memo[(child, parents, float(alpha))] = FamilyScore(
                        child, ll, n_params
                    )
                    if self.memoize:
                        self._memo.setdefault(tuple(sorted(fct.rvs)), fct)
            else:
                self._ensure_cells()
                for group in self._shape_groups(todo):
                    stacked, mask, metas = stacked_family_tables(
                        {v: self._digit(v) for f in group for v in (f[0],) + f[1]},
                        self._cell_counts, self._cards, group, impl=impl,
                    )
                    scores = stacked_family_scores(
                        stacked, mask, metas, alpha, impl=impl
                    )
                    for (child, parents), fs in zip(group, scores):
                        self._score_memo[(child, parents, float(alpha))] = fs

        return [self._score_memo[key + (float(alpha),)] for key in canon]

    # -- fused device-resident sparse scoring --------------------------------

    #: Row cap per fused sparse launch: the concatenated stream holds
    #: B_pad x nnz int32 codes + float32 weights, so bound its footprint
    #: (2**25 rows = 256 MiB for both columns) and chunk batches beyond it.
    SPARSE_BATCH_ROW_BUDGET: int = 1 << 25

    def _sparse_groups(
        self, todo: "list[tuple[str, tuple[str, ...]]]"
    ) -> "list[list[tuple[tuple[str, tuple[str, ...]], int]]]":
        """Chunk a sparse batch under the int32 code-space and row budgets.

        Family code spaces concatenate into one int32 stream, so a chunk's
        cumulative ``prod(cards)`` (plus one padding slot per padded family)
        must stay under 2**31, its family count under the kernel's
        ``MAX_FAMILIES`` lane cap, and its ``B_pad * nnz`` rows under
        :data:`SPARSE_BATCH_ROW_BUDGET` *after* the ops layer's bucket
        padding (the stream is topped up to the ``kernels.bucketing`` row
        ladder, at most one growth factor — the budget here is shrunk by
        that factor so guard and padding can never disagree).  Typical
        sweep batches (bounded family domains) stay ONE launch group.
        Returns chunks of ``(family, code_space)`` pairs so the scorer
        never recomputes the spaces this guard was sized with.
        """
        self._ensure_cells()
        nnz = int(self._cell_counts.shape[0])
        _, growth = bucketing.bucket_ladder()
        max_rows = max(1, int(self.SPARSE_BATCH_ROW_BUDGET / growth))
        max_rows_fams = max(1, max_rows // max(nnz, 1))
        space_guard = 2**31 - 2 * MAX_FAMILIES

        out: list[list[tuple[tuple[str, tuple[str, ...]], int]]] = []
        cur: list[tuple[tuple[str, tuple[str, ...]], int]] = []
        cur_space = 0
        for fam in todo:
            child, parents = fam
            space = self._cards[child] * math.prod(
                (self._cards[p] for p in parents), start=1
            )
            if space >= space_guard:
                raise OverflowError(
                    f"family {fam} needs a {space:.3g}-cell code space; too "
                    "large for the int32 fused sparse scorer"
                )
            full = cur and (
                len(cur) >= MAX_FAMILIES
                or pow2_bucket(len(cur) + 1) > max_rows_fams
                or cur_space + space + pow2_bucket(len(cur) + 1) > space_guard
            )
            if full:
                out.append(cur)
                cur, cur_space = [], 0
            cur.append((fam, space))
            cur_space += space
        if cur:
            out.append(cur)
        return out

    def _score_sparse_device(
        self, todo: "list[tuple[str, tuple[str, ...]]]", alpha: float, impl: str
    ) -> None:
        """Score a batch against a device-resident sparse joint, fused.

        Every family is re-encoded (from the cached device digit columns)
        into a disjoint slot of one concatenated int32 code space — child as
        the minor radix digit — and the whole stream goes through ONE
        ``ops.sparse_family_score`` launch per chunk: device sort, cell and
        parent-run totals, and the masked ``n * log cp`` contraction, with
        nothing but the ``(B,)`` log-likelihood row returning to host.
        The re-encode itself is a handful of stacked gather/multiply-add
        dispatches over an ``(R, nnz)`` digit matrix — O(max arity), not
        O(batch x arity).  Free-parameter counts are static family metadata
        (full parent config space x (child cardinality - 1)), host-side.
        """
        self._ensure_cells()
        nnz = int(self._cell_counts.shape[0])
        kimpl = ops.kernel_impl(impl)

        for group in self._sparse_groups(todo):
            fams = [fam for fam, _ in group]
            b = len(fams)
            b_pad = pow2_bucket(b)
            # padding families: 1 empty cell each
            spaces = [space for _, space in group] + [1] * (b_pad - b)
            ccards = [self._cards[c] for c, _ in fams] + [1] * (b_pad - b)
            bounds = np.zeros(b_pad + 1, np.int64)
            bounds[1:] = np.cumsum(spaces)

            if nnz == 0:
                lls = np.zeros(b_pad, np.float32)
            else:
                # slot tables: family i's radix digit s comes from digit row
                # sel[i, s] with stride strides[i, s] (0-stride no-op slots
                # pad short families and the empty padding families)
                row_of = {v: r for r, v in enumerate(self._joint_rvs)}
                n_slots = max(len(ps) + 1 for _, ps in fams)
                sel = np.zeros((b_pad, n_slots), np.int64)
                strides = np.zeros((b_pad, n_slots), np.int32)
                for i, (child, parents) in enumerate(fams):
                    cards = [self._cards[p] for p in parents] + [self._cards[child]]
                    for s, (v, stride) in enumerate(
                        zip(parents + (child,), radix_strides(cards))
                    ):
                        sel[i, s] = row_of[v]
                        strides[i, s] = stride
                digit_mat = self._digit_matrix()
                codes = jnp.broadcast_to(
                    ops.to_device(bounds[:-1].astype(np.int32))[:, None],
                    (b_pad, nnz),
                )
                for s in range(n_slots):
                    codes = codes + (
                        digit_mat[ops.to_device(sel[:, s])]
                        * ops.to_device(strides[:, s])[:, None]
                    )
                weights = jnp.tile(self._cell_counts, b)
                if b_pad > b:
                    weights = jnp.concatenate(
                        [weights, jnp.zeros(nnz * (b_pad - b), jnp.float32)]
                    )
                lls = ops.to_host(
                    ops.sparse_family_score_batched(
                        codes.reshape(-1), weights,
                        ops.to_device(bounds.astype(np.int32)),
                        ops.to_device(np.asarray(ccards, np.int32)),
                        alpha, impl=kimpl,
                    )
                )
            for i, (child, parents) in enumerate(fams):
                c_card = self._cards[child]
                n_params = (spaces[i] // c_card) * (c_card - 1)
                self._score_memo[(child, parents, float(alpha))] = FamilyScore(
                    child, float(lls[i]), n_params
                )

    def _shape_groups(
        self, todo: "list[tuple[str, tuple[str, ...]]]"
    ) -> "list[list[tuple[str, tuple[str, ...]]]]":
        """Chunk a batch so its padded stack stays under the cell budget.

        ``stacked_family_tables`` pads every slot to the batch maxima, so a
        single high-cardinality family must not inflate hundreds of tiny
        slots, and a chunk's total padded cells ``B_pad * P_max * C_max``
        must stay under :data:`~repro.core.counts.DENSE_CELL_BUDGET` — the
        same cap the serial path's dense family tables respect (beyond it
        the stacked histogram could also overflow its int32 bin space).
        Families are greedily packed largest-slot-first, so a typical sweep
        batch (bounded family domains) stays ONE launch group and a
        pathological batch degrades to a few, never to one per family.
        """
        self._ensure_cells()
        # resolved at call time so set_dense_cell_budget() / engine_config
        # scoping are honored
        cell_budget = config.resolve("dense_cell_budget")
        bucket = pow2_bucket

        dims = {
            fam: (
                bucket(math.prod((self._cards[p] for p in fam[1]), start=1)),
                bucket(self._cards[fam[0]]),
            )
            for fam in todo
        }
        order = sorted(todo, key=lambda f: dims[f][0] * dims[f][1], reverse=True)
        out: list[list[tuple[str, tuple[str, ...]]]] = []
        cur: list[tuple[str, tuple[str, ...]]] = []
        cur_p = cur_c = 1
        for fam in order:
            p_b, c_b = dims[fam]
            cand_p, cand_c = max(cur_p, p_b), max(cur_c, c_b)
            if not cur or bucket(len(cur) + 1) * cand_p * cand_c <= cell_budget:
                cur.append(fam)
                cur_p, cur_c = cand_p, cand_c
            else:
                out.append(cur)
                cur, cur_p, cur_c = [fam], p_b, c_b
        if cur:
            out.append(cur)
        return out

    def score_one(
        self,
        child: str,
        parents: tuple[str, ...],
        alpha: float = 0.0,
        *,
        impl: str | None = None,
    ) -> FamilyScore:
        """Memoized single-family score (a batch of one)."""
        return self.score_batch([(child, parents)], alpha, impl=impl)[0]
