"""Model Score Computation (paper §V-C): log-likelihood, #params, AIC/BIC.

BN scores are decomposable: the total is a sum of per-family local scores,
each computed from the family CT and factor table by the
``SUM(count * log cp)`` contraction (Pallas ``factor_loglik`` kernel on TPU).
The ``Scores`` MDB table becomes :class:`ScoreTable`.

Both count backends are accepted (the ``CTLike`` protocol): dense family CTs
go through the factor-table kernels; sparse family CTs are scored over their
*realized cells only* (``sparse_family_stats``) without ever materializing
the dense family tensor — numerically identical by the 0·log0 := 0
convention.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import numpy as np

from ..kernels import ops
from .bn import BayesNet
from .counts import CTLike
from .cpt import FactorTable, mle_factor
from .sparse_counts import SparseCT, as_host, sparse_factor_loglik, sparse_family_stats


@dataclass(frozen=True)
class FamilyScore:
    child: str
    loglik: float
    n_params: int

    def aic(self) -> float:
        """Paper's convention: AIC(G, D) = ln P(D) - #par(G)."""
        return self.loglik - self.n_params

    def bic(self, n_groundings: float) -> float:
        return self.loglik - 0.5 * self.n_params * math.log(max(n_groundings, 1.0))


@dataclass(frozen=True)
class ScoreTable:
    """The MDB ``Scores`` table: per-family rows + decomposable totals."""

    families: dict[str, FamilyScore]

    @property
    def loglik(self) -> float:
        return sum(f.loglik for f in self.families.values())

    @property
    def n_params(self) -> int:
        return sum(f.n_params for f in self.families.values())

    @property
    def aic(self) -> float:
        return sum(f.aic() for f in self.families.values())

    def bic(self, n_groundings: float) -> float:
        return sum(f.bic(n_groundings) for f in self.families.values())


def family_loglik(
    fct: CTLike, factor: FactorTable, *, impl: str = "auto"
) -> float:
    """sum(count * log cp) for one family (the §V-C SQL query)."""
    fct = as_host(fct)
    if isinstance(fct, SparseCT):
        return sparse_factor_loglik(fct, factor.rvs, factor.table)
    ct = fct.transpose(factor.rvs)
    return float(ops.factor_loglik(ct.table, factor.table, impl=ops.kernel_impl(impl)))


def score_family(
    counts_of,
    child: str,
    parents: tuple[str, ...],
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> FamilyScore:
    """MLE-fit one family and return its local score row.

    Sparse family CTs are scored over nonzero cells only — no dense factor
    table is built, so scoring scales with #SS rather than the domain cross
    product.
    """
    fct = as_host(counts_of(tuple(parents) + (child,)))
    if isinstance(fct, SparseCT):
        ll, n_params = sparse_family_stats(fct, child, tuple(parents), alpha)
        return FamilyScore(child, ll, n_params)
    factor = mle_factor(fct, child, parents, alpha, impl=impl)
    ll = family_loglik(fct, factor, impl=impl)
    return FamilyScore(child, ll, factor.n_params)


def stacked_family_scores(
    stacked: jax.Array,
    child_mask: jax.Array,
    metas: list[tuple[str, int, int]],
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> list[FamilyScore]:
    """Score a whole stack of padded family CTs in two kernel launches.

    The set-oriented §V-C ``Scores`` build: ``stacked`` is the
    ``(B, P_max, C_max)`` output of
    :func:`~repro.core.counts.stacked_family_tables`, ``child_mask`` its
    valid-lane mask and ``metas`` the per-family ``(child, n_parent_configs,
    child_card)``.  One ``mle_cpt_batched`` launch fits every CPT and one
    ``factor_loglik_batched`` launch contracts every family's
    ``SUM(count * log cp)`` — versus two launches *per candidate* on the
    serial path.  Free-parameter counts come from the unpadded family
    shapes, so AIC/BIC penalties are unaffected by batch padding.
    """
    kimpl = ops.kernel_impl(impl)
    b = stacked.shape[0]
    cpts = ops.mle_cpt_batched(stacked, child_mask, alpha, impl=kimpl)
    lls = np.asarray(
        ops.factor_loglik_batched(
            stacked.reshape(b, -1), cpts.reshape(b, -1), impl=kimpl
        )
    )
    return [
        FamilyScore(child, float(lls[i]), p_i * (c_i - 1))
        for i, (child, p_i, c_i) in enumerate(metas)
    ]


def score_structure(
    bn: BayesNet,
    counts_of,
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> ScoreTable:
    """Score every family of a structure (decomposable total)."""
    return ScoreTable(
        {
            child: score_family(counts_of, child, tuple(bn.parents[child]), alpha, impl=impl)
            for child in bn.rvs
        }
    )
