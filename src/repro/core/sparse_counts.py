"""Sparse (COO) contingency tables: sufficient statistics as relational tuples.

The paper's count manager observation (§IV, Table VI): the number of
*realized* sufficient statistics (#SS) is vastly smaller than the cross
product of the par-RV domains, which is why FACTORBASE stores CTs as
relational tuples rather than dense arrays.  :class:`SparseCT` is that
representation on the tensor stack: a COO table of

    ``codes``  — int64 mixed-radix composite keys (row-major over ``rvs``,
                 the same layout as the dense tensor's flat index), and
    ``counts`` — float32 realized counts,

kept canonical (codes strictly increasing, no explicit zeros).  All CT
algebra — ``marginal`` (GROUP BY), ``transpose``, the Möbius virtual join
``CT[F] = CT[*] − CT[T]`` — runs directly on codes: decode the mixed-radix
digits, drop/permute axes, re-encode, then re-aggregate by
**sort-then-segment-sum** (``kernels.ops.sorted_segment_sum`` on device for
large runs, ``np.add.reduceat`` for small host-side ones).

Construction mirrors the dense join-tree contraction in
:mod:`repro.core.counts` — the two backends share :func:`~repro.core.counts.
plan_conditional` — but messages are COO ``(entity_row, code) -> weight``
tables instead of dense ``(rows, code_space)`` tensors, so intermediate and
final storage scale with realized tuples, never with the domain cross
product.  This is what unlocks schemas whose dense joint CT would need
>10^9 cells (see ``benchmarks/bench_sparse.py``).

Dispatch: ``contingency_table(..., impl="sparse")`` forces this backend;
``impl="auto"`` switches to it when the dense cell count exceeds
:data:`~repro.core.counts.DENSE_CELL_BUDGET`.

Two residency twins implement the representation:

  * :class:`SparseCT` — host numpy arrays.  The small-N fast path (no
    dispatch overhead) and the semantic oracle every device result is
    validated against.
  * :class:`DeviceSparseCT` — the same COO columns as ``jax.Array``s.  All
    CT algebra (re-encode, marginal, batched marginal, transpose) runs on
    device through ``jax.lax.sort``-based aggregation
    (``kernels.ops.coo_aggregate``), and batched family scoring feeds the
    fused ``kernels.ops.sparse_family_score`` kernel — the structure-search
    hot loop never round-trips the COO stream to host.

and two build routes produce them:

  * the **host build** (:func:`sparse_ct_conditional` /
    :func:`sparse_contingency_table`) — numpy messages, ``np.lexsort`` /
    ``reduceat`` aggregation.  The small-N fast path and the equivalence
    oracle; ``SparseCT.to_device()`` ships its result across in one bulk
    h2d copy.
  * the **device build** (:func:`device_sparse_ct_conditional` /
    :func:`device_sparse_contingency_table`, selected by
    ``contingency_table(..., device_resident=True)``) — the same join-tree
    contraction re-expressed as COO code algebra over ``jax.Array``s: leaf
    tuple encode is digit arithmetic on the (already device-resident)
    database columns, the foreign-key join is a sort-merge on entity rows
    (``kernels.ops.coo_join``), and every canonicalization — including each
    Möbius T/don't-care subtraction — is a (signed) ``ops.coo_aggregate``
    pass.  No COO column ever exists on host; the only d2h traffic is
    accounted scalar size syncs.  ``to_host()`` of a device-built table is
    bit-identical (codes and float32 counts) to the host build.

**enable_x64 scoping contract.** The global JAX dtype default stays 32-bit;
every computation that touches int64 composite codes (or wants float64
count accumulation) opens a *local* ``jax.experimental.enable_x64()``
scope around exactly the jnp calls that need it.  Two rules keep this
sound: (1) any function returning int64 device arrays documents it, and
callers doing further arithmetic on them must open their own scope —
int64 *storage* survives outside the scope, but new literals/conversions
inside an unscoped expression would silently truncate to int32; (2) the
scope is never held across a host sync or a public API boundary, so user
code never observes a flipped global flag.

:func:`as_host` coerces a device table back to its host twin.
"""

from __future__ import annotations

import contextlib
import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..kernels import bucketing, ops
from . import config
from .counts import (
    GROUP_AXIS,
    ContingencyTable,
    QueryPlan,
    mobius_setup,
    plan_conditional,
    radix_strides,
)
from .database import RelationalDatabase, RelationshipTable
from .schema import KIND_REL_ATTR

# Mixed-radix codes are int64: the composite code space (dense cell count)
# must stay below 2**62 for exact arithmetic with headroom.
_MAX_CODE_SPACE = 1 << 62

# Above this many rows the sort-then-segment-sum aggregation runs on device
# via the kernels layer; below it, host numpy wins on dispatch overhead.
_DEVICE_AGG_MIN_ROWS = 1 << 17

# Above this many concatenated rows a host marginal_batch ships the whole
# re-encoded stream to the device for ONE fused sort+segment-sum
# (ops.coo_aggregate) instead of sorting on host with np.argsort.
_DEVICE_SORT_MIN_ROWS = 1 << 18

#: Accumulation dtype for COO count totals, shared by the host and device
#: backends.  Counts are integer-valued float32, so float64 accumulation is
#: exact (any total below 2**53) and therefore independent of both the
#: reduction order and the backend — host and device ``total()`` are
#: bit-identical after the final float32 cast.
TOTAL_ACC_DTYPE = np.float64


# ---------------------------------------------------------------------------
# COO aggregation: sort-then-segment-sum
# ---------------------------------------------------------------------------


def _run_boundaries(sorted_codes: np.ndarray):
    """``(boundary_mask, run_starts)`` of equal-value runs in a sorted vector.

    The shared first step of every host segment reduction below (and of
    :func:`sparse_family_stats`'s parent-total pass): ``boundary[i]`` marks
    the first element of each run, ``run_starts`` its positions.
    """
    boundary = np.empty(sorted_codes.size, bool)
    boundary[0] = True
    np.not_equal(sorted_codes[1:], sorted_codes[:-1], out=boundary[1:])
    return boundary, np.flatnonzero(boundary)


def _segment_reduce(sorted_codes: np.ndarray, weights: np.ndarray):
    """Sum ``weights`` over equal runs of pre-sorted ``sorted_codes``.

    Accumulates in :data:`TOTAL_ACC_DTYPE` (float64 — exact for
    integer-valued counts) and stores the correctly-rounded float32, so
    aggregated cells are bit-identical however the reduction is ordered —
    the contract that keeps host and device aggregation interchangeable.
    (On a TPU backend, where float64 cannot lower, the device branch keeps
    float32 accumulation — ``ops.count_acc_dtype`` makes that call.)
    """
    boundary, starts = _run_boundaries(sorted_codes)
    uniq = sorted_codes[starts]
    if weights.size >= _DEVICE_AGG_MIN_ROWS:
        seg_ids = np.cumsum(boundary) - 1
        with enable_x64():
            sums = np.asarray(
                ops.sorted_segment_sum(
                    jnp.asarray(weights, ops.count_acc_dtype()),
                    jnp.asarray(seg_ids, np.int32), int(uniq.size),
                )
            )
    else:
        sums = np.add.reduceat(weights.astype(TOTAL_ACC_DTYPE), starts)
    return uniq, sums.astype(np.float32, copy=False)


def aggregate_codes(codes: np.ndarray, weights: np.ndarray):
    """Canonicalize a COO vector: sort by code, segment-sum, drop zeros."""
    codes = np.asarray(codes, np.int64)
    weights = np.asarray(weights, np.float32)
    if codes.size == 0:
        return codes, weights
    order = np.argsort(codes, kind="stable")
    uniq, sums = _segment_reduce(codes[order], weights[order])
    keep = sums != 0.0
    return uniq[keep], sums[keep]


def _aggregate_pairs(rows: np.ndarray, codes: np.ndarray, weights: np.ndarray):
    """Canonicalize a COO message: lexsort by (row, code), segment-sum."""
    if rows.size == 0:
        return rows.astype(np.int64), codes.astype(np.int64), weights.astype(np.float32)
    order = np.lexsort((codes, rows))
    rows, codes, weights = rows[order], codes[order], weights[order]
    boundary = np.empty(rows.size, bool)
    boundary[0] = True
    np.logical_or(rows[1:] != rows[:-1], codes[1:] != codes[:-1], out=boundary[1:])
    starts = np.flatnonzero(boundary)
    sums = np.add.reduceat(weights.astype(TOTAL_ACC_DTYPE), starts).astype(
        np.float32, copy=False
    )
    keep = sums != 0.0
    return rows[starts][keep], codes[starts][keep], sums[keep]


# ---------------------------------------------------------------------------
# SparseCT
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SparseCT:
    """COO sufficient-statistics table (implements the ``CTLike`` protocol).

    ``codes[i]`` is the row-major mixed-radix flat index (over ``cards``) of
    the ``i``-th realized cell, ``counts[i]`` its count.  Codes are strictly
    increasing and no stored count is zero, so ``len(codes)`` is the paper's
    #SS and ``to_dense()`` is a single scatter.
    """

    rvs: tuple[str, ...]
    cards: tuple[int, ...]
    codes: np.ndarray   # int64, strictly increasing
    counts: np.ndarray  # float32, no explicit zeros

    def __post_init__(self):
        assert len(self.rvs) == len(self.cards), (self.rvs, self.cards)
        assert self.codes.shape == self.counts.shape, (self.codes.shape, self.counts.shape)

    @property
    def n_cells(self) -> int:
        """Dense cell count this table *would* have (exact Python int)."""
        return math.prod(self.cards) if self.cards else 1

    def total(self):
        """Grand total, accumulated in :data:`TOTAL_ACC_DTYPE` -> float32."""
        return np.float32(self.counts.sum(dtype=TOTAL_ACC_DTYPE))

    def n_nonzero(self) -> int:
        """Number of realized sufficient statistics (the paper's #SS)."""
        return int(self.codes.size)

    def card_of(self, rv: str) -> int:
        return self.cards[self.rvs.index(rv)]

    def _digits(self, rv: str) -> np.ndarray:
        """Decode one axis' digit column from the composite codes."""
        i = self.rvs.index(rv)
        stride = radix_strides(list(self.cards))[i]
        return (self.codes // stride) % self.cards[i]

    def _reencode(self, order: tuple[str, ...]):
        """Codes of the kept axes, re-encoded row-major in ``order``."""
        new_cards = tuple(self.card_of(v) for v in order)
        new_strides = radix_strides(list(new_cards))
        new_codes = np.zeros(self.codes.shape, np.int64)
        for v, s in zip(order, new_strides):
            new_codes += self._digits(v) * s
        return new_cards, new_codes

    def marginal(self, keep: tuple[str, ...]) -> "SparseCT":
        """GROUP BY a subset of the par-RVs (sum out the rest)."""
        missing = [v for v in keep if v not in self.rvs]
        if missing:
            raise KeyError(f"par-RVs {missing} not in this CT {self.rvs}")
        new_cards, new_codes = self._reencode(tuple(keep))
        codes, counts = aggregate_codes(new_codes, self.counts)
        return SparseCT(tuple(keep), new_cards, codes, counts)

    def transpose(self, order: tuple[str, ...]) -> "SparseCT":
        if tuple(order) == self.rvs:
            return self
        if sorted(order) != sorted(self.rvs):
            raise ValueError(f"transpose order {order} != axes {self.rvs}")
        new_cards, new_codes = self._reencode(tuple(order))
        # Axis permutation is a bijection on codes: sort, no aggregation.
        perm = np.argsort(new_codes, kind="stable")
        return SparseCT(tuple(order), new_cards, new_codes[perm], self.counts[perm])

    def marginal_batch(self, keeps: list[tuple[str, ...]]) -> list["SparseCT"]:
        """GROUP BY many axis subsets in one set-oriented pass (§V-C batched).

        The serial path re-encodes and sorts once *per family*; here all
        requested marginals are concatenated into a single composite code
        space (:func:`plan_marginal_batch`) — family ``i``'s re-encoded
        codes are offset by the cumulative code-space size of families
        ``0..i-1`` — so the whole batch is canonicalized by ONE sort and ONE
        segment reduction instead of one per family.  Small batches sort on
        host (numpy, no dispatch overhead); past
        :data:`_DEVICE_SORT_MIN_ROWS` concatenated rows the stream ships to
        the device for one fused ``ops.coo_aggregate`` launch.  Per-family
        results are cell-identical either way: disjoint offset ranges make
        the shared sort equivalent to B independent sorts.
        """
        if not keeps:
            return []
        offsets, all_cards, total_space = plan_marginal_batch(self, keeps)
        digit_cache: dict[str, np.ndarray] = {}

        def digit(rv: str) -> np.ndarray:
            if rv not in digit_cache:
                digit_cache[rv] = self._digits(rv)
            return digit_cache[rv]

        chunks: list[np.ndarray] = []
        for keep, cards, off in zip(keeps, all_cards, offsets):
            strides = radix_strides(list(cards))
            codes = np.full(self.codes.shape, off, np.int64)
            for v, s in zip(keep, strides):
                codes += digit(v) * s
            chunks.append(codes)

        big_codes = np.concatenate(chunks)
        big_counts = np.tile(self.counts, len(keeps))
        if big_codes.size >= _DEVICE_SORT_MIN_ROWS:
            u, s = ops.coo_aggregate(big_codes, big_counts)
            u, s = ops.to_host(u), ops.to_host(s)
            keep_mask = s != 0.0
            codes, counts = u[keep_mask], s[keep_mask]
        else:
            codes, counts = aggregate_codes(big_codes, big_counts)

        out: list[SparseCT] = []
        bounds = list(offsets) + [total_space]
        for i, keep in enumerate(keeps):
            lo, hi = np.searchsorted(codes, [bounds[i], bounds[i + 1]])
            out.append(
                SparseCT(
                    tuple(keep), all_cards[i],
                    codes[lo:hi] - bounds[i], counts[lo:hi].copy(),
                )
            )
        return out

    def to_dense(self, *, budget: int | None = None) -> ContingencyTable:
        """Scatter into a dense :class:`ContingencyTable` (same layout)."""
        cells = self.n_cells
        if budget is not None and cells > budget:
            raise MemoryError(
                f"densifying this SparseCT needs {cells:.3g} cells > budget {budget:.3g}"
            )
        flat = np.zeros(cells, np.float32)
        flat[self.codes] = self.counts
        return ContingencyTable(self.rvs, jnp.asarray(flat.reshape(self.cards)))

    def to_device(self) -> "DeviceSparseCT":
        """Move this table's COO columns onto the device (one h2d copy)."""
        return DeviceSparseCT.from_host(self)


def sparse_from_dense(ct: ContingencyTable) -> SparseCT:
    """COO view of a dense CT (test utility and cross-check path)."""
    flat = np.asarray(ct.table, np.float32).reshape(-1)
    codes = np.flatnonzero(flat).astype(np.int64)
    return SparseCT(ct.rvs, tuple(ct.table.shape), codes, flat[codes])


def plan_marginal_batch(ct, keeps: list[tuple[str, ...]]):
    """Validate a batched-marginal request and lay out its code space.

    Shared by the host and device backends: returns ``(offsets, all_cards,
    total_space)`` where family ``i``'s re-encoded codes occupy
    ``[offsets[i], offsets[i] + prod(all_cards[i]))`` of one concatenated
    int64 code space, so a single shared sort is equivalent to per-family
    sorts.  Raises ``KeyError`` for unknown par-RVs and ``OverflowError``
    past the int64 composite-code headroom.
    """
    offsets: list[int] = []
    all_cards: list[tuple[int, ...]] = []
    offset = 0
    for keep in keeps:
        missing = [v for v in keep if v not in ct.rvs]
        if missing:
            raise KeyError(f"par-RVs {missing} not in this CT {ct.rvs}")
        cards = tuple(ct.card_of(v) for v in keep)
        offsets.append(offset)
        all_cards.append(cards)
        offset += math.prod(cards, start=1)
        if offset >= _MAX_CODE_SPACE:
            raise OverflowError(
                f"batched marginal code space {offset:.3g} overflows int64"
            )
    return offsets, all_cards, offset


# ---------------------------------------------------------------------------
# DeviceSparseCT: the COO table as device arrays (ROADMAP "device-resident COO")
# ---------------------------------------------------------------------------

#: Padding code for fixed-shape device aggregation results: sorts after
#: every valid composite code (< _MAX_CODE_SPACE) and matches the
#: ``segment_min`` fill value of ``ops.coo_aggregate``.
_PAD_CODE = np.iinfo(np.int64).max

#: Padding entity-row id for bucket-padded device messages: int32 max —
#: sorts after every valid row, never a legal row id, and identical to
#: ``ops.PAD_KEY`` so ``ops.coo_join`` recognizes padded probes directly.
_PAD_ROW = np.iinfo(np.int32).max


@dataclass(frozen=True)
class DeviceSparseCT:
    """Device-resident COO sufficient-statistics table (``CTLike``).

    The ``jax.Array`` twin of :class:`SparseCT`: ``codes`` are int64
    mixed-radix composite keys (held on device under a local
    ``enable_x64`` scope), ``counts`` float32 realized counts.  Because jit
    needs static shapes, device aggregation cannot compact dynamically:
    ``codes`` are *non-decreasing* with the unique valid cells as an
    ascending prefix, optionally followed by :data:`_PAD_CODE` entries
    carrying count 0, and individual cells may hold count 0 after exact
    cancellation — every consumer treats ``counts == 0`` as absent.
    ``to_host()`` restores the strict host canonical form.

    All CT algebra runs on device: re-encode is digit arithmetic on the
    code column, and canonicalization is one fused
    ``jax.lax.sort``+segment-sum launch (``ops.coo_aggregate``).  The
    structure-search hot loop additionally bypasses materialized marginals
    entirely via the fused ``ops.sparse_family_score`` kernel (see
    ``ScoreManager``).
    """

    rvs: tuple[str, ...]
    cards: tuple[int, ...]
    codes: jax.Array   # int64, non-decreasing, _PAD_CODE tail allowed
    counts: jax.Array  # float32, zeros allowed (treated as absent)

    def __post_init__(self):
        assert len(self.rvs) == len(self.cards), (self.rvs, self.cards)
        assert self.codes.shape == self.counts.shape, (self.codes.shape, self.counts.shape)

    # -- construction --------------------------------------------------------

    @classmethod
    def from_host(cls, ct: SparseCT) -> "DeviceSparseCT":
        """One h2d copy of an already-canonical host table."""
        with enable_x64():
            return cls(
                ct.rvs, ct.cards, ops.to_device(ct.codes), ops.to_device(ct.counts)
            )

    @classmethod
    def build(cls, rvs, cards, codes, counts) -> "DeviceSparseCT":
        """Canonicalize raw COO data on device (one ``coo_aggregate`` launch).

        Merge semantics: ``codes`` may be unsorted and contain duplicates —
        duplicate cells are summed (float64 accumulation, correctly-rounded
        float32 result, bit-identical to the host ``aggregate_codes``) —
        and entries may carry weight 0 or the :data:`_PAD_CODE` sentinel;
        both are legal padding.  The result keeps the *input length*:
        ascending unique codes as a prefix, then an int-max/zero-count tail
        (jit needs static shapes, so nothing is compacted here — builders
        trim the tail once at the end via one scalar sync, and every
        consumer treats ``counts == 0`` as absent).  Signed weights are
        allowed (the Möbius subtraction passes ``-CT[T]``); exact
        cancellations survive as zero-count cells, i.e. absent.  The dense
        cell count of ``cards`` is handed to the aggregation as its
        histogram-engine bound; small code spaces take the O(n) dense
        accumulator instead of the sort (and come back compacted to the
        realized-bin ladder rung rather than input length).
        """
        u, s = ops.coo_aggregate(
            codes, counts, num_bins=math.prod(cards) if cards else 1
        )
        return cls(tuple(rvs), tuple(cards), u, s)

    # -- CTLike protocol -----------------------------------------------------

    @property
    def n_cells(self) -> int:
        """Dense cell count this table *would* have (exact Python int)."""
        return math.prod(self.cards) if self.cards else 1

    def total(self):
        """Grand total, accumulated in :data:`TOTAL_ACC_DTYPE` -> float32.

        Counts are integer-valued, so the float64 accumulation is exact and
        the result is bit-identical to the host twin's ``total()`` (on a
        TPU backend, ``ops.count_acc_dtype`` falls back to float32 — exact
        up to 2**24-count totals — because float64 cannot lower there).
        """
        with enable_x64():
            t = _sp_total(self.counts)
        return np.float32(float(t))

    def n_nonzero(self) -> int:
        """Number of realized sufficient statistics (the paper's #SS)."""
        return int(_sp_n_nonzero(self.counts))

    def card_of(self, rv: str) -> int:
        return self.cards[self.rvs.index(rv)]

    def _reencode(self, order: tuple[str, ...]):
        """Kept axes re-encoded row-major in ``order`` -> (cards, codes, counts).

        Padding / zero-count entries are pinned to :data:`_PAD_CODE` so
        their (meaningless) digit arithmetic never lands on a real cell.
        The program is de-diversified on BOTH axes of its jit key: the
        input is bucket-padded up front (under a device build's stream
        floor, every sub-floor CT re-encode shares one length rung — the
        returned counts column is the padded twin, aligned with the
        codes), and the axis dimension is padded to :data:`_REENC_ARITY`
        identity axes (stride 1, card 1, new-stride 0: digit identically
        0, contributing nothing) so arity drops out of the key too.  CTs
        wider than the pad width (not seen in practice — FactorBase
        families are a child plus a handful of parents) fall back to
        their natural arity.
        """
        new_cards = tuple(self.card_of(v) for v in order)
        strides = radix_strides(list(self.cards))
        idxs = [self.rvs.index(v) for v in order]
        sel_strides = [strides[i] for i in idxs]
        sel_cards = [self.cards[i] for i in idxs]
        sel_new = radix_strides(list(new_cards))
        if (pad := _REENC_ARITY - len(idxs)) > 0:
            sel_strides += [1] * pad
            sel_cards += [1] * pad
            sel_new += [0] * pad
        with enable_x64():
            codes, counts, _ = ops._pad_coo_stream(
                self.codes, self.counts, _PAD_CODE
            )
            code = _sp_reencode(
                codes, counts,
                jnp.asarray(sel_strides, jnp.int64),
                jnp.asarray(sel_cards, jnp.int64),
                jnp.asarray(sel_new, jnp.int64),
            )
        return new_cards, code, counts

    def marginal(self, keep: tuple[str, ...]) -> "DeviceSparseCT":
        """GROUP BY a subset of the par-RVs — one device sort+segment-sum."""
        missing = [v for v in keep if v not in self.rvs]
        if missing:
            raise KeyError(f"par-RVs {missing} not in this CT {self.rvs}")
        new_cards, new_codes, counts = self._reencode(tuple(keep))
        return DeviceSparseCT.build(tuple(keep), new_cards, new_codes, counts)

    def transpose(self, order: tuple[str, ...]) -> "DeviceSparseCT":
        if tuple(order) == self.rvs:
            return self
        if sorted(order) != sorted(self.rvs):
            raise ValueError(f"transpose order {order} != axes {self.rvs}")
        new_cards, new_codes, counts = self._reencode(tuple(order))
        # permutation is a bijection on valid codes: the aggregation step of
        # build() only merges the zero-count padding entries
        return DeviceSparseCT.build(tuple(order), new_cards, new_codes, counts)

    def marginal_batch(self, keeps: list[tuple[str, ...]]) -> list["DeviceSparseCT"]:
        """Batched GROUP BY, device end-to-end (no host sort).

        Same concatenated-code-space construction as the host twin
        (:func:`plan_marginal_batch`), canonicalized by ONE
        ``ops.coo_aggregate`` launch; the only host round-trip is the B+1
        split bounds (a few dozen bytes).
        """
        if not keeps:
            return []
        offsets, all_cards, total_space = plan_marginal_batch(self, keeps)
        strides_self = radix_strides(list(self.cards))
        # (B, m_max) traced stride/card matrices, short keeps padded with
        # (stride 1, card 1, new-stride 0) — the padded digit is 0 and
        # contributes nothing, so ONE _sp_marginal_batch_encode program
        # serves every batch of this (#SS, B, m_max) signature.
        m_max = max((len(k) for k in keeps), default=1) or 1
        sel_s, sel_c, new_s = [], [], []
        for keep, cards in zip(keeps, all_cards):
            idxs = [self.rvs.index(v) for v in keep]
            pad = m_max - len(keep)
            sel_s.append([strides_self[i] for i in idxs] + [1] * pad)
            sel_c.append([self.cards[i] for i in idxs] + [1] * pad)
            new_s.append(list(radix_strides(list(cards))) + [0] * pad)
        with enable_x64():
            big_codes, big_counts = _sp_marginal_batch_encode(
                self.codes, self.counts,
                jnp.asarray(sel_s, jnp.int64),
                jnp.asarray(sel_c, jnp.int64),
                jnp.asarray(new_s, jnp.int64),
                jnp.asarray(list(offsets), jnp.int64),
            )
        codes, counts = ops.coo_aggregate(
            big_codes, big_counts, num_bins=total_space
        )
        with enable_x64():
            bounds_dev = _sp_bounds(
                codes, jnp.asarray(list(offsets) + [total_space], dtype=jnp.int64)
            )
        bounds = [int(b) for b in ops.to_host(bounds_dev)]
        out: list[DeviceSparseCT] = []
        for i, keep in enumerate(keeps):
            lo, hi = bounds[i], bounds[i + 1]
            with enable_x64():
                fam_codes, fam_counts = _sp_slice_shift(
                    codes, counts, lo, hi, jnp.int64(offsets[i])
                )
            out.append(
                DeviceSparseCT(tuple(keep), all_cards[i], fam_codes, fam_counts)
            )
        return out

    # -- residency -----------------------------------------------------------

    def to_host(self) -> SparseCT:
        """One d2h copy, compacted back to the strict host canonical form."""
        codes = ops.to_host(self.codes).astype(np.int64, copy=False)
        counts = ops.to_host(self.counts).astype(np.float32, copy=False)
        keep = counts != 0.0
        return SparseCT(self.rvs, self.cards, codes[keep], counts[keep])

    def to_dense(self, *, budget: int | None = None) -> ContingencyTable:
        return self.to_host().to_dense(budget=budget)


def as_host(ct):
    """Coerce a :class:`DeviceSparseCT` to its host twin (else pass through).

    The seam for host-side consumers (dense factor tables, per-cell numpy
    scoring): exactly one d2h copy, already compacted.
    """
    return ct.to_host() if isinstance(ct, DeviceSparseCT) else ct


# ---------------------------------------------------------------------------
# Sparse messages: COO (entity_row, code) -> weight
# ---------------------------------------------------------------------------


@dataclass
class _Msg:
    """One join-tree message, lexsorted by ``(rows, codes)`` and aggregated."""

    rows: np.ndarray     # int64 entity row ids
    codes: np.ndarray    # int64 mixed-radix codes over `cards`
    weights: np.ndarray  # float32
    cards: list[int]
    folded: list[str]    # par-RV vids, row-major axis order matching `cards`

    @property
    def code_space(self) -> int:
        return math.prod(self.cards) if self.cards else 1


def _combine_sparse(a: _Msg, b: _Msg) -> _Msg:
    """Join two messages of one fovar on entity row; code spaces concatenate.

    The sparse analogue of the dense ``_combine_messages`` outer product:
    output code = ``a_code * |b| + b_code`` (a-axes major).  A sort-merge
    join — both inputs are row-sorted, so matches are contiguous slices.
    """
    cb = b.code_space
    lo = np.searchsorted(b.rows, a.rows, side="left")
    hi = np.searchsorted(b.rows, a.rows, side="right")
    cnt = hi - lo
    total = int(cnt.sum())
    idx_a = np.repeat(np.arange(a.rows.size), cnt)
    starts = np.cumsum(cnt) - cnt
    within = np.arange(total) - np.repeat(starts, cnt)
    idx_b = np.repeat(lo, cnt) + within
    # (row, a_code, b_code) unique and lexsorted by construction — no re-agg.
    return _Msg(
        rows=a.rows[idx_a],
        codes=a.codes[idx_a] * cb + b.codes[idx_b],
        weights=a.weights[idx_a] * b.weights[idx_b],
        cards=a.cards + b.cards,
        folded=a.folded + b.folded,
    )


def _fold_all(msgs: list[_Msg]) -> _Msg:
    out = msgs[0]
    for m in msgs[1:]:
        out = _combine_sparse(out, m)
    return out


# ---------------------------------------------------------------------------
# Sparse join-tree contraction (conditional CT, relationships = True)
# ---------------------------------------------------------------------------


def _contract_join_tree(plan: QueryPlan, cat, cond_true, comp, *,
                        initial, fold, eliminate, finish):
    """Leaf-elimination driver shared by the host and device sparse builders.

    The tree walk itself (leaf choice, root choice, edge bookkeeping) is
    residency-independent; the two builders differ only in how messages are
    represented and combined, injected via the four callbacks:

      * ``initial(fid)`` — a fovar's own attribute message;
      * ``fold(msgs)`` — combine one fovar's pending messages (row join);
      * ``eliminate(msg, rname, leaf, other)`` — push a folded leaf message
        through a relationship (the foreign-key join);
      * ``finish(fid, msgs)`` — contract the root over its entity rows.

    Root choice matches the dense backend: the group fovar when present
    (its rows must survive as the §VI block axis), else the max-degree hub.
    """
    if len(comp) == 1 and not plan.adj[comp[0]]:
        return finish(comp[0], [initial(comp[0])])

    state = {f: [initial(f)] for f in comp}
    remaining_edges = {
        rname: tuple(f.fid for f in cat.rel_var_of(rname).fovars)
        for rname in cond_true
        if plan.comp_of[cat.rel_var_of(rname).fovars[0].fid]
        == plan.comp_of[comp[0]]
    }
    degree = {f: len(plan.adj[f]) for f in comp}
    alive = set(comp)
    if plan.group_fovar in comp:
        root = plan.group_fovar
    else:
        root = max(comp, key=lambda f: (degree[f], f))

    while len(alive) > 1:
        leaf = min(f for f in alive if degree[f] <= 1 and f != root)
        rname, (f1, f2) = next(
            (rn, fs) for rn, fs in remaining_edges.items() if leaf in fs
        )
        other = f2 if leaf == f1 else f1
        msg = fold(state[leaf])
        state[other].append(eliminate(msg, rname, leaf, other))
        alive.discard(leaf)
        degree[other] -= 1
        degree[leaf] -= 1
        del remaining_edges[rname]

    assert next(iter(alive)) == root
    return finish(root, state[root])


#: Expansion cap for the cyclic-component ground join below: past this many
#: intermediate tuples the explicit join is genuinely out of scope (the
#: fail-loud boundary of the schema contract, see docs/ARCHITECTURE.md).
_GROUND_JOIN_MAX_TUPLES = 1 << 27


def _ground_join_component(db, plan: QueryPlan, cond_true, comp):
    """Contract one *cyclic* component by explicit host natural join.

    Components whose join graph has more edges than a spanning tree —
    parallel relationships between the same fovar pair, rings, diamonds,
    two self-relationships over one entity — admit no leaf-elimination
    order, so this materializes the groundings directly: seed a tuple
    table from one relationship's rows, then join the remaining component
    relationships in shared-fovar order (a both-endpoints-bound
    relationship filters, a one-bound relationship expands and binds the
    new fovar).  Each surviving tuple is one set of relationship rows
    jointly grounding the component, so folding the bound entities'
    attribute codes (plus queried relationship attributes, the §VI group
    axis and ``restrict`` filters) and aggregating with weight 1 yields
    exactly the component count vector the tree contraction would.

    Output matches the tree path's component contract: ``(codes, counts,
    cards, folded)`` with strictly-increasing codes, float64-accumulated
    float32 counts (bit-identical wherever both paths apply), no zeros.
    Counts stay multilinear in every relationship's row multiset — the
    join expands one tuple per matching *row* — so sharded builds and
    signed delta views factor through it unchanged.

    Cost is the realized grounding count, bounded fail-loud at
    :data:`_GROUND_JOIN_MAX_TUPLES`; the fuzz corpus keeps populations
    tiny, and real FactorBase schemas are trees (the paper's lattice walks
    relationship chains), so this is the correctness backstop, not a hot
    path.
    """
    cat = db.catalog
    comp_set = set(comp)
    rels = [
        r for r in cond_true
        if cat.rel_var_of(r).fovars[0].fid in comp_set
    ]

    def rel_fids(r: str) -> set[str]:
        return {f.fid for f in cat.rel_var_of(r).fovars}

    # Join order: seed with the smallest fact table (delta views pass the
    # touched relationship's O(Δ) rows, which keeps the whole walk O(Δ)),
    # then always attach the smallest pending relationship sharing a bound
    # fovar — every step is a join, never a cross product.
    ordered = [min(rels, key=lambda r: (db.relationships[r].n_rows, r))]
    bound_fids = rel_fids(ordered[0])
    pending = [r for r in rels if r != ordered[0]]
    while pending:
        nxt = min(
            (r for r in pending if rel_fids(r) & bound_fids),
            key=lambda r: (db.relationships[r].n_rows, r),
        )
        ordered.append(nxt)
        bound_fids |= rel_fids(nxt)
        pending.remove(nxt)

    first = db.relationships[ordered[0]]
    g1, g2 = (f.fid for f in cat.rel_var_of(ordered[0]).fovars)
    bound = {
        g1: np.asarray(first.fk1, np.int64),
        g2: np.asarray(first.fk2, np.int64),
    }
    # queried relationship-attribute digit columns, one entry per tuple
    parts: list[tuple[np.ndarray, int, str]] = [
        (np.asarray(first.attrs[rv.column], np.int64), rv.cardinality, rv.vid)
        for rv in plan.rel_attrs[ordered[0]]
    ]

    for rname in ordered[1:]:
        rel = db.relationships[rname]
        f1, f2 = (f.fid for f in cat.rel_var_of(rname).fovars)
        fk1 = np.asarray(rel.fk1, np.int64)
        fk2 = np.asarray(rel.fk2, np.int64)
        new_fovar = f2 if f2 not in bound else (f1 if f1 not in bound else None)
        if new_fovar is None:
            # both endpoints bound: match on the composite pair key
            n2 = max(db.entities[cat.fovar(f2).entity].n_rows, 1)
            keys = fk1 * n2 + fk2
            probe = bound[f1] * n2 + bound[f2]
        else:
            keys = fk1 if new_fovar == f2 else fk2
            probe = bound[f1 if new_fovar == f2 else f2]
        order = np.argsort(keys, kind="stable")
        skeys = keys[order]
        lo = np.searchsorted(skeys, probe, side="left")
        hi = np.searchsorted(skeys, probe, side="right")
        cnt = hi - lo
        total = int(cnt.sum())
        if total > _GROUND_JOIN_MAX_TUPLES:
            raise MemoryError(
                f"ground join of cyclic component {list(comp)} expands to "
                f"{total:.3g} tuples at {rname}; this shape is out of scope "
                "for explicit grounding at this population size"
            )
        idx_t = np.repeat(np.arange(probe.size), cnt)
        starts = np.cumsum(cnt) - cnt
        within = np.arange(total) - np.repeat(starts, cnt)
        idx_r = order[np.repeat(lo, cnt) + within]

        bound = {f: a[idx_t] for f, a in bound.items()}
        parts = [(d[idx_t], c, v) for d, c, v in parts]
        if new_fovar is not None:
            bound[new_fovar] = (fk1 if new_fovar == f1 else fk2)[idx_r]
        parts += [
            (np.asarray(rel.attrs[rv.column], np.int64)[idx_r],
             rv.cardinality, rv.vid)
            for rv in plan.rel_attrs[rname]
        ]

    # a connected cyclic component has every fovar on some edge, so all
    # of ``comp`` is bound now
    assert comp_set <= set(bound), (comp, sorted(bound))
    for fid, row in plan.restrict.items():
        if fid in bound:
            m = bound[fid] == row
            bound = {f: a[m] for f, a in bound.items()}
            parts = [(d[m], c, v) for d, c, v in parts]

    fold: list[tuple[np.ndarray, int, str]] = []
    if plan.group_fovar in bound:
        fold.append((
            bound[plan.group_fovar],
            db.entities[cat.fovar(plan.group_fovar).entity].n_rows,
            GROUP_AXIS,
        ))
    for fid in comp:
        for rv in plan.ent_attrs[fid]:
            col = np.asarray(db.entities[rv.table].attrs[rv.column], np.int64)
            fold.append((col[bound[fid]], rv.cardinality, rv.vid))
    fold += parts

    cards = [c for _, c, _ in fold]
    folded = [v for _, _, v in fold]
    n = bound[comp[0]].size
    codes = np.zeros(n, np.int64)
    for (digits, _, _), stride in zip(fold, radix_strides(cards)):
        codes += digits * stride
    codes, counts = aggregate_codes(codes, np.ones(n, np.float32))
    return codes, counts, cards, folded


def sparse_ct_conditional(
    db: RelationalDatabase,
    attr_rvs: tuple[str, ...],
    cond_true: tuple[str, ...],
    fovar_universe: tuple[str, ...] | None = None,
    *,
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
    msg_cache: "LeafMessageCache | None" = None,
) -> SparseCT:
    """Sparse twin of :func:`repro.core.counts.ct_conditional`.

    Same cells (validated against the dense backend and the int64 brute
    force), but every intermediate is a COO tuple table, so memory scales
    with realized groundings instead of domain cross products.

    ``msg_cache`` (incremental maintenance) serves unchanged leaf messages
    — which depend only on entity tables, immutable across relationship
    deltas — from a :class:`LeafMessageCache` instead of re-encoding them.
    """
    cat = db.catalog
    plan: QueryPlan = plan_conditional(
        db, attr_rvs, cond_true, fovar_universe,
        group_fovar=group_fovar, restrict=restrict,
    )
    code_space = math.prod((cat[v].cardinality for v in attr_rvs), start=1)
    if group_fovar is not None:
        code_space *= db.entities[cat.fovar(group_fovar).entity].n_rows
    if code_space >= _MAX_CODE_SPACE:
        raise OverflowError(
            f"query code space {code_space:.3g} overflows int64 composite codes"
        )

    def fovar_n_rows(fid: str) -> int:
        return db.entities[cat.fovar(fid).entity].n_rows

    def _build_initial(fid: str) -> _Msg:
        n = fovar_n_rows(fid)
        rows = np.arange(n, dtype=np.int64)
        weights = np.ones(n, np.float32)
        cards = [rv.cardinality for rv in plan.ent_attrs[fid]]
        codes = np.zeros(n, np.int64)
        for rv, stride in zip(plan.ent_attrs[fid], radix_strides(cards)):
            col = np.asarray(db.entities[rv.table].attrs[rv.column], np.int64)
            codes += col * stride
        if fid in plan.restrict:
            keep = rows == plan.restrict[fid]
            rows, codes, weights = rows[keep], codes[keep], weights[keep]
        # rows are sorted; codes unique per row (one tuple per entity)
        return _Msg(rows, codes, weights, cards, [rv.vid for rv in plan.ent_attrs[fid]])

    def initial_message(fid: str) -> _Msg:
        if msg_cache is None:
            return _build_initial(fid)
        key = ("host", fid, tuple(rv.vid for rv in plan.ent_attrs[fid]),
               plan.restrict.get(fid))
        return msg_cache.get(key, lambda: _build_initial(fid))

    def eliminate_leaf(msg: _Msg, rname: str, leaf: str, other: str) -> _Msg:
        """Push a leaf's message through a relationship (sparse FK join)."""
        rel = db.relationships[rname]
        rel_rv = cat.rel_var_of(rname)
        f1, f2 = (f.fid for f in rel_rv.fovars)
        fk_leaf = np.asarray(rel.fk1 if leaf == f1 else rel.fk2, np.int64)
        fk_other = np.asarray(rel.fk2 if leaf == f1 else rel.fk1, np.int64)
        r_cards = [rv.cardinality for rv in plan.rel_attrs[rname]]
        r_names = [rv.vid for rv in plan.rel_attrs[rname]]
        d_r = math.prod(r_cards, start=1)
        rcode = np.zeros(fk_leaf.size, np.int64)
        for rv, stride in zip(plan.rel_attrs[rname], radix_strides(r_cards)):
            rcode += np.asarray(rel.attrs[rv.column], np.int64) * stride

        lo = np.searchsorted(msg.rows, fk_leaf, side="left")
        hi = np.searchsorted(msg.rows, fk_leaf, side="right")
        cnt = hi - lo
        total = int(cnt.sum())
        idx_r = np.repeat(np.arange(fk_leaf.size), cnt)
        starts = np.cumsum(cnt) - cnt
        within = np.arange(total) - np.repeat(starts, cnt)
        idx_m = np.repeat(lo, cnt) + within
        rows, codes, weights = _aggregate_pairs(
            fk_other[idx_r],
            msg.codes[idx_m] * d_r + rcode[idx_r],
            msg.weights[idx_m],
        )
        return _Msg(rows, codes, weights, msg.cards + r_cards, msg.folded + r_names)

    def finish_root(fid: str, msgs: list[_Msg]):
        """Contract the root over its entity rows -> flat COO count vector."""
        msg = _fold_all(msgs)
        if fid == plan.group_fovar:
            c = msg.code_space
            return (
                msg.rows * c + msg.codes,          # lexsorted => still sorted
                msg.weights,
                [fovar_n_rows(fid)] + msg.cards,
                [GROUP_AXIS] + msg.folded,
            )
        codes, counts = aggregate_codes(msg.codes, msg.weights)
        return codes, counts, msg.cards, msg.folded

    def contract_component(comp: tuple[str, ...]):
        if plan.comp_of[comp[0]] in plan.cyclic:
            # no leaf-elimination order exists — ground join instead
            return _ground_join_component(db, plan, cond_true, comp)
        return _contract_join_tree(
            plan, cat, cond_true, comp,
            initial=initial_message, fold=_fold_all,
            eliminate=eliminate_leaf, finish=finish_root,
        )

    # Contract each component; cross product of sparse count vectors.
    vec_codes = np.zeros(1, np.int64)
    vec_counts = np.ones(1, np.float32)
    all_cards: list[int] = []
    all_folded: list[str] = []
    for comp in plan.comps:
        c_codes, c_counts, cards, folded = contract_component(comp)
        if not cards:
            # Attribute-less component: a scalar multiplier (its population
            # count), exactly the dense path's squeezed "__scalar__" axis.
            scalar = float(c_counts.sum(dtype=np.float64))
            vec_counts = vec_counts * np.float32(scalar)
            continue
        c = math.prod(cards)
        vec_codes = (vec_codes[:, None] * c + c_codes[None, :]).reshape(-1)
        vec_counts = (vec_counts[:, None] * c_counts[None, :]).reshape(-1)
        all_cards += cards
        all_folded += folded
    keep = vec_counts != 0.0
    vec_codes, vec_counts = vec_codes[keep], vec_counts[keep]

    ct = SparseCT(tuple(all_folded), tuple(all_cards), vec_codes, vec_counts)
    out_order = tuple(attr_rvs)
    if group_fovar is not None:
        out_order = (GROUP_AXIS,) + out_order
    return ct.transpose(out_order)


# ---------------------------------------------------------------------------
# Möbius virtual join on COO tables
# ---------------------------------------------------------------------------


def _sparse_sub(star: SparseCT, t_sum: SparseCT) -> SparseCT:
    """``CT[F] = CT[*] − CT[T]`` cellwise on aligned COO tables."""
    assert star.rvs == t_sum.rvs, (star.rvs, t_sum.rvs)
    codes = np.concatenate([star.codes, t_sum.codes])
    deltas = np.concatenate([star.counts, -t_sum.counts])
    codes, counts = aggregate_codes(codes, deltas)
    return SparseCT(star.rvs, star.cards, codes, counts)


def mobius_code_space(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    added: list[str],
    group_fovar: str | None,
) -> int:
    """Largest code space any Möbius recursion level assembles into.

    Every queried axis, plus an extra indicator digit (x2) for each
    relationship injected only to support its attributes, plus the group
    axis.  Shared overflow guard of the host and device sparse builders —
    without it, huge schemas would wrap int64 silently instead of raising.
    Exact Python int.
    """
    cat = db.catalog
    code_space = math.prod((cat[v].cardinality for v in rvs), start=1)
    code_space *= 2 ** len(added)
    if group_fovar is not None:
        code_space *= db.entities[cat.fovar(group_fovar).entity].n_rows
    return code_space


def sparse_contingency_table(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    *,
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
    fovar_universe: tuple[str, ...] | None = None,
    touched_rel: str | None = None,
    msg_cache: "LeafMessageCache | None" = None,
) -> SparseCT:
    """Sparse twin of :func:`repro.core.counts.contingency_table`.

    The Möbius recursion is structurally identical to the dense one; the
    per-relationship assembly works on codes: the F block is the sparse
    difference ``star − Σ_rattrs T`` embedded at the ``n/a`` (code-0)
    relationship-attribute cells, and the indicator becomes the leading
    mixed-radix digit, so F-cells and T-cells occupy disjoint sorted halves
    of the code space and concatenate without re-sorting.

    ``touched_rel`` switches the recursion to **delta mode** (incremental
    maintenance, see :func:`sparse_ct_delta`): the caller passes a delta
    *view* whose ``touched_rel`` table holds only the delta rows, and the
    table computed is ``ΔCT`` — the star branch at ``touched_rel``'s level
    excludes that relationship entirely, so its delta is identically zero
    and the branch is pruned (``F = 0 − Σ_rattrs T``).  Every surviving
    leaf conditional then has ``touched_rel`` among its joined fact tables
    and is linear in its (delta) rows.
    """
    cat = db.catalog
    want, rel_names, added, attr_rvs, universe_t = mobius_setup(db, rvs, fovar_universe)

    code_space = mobius_code_space(db, rvs, added, group_fovar)
    if code_space >= _MAX_CODE_SPACE:
        raise OverflowError(
            f"CT code space {code_space:.3g} overflows int64 composite codes; "
            "split the query into smaller par-RV subsets"
        )

    g_prefix: tuple[str, ...] = (GROUP_AXIS,) if group_fovar is not None else ()

    def recurse(
        remaining: tuple[str, ...], fixed_true: tuple[str, ...], attrs: tuple[str, ...]
    ) -> SparseCT:
        if not remaining:
            return sparse_ct_conditional(
                db, attrs, fixed_true, universe_t,
                group_fovar=group_fovar, restrict=restrict,
                msg_cache=msg_cache,
            )
        r, rest = remaining[0], remaining[1:]
        r_attr_vids = tuple(
            v.vid for v in want if v.kind == KIND_REL_ATTR and v.table == r
        )
        t_branch = recurse(rest, fixed_true + (r,), attrs)

        shared = tuple(v for v in t_branch.rvs if v not in r_attr_vids)
        t_ct = t_branch.transpose(shared + r_attr_vids)
        t_sum = t_ct.marginal(shared) if r_attr_vids else t_ct
        if r == touched_rel:
            # Delta mode: the star branch never joins ``r``, so Δstar ≡ 0
            # and the whole subtree is pruned — ``ΔF = 0 − Σ_rattrs ΔT``.
            f_count = SparseCT(t_sum.rvs, t_sum.cards, t_sum.codes, -t_sum.counts)
        else:
            star_attrs = tuple(v for v in attrs if v not in r_attr_vids)
            star_branch = recurse(rest, fixed_true, star_attrs)
            star = star_branch.transpose(shared)
            f_count = _sparse_sub(star, t_sum)  # counts with r = False

        r_cards = tuple(cat[v].cardinality for v in r_attr_vids)
        d_r = math.prod(r_cards, start=1)
        shared_cards = t_ct.cards[: len(shared)]
        d_rest = math.prod(shared_cards, start=1) * d_r

        # F block: mass at the n/a (code 0) cells of the r-attribute axes;
        # T block: shifted past the F half by the indicator digit.
        f_codes = f_count.codes * d_r
        t_codes = t_ct.codes + d_rest
        rel_vid = cat.rel_var_of(r).vid
        return SparseCT(
            (rel_vid,) + shared + r_attr_vids,
            (2,) + shared_cards + r_cards,
            np.concatenate([f_codes, t_codes]),
            np.concatenate([f_count.counts, t_ct.counts]),
        )

    full = recurse(tuple(rel_names), (), attr_rvs)
    if added:
        keep = g_prefix + tuple(v.vid for v in want)
        full = full.marginal(keep)
    return full.transpose(g_prefix + tuple(rvs))


# ---------------------------------------------------------------------------
# Device-side build: COO messages as jax.Arrays (ROADMAP "device-side builds")
# ---------------------------------------------------------------------------
#
# The device twin of the host builder above: the same join-tree contraction
# and Möbius recursion (shared ``plan_conditional`` / ``mobius_setup`` /
# ``_contract_join_tree``), but every message is a device COO table, the
# foreign-key join is ``ops.coo_join`` (sort-merge on entity rows), and every
# canonicalization — including each Möbius T/don't-care subtraction — is a
# signed ``ops.coo_aggregate`` pass.  No COO column ever materializes on
# host; the only d2h traffic is the scalar size syncs (``ops.sync_scalar``)
# that fix data-dependent launch shapes.  Counts are exact: every weight is
# an integer-valued float32 and all aggregation accumulates in float64, so
# ``to_host()`` of a device-built table is bit-identical to the host build
# (codes and counts) for any total below 2**53.


@dataclass
class _DevMsg:
    """Device join-tree message: the ``jax.Array`` twin of :class:`_Msg`.

    Same invariants as the host message — lexsorted by ``(rows, codes)``
    and aggregated — except for **shape-bucket padding**: every column is
    padded up to the ``kernels.bucketing`` row ladder with an identity
    suffix (``rows = _PAD_ROW``, ``codes = _PAD_CODE``, ``weights = 0``),
    so the whole build flows through the small set of jitted per-rung
    super-programs below — one compiled program per (ladder rung, arity)
    signature, not one per data-dependent message length or per radix
    constant.  Valid entries form a prefix
    (weights strictly positive — messages never subtract), pads a suffix
    that sorts last, so ``rows`` is still ready to be the sorted side of
    the next ``ops.coo_join`` (pad rows are never matched: every valid
    entity row id is < ``_PAD_ROW``).  ``rows`` are int32 (entity row
    ids), ``codes`` int64 mixed-radix composite keys held under the
    module's ``enable_x64`` scoping contract, ``weights`` float32.
    """

    rows: jax.Array      # int32 entity row ids, non-decreasing
    codes: jax.Array     # int64 mixed-radix codes over `cards`
    weights: jax.Array   # float32
    cards: list[int]
    folded: list[str]    # par-RV vids, row-major axis order matching `cards`
    #: entry index == entity row id over the whole population (the shape of
    #: an un-restricted initial message: ``rows`` is ``arange(n)`` plus the
    #: bucket-pad suffix).  Joins against a dense message need no sort-merge
    #: — the other side's row column IS the gather index.
    dense_rows: bool = False

    @property
    def code_space(self) -> int:
        return math.prod(self.cards) if self.cards else 1


# ---------------------------------------------------------------------------
# Build super-programs: one traced function per (shape, arity) signature
# ---------------------------------------------------------------------------
#
# Every step of the device build used to run as an *eager* chain of jnp
# ops — correct, but each distinct chain backend-compiles its own set of
# one-off programs, and the radix constants baked into the chains (strides,
# code spaces, arity offsets) multiplied the count into the hundreds.  The
# functions below are the same arithmetic folded into a small set of jitted
# **super-programs**.  Two rules keep their compile count flat:
#
#   1. All radix constants are passed as *traced* int64 scalars/vectors.
#      jit keys its cache on (shape, dtype, weak_type) — never on traced
#      values — so one compiled program serves every stride/cardinality
#      combination of a given arity.  Calls happen inside ``enable_x64``
#      so the int64 arithmetic contract is unchanged.
#   2. Arity and ladder rung are the ONLY cache keys (argument counts and
#      shapes), both bounded: arity by the schema, shapes by the
#      ``kernels.bucketing`` row ladder.
#
# ``REPRO_FUSED_BUILD=0`` (or :func:`set_fused_build`) drops every
# super-program back to its eager body — same source, same results — as a
# bisection aid when a fusion is suspected.

def fused_build() -> bool:
    """Whether the device build runs its jitted super-programs (default).

    Resolves through :mod:`repro.core.config` (``REPRO_FUSED_BUILD`` env
    fallback, ``engine_config(fused_build=...)`` for scoped use).
    """
    return config.resolve("fused_build")


def set_fused_build(on: bool) -> bool:
    """Toggle the super-program fusion; returns the previous setting.

    .. deprecated:: delegates to :mod:`repro.core.config`; prefer
       ``engine_config(fused_build=...)`` for scoped use.
    """
    return config.set_override("fused_build", bool(on))


def _maybe_jit(fn=None, *, static_argnums=()):
    """jit a build super-program behind the ``REPRO_FUSED_BUILD`` knob.

    The decorated function dispatches per call: jitted when fusion is on,
    the plain eager body when it is off — one source of truth either way.
    """
    if fn is None:
        return functools.partial(_maybe_jit, static_argnums=static_argnums)
    jitted = jax.jit(fn, static_argnums=static_argnums)

    @functools.wraps(fn)
    def wrapper(*args):
        return (jitted if fused_build() else fn)(*args)

    return wrapper


@_maybe_jit
def _sp_encode(strides, *cols):
    """Mixed-radix encode of attribute columns: ``sum(col_i * stride_i)``."""
    code = jnp.zeros(cols[0].shape, jnp.int64)
    for i, col in enumerate(cols):
        code = code + col.astype(jnp.int64) * strides[i]
    return code


def _pad_cols_to(rows, codes, weights, n_pad: int):
    """(traced helper) top message columns up to ``n_pad`` with the identity
    suffix ``(_PAD_ROW, _PAD_CODE, 0)``."""
    n = int(codes.shape[0])
    if n_pad <= n:
        return rows, codes, weights
    w = n_pad - n
    rows = jnp.concatenate([rows, jnp.full((w,), _PAD_ROW, jnp.int32)])
    codes = jnp.concatenate([codes, jnp.full((w,), _PAD_CODE, jnp.int64)])
    weights = jnp.concatenate([weights, jnp.zeros((w,), jnp.float32)])
    return rows, codes, weights


@_maybe_jit(static_argnums=(1, 2))
def _sp_initial_dense(strides, n: int, n_pad: int, *cols):
    """Un-restricted initial message: encode + arange rows + unit weights +
    bucket pad, one program per (entity size, arity)."""
    codes = jnp.zeros((n,), jnp.int64)
    for i, col in enumerate(cols):
        codes = codes + col.astype(jnp.int64) * strides[i]
    rows = jnp.arange(n, dtype=jnp.int32)
    weights = jnp.ones((n,), jnp.float32)
    return _pad_cols_to(rows, codes, weights, n_pad)


@_maybe_jit(static_argnums=(2, 3))
def _sp_initial_restrict(strides, r, n: int, n_pad: int, *cols):
    """Restricted initial message: the single kept entity row, selected by a
    *traced* ``dynamic_slice`` so the program is independent of which row —
    the restrict value changes per group sweep, the program must not."""
    codes = jnp.zeros((n,), jnp.int64)
    for i, col in enumerate(cols):
        codes = codes + col.astype(jnp.int64) * strides[i]
    r = r.astype(jnp.int32)
    codes1 = jax.lax.dynamic_slice(codes, (r,), (1,))
    rows1 = jnp.full((1,), r, jnp.int32)
    weights1 = jnp.ones((1,), jnp.float32)
    return _pad_cols_to(rows1, codes1, weights1, n_pad)


def _pack_inline(rows, codes, weights, code_space):
    """(traced helper) pack ``(row, code)`` into one int64 composite,
    row-major.  Zero-weight entries (bucket padding — message weights
    proper are strictly positive) are pinned to :data:`_PAD_CODE` before
    packing so their garbage row/code values can neither overflow the
    packing nor land on a real cell."""
    valid = weights != 0.0
    return jnp.where(
        valid,
        jnp.where(valid, rows, 0).astype(jnp.int64) * code_space
        + jnp.where(valid, codes, 0),
        _PAD_CODE,
    )


_sp_pack = _maybe_jit(_pack_inline)


@_maybe_jit
def _sp_elim_dense_pack(codes_m, weights_m, fk_leaf, fk_other, rcode, d_r, cs_out):
    """Dense-message leaf elimination + pack, fused: the FK column gathers
    the message directly (entry index == entity row id), relationship
    attributes splice in at radix ``d_r``, and the result is packed against
    the receiving fovar's rows in the same program."""
    codes = codes_m[fk_leaf] * d_r + rcode
    weights = weights_m[fk_leaf]
    rows_j = fk_other.astype(jnp.int32)
    return _pack_inline(rows_j, codes, weights, cs_out), weights


@_maybe_jit
def _sp_elim_join_pack(
    codes_m, weights_m, rcode, fk_other, idx_m, idx_r, valid, d_r, cs_out
):
    """Sort-merge leaf elimination + pack, fused: gather both join sides
    through the validity mask (garbage-slot gathers may surface
    :data:`_PAD_CODE` values whose radix shift would overflow int64),
    splice relationship attributes, pack against the receiving fovar."""
    cm = jnp.where(valid, codes_m[idx_m], 0)
    codes = jnp.where(valid, cm * d_r + rcode[idx_r], _PAD_CODE)
    weights = jnp.where(valid, weights_m[idx_m], 0.0)
    rows_j = jnp.where(valid, fk_other[idx_r].astype(jnp.int32), _PAD_ROW)
    return _pack_inline(rows_j, codes, weights, cs_out), weights


@_maybe_jit(static_argnums=(3,))
def _sp_unpack(u, s, code_space, n_keep: int):
    """Slice an aggregation result to its compaction rung and unpack the
    row/code composite — one program per (rung in, rung out) pair.  Dead
    cells are pinned back to the ``_PAD_ROW``/``_PAD_CODE`` identity."""
    u, s = u[:n_keep], s[:n_keep]
    ok = s != 0.0
    u_safe = jnp.where(ok, u, 0)
    rows = jnp.where(ok, u_safe // code_space, _PAD_ROW).astype(jnp.int32)
    codes = jnp.where(ok, u_safe % code_space, _PAD_CODE)
    return rows, codes, s


#: Tail-compaction slice of a (codes, counts) pair.  Aliases the ops-side
#: dispatcher's program so build- and dispatcher-side compactions of the
#: same (width, keep) signature share ONE compiled slice instead of two.
_sp_slice2 = ops._slice2_jit


@_maybe_jit
def _sp_count_valid(codes):
    """Non-pad entry count of a canonicalized code column."""
    return jnp.sum(codes != _PAD_CODE)


@_maybe_jit(static_argnums=(6,))
def _sp_combine_dense(sp_rows, sp_codes, sp_weights, dn_codes, dn_weights, cb, b_dense: bool):
    """Message combine against a dense side: the sparse side's row column
    IS the gather index.  ``b_dense`` fixes which factor is code-major."""
    valid = sp_weights != 0.0
    idx = jnp.where(valid, sp_rows, 0)
    # mask codes through validity first: pad-lane _PAD_CODE values would
    # overflow the int64 radix shift
    cs = jnp.where(valid, sp_codes, 0)
    cd = jnp.where(valid, dn_codes[idx], 0)
    ca_, cb_ = (cs, cd) if b_dense else (cd, cs)
    codes = jnp.where(valid, ca_ * cb + cb_, _PAD_CODE)
    weights = jnp.where(valid, sp_weights * dn_weights[idx], 0.0)
    return codes, weights


@_maybe_jit
def _sp_combine_join(a_rows, a_codes, a_weights, b_codes, b_weights, idx_b, idx_a, valid, cb):
    """Message combine through a sort-merge join's match indices."""
    ca = jnp.where(valid, a_codes[idx_a], 0)
    codes = jnp.where(valid, ca * cb + b_codes[idx_b], _PAD_CODE)
    rows = jnp.where(valid, a_rows[idx_a], _PAD_ROW)
    weights = jnp.where(valid, a_weights[idx_a] * b_weights[idx_b], 0.0)
    return rows, codes, weights


@_maybe_jit
def _sp_cross(vec_codes, vec_counts, c_codes, c_counts, c):
    """Cross product of two component count vectors (codes a-major)."""
    new_counts = (vec_counts[:, None] * c_counts[None, :]).reshape(-1)
    # pad entries of either factor (count 0, code _PAD_CODE) would overflow
    # the radix shift — zero them through the mask, then re-pin the
    # product's dead cells to the padding identity
    va = jnp.where(vec_counts != 0.0, vec_codes, 0)
    vb = jnp.where(c_counts != 0.0, c_codes, 0)
    new_codes = jnp.where(
        new_counts != 0.0,
        (va[:, None] * c + vb[None, :]).reshape(-1),
        _PAD_CODE,
    )
    return new_codes, new_counts


def _concat_pad(codes_a, counts_a, codes_b, counts_b, n_pad: int):
    """Concatenate two COO streams and bucket-pad in the same program.

    The concatenated length ``len(a) + len(b)`` is almost never a ladder
    rung, so emitting it raw forces the downstream aggregation to run a
    separate pad program per odd length; fusing the identity padding
    (:data:`_PAD_CODE` / count 0) here keeps the whole subtract/assemble →
    aggregate chain at two programs per signature instead of three.
    """
    fill = n_pad - codes_a.shape[0] - codes_b.shape[0]
    return (
        jnp.concatenate(
            [codes_a, codes_b, jnp.full((fill,), _PAD_CODE, codes_a.dtype)]
        ),
        jnp.concatenate(
            [counts_a, counts_b, jnp.zeros((fill,), counts_a.dtype)]
        ),
    )


@_maybe_jit(static_argnums=(4,))
def _sp_signed_concat(codes_a, counts_a, codes_b, counts_b, n_pad: int):
    """Concatenate ``(a, -b)`` for the Möbius don't-care subtraction,
    bucket-padded to ``n_pad`` in the same program."""
    return _concat_pad(codes_a, counts_a, codes_b, -counts_b, n_pad)


@_maybe_jit(static_argnums=(6,))
def _sp_mobius_assemble(f_codes, f_counts, t_codes, t_counts, d_r, d_rest, n_pad: int):
    """F/T block assembly of one Möbius level: the F block embeds at the
    ``n/a`` (code 0) relationship-attribute cells, the T block shifts past
    the F half by the indicator digit.  Padding/zero cells are pinned to
    :data:`_PAD_CODE` *before* the shift so garbage codes can't wrap into
    range.  Output is bucket-padded to ``n_pad`` in the same program."""
    f_valid = f_counts != 0.0
    f_c = jnp.where(f_valid, jnp.where(f_valid, f_codes, 0) * d_r, _PAD_CODE)
    t_valid = t_counts != 0.0
    t_c = jnp.where(t_valid, jnp.where(t_valid, t_codes, 0) + d_rest, _PAD_CODE)
    return _concat_pad(f_c, f_counts, t_c, t_counts, n_pad)


#: Fixed axis width for :meth:`DeviceSparseCT._reencode`'s program: selection
#: vectors are padded to this many identity axes so the re-encode compiles
#: once per length rung instead of once per (length, arity) pair.  Group-by
#: re-encodes carry the group axis plus every attribute (10-14 axes on the
#: benchmark schemas), so the width must clear that, not just family arity.
_REENC_ARITY = 16


@_maybe_jit
def _sp_reencode(codes, counts, sel_strides, sel_cards, new_strides):
    """Digit-extract + re-encode the kept axes of a CT code column.
    Keyed by length rung only — strides and cardinalities ride along as
    traced vectors, padded to :data:`_REENC_ARITY` identity axes."""
    valid = counts != 0.0
    code = jnp.zeros(codes.shape, jnp.int64)
    for i in range(sel_strides.shape[0]):
        digit = (codes // sel_strides[i]) % sel_cards[i]
        code = code + digit * new_strides[i]
    return jnp.where(valid, code, _PAD_CODE)


@_maybe_jit
def _sp_marginal_batch_encode(codes, counts, sel_strides, sel_cards, new_strides, offsets):
    """Concatenated-code-space encode of a whole marginal batch, fused.

    ``sel_strides``/``sel_cards``/``new_strides`` are (B, m_max) matrices,
    short keeps padded with (stride 1, card 1, new-stride 0) — the padded
    digit is identically 0 and contributes nothing.  One program per
    (#SS, B, m_max), replacing the per-family eager encode chains of the
    search phase.
    """
    valid = counts != 0.0
    n_b = offsets.shape[0]
    chunks = []
    for b in range(n_b):
        code = jnp.full(codes.shape, offsets[b], jnp.int64)
        for j in range(sel_strides.shape[1]):
            digit = (codes // sel_strides[b, j]) % sel_cards[b, j]
            code = code + digit * new_strides[b, j]
        chunks.append(jnp.where(valid, code, _PAD_CODE))
    return jnp.concatenate(chunks), jnp.tile(counts, n_b)


@_maybe_jit
def _sp_bounds(codes, offsets):
    """Split bounds of a concatenated-code-space aggregation result."""
    return jnp.searchsorted(codes, offsets)


@_maybe_jit(static_argnums=(2, 3))
def _sp_slice_shift(codes, counts, lo: int, hi: int, offset):
    """One family's slice of a batched marginal, shifted back to its own
    code space — slice + subtract as a single program."""
    return codes[lo:hi] - offset, counts[lo:hi]


@_maybe_jit
def _sp_total(counts):
    """Grand total: exact accumulation, rounded to float32 in-program (the
    one rounding every consumer applies anyway — fused so no caller pays a
    separate eager convert)."""
    return jnp.sum(counts, dtype=ops.count_acc_dtype()).astype(jnp.float32)


@_maybe_jit
def _sp_neg(counts):
    """Signed-count negation (the ``0 − ΔT`` of a pruned delta star branch)."""
    return -counts


@_maybe_jit
def _sp_n_nonzero(counts):
    return jnp.sum(counts != 0.0)


def _aggregate_packed(comp, weights, pack_space: int, code_space: int):
    """Canonicalize a packed device COO message: aggregate + compact + unpack.

    The device twin of :func:`_aggregate_pairs` from the packed composite
    on: one ``ops.coo_aggregate_counted`` launch (the non-pad count comes
    back fused with the aggregation — no separate count-and-sync pass),
    then one :func:`_sp_unpack` program slicing to the valid count's ladder
    rung and splitting the row/code composite.
    """
    if int(comp.shape[0]) == 0:
        return (
            jnp.zeros((0,), jnp.int32), comp,
            weights.astype(jnp.float32),
        )
    u, s, n_valid = ops.coo_aggregate_counted(comp, weights, num_bins=pack_space)
    n_keep = min(int(u.shape[0]), bucketing.bucket_rows(max(n_valid, 1), tight=True))
    with enable_x64():
        return _sp_unpack(u, s, jnp.int64(code_space), n_keep)


def _build_compact(rvs, cards, codes, counts) -> DeviceSparseCT:
    """``DeviceSparseCT.build`` + tail compaction, as ONE aggregation pass.

    ``ops.coo_aggregate_counted`` returns the non-pad count alongside the
    canonicalized result, so the compaction slice costs no extra launch or
    sync.  Interior zero-count cells (exact Möbius cancellations) stay —
    they are "absent" by the :class:`DeviceSparseCT` contract; only the
    contiguous int-max tail is dropped, to the valid count's ladder rung.
    """
    n_cells = math.prod(cards) if cards else 1
    u, s, n_valid = ops.coo_aggregate_counted(codes, counts, num_bins=n_cells)
    n_keep = min(int(u.shape[0]), bucketing.bucket_rows(max(n_valid, 1), tight=True))
    if n_keep < int(u.shape[0]):
        with enable_x64():
            u, s = _sp_slice2(u, s, n_keep)
    return DeviceSparseCT(tuple(rvs), tuple(cards), u, s)


def _compact_tail(ct: DeviceSparseCT) -> DeviceSparseCT:
    """Drop a device table's contiguous padding tail (one scalar sync).

    Build pipelines leave fixed-shape aggregation results whose tail is
    :data:`_PAD_CODE` / count-0 entries; trimming it once at the end keeps
    every downstream per-sweep re-encode proportional to the real #SS.
    Interior zero-count cells (exact Möbius cancellations) stay — they are
    "absent" by the :class:`DeviceSparseCT` contract.  Prefer
    :func:`_build_compact` when an aggregation happens anyway — it gets
    the count for free; this standalone probe is for already-built tables.
    """
    if int(ct.codes.shape[0]) == 0:
        return ct
    with enable_x64():
        n_valid_dev = _sp_count_valid(ct.codes)
    n_valid = ops.sync_scalar(n_valid_dev)
    n_keep = min(int(ct.codes.shape[0]), bucketing.bucket_rows(max(n_valid, 1), tight=True))
    if n_keep == int(ct.codes.shape[0]):
        return ct
    with enable_x64():
        codes, counts = _sp_slice2(ct.codes, ct.counts, n_keep)
    return DeviceSparseCT(ct.rvs, ct.cards, codes, counts)


def _dev_combine(a: _DevMsg, b: _DevMsg) -> _DevMsg:
    """Join two messages of one fovar on entity row (device sort-merge).

    Mirrors :func:`_combine_sparse`: probe with ``a`` (so the output stays
    ``a``-major and therefore lexsorted — matches within one row follow
    ``b``'s code order), gather both sides, concatenate code spaces.
    Unique and lexsorted by construction — no aggregation pass.  The
    bucketed join's garbage suffix (slots past ``total``) is pinned to the
    message padding identity, preserving the pads-are-a-suffix invariant.

    When either side is a *dense* message (entry index == entity row id,
    see :class:`_DevMsg`), the sort-merge join and its scalar sync are
    skipped: the sparse side's row column is the gather index directly.
    The output equals the generic path's, entry for entry — every valid
    probe row matches exactly one dense entry, and with ``a`` dense the
    b-major order *is* the a-major order (``a``'s rows are ``arange``) —
    so the lexsorted invariant and device/host bit-identity both hold.
    """
    cb = b.code_space
    n_a, n_b = int(a.codes.shape[0]), int(b.codes.shape[0])
    if (a.dense_rows or b.dense_rows) and n_a and n_b:
        if b.dense_rows:
            sp, dn = a, b            # sparse probe side, dense gather side
        else:
            sp, dn = b, a
        with enable_x64():
            # code composition is always a-major: a.codes * cb + b.codes
            codes, weights = _sp_combine_dense(
                sp.rows, sp.codes, sp.weights, dn.codes, dn.weights,
                jnp.int64(cb), b.dense_rows,
            )
        return _DevMsg(
            rows=sp.rows,
            codes=codes,
            weights=weights,
            cards=a.cards + b.cards,
            folded=a.folded + b.folded,
            dense_rows=a.dense_rows and b.dense_rows,
        )
    idx_b, idx_a, valid, _total = ops.coo_join(b.rows, a.rows)
    with enable_x64():
        rows, codes, weights = _sp_combine_join(
            a.rows, a.codes, a.weights, b.codes, b.weights,
            idx_b, idx_a, valid, jnp.int64(cb),
        )
    return _DevMsg(
        rows=rows,
        codes=codes,
        weights=weights,
        cards=a.cards + b.cards,
        folded=a.folded + b.folded,
    )


def _dev_fold_all(msgs: list[_DevMsg]) -> _DevMsg:
    out = msgs[0]
    for m in msgs[1:]:
        out = _dev_combine(out, m)
    return out


#: Cap on the per-build stream floor (rows).  A 16k-lane stream costs a few
#: milliseconds per aggregation on any backend, so flooring every sub-16k
#: stream of a build to one rung trades invisible compute for a collapse of
#: the build's compiled-program count.
_FLOOR_CAP = 1 << 14


@contextlib.contextmanager
def _build_ladder_floor(db: RelationalDatabase):
    """Pin all transient COO streams of a build to one per-database rung.

    Small databases are where the compile tax bites hardest: their streams
    land on many *tiny* ladder rungs (128..4096), and every distinct rung
    multiplies the per-rung super-program count — for a few-thousand-row
    schema the cold build spends seconds compiling programs whose compute
    is microseconds.  This scope raises :func:`bucketing.set_stream_floor`
    to the rung covering the database's largest table times a fan-out
    margin (capped at :data:`_FLOOR_CAP`), so *every* sub-floor stream of
    the build — initial messages, join expansions, elimination packs,
    aggregation inputs — shares ONE shape and the program count stops
    scaling with rung diversity.  Streams above the floor (large
    databases, fat join expansions) climb the normal ladder, unchanged.

    The floor pads only *streams*: compaction sites size their results
    with ``bucket_rows(..., tight=True)``, so materialized CTs keep their
    natural rung.  That split is load-bearing — the attribute-component
    cross product materializes ``n1 * n2`` entries and the scorer sweeps
    every CT it is handed, so flooring *results* (an earlier iteration
    raised the ladder base itself) turns microsecond crosses into
    gigabyte outer products and slows every downstream scoring pass.

    The floor is an existing ladder rung (computed via ``bucket_rows``),
    so floored and tight shapes form one consistent set, and a warm
    rebuild re-derives the identical floor — zero recompiles.  Results
    are unaffected everywhere: padding is identity.
    """
    n_max = max(
        [t.n_rows for t in db.entities.values()]
        + [r.n_rows for r in db.relationships.values()],
        default=1,
    )
    floor = bucketing.bucket_rows(
        min(max(64 * n_max, 1), _FLOOR_CAP), tight=True
    )
    old = bucketing.set_stream_floor(floor)
    try:
        yield
    finally:
        bucketing.set_stream_floor(old)


def coo_shards() -> int:
    """Default shard count for the device COO build (``REPRO_COO_SHARDS``).

    ``1`` (the unset default) is the single-device build.  Like the other
    env knobs, a malformed value fails loudly rather than silently running
    unsharded.  Resolves through :mod:`repro.core.config`
    (``engine_config(coo_shards=...)`` for scoped use).
    """
    return config.resolve("coo_shards")


def _shard_view(
    db: RelationalDatabase, rel_name: str, lo: int, hi: int
) -> RelationalDatabase:
    """The star-schema split as a database view: one fact table row-sliced.

    Entity (dimension) tables and every other relationship are shared by
    reference — only the pivot relationship's columns are sliced, so S
    shard views cost S slices of the fact columns and nothing else.
    """
    rel = db.relationships[rel_name]
    sliced = RelationshipTable(
        rel.name, hi - lo, rel.fk1[lo:hi], rel.fk2[lo:hi],
        {a: c[lo:hi] for a, c in rel.attrs.items()},
    )
    return RelationalDatabase(
        db.schema, db.catalog, db.entities,
        {**db.relationships, rel_name: sliced},
    )


def _merge_shard_partials(parts: list[DeviceSparseCT]) -> DeviceSparseCT:
    """Combine per-shard partial CTs: concatenate + ONE signed aggregate.

    Conditional counts are sums over fact-table rows, so per-shard partials
    over a disjoint row split add cell-wise.  Every partial count is an
    exact integer-valued float32 (each is <= the merged cell, which the
    2**24 precision contract bounds), the aggregation accumulates in
    float64 and rounds once — hence the merged table is bit-identical to
    the single-device build.  Empty-shard partials contribute only padding
    and vanish in the merge.
    """
    first = parts[0]
    assert all(p.rvs == first.rvs and p.cards == first.cards for p in parts), [
        (p.rvs, p.cards) for p in parts
    ]
    with enable_x64():
        codes = jnp.concatenate([p.codes for p in parts])
        counts = jnp.concatenate([p.counts for p in parts])
    return _build_compact(first.rvs, first.cards, codes, counts)


def _shard_pivot(
    db: RelationalDatabase, cond_true: tuple[str, ...]
) -> str | None:
    """The relationship to row-shard: the largest fact table of the query."""
    if not cond_true:
        return None
    return max(cond_true, key=lambda r: (db.relationships[r].n_rows, r))


def device_sparse_ct_conditional(
    db: RelationalDatabase,
    attr_rvs: tuple[str, ...],
    cond_true: tuple[str, ...],
    fovar_universe: tuple[str, ...] | None = None,
    *,
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
    shards: int = 1,
    msg_cache: "LeafMessageCache | None" = None,
) -> DeviceSparseCT:
    """Device twin of :func:`sparse_ct_conditional` (same cells, no host COO).

    Every join-tree message lives on device from the first gather of the
    database columns (which are device arrays already); leaf elimination is
    ``ops.coo_join`` + one ``ops.coo_aggregate``, root contraction one more
    aggregate.  ``to_host()`` of the result is bit-identical to the host
    builder's table — the equivalence the device-build tests pin down.

    ``shards > 1`` row-shards the query's largest fact table (the classic
    star-schema split of ``core.distributed``, applied to the COO stream):
    each shard runs the full contraction over its row slice and the
    partials merge by one signed aggregate (:func:`_merge_shard_partials`).
    Conditional counts are *multilinear* in the fact tables — every join
    path crosses the pivot exactly once — so the disjoint row split sums
    to the unsharded table, bit-identically (integer-exact float32
    partials, float64 merge, one rounding).  Conditionals that touch no
    fact table (``cond_true == ()``) are computed once, unsharded.

    The whole contraction runs under :func:`_build_ladder_floor`: every
    sub-floor stream of the build shares one ladder rung, keeping the
    per-rung super-program count flat.
    """
    with _build_ladder_floor(db):
        return _device_ct_conditional(
            db, attr_rvs, cond_true, fovar_universe,
            group_fovar=group_fovar, restrict=restrict, shards=shards,
            msg_cache=msg_cache,
        )


def _device_ct_conditional(
    db: RelationalDatabase,
    attr_rvs: tuple[str, ...],
    cond_true: tuple[str, ...],
    fovar_universe: tuple[str, ...] | None = None,
    *,
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
    shards: int = 1,
    msg_cache: "LeafMessageCache | None" = None,
) -> DeviceSparseCT:
    """:func:`device_sparse_ct_conditional` body, run under the ladder floor."""
    pivot = _shard_pivot(db, cond_true) if shards > 1 else None
    if pivot is not None:
        n = db.relationships[pivot].n_rows
        parts = [
            _device_ct_conditional(
                _shard_view(db, pivot, lo, hi), attr_rvs, cond_true,
                fovar_universe, group_fovar=group_fovar, restrict=restrict,
                msg_cache=msg_cache,
            )
            for lo, hi in bucketing.shard_ranges(n, shards)
        ]
        return _merge_shard_partials(parts)
    cat = db.catalog
    plan: QueryPlan = plan_conditional(
        db, attr_rvs, cond_true, fovar_universe,
        group_fovar=group_fovar, restrict=restrict,
    )
    code_space = math.prod((cat[v].cardinality for v in attr_rvs), start=1)
    if group_fovar is not None:
        code_space *= db.entities[cat.fovar(group_fovar).entity].n_rows
    if code_space >= _MAX_CODE_SPACE:
        raise OverflowError(
            f"query code space {code_space:.3g} overflows int64 composite codes"
        )

    def fovar_n_rows(fid: str) -> int:
        return db.entities[cat.fovar(fid).entity].n_rows

    def _build_initial(fid: str) -> _DevMsg:
        n = fovar_n_rows(fid)
        cards = [rv.cardinality for rv in plan.ent_attrs[fid]]
        folded = [rv.vid for rv in plan.ent_attrs[fid]]
        cols = [
            db.entities[rv.table].attrs[rv.column] for rv in plan.ent_attrs[fid]
        ]
        with enable_x64():
            strides = jnp.asarray(radix_strides(cards), jnp.int64)
            if fid in plan.restrict:
                # the restriction keeps exactly one entity row, selected by
                # a traced dynamic_slice (one program per entity size)
                rows, codes, weights = _sp_initial_restrict(
                    strides, jnp.int32(plan.restrict[fid]),
                    n, bucketing.bucket_rows(1), *cols,
                )
            else:
                rows, codes, weights = _sp_initial_dense(
                    strides, n, bucketing.bucket_rows(n), *cols,
                )
        return _DevMsg(
            rows, codes, weights, cards, folded,
            dense_rows=fid not in plan.restrict,
        )

    def initial_message(fid: str) -> _DevMsg:
        if msg_cache is None:
            return _build_initial(fid)
        # The stream floor is part of the key: a device message's padded
        # shape is fixed by the floor active when it was built, and mixing
        # floors would leak new shapes into downstream programs.
        key = ("dev", fid, tuple(rv.vid for rv in plan.ent_attrs[fid]),
               plan.restrict.get(fid), bucketing.stream_floor())
        return msg_cache.get(key, lambda: _build_initial(fid))

    def eliminate_leaf(msg: _DevMsg, rname: str, leaf: str, other: str) -> _DevMsg:
        """Push a leaf's message through a relationship (device FK join)."""
        rel = db.relationships[rname]
        f1, f2 = (f.fid for f in cat.rel_var_of(rname).fovars)
        fk_leaf = rel.fk1 if leaf == f1 else rel.fk2
        fk_other = rel.fk2 if leaf == f1 else rel.fk1
        r_cards = [rv.cardinality for rv in plan.rel_attrs[rname]]
        r_names = [rv.vid for rv in plan.rel_attrs[rname]]
        d_r = math.prod(r_cards, start=1)
        cs_out = msg.code_space * d_r
        n_other = fovar_n_rows(other)
        if n_other * cs_out >= _MAX_CODE_SPACE:
            raise OverflowError(
                f"device message packs {n_other} rows x {cs_out:.3g} codes; "
                "overflows int64 — use the host builder for this query"
            )
        rcols = [rel.attrs[rv.column] for rv in plan.rel_attrs[rname]]
        with enable_x64():
            if rcols:
                rcode = _sp_encode(
                    jnp.asarray(radix_strides(r_cards), jnp.int64), *rcols
                )
            else:
                rcode = jnp.zeros((int(fk_leaf.shape[0]),), jnp.int64)
        if msg.dense_rows and int(msg.codes.shape[0]) and int(fk_leaf.shape[0]):
            # dense (un-restricted initial) message: entry index == entity
            # row id, so the FK column IS the join — gather directly,
            # skipping the sort-merge join and its scalar sync.  Output
            # order is the relationship's row order; the aggregation below
            # canonicalizes, so the result is bit-identical to the joined
            # path (float64 accumulation of integer-valued weights is
            # order-independent).
            with enable_x64():
                comp, weights = _sp_elim_dense_pack(
                    msg.codes, msg.weights, fk_leaf, fk_other, rcode,
                    jnp.int64(d_r), jnp.int64(cs_out),
                )
        else:
            idx_m, idx_r, valid, _total = ops.coo_join(msg.rows, fk_leaf)
            with enable_x64():
                comp, weights = _sp_elim_join_pack(
                    msg.codes, msg.weights, rcode, fk_other, idx_m, idx_r,
                    valid, jnp.int64(d_r), jnp.int64(cs_out),
                )
        rows, codes, weights = _aggregate_packed(
            comp, weights, n_other * cs_out, cs_out
        )
        return _DevMsg(rows, codes, weights, msg.cards + r_cards, msg.folded + r_names)

    def finish_root(fid: str, msgs: list[_DevMsg]):
        """Contract the root over its entity rows -> device COO count vector."""
        msg = _dev_fold_all(msgs)
        if fid == plan.group_fovar:
            with enable_x64():
                # lexsorted => still sorted (padding is a suffix)
                codes = _sp_pack(
                    msg.rows, msg.codes, msg.weights, jnp.int64(msg.code_space)
                )
            return (
                codes, msg.weights,
                [fovar_n_rows(fid)] + msg.cards,
                [GROUP_AXIS] + msg.folded,
            )
        u, s, n_valid = ops.coo_aggregate_counted(
            msg.codes, msg.weights, num_bins=msg.code_space
        )
        if int(u.shape[0]):
            n_keep = min(int(u.shape[0]), bucketing.bucket_rows(max(n_valid, 1), tight=True))
            if n_keep < int(u.shape[0]):
                with enable_x64():
                    u, s = _sp_slice2(u, s, n_keep)
        return u, s, msg.cards, msg.folded

    # Contract each component; cross product of device count vectors.
    # (numpy seeds: jnp.zeros/ones here would each compile a trivial
    # broadcast program; downstream jits device_put them for free)
    vec_codes = np.zeros((1,), np.int64)
    vec_counts = np.ones((1,), np.float32)
    all_cards: list[int] = []
    all_folded: list[str] = []
    n_attr_comps = 0
    for comp in plan.comps:
        if plan.comp_of[comp[0]] in plan.cyclic:
            # Cyclic components have no leaf-elimination order: compute the
            # ground join on host and upload its (tiny, #SS-sized) count
            # vector into the device cross product.  Bit-identity holds —
            # the stream is the host builder's own component result — and
            # sharded builds stay exact because the ground join is run per
            # shard *view* (each grounding crosses the sliced pivot row
            # exactly once, so disjoint row slices partition groundings).
            h_codes, h_counts, cards, folded = _ground_join_component(
                db, plan, cond_true, comp
            )
            if not cards:
                # scalar multiplier: float64 sum, one float32 rounding —
                # the same arithmetic as the host path
                vec_counts = vec_counts * np.float32(
                    h_counts.sum(dtype=TOTAL_ACC_DTYPE)
                )
                continue
            n_pad = bucketing.bucket_rows(max(h_codes.size, 1))
            h_codes = np.concatenate(
                [h_codes, np.full(n_pad - h_codes.size, _PAD_CODE, np.int64)]
            )
            h_counts = np.concatenate(
                [h_counts, np.zeros(n_pad - h_counts.size, np.float32)]
            )
            with enable_x64():
                c_codes = ops.to_device(h_codes)
            c_counts = ops.to_device(h_counts)
        else:
            c_codes, c_counts, cards, folded = _contract_join_tree(
                plan, cat, cond_true, comp,
                initial=initial_message, fold=_dev_fold_all,
                eliminate=eliminate_leaf, finish=finish_root,
            )
        if not cards:
            # Attribute-less component: a scalar multiplier (its population
            # count), float64-accumulated then rounded like the host path.
            with enable_x64():
                scalar = _sp_total(c_counts)
            vec_counts = vec_counts * scalar
            continue
        c = math.prod(cards)
        n_attr_comps += 1
        with enable_x64():
            vec_codes, vec_counts = _sp_cross(
                vec_codes, vec_counts, c_codes, c_counts, jnp.int64(c)
            )
        all_cards += cards
        all_folded += folded
        if n_attr_comps > 1:
            # the pairwise product interleaves the factors' bucket-padding
            # suffixes into the interior AND multiplies their lengths —
            # left alone, the ladder's base floor would compound across
            # components (base^3 rows for a 3-component query of tiny
            # factors).  One counted aggregate after each multiply keeps
            # the running vector at its #SS bucket, so every product
            # stays #SS x bucket and the final transpose re-encodes at #SS.
            tmp = _build_compact(
                tuple(all_folded), tuple(all_cards), vec_codes, vec_counts
            )
            vec_codes, vec_counts = tmp.codes, tmp.counts

    ct = DeviceSparseCT(tuple(all_folded), tuple(all_cards), vec_codes, vec_counts)
    out_order = tuple(attr_rvs)
    if group_fovar is not None:
        out_order = (GROUP_AXIS,) + out_order
    if tuple(out_order) == ct.rvs:
        return _compact_tail(ct)
    new_cards, new_codes, new_counts = ct._reencode(out_order)
    return _build_compact(out_order, new_cards, new_codes, new_counts)


def _dev_sparse_sub(star: DeviceSparseCT, t_sum: DeviceSparseCT) -> DeviceSparseCT:
    """``CT[F] = CT[*] − CT[T]`` as ONE signed ``ops.coo_aggregate`` pass.

    Padding entries of either operand carry count 0 and merge into the
    result's tail; exact cancellations become zero-count cells (absent by
    contract).  float64 accumulation over integer-valued float32 counts
    keeps the subtraction bit-identical to the host :func:`_sparse_sub`.
    """
    assert star.rvs == t_sum.rvs, (star.rvs, t_sum.rvs)
    n_cat = int(star.codes.shape[0]) + int(t_sum.codes.shape[0])
    with enable_x64():
        codes, deltas = _sp_signed_concat(
            star.codes, star.counts, t_sum.codes, t_sum.counts,
            bucketing.bucket_rows(n_cat),
        )
    u, s = ops.coo_aggregate(codes, deltas, num_bins=star.n_cells)
    return DeviceSparseCT(star.rvs, star.cards, u, s)


def device_sparse_contingency_table(
    db: RelationalDatabase,
    rvs: tuple[str, ...],
    *,
    group_fovar: str | None = None,
    restrict: dict[str, int] | None = None,
    fovar_universe: tuple[str, ...] | None = None,
    shards: int | None = None,
    touched_rel: str | None = None,
    msg_cache: "LeafMessageCache | None" = None,
) -> DeviceSparseCT:
    """Device twin of :func:`sparse_contingency_table` (Möbius on device).

    ``touched_rel`` selects delta mode exactly as in the host builder: the
    star branch at that relationship's level is pruned (its delta is zero)
    and ``ΔF = −Σ_rattrs ΔT`` via one :func:`_sp_neg` program per rung.

    Structurally identical recursion; each level's don't-care subtraction is
    a signed ``ops.coo_aggregate`` pass (:func:`_dev_sparse_sub`) and the
    F/T assembly one ``DeviceSparseCT.build`` canonicalization — the F block
    embedded at the ``n/a`` (code-0) relationship-attribute cells, the T
    block shifted past it by the indicator digit, exactly like the host
    builder.  This is the default route of ``contingency_table(...,
    device_resident=True)`` on the sparse backend: the joint CT is built
    with zero host-side COO materialization.

    ``shards`` (default: the ``REPRO_COO_SHARDS`` env knob via
    :func:`coo_shards`) row-shards every fact-table-touching conditional of
    the Möbius recursion — see :func:`device_sparse_ct_conditional`; the
    result stays bit-identical to the single-device build.
    """
    shards = coo_shards() if shards is None else int(shards)
    cat = db.catalog
    want, rel_names, added, attr_rvs, universe_t = mobius_setup(db, rvs, fovar_universe)

    if mobius_code_space(db, rvs, added, group_fovar) >= _MAX_CODE_SPACE:
        raise OverflowError(
            f"CT code space {mobius_code_space(db, rvs, added, group_fovar):.3g} "
            "overflows int64 composite codes; split the query into smaller "
            "par-RV subsets"
        )

    g_prefix: tuple[str, ...] = (GROUP_AXIS,) if group_fovar is not None else ()

    def recurse(
        remaining: tuple[str, ...], fixed_true: tuple[str, ...], attrs: tuple[str, ...]
    ) -> DeviceSparseCT:
        if not remaining:
            return _device_ct_conditional(
                db, attrs, fixed_true, universe_t,
                group_fovar=group_fovar, restrict=restrict, shards=shards,
                msg_cache=msg_cache,
            )
        r, rest = remaining[0], remaining[1:]
        r_attr_vids = tuple(
            v.vid for v in want if v.kind == KIND_REL_ATTR and v.table == r
        )
        t_branch = recurse(rest, fixed_true + (r,), attrs)

        shared = tuple(v for v in t_branch.rvs if v not in r_attr_vids)
        t_ct = t_branch.transpose(shared + r_attr_vids)
        t_sum = t_ct.marginal(shared) if r_attr_vids else t_ct
        if r == touched_rel:
            # Delta mode: Δstar ≡ 0 (the star branch never joins ``r``), so
            # the subtree is pruned and ``ΔF = 0 − Σ_rattrs ΔT``.
            f_count = DeviceSparseCT(
                t_sum.rvs, t_sum.cards, t_sum.codes, _sp_neg(t_sum.counts)
            )
        else:
            star_attrs = tuple(v for v in attrs if v not in r_attr_vids)
            star_branch = recurse(rest, fixed_true, star_attrs)
            star = star_branch.transpose(shared)
            f_count = _dev_sparse_sub(star, t_sum)  # counts with r = False

        r_cards = tuple(cat[v].cardinality for v in r_attr_vids)
        d_r = math.prod(r_cards, start=1)
        shared_cards = t_ct.cards[: len(shared)]
        d_rest = math.prod(shared_cards, start=1) * d_r

        # F block at the n/a (code 0) r-attribute cells, T block shifted
        # past the F half (one fused program; padding/zero cells pinned to
        # _PAD_CODE before the shift so garbage codes can't wrap into range)
        n_cat = int(f_count.codes.shape[0]) + int(t_ct.codes.shape[0])
        with enable_x64():
            codes, counts = _sp_mobius_assemble(
                f_count.codes, f_count.counts, t_ct.codes, t_ct.counts,
                jnp.int64(d_r), jnp.int64(d_rest),
                bucketing.bucket_rows(n_cat),
            )
        rel_vid = cat.rel_var_of(r).vid
        # compact each recursion level back to its #SS bucket (the counted
        # aggregation's free scalar sync) so branch concatenations can't
        # snowball padding through the Möbius levels
        return _build_compact(
            (rel_vid,) + shared + r_attr_vids,
            (2,) + shared_cards + r_cards,
            codes, counts,
        )

    with _build_ladder_floor(db):
        full = recurse(tuple(rel_names), (), attr_rvs)
        if added:
            keep = g_prefix + tuple(v.vid for v in want)
            full = full.marginal(keep)
        out_order = g_prefix + tuple(rvs)
        if tuple(out_order) == full.rvs:
            return _compact_tail(full)
        new_cards, new_codes, new_counts = full._reencode(out_order)
        return _build_compact(out_order, new_cards, new_codes, new_counts)


# ---------------------------------------------------------------------------
# Incremental maintenance: signed O(Δ) delta propagation (ROADMAP "live db")
# ---------------------------------------------------------------------------
#
# Every count statistic the builders above produce is *linear* in each
# relationship's row multiset: a conditional that joins R sums one term per
# R row crossed (PR 6's shard-merge multilinearity), and conditionals that
# do not join R never read its rows at all.  The Möbius assembly, marginals
# and signed aggregations are all linear in counts.  Hence, for a delta
# touching one relationship R,
#
#     ΔCT = CT(db′) − CT(db) = CT(view with only inserted R rows)
#                            − CT(view with only deleted R rows)
#
# where both views share every other table by reference — and inside each
# view build, the recursion level for R prunes its star branch (Δstar ≡ 0,
# since that branch never joins R).  The delta merges into the live table
# by the same signed concat + aggregate as the sharded build: float64
# accumulation of integer-valued float32 counts, one rounding, hence
# bit-identical (in canonical host form) to a from-scratch rebuild.  Exact
# insert/delete cancellations become true zero-count cells, absent by
# contract and dropped by ``to_host()`` / ``aggregate_codes``.


def msg_cache_cap() -> int:
    """Leaf-message cache capacity (entries) — env knob ``REPRO_MSG_CACHE``.

    Default 128 entries; ``0`` disables caching entirely.  Like the other
    env knobs, a malformed value fails loudly rather than silently running
    uncached.  Resolves through :mod:`repro.core.config`
    (``engine_config(msg_cache=...)`` for scoped use).
    """
    return config.resolve("msg_cache")


class LeafMessageCache:
    """Per-lineage cache of join-tree leaf (initial) messages.

    A delta contraction re-runs the full join-tree walk, but its leaf
    messages encode *entity* columns only — and relationship deltas never
    touch entity tables, so within one database lineage (a base instance
    evolved purely through ``database.apply_delta``) every leaf message is
    reusable across delta applications.  Keys carry the builder residency,
    the fovar, its queried attribute vids, the restriction row and (for
    device messages) the active stream floor, so distinct plans and padded
    shapes never collide.  FIFO eviction beyond ``cap`` entries
    (:func:`msg_cache_cap`); messages are immutable downstream, so sharing
    one instance across contractions is safe.

    Do NOT share a cache across unrelated databases: entries are only valid
    while the entity tables they encode are the live ones.
    """

    def __init__(self, cap: int | None = None):
        self.cap = msg_cache_cap() if cap is None else int(cap)
        self._entries: dict = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key, build):
        if self.cap == 0:
            return build()
        try:
            msg = self._entries[key]
        except KeyError:
            self.misses += 1
            msg = build()
            while len(self._entries) >= self.cap:
                self._entries.pop(next(iter(self._entries)))
            self._entries[key] = msg
            return msg
        self.hits += 1
        return msg


def _delta_view(
    db: RelationalDatabase, table: str, rows: RelationshipTable
) -> RelationalDatabase:
    """A database view with one relationship's rows replaced by delta rows.

    The delta twin of :func:`_shard_view`: entity tables and every other
    relationship are shared by reference, so the view is O(1) to build and
    its contraction cost scales with the delta, not the table.
    """
    return RelationalDatabase(
        db.schema, db.catalog, db.entities, {**db.relationships, table: rows}
    )


def sparse_ct_delta(
    db: RelationalDatabase,
    delta,
    rvs: tuple[str, ...],
    *,
    fovar_universe: tuple[str, ...] | None = None,
    device: bool | None = None,
    shards: int | None = None,
    msg_cache: LeafMessageCache | None = None,
):
    """Signed ΔCT of a single-table delta over ``rvs``.

    ``db`` is the post-delta database (any instance of the lineage works —
    the delta contraction reads only tables the delta did not touch, which
    are shared by reference).  ``delta`` is a ``database.TableDelta``.
    Returns a signed :class:`SparseCT` or :class:`DeviceSparseCT` such that

        ``apply_ct_delta(CT(old_db), Δ)`` ≡ ``CT(new_db)``

    bit-identically in canonical host form (codes and float32 counts).

    ``device=None`` routes by the delta view's tuple count against
    ``counts.device_min_rows()`` — the same crossover the full build uses —
    so small deltas take the dispatch-free host contraction (the O(Δ) fast
    path) and huge deltas the device one.  Either route rides the existing
    bucket ladder: a warm apply at a seen delta shape compiles nothing.
    """
    cat = db.catalog
    _want, rel_names, _added, _attr_rvs, _universe = mobius_setup(
        db, rvs, fovar_universe
    )
    halves = [
        (sign, rows)
        for sign, rows in ((1.0, delta.inserted), (-1.0, delta.deleted))
        if rows.n_rows
    ]
    if delta.table not in rel_names or not halves:
        # The queried axes never join the touched table (its indicator and
        # attributes are all marginalized away and the grounding population
        # is fixed), or the delta is empty — ΔCT ≡ 0.
        cards = tuple(cat[v].cardinality for v in rvs)
        empty = SparseCT(
            tuple(rvs), cards, np.zeros(0, np.int64), np.zeros(0, np.float32)
        )
        return empty.to_device() if device else empty

    if device is None:
        from .counts import device_min_rows

        n_view = max(
            _delta_view(db, delta.table, rows).total_tuples
            for _sign, rows in halves
        )
        device = n_view >= device_min_rows()

    parts = []
    for sign, rows in halves:
        view = _delta_view(db, delta.table, rows)
        if device:
            ct = device_sparse_contingency_table(
                view, rvs, fovar_universe=fovar_universe, shards=shards,
                touched_rel=delta.table, msg_cache=msg_cache,
            )
        else:
            ct = sparse_contingency_table(
                view, rvs, fovar_universe=fovar_universe,
                touched_rel=delta.table, msg_cache=msg_cache,
            )
        parts.append((sign, ct))

    if len(parts) == 1:
        sign, ct = parts[0]
        if sign > 0:
            return ct
        if isinstance(ct, SparseCT):
            return SparseCT(ct.rvs, ct.cards, ct.codes, -ct.counts)
        return DeviceSparseCT(ct.rvs, ct.cards, ct.codes, _sp_neg(ct.counts))
    ins, dele = parts[0][1], parts[1][1]
    if isinstance(ins, SparseCT):
        return _sparse_sub(ins, dele)
    return _dev_sparse_sub(ins, dele)


def apply_ct_delta(live, delta_ct):
    """Merge a signed ΔCT into a live table: concat + ONE signed aggregate.

    The incremental twin of :func:`_merge_shard_partials` (same linearity
    argument, same float64-accumulate/one-rounding numerics): the merged
    table is bit-identical in canonical host form to a from-scratch build
    of the post-delta database.  Residency follows ``live``; a host delta
    merging into a device table ships across in one h2d copy.  Cells the
    delta cancels exactly become zero-count entries — absent by contract on
    the device twin (``to_host()`` drops them), dropped eagerly on host.
    """
    if isinstance(live, SparseCT):
        dh = delta_ct.to_host() if isinstance(delta_ct, DeviceSparseCT) else delta_ct
        dh = dh.transpose(live.rvs)
        assert dh.cards == live.cards, (dh.cards, live.cards)
        codes, counts = aggregate_codes(
            np.concatenate([live.codes, dh.codes]),
            np.concatenate([live.counts, dh.counts]),
        )
        return SparseCT(live.rvs, live.cards, codes, counts)
    if isinstance(delta_ct, SparseCT):
        dh = delta_ct if delta_ct.rvs == live.rvs else delta_ct.transpose(live.rvs)
        # Rung-pad the host delta before the h2d copy: the merge aggregation
        # compiles per concat shape, so shipping the exact (and
        # delta-dependent) nnz would recompile on every apply — padded to a
        # ladder rung, every delta in the rung reuses one program.
        n = int(dh.codes.shape[0])
        n_pad = bucketing.bucket_rows(n)
        codes = np.full(n_pad, _PAD_CODE, np.int64)
        counts = np.zeros(n_pad, np.float32)
        codes[:n] = dh.codes
        counts[:n] = dh.counts
        with enable_x64():
            dd = DeviceSparseCT(
                dh.rvs, dh.cards, ops.to_device(codes), ops.to_device(counts)
            )
    else:
        dd = delta_ct
        if dd.rvs != live.rvs:
            dd = dd.transpose(live.rvs)
    return _merge_shard_partials([live, dd])


# ---------------------------------------------------------------------------
# Sparse consumers: scoring and prediction over nonzero cells only
# ---------------------------------------------------------------------------

_LOG_TINY = 1e-30


def sparse_family_stats(
    fct: SparseCT, child: str, parents: tuple[str, ...], alpha: float = 0.0
) -> tuple[float, int]:
    """``(loglik, n_params)`` of one family from its sparse CT.

    Computes ``Σ n · log cp`` over *realized cells only* — the MLE/Dirichlet
    conditional probability ``cp = (n + α) / (N_parents + α·|child|)`` needs
    just the parent-marginal count of each realized cell, found by a segment
    reduction over the parent-prefix codes (child is the minor axis, so the
    prefix is ``code // |child|`` and stays sorted).  Numerically identical
    to densify-then-``mle_cpt``-then-``factor_loglik``: unrealized cells
    contribute exactly 0 under the 0·log0 := 0 convention, and dense rows
    never realized get probabilities that multiply only zero counts.

    Precision contract (shared with the device oracle path of
    ``kernels.ops.sparse_family_score``): parent totals, conditional
    probabilities and the accumulation all run in float64 over the stored
    float32 cell counts, so host and device-oracle scores agree to float64
    rounding even for billion-grounding log-likelihoods.
    """
    ct = fct.transpose(tuple(parents) + (child,))
    child_card = ct.cards[-1]
    n_parent_configs = math.prod(ct.cards[:-1], start=1)
    if ct.codes.size == 0:
        return 0.0, n_parent_configs * (child_card - 1)
    parent_codes = ct.codes // child_card
    boundary, starts = _run_boundaries(parent_codes)
    counts64 = ct.counts.astype(TOTAL_ACC_DTYPE)
    parent_tot = np.add.reduceat(counts64, starts)
    seg = np.cumsum(boundary) - 1
    denom = parent_tot[seg] + alpha * child_card
    cp = (counts64 + alpha) / denom
    loglik = float(np.sum(ct.counts * np.log(np.maximum(cp, _LOG_TINY))))
    return loglik, n_parent_configs * (child_card - 1)


def sparse_factor_loglik(fct: SparseCT, factor_rvs: tuple[str, ...], factor_table) -> float:
    """``Σ count · log cp`` against a dense factor, gathering realized cells."""
    ct = fct.transpose(tuple(factor_rvs))
    flat = np.asarray(factor_table, np.float32).reshape(-1)
    logp = np.log(np.maximum(flat[ct.codes], _LOG_TINY))
    return float(np.sum(ct.counts * logp, dtype=np.float64))


def sparse_block_scores(gct: SparseCT, log_cpt: np.ndarray, n_entities: int) -> np.ndarray:
    """§VI block scoring from a grouped sparse CT.

    ``gct`` must have the ``__group__`` axis leading; ``log_cpt`` is
    ``(config_space, |Y|)``.  Scatter-accumulates
    ``scores[e, y] += count · log_cpt[cfg, y]`` over realized cells only —
    the sparse analogue of the dense ``counts @ log_cpt`` matmul.
    """
    assert gct.rvs and gct.rvs[0] == GROUP_AXIS, gct.rvs
    c_rest = math.prod(gct.cards[1:], start=1)
    e_idx = gct.codes // c_rest
    cfg = gct.codes % c_rest
    out = np.zeros((n_entities, log_cpt.shape[1]), np.float32)
    np.add.at(out, e_idx, gct.counts[:, None] * log_cpt[cfg])
    return out
