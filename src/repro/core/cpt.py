"""The Parameter Manager (paper §V-B): factor tables / CPTs from count tables.

Maximum-likelihood estimates are observed child frequencies given parent
configurations; in the RDBMS this is a NATURAL JOIN of the family CT with a
parent-marginal GROUP BY subquery, here a segmented row-normalization
(Pallas ``mle_cpt`` kernel on TPU).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from ..kernels import ops
from .bn import BayesNet
from .counts import CTLike


@dataclass(frozen=True)
class FactorTable:
    """The ``@par-RVID@_CPT`` table: P(child | parents) for one family.

    ``table`` is dense with axes ordered (*parents, child) — the same layout
    as the family contingency table, so likelihood contractions are
    co-indexed elementwise products.
    """

    child: str
    parents: tuple[str, ...]
    table: jax.Array  # float32 (*parent_cards, child_card)

    @property
    def rvs(self) -> tuple[str, ...]:
        return self.parents + (self.child,)

    @property
    def n_parent_configs(self) -> int:
        return int(np.prod(self.table.shape[:-1])) if self.table.ndim > 1 else 1

    @property
    def n_params(self) -> int:
        """Free parameters: (#parent configs) x (child cardinality - 1) (§V-C.1)."""
        return self.n_parent_configs * (self.table.shape[-1] - 1)


def family_ct(joint_or_local: CTLike, child: str, parents: tuple[str, ...]) -> CTLike:
    """Family CT with axes (*parents, child) from any CT covering the family."""
    return joint_or_local.marginal(tuple(parents) + (child,))


def mle_factor(
    fct: CTLike,
    child: str,
    parents: tuple[str, ...],
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> FactorTable:
    """Maximum-likelihood CPT from a family contingency table.

    Factor tables are dense (one ``cp`` per family configuration), so a
    sparse family CT is densified here — family domains are bounded by
    ``max_parents``, unlike the joint CTs the sparse backend exists for.
    Structure-search scoring never calls this on sparse CTs (see
    ``scores.score_family``); only final parameter learning does.
    """
    from .sparse_counts import SparseCT, as_host

    fct = as_host(fct)
    if isinstance(fct, SparseCT):
        from .config import resolve

        fct = fct.to_dense(budget=resolve("dense_cell_budget"))
    ct = fct.transpose(tuple(parents) + (child,))
    t = ct.table
    child_card = t.shape[-1]
    flat = t.reshape(-1, child_card)
    cpt = ops.mle_cpt(flat, alpha, impl=ops.kernel_impl(impl))
    return FactorTable(child, tuple(parents), cpt.reshape(t.shape))


def learn_parameters(
    bn: BayesNet,
    counts_of: "callable",
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> dict[str, FactorTable]:
    """Estimate every family's CPT.  ``counts_of(rvs) -> ContingencyTable``.

    ``counts_of`` is the count-manager handle — either marginals of a
    pre-counted joint CT or on-demand queries (paper §VII-B discusses both).
    """
    factors = {}
    for child in bn.rvs:
        parents = tuple(bn.parents[child])
        fct = counts_of(tuple(parents) + (child,))
        factors[child] = mle_factor(fct, child, parents, alpha, impl=impl)
    return factors
