"""Durable model store: learned first-order Bayes nets as managed artifacts.

FactorBase inherits BayesStore's stance that statistical models are
first-class database citizens (paper §I): the learned structure and its
``@par-RVID@_CPT`` tables live in relations, not in the memory of the
process that happened to learn them.  This module is that contract for the
jax_pallas engine: :func:`save_model` serializes a :class:`LearnedModel` —
schema + BN structure + :class:`~repro.core.cpt.FactorTable` CPTs — into a
single versioned ``.npz`` artifact, and :func:`load_model` reloads it
**device-resident** (every CPT lands back on the accelerator via the
transfer-accounted ``ops.to_device``) so the serving tier can answer
``P(y | x)`` queries without re-learning anything.

Artifact layout (format ``repro-model`` v1)::

    model.npz
      __meta__     JSON: format/version tag, schema spec (the declarative
                   catalog of data/ingest.py, schema-only), BN rvs+parents,
                   per-factor child/parents/axis metadata, free-form user
                   metadata
      factor_000…  one float32 array per family CPT, axes (*parents, child)

Everything numeric rides ``.npz`` raw bytes — float32 tables round-trip
**bit-identically**, which is what makes save → fresh process → load →
predict produce the same posteriors to the last ulp (enforced by
``tests/test_model_store.py`` and the ``bench_serve`` gate).  The schema
travels as the same declarative spec ``data/ingest.py`` ingests, so an
artifact is self-describing: a fresh process can validate an incoming
database against ``model.schema`` before serving it.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Mapping

import numpy as np

from ..kernels import ops
from .bn import BayesNet
from .cpt import FactorTable
from .schema import RelationalSchema

__all__ = [
    "FORMAT",
    "VERSION",
    "LearnedModel",
    "ModelStoreError",
    "load_model",
    "save_model",
    "schema_spec",
]

FORMAT = "repro-model"
VERSION = 1

_META_KEY = "__meta__"


class ModelStoreError(ValueError):
    """A model artifact failed validation (wrong format, version, shape)."""


@dataclass(frozen=True)
class LearnedModel:
    """A learned model: schema contract + BN structure + CPT factors.

    ``factors`` maps each child par-RV to its family CPT; ``meta`` is
    free-form JSON-serializable provenance (score used, alpha, dataset
    name, …) that rides along in the artifact.
    """

    schema: RelationalSchema
    bn: BayesNet
    factors: dict[str, FactorTable]
    meta: Mapping[str, Any] = field(default_factory=dict)

    def validate(self) -> None:
        self.schema.validate()
        missing = [rv for rv in self.bn.rvs if rv not in self.factors]
        if missing:
            raise ModelStoreError(
                f"model is missing CPTs for {missing}; every BN family "
                "needs a factor table"
            )
        for child, factor in self.factors.items():
            if factor.child != child:
                raise ModelStoreError(
                    f"factor stored under {child!r} is for {factor.child!r}"
                )
            if tuple(factor.parents) != tuple(self.bn.parents[child]):
                raise ModelStoreError(
                    f"factor {child!r} has parents {factor.parents}, BN "
                    f"says {tuple(self.bn.parents[child])}"
                )


def schema_spec(schema: RelationalSchema) -> dict:
    """The schema as a declarative, row-free ``data/ingest.py`` spec.

    ``ingest_schema(schema_spec(s)) == s`` — the artifact's schema block is
    exactly the catalog form the ingestion front door already validates.
    """
    tables: dict[str, Any] = {}
    for edecl in schema.entities:
        tables[edecl.name] = {
            "columns": {a: list(dom) for a, dom in edecl.attributes},
        }
    for rdecl in schema.relationships:
        tables[rdecl.name] = {
            "foreign_keys": {"fk1": rdecl.entities[0], "fk2": rdecl.entities[1]},
            "columns": {a: list(dom) for a, dom in rdecl.attributes},
        }
    return {"tables": tables}


def save_model(model: LearnedModel, path) -> str:
    """Serialize ``model`` into one versioned ``.npz`` artifact at ``path``.

    Returns the path written.  CPT arrays are stored as raw float32 —
    loading them back is bit-identical.
    """
    model.validate()
    try:
        user_meta = json.loads(json.dumps(dict(model.meta)))
    except (TypeError, ValueError) as e:
        raise ModelStoreError(
            f"model.meta must be JSON-serializable: {e}"
        ) from e

    arrays: dict[str, np.ndarray] = {}
    factor_meta = []
    for i, child in enumerate(sorted(model.factors)):
        factor = model.factors[child]
        key = f"factor_{i:03d}"
        arrays[key] = np.asarray(ops.to_host(factor.table), np.float32)
        factor_meta.append(
            {"child": factor.child, "parents": list(factor.parents), "key": key}
        )

    meta = {
        "format": FORMAT,
        "version": VERSION,
        "schema": schema_spec(model.schema),
        "bn": {
            "rvs": list(model.bn.rvs),
            "parents": {rv: list(model.bn.parents[rv]) for rv in model.bn.rvs},
        },
        "factors": factor_meta,
        "meta": user_meta,
    }
    # no sort_keys: the spec's table order IS the schema's declaration
    # order, and the catalog derives par-RV enumeration from it
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8
    )
    path = str(path)
    with open(path, "wb") as fh:
        np.savez(fh, **arrays)
    return path


def _read_meta(archive: np.lib.npyio.NpzFile, path: str) -> dict:
    if _META_KEY not in archive:
        raise ModelStoreError(
            f"{path}: not a {FORMAT} artifact (missing {_META_KEY!r} entry)"
        )
    try:
        meta = json.loads(bytes(archive[_META_KEY].tobytes()).decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ModelStoreError(f"{path}: corrupt {_META_KEY!r} block: {e}") from e
    if not isinstance(meta, dict) or meta.get("format") != FORMAT:
        raise ModelStoreError(
            f"{path}: not a {FORMAT} artifact "
            f"(format tag {meta.get('format') if isinstance(meta, dict) else meta!r})"
        )
    if meta.get("version") != VERSION:
        raise ModelStoreError(
            f"{path}: artifact version {meta.get('version')!r} is not the "
            f"supported version {VERSION}; re-save the model with this engine"
        )
    return meta


def load_model(path, *, device_resident: bool = True) -> LearnedModel:
    """Reload a saved model, CPTs device-resident by default.

    The load path issues no jit compilations of its own — warm-path
    recompiles stay at zero — and every CPT transfer is accounted through
    ``ops.to_device`` (``device_resident=False`` keeps host arrays, for
    tooling that only inspects the artifact).
    """
    from ..data.ingest import ingest_schema

    path = str(path)
    with np.load(path) as archive:
        meta = _read_meta(archive, path)
        schema = ingest_schema(meta["schema"])
        bn_meta = meta["bn"]
        bn = BayesNet(
            rvs=tuple(bn_meta["rvs"]),
            parents={
                rv: tuple(parents) for rv, parents in bn_meta["parents"].items()
            },
        )
        factors: dict[str, FactorTable] = {}
        for fmeta in meta["factors"]:
            key = fmeta["key"]
            if key not in archive:
                raise ModelStoreError(
                    f"{path}: factor array {key!r} for {fmeta['child']!r} "
                    "is missing from the archive"
                )
            table = np.asarray(archive[key], np.float32)
            factors[fmeta["child"]] = FactorTable(
                child=fmeta["child"],
                parents=tuple(fmeta["parents"]),
                table=ops.to_device(table) if device_resident else table,
            )

    model = LearnedModel(
        schema=schema, bn=bn, factors=factors, meta=meta.get("meta", {})
    )
    model.validate()
    return model
