"""Fault-tolerant training driver.

The loop is crash-equivalent to its checkpoint stream: every ``ckpt_every``
steps an async atomic checkpoint is written; on *any* step failure the
driver restores the last committed state and replays (the data pipeline is
a pure function of step, so replay is exact).  ``max_restarts`` bounds the
retry budget; a ``fault_hook`` lets tests inject failures at chosen steps.

Straggler mitigation: per-step wall time is tracked with an EWMA; steps
slower than ``straggler_factor`` x EWMA increment a counter and fire
``on_straggler`` (on a real cluster this is where a hot spare takes over
the slow host's shard — single-process here, so the hook logs/records; the
data pipeline's statelessness is what makes the swap cheap).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from ..data.pipeline import DataConfig, batch_at
from .checkpoint import CheckpointManager
from .optimizer import AdamWConfig
from .schedules import make_schedule
from .step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    accum_steps: int = 1
    max_restarts: int = 3
    straggler_factor: float = 3.0
    schedule: str = "cosine"          # cosine | wsd | const
    warmup: int = 10
    opt: AdamWConfig = field(default_factory=AdamWConfig)


@dataclass
class TrainResult:
    final_step: int
    losses: list[float]
    restarts: int
    straggler_steps: list[int]
    seconds: float


class Trainer:
    def __init__(
        self,
        model_cfg,
        data_cfg: DataConfig,
        cfg: TrainerConfig,
        *,
        fault_hook: Callable[[int], None] | None = None,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.on_straggler = on_straggler
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.schedule = make_schedule(cfg.schedule, warmup=cfg.warmup, total=cfg.steps)
        self._step_fn = None

    def _build(self):
        if self._step_fn is None:
            raw = make_train_step(
                self.model_cfg, self.cfg.opt, remat=True,
                accum_steps=self.cfg.accum_steps,
            )
            self._step_fn = jax.jit(raw, donate_argnums=(0, 1))
        return self._step_fn

    def init_state(self, seed: int = 0):
        from .step import init_train_state

        return init_train_state(self.model_cfg, jax.random.PRNGKey(seed))

    def run(self, *, seed: int = 0, resume: bool = True) -> TrainResult:
        t0 = time.perf_counter()
        cfg = self.cfg
        step_fn = self._build()

        start = 0
        params = opt_state = None
        if resume and self.ckpt.latest_step() is not None:
            like = self.init_state(seed)
            start, (params, opt_state) = self.ckpt.restore(None, like)
            start += 1
        if params is None:
            params, opt_state = self.init_state(seed)

        losses: list[float] = []
        stragglers: list[int] = []
        restarts = 0
        ewma = None
        step = start
        while step < cfg.steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = {
                    k: jax.numpy.asarray(v)
                    for k, v in batch_at(self.data_cfg, step).items()
                }
                t_step = time.perf_counter()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t_step
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                losses.append(loss)

                if ewma is None:
                    ewma = dt
                elif dt > cfg.straggler_factor * ewma:
                    stragglers.append(step)
                    if self.on_straggler:
                        self.on_straggler(step, dt / ewma)
                ewma = 0.9 * (ewma or dt) + 0.1 * dt

                if step % cfg.log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} ({dt:.2f}s)",
                          flush=True)
                if step % cfg.ckpt_every == 0 or step == cfg.steps - 1:
                    self.ckpt.save(step, (params, opt_state))
                step += 1
            except (KeyboardInterrupt,):
                raise
            except Exception as e:  # node failure semantics: restore + replay
                restarts += 1
                print(f"[train] step {step} FAILED ({e!r}); "
                      f"restart {restarts}/{cfg.max_restarts}", flush=True)
                if restarts > cfg.max_restarts:
                    raise
                self.ckpt.wait()
                like = self.init_state(seed)
                last = self.ckpt.latest_step()
                if last is None:
                    params, opt_state = self.init_state(seed)
                    step = 0
                else:
                    last, (params, opt_state) = self.ckpt.restore(None, like)
                    step = last + 1
        self.ckpt.wait()
        return TrainResult(
            final_step=step - 1,
            losses=losses,
            restarts=restarts,
            straggler_steps=stragglers,
            seconds=time.perf_counter() - t0,
        )
