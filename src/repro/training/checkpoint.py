"""Fault-tolerant sharded checkpointing.

Layout (one directory per step):

    ckpt_dir/
      step_000100/
        manifest.json      # pytree structure, shapes, dtypes, shard map
        shard_00000.npz    # this process's addressable shards
      step_000100.COMMITTED  # atomic commit marker (written last)
      LATEST                 # text file with the last committed step

Guarantees:
  * **atomic**: readers only trust directories with a COMMITTED marker, so
    a crash mid-save never corrupts restore (the half-written dir is
    garbage-collected on the next save).
  * **async**: ``save()`` snapshots device arrays to host then hands the
    file I/O to a background thread — training resumes immediately
    (overlap of checkpoint I/O with compute).
  * **keep-k**: old committed steps beyond ``keep`` are deleted.
  * **elastic**: ``restore()`` takes the *target* shardings — a checkpoint
    written on one mesh restores onto a different mesh/device count (the
    manifest stores global shapes; shards are reassembled then resharded),
    which is the elastic-scaling path (DESIGN.md §5).

On this single-process CPU container every array is fully addressable; on a
multi-host pod each process writes its addressable shards — the format
already carries per-shard index metadata for that case.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np

Params = Any

# npz cannot store ml_dtypes (bf16 etc.) natively: stored as uint views with
# the logical dtype recorded in the manifest.
_UINT_OF_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_storable(a: np.ndarray) -> np.ndarray:
    try:
        np.dtype(a.dtype).name  # noqa: B018
        if a.dtype.kind in "biufc":
            return a
    except TypeError:
        pass
    return a.view(_UINT_OF_SIZE[a.dtype.itemsize])


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(a.dtype) == dtype_name:
        return a
    return a.view(np.dtype(getattr(ml_dtypes, dtype_name, dtype_name)))


def _flatten_with_names(tree: Params) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Params, *, block: bool = False) -> None:
        self.wait()  # one in-flight save at a time; surfaces prior errors
        named = _flatten_with_names(tree)
        # snapshot to host memory synchronously (cheap, consistent view)
        host = [(n, np.asarray(jax.device_get(x))) for n, x in named]
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            try:
                tmp = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = {
                    "step": step,
                    "treedef": str(treedef),
                    "leaves": [
                        {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                        for n, a in host
                    ],
                    "process_count": jax.process_count(),
                    "time": time.time(),
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
                np.savez(tmp / f"shard_{jax.process_index():05d}.npz",
                         **{f"leaf_{i}": _to_storable(a) for i, (_, a) in enumerate(host)})
                # commit
                (self.dir / f"step_{step:08d}.COMMITTED").write_text("ok")
                latest = self.dir / "LATEST"
                tmp_latest = self.dir / ".LATEST.tmp"
                tmp_latest.write_text(str(step))
                tmp_latest.replace(latest)
                self._gc()
            except BaseException as e:  # surfaced by next wait()
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}") from err

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
            (self.dir / f"step_{s:08d}.COMMITTED").unlink(missing_ok=True)

    # ------------------------------------------------------------- restore
    def committed_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*.COMMITTED"):
            try:
                out.append(int(p.stem.split("_")[1].split(".")[0]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, like: Params, *, shardings: Params | None = None) -> tuple[int, Params]:
        """Restore into the structure of ``like`` (shapes/dtypes verified).

        ``shardings``: optional target NamedSharding pytree — this is the
        elastic path: the host arrays are placed onto whatever mesh the
        *current* run uses, regardless of the mesh that wrote them.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data: dict[str, np.ndarray] = {}
        for shard in sorted(d.glob("shard_*.npz")):
            with np.load(shard) as z:
                data.update({k: z[k] for k in z.files})
        named = _flatten_with_names(like)
        assert len(named) == len(manifest["leaves"]), "tree structure changed"
        leaves = []
        for i, ((name, ref), meta) in enumerate(zip(named, manifest["leaves"])):
            assert name == meta["name"], (name, meta["name"])
            arr = _from_storable(data[f"leaf_{i}"], meta["dtype"])
            assert list(arr.shape) == meta["shape"]
            ref_shape = tuple(getattr(ref, "shape", arr.shape))
            assert tuple(arr.shape) == ref_shape, (name, arr.shape, ref_shape)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return step, tree
