"""AdamW + LR schedules (cosine, WSD) as pure pytree transforms.

Optimizer moments are float32 and shard exactly like their parameters
(ZeRO-3: the param sharding rules apply verbatim to m/v), which is what the
dry-run memory analysis accounts.  Weight decay is decoupled (AdamW).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Params,
    grads: Params,
    state: dict,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> tuple[Params, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def cosine_schedule(step, *, warmup: int, total: int, min_ratio: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return jnp.where(s < warmup, warm, cos)


def wsd_schedule(step, *, warmup: int, total: int, decay_frac: float = 0.1,
                 min_ratio: float = 0.1):
    """Warmup-Stable-Decay (MiniCPM): flat LR, then a short final decay."""
    s = jnp.asarray(step, jnp.float32)
    decay_start = total * (1 - decay_frac)
    warm = s / max(warmup, 1)
    stable = jnp.ones_like(s)
    prog = jnp.clip((s - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    decay = min_ratio ** prog  # exponential anneal to min_ratio
    out = jnp.where(s < warmup, warm, jnp.where(s < decay_start, stable, decay))
    return out
