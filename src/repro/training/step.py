"""Train / prefill / decode step builders (the functions the launcher jits).

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with loss+grad+AdamW fused in one jit (single-program multiple-data under
pjit; gradient accumulation wraps it at the driver level).  The same builders
are used by the dry-run, so what is lowered for the 512-chip mesh is exactly
what the trainer runs.
"""

from __future__ import annotations
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import forward, loss_fn
from ..serving.decode import decode_step as _decode_step
from .optimizer import AdamWConfig, adamw_init, adamw_update

Params = Any


def make_train_step(
    cfg,
    opt_cfg: AdamWConfig | None = None,
    *,
    remat: bool = True,
    accum_steps: int = 1,
):
    """loss+grad+AdamW in one jit; ``accum_steps`` microbatches the global
    batch with f32 gradient accumulation (the activation-memory knob that
    fits the train_4k shapes into 16 GB v5e HBM — see EXPERIMENTS §Dry-run).
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            b = jax.tree.leaves(batch)[0].shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            mbs = b // accum_steps

            def micro(carry, i):
                g_acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * mbs, mbs, axis=0),
                    batch,
                )
                (loss, metrics), g = grad_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g
                )
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, loss_sum), metrics = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), jnp.arange(accum_steps)
            )
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = loss_sum / accum_steps
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg):
    """Full-sequence forward; emits last-position logits (cache materialization
    is measured by the decode workload — see EXPERIMENTS.md §Dry-run notes)."""

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch["tokens"], memory=batch.get("memory"),
                            remat=False)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg):
    def serve_step(params, cache, tokens):
        return _decode_step(params, cfg, cache, tokens)

    return serve_step


def init_train_state(cfg, key):
    from ..models.transformer import init_params

    params = init_params(cfg, key)
    return params, adamw_init(params)
