"""Named LR schedules (fraction-of-base multipliers)."""

from __future__ import annotations

import functools

from .optimizer import cosine_schedule, wsd_schedule


def make_schedule(name: str, *, warmup: int, total: int):
    if name == "cosine":
        return functools.partial(cosine_schedule, warmup=warmup, total=total)
    if name == "wsd":  # MiniCPM warmup-stable-decay
        return functools.partial(wsd_schedule, warmup=warmup, total=total)
    if name == "const":
        return lambda step: 1.0
    raise ValueError(f"unknown schedule {name!r}")
