"""Gradient compression for data-parallel all-reduce (beyond-paper).

Two production-grade distributed-optimization tricks, both off by default:

  * ``bf16``: cast gradients to bf16 before the cross-replica reduction —
    halves DP all-reduce bytes, negligible quality effect at LM scale.
  * ``int8``: per-tensor affine quantization with **error feedback**: the
    quantization residual is carried in a state pytree and added back before
    the next step's quantization, making the compression unbiased over time
    (Seide et al. / 1-bit-SGD lineage).  4x all-reduce byte reduction.

Usage (wraps the grads right before ``adamw_update``):

    comp = GradCompressor("int8")
    state = comp.init(params)
    grads, state = comp.compress_decompress(grads, state)

Under pjit the cast/quantize ops sit before the reduce-scatter, so XLA
performs the collective at the compressed width; tests assert numerics
(relative error bounds and error-feedback convergence).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


class GradCompressor:
    def __init__(self, mode: str = "none"):
        assert mode in ("none", "bf16", "int8")
        self.mode = mode

    def init(self, params: Params) -> Params:
        if self.mode != "int8":
            return {}
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress_decompress(self, grads: Params, state: Params) -> tuple[Params, Params]:
        if self.mode == "none":
            return grads, state
        if self.mode == "bf16":
            out = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads
            )
            return out, state

        def q(g, err):
            g = g.astype(jnp.float32) + err
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            qg = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = qg.astype(jnp.float32) * scale
            return deq, g - deq

        flat_g, td = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(state)
        outs = [q(g, e) for g, e in zip(flat_g, flat_e)]
        return (
            jax.tree.unflatten(td, [o[0] for o in outs]),
            jax.tree.unflatten(td, [o[1] for o in outs]),
        )
