"""Deterministic synthetic LM data pipeline.

Design goals (1000+-node posture):
  * **stateless-resumable**: batch at step ``t`` is a pure function of
    (seed, step) — no iterator state to checkpoint; straggler/hot-spare
    recovery just asks for step t again (DESIGN.md §5).
  * **host-shardable**: each host materializes only its slice
    (``host_index / host_count``); on a real multi-host pod the global
    array is assembled with ``jax.make_array_from_process_local_data``.
  * **structured, not uniform noise**: tokens follow a seeded Markov chain
    + copy motif so that a trained model's loss actually decreases
    (examples/train_lm.py shows >2 nats of learnable signal).

The same module feeds the relational pipeline: ``relational_token_stream``
serializes FactorBase ground atoms into token sequences, which is how the
paper's databases become an LM pretraining corpus (count-manager tie-in:
domain-value frequencies are GROUP BY counts via ``kernels.ct_count``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_motifs: int = 64
    motif_len: int = 16


def _rng_for(cfg: DataConfig, step: int, host_index: int = 0) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_index])
    )


def _motifs(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(2, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))


def batch_at(
    cfg: DataConfig, step: int, *, host_index: int = 0, host_count: int = 1
) -> dict[str, np.ndarray]:
    """Batch for ``step`` (this host's slice): tokens + next-token labels."""
    assert cfg.global_batch % host_count == 0
    b = cfg.global_batch // host_count
    rng = _rng_for(cfg, step, host_index)
    motifs = _motifs(cfg)

    # order-1 Markov backbone with a small state space projected to vocab
    n_states = min(cfg.vocab, 257)
    trans = np.random.default_rng(cfg.seed + 1).dirichlet(
        np.full(n_states, 0.2), size=n_states
    )
    seq = np.empty((b, cfg.seq_len + 1), np.int64)
    state = rng.integers(0, n_states, size=b)
    u = rng.random((b, cfg.seq_len + 1))
    cum = np.cumsum(trans, axis=1)
    for t in range(cfg.seq_len + 1):
        state = (u[:, t : t + 1] < cum[state]).argmax(axis=1)
        seq[:, t] = state
    seq = seq % cfg.vocab

    # splice in copyable motifs (induction-head signal)
    n_splice = cfg.seq_len // (4 * cfg.motif_len)
    for i in range(b):
        ids = rng.integers(0, cfg.n_motifs, size=n_splice)
        pos = rng.integers(0, cfg.seq_len - cfg.motif_len, size=n_splice)
        for m, p in zip(ids, pos):
            seq[i, p : p + cfg.motif_len] = motifs[m]

    return {
        "tokens": seq[:, :-1].astype(np.int32),
        "labels": seq[:, 1:].astype(np.int32),
    }


def relational_token_stream(db, cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Serialize relationship tuples as token sequences (FactorBase corpus).

    Each relationship row becomes  [REL_ID, e1_attrs..., e2_attrs...,
    rel_attrs..., SEP]; sequences are concatenations of random rows.  Vocab
    layout: 0=PAD/SEP, 1..k reserved, attribute codes offset per par-RV so
    the LM vocabulary mirrors the VDB domains.
    """
    rng = _rng_for(cfg, step)
    cat = db.catalog
    offsets: dict[str, int] = {}
    off = 8
    for v in cat.par_rvs:
        offsets[v.vid] = off
        off += v.cardinality
    assert off <= cfg.vocab, f"vocab {cfg.vocab} < required {off}"

    rows = []
    for rname, rel in db.relationships.items():
        rv = cat.rel_var_of(rname)
        f1, f2 = rv.fovars
        e1 = db.entities[f1.entity]
        e2 = db.entities[f2.entity]
        fk1 = np.asarray(rel.fk1)
        fk2 = np.asarray(rel.fk2)
        cols = [np.full(rel.n_rows, offsets[rv.vid] + 1)]  # R = T
        for a in cat.attrs_of_fovar(f1.fid):
            cols.append(offsets[a.vid] + np.asarray(e1.attrs[a.column])[fk1])
        for a in cat.attrs_of_fovar(f2.fid):
            cols.append(offsets[a.vid] + np.asarray(e2.attrs[a.column])[fk2])
        for a in cat.attrs_of_rel(rname):
            cols.append(offsets[a.vid] + np.asarray(rel.attrs[a.column]))
        rows.append(np.stack(cols, axis=1))
    atoms = np.concatenate([r.reshape(r.shape[0], -1) for r in rows], axis=0) \
        if len({r.shape[1] for r in rows}) == 1 else None
    flat = np.concatenate([np.concatenate([r, np.zeros((r.shape[0], 1), r.dtype)], 1).reshape(-1)
                           for r in rows])
    b = cfg.global_batch
    need = b * (cfg.seq_len + 1)
    start = rng.integers(0, max(len(flat) - need, 1))
    stream = np.resize(flat[start:], need).reshape(b, cfg.seq_len + 1)
    return {
        "tokens": stream[:, :-1].astype(np.int32),
        "labels": stream[:, 1:].astype(np.int32),
    }
