"""Million-row synthetic star schemas: the scale leg's workload generator.

:mod:`repro.data.relational` generates the six paper-analogue benchmark
databases with a Python rejection loop over foreign-key pairs — fine at the
paper's 10^3..10^6 tuple range, unusable at the 10^6..10^7+ fact rows the
``launch/dryrun_factorbase.py`` workload model targets.  This module is the
fully-vectorized generator for exactly that workload model: ONE relationship
(fact) table over two entity (dimension) populations, two chained attributes
of cardinality 3 per entity side, one relationship attribute of cardinality
3 (4 with the ``n/a`` code) — the Fig. 3(c) CT shape the dry run lowers,
``cards = [3, 3, 3, 3, 4]`` plus the relationship indicator.

Design constraints, in order:

  * **Determinism by seed.**  Every sample comes from one
    ``np.random.default_rng(seed)`` stream through vectorized draws only;
    the same ``(spec, seed)`` pair reproduces the database bit-for-bit on
    any platform numpy supports (``tests/test_scale.py`` pins this).
  * **Distinct foreign-key pairs.**  A relationship instance table stores a
    *set* of true groundings; duplicate ``(fk1, fk2)`` pairs would double
    count groundings and push the Möbius ``F = star − T`` negative.  Pairs
    are sampled with replacement under a Zipf-like popularity skew and
    deduplicated wholesale with ``np.unique`` over packed pair codes —
    no per-row Python.
  * **float32-exact counting.**  The count stack's precision contract
    rounds every CT cell to float32, exact only below ``2**24``.  The
    binding cells are the Möbius star products ``h_src[a] · h_dst[b]`` of
    the entity config histograms, so entity attributes are drawn
    near-uniform and :func:`generate_scale` asserts the realized
    ``max(h_src) * max(h_dst)`` (and the max fact-table cell) stay under
    the bound — a finer-grained guard than ``relational.generate``'s
    wholesale ``n1 * n2 <= 2**24``, which would cap entity populations far
    below what 10^7 distinct fact pairs need.

Presets (``SCALE_PRESETS``) ride the same ``benchmarks/common.load`` path
as the paper-analogue datasets; ``benchmarks/bench_scale.py`` is the
consumer that earns the device COO path against these.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

import jax.numpy as jnp
import numpy as np

from ..core.database import EntityTable, RelationalDatabase, RelationshipTable
from ..core.schema import RelationalSchema, analyze_schema, make_schema

#: float32 exactly represents integers below this; every CT cell must fit.
_F32_EXACT = 2 ** 24


@dataclass(frozen=True)
class ScaleSpec:
    """One star-schema instance size (the dry-run workload model's knobs)."""

    name: str
    n_facts: int          # distinct true groundings of the fact relationship
    n_src: int            # rows of the first (probe-side) entity population
    n_dst: int            # rows of the second entity population
    src_attrs: tuple[tuple[str, int], ...] = (("a1", 3), ("a2", 3))
    dst_attrs: tuple[tuple[str, int], ...] = (("b1", 3), ("b2", 3))
    rel_attrs: tuple[tuple[str, int], ...] = (("ra", 3),)
    skew: float = 0.8     # FK popularity skew (rank^-skew weights), 0 = uniform

    def scaled(self, scale: float) -> "ScaleSpec":
        """Scale fact rows by ``scale`` and entity rows by ``sqrt(scale)``."""
        if scale == 1.0:
            return self
        return replace(
            self,
            n_facts=max(1024, int(self.n_facts * scale)),
            n_src=max(256, int(self.n_src * scale ** 0.5)),
            n_dst=max(256, int(self.n_dst * scale ** 0.5)),
        )

    @property
    def total_tuples(self) -> int:
        return self.n_facts + self.n_src + self.n_dst

    def schema(self) -> RelationalSchema:
        dom = lambda k: tuple(str(i + 1) for i in range(k))
        return make_schema(
            entities={
                "src": {a: dom(c) for a, c in self.src_attrs},
                "dst": {a: dom(c) for a, c in self.dst_attrs},
            },
            relationships={
                "fact": (("src", "dst"), {a: dom(c) for a, c in self.rel_attrs}),
            },
        )


def _entity_codes(rng: np.random.Generator, n: int,
                  attrs: tuple[tuple[str, int], ...]) -> dict[str, np.ndarray]:
    """Chained attribute columns (attr_k | attr_{k-1}), near-uniform marginals.

    The chain plants the same intra-entity dependence structure as the
    paper-analogue generator; the high Dirichlet concentration keeps every
    joint-config histogram cell close to ``n / prod(cards)`` so the Möbius
    star products stay inside the float32-exact envelope at million-row
    entity populations.
    """
    cols: dict[str, np.ndarray] = {}
    prev: np.ndarray | None = None
    for attr, card in attrs:
        if prev is None:
            p = rng.dirichlet(np.full(card, 24.0))
            col = rng.choice(card, size=n, p=p)
        else:
            prev_card = int(prev.max(initial=0)) + 1
            cpt = np.cumsum(
                rng.dirichlet(np.full(card, 16.0), size=prev_card), axis=1
            )
            u = rng.random(n)
            col = np.empty(n, np.int64)
            for cfg in range(prev_card):
                m = prev == cfg
                col[m] = np.searchsorted(cpt[cfg], u[m], side="right")
            np.clip(col, 0, card - 1, out=col)
        cols[attr] = col.astype(np.int32)
        prev = col
    return cols


def _distinct_pairs(rng: np.random.Generator, spec: ScaleSpec) -> np.ndarray:
    """``n_facts`` distinct packed pair codes ``fk1 * n_dst + fk2``.

    Popularity-skewed sampling with replacement, deduplicated in bulk; the
    final trim runs through an rng permutation so the kept set is not
    biased toward small row ids.  Purely vectorized — the paper-analogue
    generator's per-pair rejection loop is the thing this replaces.
    """
    n1, n2, want = spec.n_src, spec.n_dst, spec.n_facts
    if want > n1 * n2:
        raise ValueError(
            f"{spec.name}: n_facts={want} exceeds the {n1}x{n2} pair space"
        )
    # rank^-skew popularity, assigned to rows in rng-permuted order so row
    # id carries no information
    w1 = (np.arange(1, n1 + 1, dtype=np.float64) ** -spec.skew)[rng.permutation(n1)]
    p1 = w1 / w1.sum()
    w2 = (np.arange(1, n2 + 1, dtype=np.float64) ** -(spec.skew * 0.5))[
        rng.permutation(n2)
    ]
    p2 = w2 / w2.sum()
    have = np.empty(0, np.int64)
    while have.size < want:
        k = int((want - have.size) * 1.5) + 1024
        i = rng.choice(n1, size=k, p=p1).astype(np.int64)
        j = rng.choice(n2, size=k, p=p2).astype(np.int64)
        have = np.unique(np.concatenate([have, i * n2 + j]))
    return rng.permutation(have)[:want]


def generate_scale(spec: ScaleSpec, seed: int = 7) -> RelationalDatabase:
    """Sample one star-schema database instance (see module docstring)."""
    rng = np.random.default_rng(seed)
    schema = spec.schema()

    src_cols = _entity_codes(rng, spec.n_src, spec.src_attrs)
    dst_cols = _entity_codes(rng, spec.n_dst, spec.dst_attrs)

    pair = _distinct_pairs(rng, spec)
    fk1 = (pair // spec.n_dst).astype(np.int32)
    fk2 = (pair % spec.n_dst).astype(np.int32)

    # relationship attributes conditional on the first attribute of each
    # side — the cross-table dependence structure learning should find
    a1 = src_cols[spec.src_attrs[0][0]][fk1]
    b1 = dst_cols[spec.dst_attrs[0][0]][fk2]
    c1, c2 = spec.src_attrs[0][1], spec.dst_attrs[0][1]
    cfg = a1.astype(np.int64) * c2 + b1
    rel_cols: dict[str, np.ndarray] = {}
    for attr, card in spec.rel_attrs:
        cpt = np.cumsum(rng.dirichlet(np.full(card, 2.0), size=c1 * c2), axis=1)
        u = rng.random(spec.n_facts)
        col = np.empty(spec.n_facts, np.int64)
        for c in range(c1 * c2):
            m = cfg == c
            col[m] = np.searchsorted(cpt[c], u[m], side="right")
        np.clip(col, 0, card - 1, out=col)
        rel_cols[attr] = (col + 1).astype(np.int32)  # +1: code 0 is n/a

    # float32-exactness guards (finer-grained than relational.generate's
    # wholesale n1*n2 bound — see module docstring)
    def _config_hist(cols, attrs):
        code = np.zeros(len(next(iter(cols.values()))), np.int64)
        for (a, card) in attrs:
            code = code * card + cols[a]
        return np.bincount(code, minlength=math.prod(c for _, c in attrs))

    h_src = _config_hist(src_cols, spec.src_attrs)
    h_dst = _config_hist(dst_cols, spec.dst_attrs)
    star_max = int(h_src.max(initial=0)) * int(h_dst.max(initial=0))
    assert star_max < _F32_EXACT, (
        f"{spec.name}: max Möbius star cell {star_max} exceeds the "
        f"float32-exact bound {_F32_EXACT}; reduce entity populations"
    )
    fact_code = a1.astype(np.int64)
    for a, card in spec.src_attrs[1:]:
        fact_code = fact_code * card + src_cols[a][fk1]
    for a, card in spec.dst_attrs:
        fact_code = fact_code * card + dst_cols[a][fk2]
    for a, card in spec.rel_attrs:
        fact_code = fact_code * (card + 1) + rel_cols[a]
    fact_max = int(np.bincount(fact_code).max(initial=0))
    assert fact_max < _F32_EXACT, (
        f"{spec.name}: max fact-table CT cell {fact_max} exceeds the "
        f"float32-exact bound {_F32_EXACT}"
    )

    entities = {
        "src": EntityTable(
            "src", spec.n_src, {a: jnp.asarray(c) for a, c in src_cols.items()}
        ),
        "dst": EntityTable(
            "dst", spec.n_dst, {a: jnp.asarray(c) for a, c in dst_cols.items()}
        ),
    }
    relationships = {
        "fact": RelationshipTable(
            "fact", spec.n_facts, jnp.asarray(fk1), jnp.asarray(fk2),
            {a: jnp.asarray(c) for a, c in rel_cols.items()},
        )
    }
    return RelationalDatabase(
        schema, analyze_schema(schema), entities, relationships
    )


# ---------------------------------------------------------------------------
# Presets: the bench_scale ladder
# ---------------------------------------------------------------------------
# Entity populations are sized so the realized star products stay under the
# float32-exact bound (near-uniform 9-config histograms: max cell ~ 1.2·n/9,
# so n <= ~28k per side keeps max(h)^2 < 2^24) while the pair space leaves
# ample room for distinct fact pairs.

SCALE_PRESETS: dict[str, ScaleSpec] = {
    s.name: s
    for s in (
        # CI smoke: big enough to exercise the sharded build, small enough
        # for a PR-gate bench step
        ScaleSpec("synth-smoke", n_facts=50_000, n_src=2_000, n_dst=2_000),
        # the acceptance-bar dataset: >= 10^6 fact rows
        ScaleSpec("synth-1m", n_facts=1_000_000, n_src=20_000, n_dst=20_000),
        ScaleSpec("synth-4m", n_facts=4_000_000, n_src=24_000, n_dst=24_000),
        # weekly slow schedule only
        ScaleSpec("synth-10m", n_facts=10_000_000, n_src=27_000, n_dst=27_000),
    )
}
