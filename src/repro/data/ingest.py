"""Declarative schema ingestion: dict/JSON table catalogs -> core objects.

FactorBase is driven entirely by schema metadata (paper §III): the schema
analyzer reads table/FK declarations out of the system catalog and derives
the par-RV database from them.  This module is the catalog *front door* for
arbitrary relational schemas — CTU Relational / RelBench-style table lists
are expressible in the same declarative spec:

    {
      "tables": {
        "person":  {"columns": {"age": ["young", "old"]}},
        "course":  {"columns": {"level": ["100", "200", "300"]}},
        "advises": {
            "foreign_keys": {"advisor": "person", "advisee": "person"},
            "columns": {"strength": ["weak", "strong"]},
        },
      }
    }

A table with no foreign keys is an *entity* table (implicit primary key =
row index); a table with exactly two foreign keys is a *relationship* table
(paper footnote 2: relationships are binary — anything else fails loud).
Self-referencing FK pairs (both keys naming the same entity), parallel
relationships between the same entity pair, rings, and diamond chains are
all legal shapes; the planner's handling of them is fuzz-enforced by
``tests/test_schema_fuzz.py`` (see docs/ARCHITECTURE.md "schema contract").

Optionally each table carries ``rows`` and the same spec ingests a full
database instance.  ``export_spec`` round-trips a database back into the
spec form (used by ``tools/shrink_schema.py`` to minimize fuzz failures).
"""

from __future__ import annotations

import json
from typing import Any, Mapping

import numpy as np

from ..core.database import RelationalDatabase, from_labels
from ..core.schema import N_A, RelationalSchema, make_schema


class SchemaSpecError(ValueError):
    """A declarative spec failed validation (always names the table/column)."""


def _err(msg: str) -> "SchemaSpecError":
    return SchemaSpecError(msg)


def _check_name(name: Any, what: str) -> str:
    if not isinstance(name, str) or not name.isidentifier():
        raise _err(f"{what} name {name!r} must be a Python-style identifier "
                   "(par-RV ids like 'attr(entity0)' must stay unambiguous)")
    return name


def _check_domain(table: str, col: str, dom: Any) -> tuple[str, ...]:
    if not isinstance(dom, (list, tuple)) or not all(isinstance(v, str) for v in dom):
        raise _err(f"{table}.{col}: domain must be a list of strings, got {dom!r}")
    values = tuple(dom)
    if len(values) < 2:
        raise _err(f"{table}.{col}: attribute domains need >= 2 values, got {values}")
    if len(set(values)) != len(values):
        raise _err(f"{table}.{col}: duplicate domain values in {values}")
    if N_A in values:
        raise _err(f"{table}.{col}: do not declare {N_A!r}; it is the implicit "
                   "code-0 value of relationship attributes")
    return values


def _split_tables(spec: Mapping[str, Any]) -> tuple[dict, dict]:
    """Validate the spec skeleton and split tables into (entities, rels)."""
    if not isinstance(spec, Mapping) or "tables" not in spec:
        raise _err("spec must be a mapping with a 'tables' key")
    tables = spec["tables"]
    if not isinstance(tables, Mapping) or not tables:
        raise _err("'tables' must be a non-empty mapping of table name -> decl")
    unknown_top = set(spec) - {"tables", "name"}
    if unknown_top:
        raise _err(f"unknown top-level keys {sorted(unknown_top)}")

    entities: dict[str, dict] = {}
    rels: dict[str, dict] = {}
    for name, decl in tables.items():
        _check_name(name, "table")
        if not isinstance(decl, Mapping):
            raise _err(f"table {name!r}: decl must be a mapping, got {decl!r}")
        unknown = set(decl) - {"columns", "foreign_keys", "rows", "n_rows"}
        if unknown:
            raise _err(f"table {name!r}: unknown keys {sorted(unknown)}")
        fks = decl.get("foreign_keys", {})
        if not isinstance(fks, Mapping):
            raise _err(f"table {name!r}: 'foreign_keys' must be a mapping "
                       "column -> referenced table")
        (rels if fks else entities)[name] = dict(decl)

    for name, decl in rels.items():
        fks = decl["foreign_keys"]
        if len(fks) != 2:
            raise _err(
                f"table {name!r}: relationships are binary (paper footnote 2); "
                f"expected exactly 2 foreign keys, got {len(fks)} "
                f"({sorted(fks)})"
            )
        for col, ref in fks.items():
            _check_name(col, f"{name} foreign-key column")
            if ref in rels:
                raise _err(f"{name}.{col}: foreign key references relationship "
                           f"table {ref!r}; FKs must target entity tables")
            if ref not in entities:
                raise _err(f"{name}.{col}: foreign key references unknown "
                           f"table {ref!r}")
    return entities, rels


def _decl_columns(name: str, decl: Mapping[str, Any],
                  fk_cols: tuple[str, ...] = ()) -> dict[str, tuple[str, ...]]:
    cols = decl.get("columns", {})
    if not isinstance(cols, Mapping):
        raise _err(f"table {name!r}: 'columns' must map column -> domain list")
    out: dict[str, tuple[str, ...]] = {}
    for col, dom in cols.items():
        _check_name(col, f"{name} column")
        if col in fk_cols:
            raise _err(f"{name}.{col}: column is declared both as an "
                       "attribute and a foreign key")
        out[col] = _check_domain(name, col, dom)
    return out


def ingest_schema(spec: Mapping[str, Any]) -> RelationalSchema:
    """Walk a declarative table spec into a validated :class:`RelationalSchema`.

    Entity/relationship classification comes from the FK count (0 vs 2);
    the two FK declarations' order fixes the ``fk1``/``fk2`` role order,
    which matters for self-relationships (advisor vs advisee).
    """
    entities, rels = _split_tables(spec)
    ent_decls = {
        name: _decl_columns(name, decl) for name, decl in entities.items()
    }
    rel_decls = {}
    for name, decl in rels.items():
        fk_cols = tuple(decl["foreign_keys"])
        refs = tuple(decl["foreign_keys"][c] for c in fk_cols)
        rel_decls[name] = (refs, _decl_columns(name, decl, fk_cols))
    return make_schema(entities=ent_decls, relationships=rel_decls)


def _column_rows(name: str, col: str, rows: Mapping[str, Any],
                 dom: tuple[str, ...], n: int | None) -> list[str]:
    if col not in rows:
        raise _err(f"{name}: 'rows' is missing column {col!r}")
    vals = rows[col]
    if not isinstance(vals, (list, tuple)):
        raise _err(f"{name}.{col}: rows must be a list, got {vals!r}")
    if n is not None and len(vals) != n:
        raise _err(f"{name}.{col}: expected {n} rows, got {len(vals)}")
    bad = [v for v in vals if v not in dom]
    if bad:
        raise _err(f"{name}.{col}: values {bad[:3]!r} not in domain {dom}")
    return list(vals)


def ingest_database(spec: Mapping[str, Any]) -> RelationalDatabase:
    """Ingest a spec whose tables also carry ``rows`` into a full database.

    Entity rows: ``rows = {attr: [labels...]}`` (plus ``n_rows`` for
    attribute-less entities).  Relationship rows: ``rows`` maps each FK
    column to a list of 0-based row indices into the referenced entity and
    each attribute column to its labels.  ``(fk1, fk2)`` pairs must be
    unique — duplicate groundings break the Möbius true/false split (see
    ``database.apply_delta``).
    """
    entities, rels = _split_tables(spec)
    schema = ingest_schema(spec)

    entity_rows: dict[str, dict[str, list]] = {}
    ent_sizes: dict[str, int] = {}
    for name, decl in entities.items():
        rows = decl.get("rows", {})
        if not isinstance(rows, Mapping):
            raise _err(f"table {name!r}: 'rows' must be a mapping")
        edecl = schema.entity(name)
        n = decl.get("n_rows")
        cols: dict[str, list] = {}
        for attr, dom in edecl.attributes:
            vals = _column_rows(name, attr, rows, dom, n)
            n = len(vals)
            cols[attr] = vals
        if n is None:
            raise _err(f"table {name!r}: attribute-less entity needs 'n_rows'")
        unknown = set(rows) - {a for a, _ in edecl.attributes}
        if unknown:
            raise _err(f"table {name!r}: rows for undeclared columns "
                       f"{sorted(unknown)}")
        entity_rows[name] = cols
        ent_sizes[name] = int(n)

    rel_rows: dict[str, dict] = {}
    for name, decl in rels.items():
        rows = decl.get("rows", {})
        if not isinstance(rows, Mapping):
            raise _err(f"table {name!r}: 'rows' must be a mapping")
        fk_cols = tuple(decl["foreign_keys"])
        rdecl = schema.relationship(name)
        fks: list[list[int]] = []
        n: int | None = None
        for col, ref in zip(fk_cols, rdecl.entities):
            if col not in rows:
                raise _err(f"{name}: 'rows' is missing foreign-key column {col!r}")
            idx = rows[col]
            if n is not None and len(idx) != n:
                raise _err(f"{name}.{col}: expected {n} rows, got {len(idx)}")
            n = len(idx)
            cap = ent_sizes[ref]
            bad = [i for i in idx if not (isinstance(i, int) and 0 <= i < cap)]
            if bad:
                raise _err(f"{name}.{col}: foreign keys {bad[:3]!r} out of "
                           f"range [0, {cap}) for entity {ref!r}")
            fks.append(list(idx))
        pairs = list(zip(fks[0], fks[1]))
        if len(set(pairs)) != len(pairs):
            raise _err(f"{name}: duplicate (fk1, fk2) groundings; each pair "
                       "may ground a relationship at most once")
        attrs = {
            attr: _column_rows(name, attr, rows, dom, n)
            for attr, dom in rdecl.attributes
        }
        unknown = set(rows) - set(fk_cols) - {a for a, _ in rdecl.attributes}
        if unknown:
            raise _err(f"table {name!r}: rows for undeclared columns "
                       f"{sorted(unknown)}")
        rel_rows[name] = {"fk1": fks[0], "fk2": fks[1], "attrs": attrs}

    return from_labels(schema, entity_rows, rel_rows, entity_sizes=ent_sizes)


def load_spec(path: str) -> dict:
    """Read a JSON spec file (the on-disk form of the declarative catalog)."""
    with open(path) as fh:
        spec = json.load(fh)
    if not isinstance(spec, dict):
        raise _err(f"{path}: top-level JSON must be an object")
    return spec


def export_spec(db: RelationalDatabase) -> dict:
    """Round-trip a database back into the declarative spec (with rows).

    ``ingest_database(export_spec(db))`` reproduces the same schema and the
    same int-coded tables; the fuzz shrinker leans on this to emit minimal
    self-contained reproducers.
    """
    tables: dict[str, Any] = {}
    for edecl in db.schema.entities:
        t = db.entities[edecl.name]
        rows = {
            attr: [dom[int(c)] for c in np.asarray(t.attrs[attr])]
            for attr, dom in edecl.attributes
        }
        decl: dict[str, Any] = {
            "columns": {a: list(dom) for a, dom in edecl.attributes},
        }
        decl["rows" if rows else "n_rows"] = rows if rows else t.n_rows
        tables[edecl.name] = decl
    for rdecl in db.schema.relationships:
        t = db.relationships[rdecl.name]
        rows: dict[str, Any] = {
            "fk1": [int(i) for i in np.asarray(t.fk1)],
            "fk2": [int(i) for i in np.asarray(t.fk2)],
        }
        for attr, dom in rdecl.attributes:
            # stored codes are in the n/a-augmented domain (>= 1)
            rows[attr] = [dom[int(c) - 1] for c in np.asarray(t.attrs[attr])]
        tables[rdecl.name] = {
            "foreign_keys": {"fk1": rdecl.entities[0], "fk2": rdecl.entities[1]},
            "columns": {a: list(dom) for a, dom in rdecl.attributes},
            "rows": rows,
        }
    return {"tables": tables}
