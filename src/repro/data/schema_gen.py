"""Seeded random relational-schema + database generator (the fuzz corpus).

The planner stack (``plan_conditional`` -> join-tree contraction -> Möbius
virtual join) must hold for *any* legal schema, not just the hand-written
benchmarks.  :class:`SchemaSpec` parametrizes a family of adversarial
shapes — self-referencing FKs, parallel relationships between the same
entity pair, entity chains that close into rings — and ``generate_database``
deterministically materializes (schema, populated instance) from
``(spec, seed)``.  Populations are kept tiny so ``tests/bruteforce.py`` can
enumerate every grounding: the differential oracles in
``tests/test_schema_fuzz.py`` compare brute force vs host vs device vs
sharded vs incremental on each draw.

Everything is reproducible from ``(spec, seed)`` alone; a failing draw is
replayed and minimized with ``tools/shrink_schema.py``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core.database import EntityTable, RelationalDatabase, RelationshipTable
from ..core.schema import RelationalSchema, analyze_schema, make_schema


@dataclass(frozen=True)
class SchemaSpec:
    """Knobs for one random-schema family.  ``repr`` is the bug-report key."""

    n_entities: int = 2
    n_relationships: int = 2
    # per-relationship shape probabilities (checked in this order)
    self_ref_prob: float = 0.25       # rel over (e, e): two first-order vars
    parallel_prob: float = 0.25       # duplicate an earlier rel's entity pair
    chain_prob: float = 0.5           # walk e_k -> e_{k+1 mod n} (rings close
    #                                   when the walk wraps past the last entity)
    max_entity_attrs: int = 2         # 1..max attrs per entity
    max_rel_attrs: int = 1            # 0..max attrs per relationship
    min_domain: int = 2
    max_domain: int = 3
    min_rows: int = 1                 # entity population bounds
    max_rows: int = 4
    max_rel_rows: int = 5             # 0..max relationship groundings
    allow_self_pairs: bool = True     # permit (i, i) groundings in self-rels

    def __post_init__(self) -> None:
        if self.n_entities < 1 or self.n_relationships < 0:
            raise ValueError(f"degenerate spec: {self!r}")
        if not (2 <= self.min_domain <= self.max_domain):
            raise ValueError(f"domains need >= 2 values: {self!r}")
        if not (1 <= self.min_rows <= self.max_rows):
            raise ValueError(f"entity populations must be non-empty: {self!r}")


def _domains(rng: np.random.Generator, spec: SchemaSpec, n: int):
    sizes = rng.integers(spec.min_domain, spec.max_domain + 1, size=max(n, 1))
    return [tuple(str(v) for v in range(int(s))) for s in sizes[:n]]


def generate_schema(spec: SchemaSpec, seed: int) -> RelationalSchema:
    """Deterministically draw one schema from the ``(spec, seed)`` family."""
    rng = np.random.default_rng(seed)
    entities = {}
    for i in range(spec.n_entities):
        n_attrs = int(rng.integers(1, spec.max_entity_attrs + 1))
        doms = _domains(rng, spec, n_attrs)
        entities[f"e{i}"] = {f"a{i}_{j}": doms[j] for j in range(n_attrs)}

    rel_pairs: list[tuple[str, str]] = []
    relationships = {}
    for k in range(spec.n_relationships):
        u = rng.random()
        if u < spec.self_ref_prob:
            e = f"e{int(rng.integers(spec.n_entities))}"
            pair = (e, e)
        elif u < spec.self_ref_prob + spec.parallel_prob and rel_pairs:
            pair = rel_pairs[int(rng.integers(len(rel_pairs)))]
        elif rng.random() < spec.chain_prob and spec.n_entities > 1:
            # chain edge e_k -> e_{k+1}; wrapping past the end closes a ring
            i = k % spec.n_entities
            pair = (f"e{i}", f"e{(i + 1) % spec.n_entities}")
        else:
            i, j = rng.integers(spec.n_entities, size=2)
            pair = (f"e{int(i)}", f"e{int(j)}")
        rel_pairs.append(pair)
        n_attrs = int(rng.integers(0, spec.max_rel_attrs + 1))
        doms = _domains(rng, spec, n_attrs)
        relationships[f"r{k}"] = (pair, {f"w{k}_{j}": doms[j] for j in range(n_attrs)})

    return make_schema(entities=entities, relationships=relationships)


def generate_database(spec: SchemaSpec, seed: int) -> RelationalDatabase:
    """Draw a schema *and* a populated instance (int codes directly)."""
    schema = generate_schema(spec, seed)
    rng = np.random.default_rng(seed + 1)  # decouple rows from schema draw
    catalog = analyze_schema(schema)

    entities = {}
    for edecl in schema.entities:
        n = int(rng.integers(spec.min_rows, spec.max_rows + 1))
        attrs = {
            attr: jnp.asarray(
                rng.integers(0, len(dom), size=n).astype(np.int32))
            for attr, dom in edecl.attributes
        }
        entities[edecl.name] = EntityTable(edecl.name, n, attrs)

    relationships = {}
    for rdecl in schema.relationships:
        n1 = entities[rdecl.entities[0]].n_rows
        n2 = entities[rdecl.entities[1]].n_rows
        # enumerate the legal pair universe, then sample without replacement
        # so (fk1, fk2) pairs stay unique (the Möbius split's invariant)
        flat = np.arange(n1 * n2, dtype=np.int64)
        if rdecl.is_self and not spec.allow_self_pairs:
            flat = flat[flat // n2 != flat % n2]
        m = int(rng.integers(0, min(spec.max_rel_rows, flat.size) + 1))
        take = np.sort(rng.permutation(flat)[:m])
        fk1 = (take // n2).astype(np.int32)
        fk2 = (take % n2).astype(np.int32)
        attrs = {
            attr: jnp.asarray(
                rng.integers(1, len(dom) + 1, size=m).astype(np.int32))
            for attr, dom in rdecl.attributes
        }
        relationships[rdecl.name] = RelationshipTable(
            rdecl.name, m, jnp.asarray(fk1), jnp.asarray(fk2), attrs
        )

    db = RelationalDatabase(schema, catalog, entities, relationships)
    db.validate()
    return db


# Named corners of the shape space — the sweep cycles through these so every
# run covers self-refs, parallel edges, and rings regardless of base seed.
SPEC_CORPUS: tuple[SchemaSpec, ...] = (
    SchemaSpec(),                                             # mixed default
    SchemaSpec(n_entities=1, n_relationships=2,
               self_ref_prob=1.0, parallel_prob=0.0),         # dual self-refs
    SchemaSpec(n_entities=2, n_relationships=3,
               self_ref_prob=0.0, parallel_prob=1.0),         # parallel edges
    SchemaSpec(n_entities=3, n_relationships=3, self_ref_prob=0.0,
               parallel_prob=0.0, chain_prob=1.0),            # 3-ring
    SchemaSpec(n_entities=4, n_relationships=4, self_ref_prob=0.0,
               parallel_prob=0.3, chain_prob=1.0),            # ring + diamond
    SchemaSpec(n_entities=3, n_relationships=4, self_ref_prob=0.4,
               parallel_prob=0.3, allow_self_pairs=False),    # loop-free self
)


def corpus_case(i: int, base_seed: int) -> tuple[SchemaSpec, int]:
    """The ``i``-th case of a sweep: cycle corpus specs, advance the seed."""
    spec = SPEC_CORPUS[i % len(SPEC_CORPUS)]
    return spec, base_seed + i


__all__ = [
    "SchemaSpec",
    "SPEC_CORPUS",
    "corpus_case",
    "generate_database",
    "generate_schema",
]
