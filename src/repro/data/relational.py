"""Synthetic multi-relational benchmark databases.

The paper evaluates on six real-world databases (Table V).  Those datasets
are not redistributable here, so this module generates *structurally
matched* synthetic analogues: same number of relationship/total tables,
comparable par-RV counts, and tuple counts scalable to the paper's range
(10^3 .. >10^6).  Crucially the generator plants real statistical structure:

  * intra-entity attribute chains (attr_k depends on attr_{k-1});
  * relationship existence biased by entity attributes (R correlates with
    attributes across tables);
  * relationship attributes sampled conditionally on both linked entities'
    first attributes (cross-table par-factors for the learner to find).

so structure learning has ground truth to recover, and the contingency
tables have realistic skew (the paper's #SS figures depend on value
sparsity, not just schema size).

float32 count exactness bounds population cross-products at 2**24; the
generator enforces this (see DESIGN.md §2 hardware-adaptation notes).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.database import RelationalDatabase, from_labels
from ..core.schema import RelationalSchema, make_schema


@dataclass(frozen=True)
class EntitySpec:
    name: str
    n_rows: int
    attrs: tuple[tuple[str, int], ...]  # (attr name, cardinality)


@dataclass(frozen=True)
class RelSpec:
    name: str
    entities: tuple[str, str]
    n_rows: int
    attrs: tuple[tuple[str, int], ...]


@dataclass(frozen=True)
class SyntheticSpec:
    name: str
    entities: tuple[EntitySpec, ...]
    rels: tuple[RelSpec, ...]

    def scaled(self, scale: float) -> "SyntheticSpec":
        """Scale tuple counts (entities by sqrt(scale), facts by scale)."""
        es = tuple(
            EntitySpec(e.name, max(8, int(e.n_rows * scale**0.5)), e.attrs)
            for e in self.entities
        )
        ns = {e.name: e.n_rows for e in es}
        rs = []
        for r in self.rels:
            cap = ns[r.entities[0]] * ns[r.entities[1]]
            rs.append(
                RelSpec(r.name, r.entities, min(max(8, int(r.n_rows * scale)), cap // 2), r.attrs)
            )
        return SyntheticSpec(self.name, es, tuple(rs))

    @property
    def n_par_rvs(self) -> int:
        n = sum(len(e.attrs) for e in self.entities)
        n += sum(1 + len(r.attrs) for r in self.rels)
        # self-relationships duplicate the entity's attribute par-RVs
        self_ents = {r.entities[0] for r in self.rels if r.entities[0] == r.entities[1]}
        n += sum(len(e.attrs) for e in self.entities if e.name in self_ents)
        return n

    @property
    def total_tuples(self) -> int:
        return sum(e.n_rows for e in self.entities) + sum(r.n_rows for r in self.rels)


def _dom(k: int) -> tuple[str, ...]:
    return tuple(str(i + 1) for i in range(k))


def _schema(spec: SyntheticSpec) -> RelationalSchema:
    return make_schema(
        entities={
            e.name: {a: _dom(c) for a, c in e.attrs} for e in spec.entities
        },
        relationships={
            r.name: (r.entities, {a: _dom(c) for a, c in r.attrs}) for r in spec.rels
        },
    )


def generate(spec: SyntheticSpec, seed: int = 0) -> RelationalDatabase:
    """Sample a database instance with planted dependencies (see module doc)."""
    rng = np.random.default_rng(seed)
    for r in spec.rels:
        n1 = next(e.n_rows for e in spec.entities if e.name == r.entities[0])
        n2 = next(e.n_rows for e in spec.entities if e.name == r.entities[1])
        assert n1 * n2 <= 2**24, (
            f"{spec.name}.{r.name}: population cross product {n1 * n2} exceeds the "
            "float32-exact counting bound 2**24; reduce entity sizes or use f64"
        )

    schema = _schema(spec)
    entity_rows: dict[str, dict[str, list]] = {}
    codes: dict[str, dict[str, np.ndarray]] = {}

    for e in spec.entities:
        cols: dict[str, list] = {}
        ccols: dict[str, np.ndarray] = {}
        prev: np.ndarray | None = None
        for attr, card in e.attrs:
            if prev is None:
                p = rng.dirichlet(np.full(card, 2.0))
                col = rng.choice(card, size=e.n_rows, p=p)
            else:
                # attribute chain: CPT conditioned on the previous attribute
                prev_card = int(prev.max()) + 1 if prev.size else 1
                cpt = np.stack([rng.dirichlet(np.full(card, 0.6)) for _ in range(prev_card)])
                u = rng.random(e.n_rows)
                cum = np.cumsum(cpt[prev], axis=1)
                col = (u[:, None] < cum).argmax(axis=1)
            ccols[attr] = col.astype(np.int32)
            cols[attr] = [str(v + 1) for v in col]
            prev = col
        entity_rows[e.name] = cols
        codes[e.name] = ccols

    rel_rows: dict[str, dict] = {}
    for r in spec.rels:
        e1 = next(e for e in spec.entities if e.name == r.entities[0])
        e2 = next(e for e in spec.entities if e.name == r.entities[1])
        a1 = codes[e1.name][e1.attrs[0][0]]
        a2 = codes[e2.name][e2.attrs[0][0]]
        c1, c2 = e1.attrs[0][1], e2.attrs[0][1]

        # Existence biased by an affinity table over the first attributes.
        affinity = rng.gamma(2.0, 1.0, size=(c1, c2))
        w1 = affinity[a1][:, 0] / affinity[a1][:, 0].sum()
        # sample without replacement over pairs via rejection
        want = r.n_rows
        seen: set[tuple[int, int]] = set()
        fk1: list[int] = []
        fk2: list[int] = []
        p1 = affinity[a1].sum(axis=1)
        p1 = p1 / p1.sum()
        batch = max(1024, want * 2)
        while len(fk1) < want:
            i = rng.choice(e1.n_rows, size=batch, p=p1)
            j = rng.choice(e2.n_rows, size=batch)
            keep_p = affinity[a1[i], a2[j]] / affinity.max()
            acc = rng.random(batch) < keep_p
            for ii, jj in zip(i[acc], j[acc]):
                if e1.name == e2.name and ii == jj:
                    continue  # no self-loops in self-relationships
                key = (int(ii), int(jj))
                if key in seen:
                    continue
                seen.add(key)
                fk1.append(int(ii))
                fk2.append(int(jj))
                if len(fk1) >= want:
                    break
        fk1a, fk2a = np.array(fk1, np.int32), np.array(fk2, np.int32)

        attrs: dict[str, list] = {}
        for attr, card in r.attrs:
            # conditional on (a1 of end1, a2 of end2)
            cpt = np.stack(
                [rng.dirichlet(np.full(card, 0.5)) for _ in range(c1 * c2)]
            )
            idx = a1[fk1a] * c2 + a2[fk2a]
            u = rng.random(len(fk1a))
            cum = np.cumsum(cpt[idx], axis=1)
            col = (u[:, None] < cum).argmax(axis=1)
            attrs[attr] = [str(v + 1) for v in col]
        rel_rows[r.name] = {"fk1": fk1.copy(), "fk2": fk2.copy(), "attrs": attrs}

    return from_labels(schema, entity_rows, rel_rows)


# ---------------------------------------------------------------------------
# The six benchmark analogues (Table V: #rel tables / total, #par-RV, #tuples)
# ---------------------------------------------------------------------------
# Domains are sized so the dense joint CT stays within the f32-exact /
# in-memory envelope while reaching the paper's #SS scale (10^2 .. >10^7).

MOVIELENS = SyntheticSpec(  # 1/3 tables, 7 par-RVs, ~1M tuples at scale=1
    "movielens",
    entities=(
        EntitySpec("user", 4000, (("age", 3), ("gender", 2), ("occupation", 3))),
        EntitySpec("movie", 3800, (("year", 3), ("genre", 3))),
    ),
    rels=(RelSpec("rated", ("user", "movie"), 990_000, (("rating", 3),)),),
)

MUTAGENESIS = SyntheticSpec(  # 2/4 tables, 11 par-RVs, ~14.5k tuples
    "mutagenesis",
    entities=(
        EntitySpec("molecule", 230, (("ind1", 2), ("inda", 2), ("logp", 3))),
        EntitySpec("atom", 1500, (("element", 3), ("charge", 3))),
    ),
    rels=(
        RelSpec("moleatm", ("molecule", "atom"), 1500, ()),
        RelSpec("bond", ("atom", "atom"), 11_000, (("type", 3), ("strength", 2))),
    ),
)

UW_CSE = SyntheticSpec(  # 2/4 tables, 14 par-RVs, ~712 tuples
    "uw-cse",
    entities=(
        # person has a self-relationship (advises) so its 4 attribute
        # par-RVs are emitted twice (person0/person1): 8 + 2 + 2 ind + 2 = 14
        EntitySpec("person", 180, (("position", 3), ("years", 3), ("area", 3), ("pubs", 2))),
        EntitySpec("course", 120, (("level", 3), ("quarter", 2))),
    ),
    rels=(
        RelSpec("advises", ("person", "person"), 110, (("strength", 2),)),
        RelSpec("teaches", ("person", "course"), 130, (("rating", 3),)),
    ),
)

MONDIAL = SyntheticSpec(  # 2/4 tables, 18 par-RVs, ~870 tuples
    "mondial",
    entities=(
        # country self-relationship (borders): 2x5 + 3 + 2 ind + 3 rel attrs = 18
        EntitySpec("country", 190, (("population", 3), ("continent", 3), ("gdp", 3), ("inflation", 2), ("government", 3))),
        EntitySpec("organization", 150, (("established", 3), ("kind", 3), ("seats", 2))),
    ),
    rels=(
        RelSpec("borders", ("country", "country"), 300, (("length", 2),)),
        RelSpec("member", ("country", "organization"), 230, (("type", 3), ("since", 2))),
    ),
)

HEPATITIS = SyntheticSpec(  # 3/7 tables (4 entity + 3 rel), 19 par-RVs, ~12.9k tuples
    "hepatitis",
    entities=(
        # 4+3+4+2 entity attrs + 3 indicators + 3 rel attrs = 19
        EntitySpec("patient", 500, (("sex", 2), ("age", 3), ("type", 3), ("stage", 2))),
        EntitySpec("bio", 700, (("fibros", 3), ("activity", 3), ("marker", 2))),
        EntitySpec("indis", 900, (("got", 3), ("gpt", 3), ("alb", 2), ("tbil", 2))),
        EntitySpec("inf", 200, (("dur", 3), ("severity", 2))),
    ),
    rels=(
        RelSpec("pat_bio", ("patient", "bio"), 4000, (("b_res", 2),)),
        RelSpec("pat_indis", ("patient", "indis"), 5000, (("i_res", 2),)),
        RelSpec("pat_inf", ("patient", "inf"), 600, (("f_res", 2),)),
    ),
)

IMDB = SyntheticSpec(  # 3/7 tables (4 entity + 3 rel), 17 par-RVs, ~1.35M tuples
    "imdb",
    entities=(
        # 3+2+4+3 entity attrs + 3 indicators + 2 rel attrs = 17
        EntitySpec("actor", 3800, (("gender", 2), ("quality", 3), ("era", 3))),
        EntitySpec("director", 1200, (("quality", 3), ("style", 2))),
        EntitySpec("movie", 3500, (("year", 3), ("rank", 3), ("genre", 3), ("runtime", 3))),
        EntitySpec("user", 4000, (("age", 3), ("occupation", 3), ("activity", 3))),
    ),
    rels=(
        RelSpec("acts", ("actor", "movie"), 130_000, (("role", 3),)),
        RelSpec("directs", ("director", "movie"), 4000, ()),
        RelSpec("rates", ("user", "movie"), 1_200_000, (("rating", 3),)),
    ),
)

BENCHMARKS: dict[str, SyntheticSpec] = {
    s.name: s for s in (MOVIELENS, MUTAGENESIS, UW_CSE, MONDIAL, HEPATITIS, IMDB)
}
