"""Pallas TPU kernel for maximum-likelihood CPT estimation.

Paper §V-B: the parameter manager computes conditional probabilities from the
contingency table by a NATURAL JOIN with the parent-marginal subquery.  In
tensor form the CT is a dense (parent_configs, child_values) matrix and the
"join" is a segmented row-normalization — one VPU pass per tile:

    cpt[p, c] = (ct[p, c] + alpha) / (sum_c' ct[p, c'] + alpha * C)

The child axis is small (par-RV cardinalities), so each tile holds full rows:
the row sum never crosses tile boundaries and the grid is 1-D over parent
blocks.  The child axis is padded to the 128-lane boundary; padded lanes are
masked out of both numerator and row-sum so smoothing stays exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BP = 512  # parent-config rows per tile


def _mle_cpt_kernel(ct_ref, out_ref, *, n_child: int, alpha: float):
    ct = ct_ref[...]  # (BP, C_pad) f32
    cpad = ct.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, (1, cpad), 1) < n_child
    ct = jnp.where(valid, ct, 0.0)
    row = jnp.sum(ct, axis=1, keepdims=True)
    denom = row + alpha * n_child
    safe = jnp.where(denom > 0, denom, 1.0)
    cpt = (ct + alpha) / safe
    uniform = 1.0 / n_child
    cpt = jnp.where(denom > 0, cpt, uniform)
    out_ref[...] = jnp.where(valid, cpt, 0.0)


@functools.partial(jax.jit, static_argnames=("alpha", "interpret", "bp"))
def mle_cpt_pallas(
    ct: jax.Array,
    alpha: float = 0.0,
    *,
    interpret: bool = False,
    bp: int = _BP,
) -> jax.Array:
    """Row-normalize a (parents, children) count matrix into a CPT."""
    p, c = ct.shape
    bp = min(bp, max(8, p))
    p_pad = -p % bp
    c_pad = -c % 128
    ct2 = jnp.pad(ct.astype(jnp.float32), ((0, p_pad), (0, c_pad)))

    out = pl.pallas_call(
        functools.partial(_mle_cpt_kernel, n_child=c, alpha=float(alpha)),
        grid=((p + p_pad) // bp,),
        in_specs=[pl.BlockSpec((bp, c + c_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bp, c + c_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p + p_pad, c + c_pad), jnp.float32),
        interpret=interpret,
    )(ct2)
    return out[:p, :c]
