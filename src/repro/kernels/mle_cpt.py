"""Pallas TPU kernel for maximum-likelihood CPT estimation.

Paper §V-B: the parameter manager computes conditional probabilities from the
contingency table by a NATURAL JOIN with the parent-marginal subquery.  In
tensor form the CT is a dense (parent_configs, child_values) matrix and the
"join" is a segmented row-normalization — one VPU pass per tile:

    cpt[p, c] = (ct[p, c] + alpha) / (sum_c' ct[p, c'] + alpha * C)

The child axis is small (par-RV cardinalities), so each tile holds full rows:
the row sum never crosses tile boundaries and the grid is 1-D over parent
blocks.  The child axis is padded to the 128-lane boundary; padded lanes are
masked out of both numerator and row-sum so smoothing stays exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BP = 512  # parent-config rows per tile


def _mle_cpt_kernel(ct_ref, out_ref, *, n_child: int, alpha: float):
    ct = ct_ref[...]  # (BP, C_pad) f32
    cpad = ct.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, (1, cpad), 1) < n_child
    ct = jnp.where(valid, ct, 0.0)
    row = jnp.sum(ct, axis=1, keepdims=True)
    denom = row + alpha * n_child
    safe = jnp.where(denom > 0, denom, 1.0)
    cpt = (ct + alpha) / safe
    uniform = 1.0 / n_child
    cpt = jnp.where(denom > 0, cpt, uniform)
    out_ref[...] = jnp.where(valid, cpt, 0.0)


@functools.partial(jax.jit, static_argnames=("alpha", "interpret", "bp"))
def mle_cpt_pallas(
    ct: jax.Array,
    alpha: float = 0.0,
    *,
    interpret: bool = False,
    bp: int = _BP,
) -> jax.Array:
    """Row-normalize a (parents, children) count matrix into a CPT."""
    p, c = ct.shape
    bp = min(bp, max(8, p))
    p_pad = -p % bp
    c_pad = -c % 128
    ct2 = jnp.pad(ct.astype(jnp.float32), ((0, p_pad), (0, c_pad)))

    out = pl.pallas_call(
        functools.partial(_mle_cpt_kernel, n_child=c, alpha=float(alpha)),
        grid=((p + p_pad) // bp,),
        in_specs=[pl.BlockSpec((bp, c + c_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bp, c + c_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p + p_pad, c + c_pad), jnp.float32),
        interpret=interpret,
    )(ct2)
    return out[:p, :c]


def _mle_cpt_batched_kernel(ct_ref, mask_ref, out_ref, *, alpha: float):
    ct = ct_ref[0]          # (BP, C_pad) f32
    mask = mask_ref[0]      # (1, C_pad)  f32, 1.0 on valid child lanes
    valid = mask > 0
    ct = jnp.where(valid, ct, 0.0)
    n_child = jnp.sum(mask)  # this family's true child cardinality
    row = jnp.sum(ct, axis=1, keepdims=True)
    denom = row + alpha * n_child
    safe = jnp.where(denom > 0, denom, 1.0)
    cpt = (ct + alpha) / safe
    uniform = 1.0 / jnp.maximum(n_child, 1.0)
    cpt = jnp.where(denom > 0, cpt, uniform)
    out_ref[0] = jnp.where(valid, cpt, 0.0)


@functools.partial(jax.jit, static_argnames=("alpha", "interpret", "bp"))
def mle_cpt_batched_pallas(
    ct: jax.Array,
    child_mask: jax.Array,
    alpha: float = 0.0,
    *,
    interpret: bool = False,
    bp: int = _BP,
) -> jax.Array:
    """Row-normalize a stack of padded family count matrices in one launch.

    ``ct`` is ``(B, P_max, C_max)``; ``child_mask`` ``(B, C_max)`` marks each
    family's valid child values (per-family cardinality = ``sum(mask)``, so
    smoothing stays exact under lane padding).  Grid is (family, parent
    blocks); each tile holds full rows of one family, so row sums never
    cross tiles — the single-family kernel's invariant, preserved per batch
    member.
    """
    b, p, c = ct.shape
    bp = min(bp, max(8, p))
    p_pad = -p % bp
    c_pad = -c % 128
    ct2 = jnp.pad(ct.astype(jnp.float32), ((0, 0), (0, p_pad), (0, c_pad)))
    mask2 = jnp.pad(child_mask.astype(jnp.float32), ((0, 0), (0, c_pad)))[:, None, :]

    out = pl.pallas_call(
        functools.partial(_mle_cpt_batched_kernel, alpha=float(alpha)),
        grid=(b, (p + p_pad) // bp),
        in_specs=[
            pl.BlockSpec((1, bp, c + c_pad), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, 1, c + c_pad), lambda bb, i: (bb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp, c + c_pad), lambda bb, i: (bb, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p + p_pad, c + c_pad), jnp.float32),
        interpret=interpret,
    )(ct2, mask2)
    return out[:, :p, :c]
