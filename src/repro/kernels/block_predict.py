"""Pallas TPU kernel for block test-set prediction.

Paper §VI: adding the target-entity id to the SELECT/GROUP BY lists scores a
whole test set with one query instead of one query per instance (the 10-100x
"block access" speedup of Figure 9).  In tensor form the grouped target
contingency table is a dense (entities, family_configs) matrix and scoring
every entity against every candidate class label is a single MXU matmul:

    scores[e, y] = sum_c target_ct[e, c] * log_cpt[c, y]

This kernel is a classic tiled matmul with a K-loop accumulator resident in
VMEM; it exists because block prediction is the paper's measured hot spot and
because its baseline (the per-instance loop) is exactly what we benchmark
against in ``benchmarks/bench_predict.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BE = 256   # entity rows per tile
_BY = 128   # class labels per tile
_BC = 512   # family configurations per K step


def _block_predict_kernel(a_ref, l_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        a_ref[...],
        l_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret", "be", "by", "bc"))
def block_predict_pallas(
    counts: jax.Array,
    log_cpt: jax.Array,
    *,
    interpret: bool = False,
    be: int = _BE,
    by: int = _BY,
    bc: int = _BC,
) -> jax.Array:
    """scores = counts(E, C) @ log_cpt(C, Y), tiled for VMEM."""
    e, c = counts.shape
    c2, y = log_cpt.shape
    assert c == c2, (counts.shape, log_cpt.shape)
    be, by, bc = min(be, max(8, e)), min(by, max(128, y)), min(bc, max(128, c))
    ep, cp, yp = -e % be, -c % bc, -y % by
    a = jnp.pad(counts.astype(jnp.float32), ((0, ep), (0, cp)))
    l = jnp.pad(log_cpt.astype(jnp.float32), ((0, cp), (0, yp)))

    out = pl.pallas_call(
        _block_predict_kernel,
        grid=((e + ep) // be, (y + yp) // by, (c + cp) // bc),
        in_specs=[
            pl.BlockSpec((be, bc), lambda i, j, k: (i, k)),
            pl.BlockSpec((bc, by), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((be, by), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e + ep, y + yp), jnp.float32),
        interpret=interpret,
    )(a, l)
    return out[:e, :y]
