"""Pallas TPU kernel for fused sparse family scoring (COO marginalize+score).

The sparse structure-search hot loop used to be a three-hop per sweep:
``SparseCT.marginal_batch`` (sort + segment-sum), then ``mle_cpt_batched``,
then ``factor_loglik_batched`` — with the per-family log-likelihood math
executed on host.  This kernel collapses the scoring side into ONE launch
over the *sorted concatenated COO stream* of a whole family batch:

    loglik[f] = sum over realized cells of family f of
                    n_cell * ( log(n_cell + alpha)
                             - log(N_parent + alpha * C_f) )

which is exactly ``SUM(count * log cp)`` with the MLE/Dirichlet conditional
probability ``cp = (n + alpha) / (N_parent + alpha * C)`` — the §V-C
``Scores`` query — evaluated over realized cells only (the 0*log0 := 0
convention makes unrealized cells contribute exactly nothing).

The kernel consumes the per-element form the ops wrapper prepares inside
the same jit (sort by composite code, run-boundary flags, cell and
parent-run totals via sorted segment sums):

    cell_tot   — total count of the element's cell (duplicates pre-summed)
    parent_tot — total count of the element's parent configuration run
    child_card — the element's family's child cardinality (float32)
    rep        — 1.0 on the FIRST element of each cell run (the cell's
                 designated representative; all duplicates contribute 0)
    fam        — the element's family index (int32, non-decreasing)

Each grid step loads one ``(1, BM)`` lane-tile of the stream, evaluates the
masked log term on the VPU, and scatters per-family partial sums through a
one-hot ``(BM, B_pad)`` MXU contraction into a revolving ``(1, B_pad)``
accumulator — B scalar reductions per launch, like ``factor_loglik_batched``
but over ragged COO families instead of padded dense stacks.

Precision: per-cell terms are float32 (the same ``n * log(cp)`` expression
the host path rounds), and the cross-tile accumulation is
Kahan-compensated — a second revolving buffer carries the running
compensation — so the returned float32 scores lose only the final-cast
ulp, not one ulp per tile.  (The jnp oracle instead accumulates in float64
under the ops wrapper's ``enable_x64`` scope; both stay inside the
structure-search walk-alignment margin.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: COO elements per tile.  Kept moderate because each tile materializes a
#: (BM, B_pad) one-hot family-selector in VMEM for the MXU contraction.
_BM = 1024

#: Family-lane cap per launch: the one-hot selector is (BM, B_pad) f32, so
#: B_pad x BM x 4 bytes must stay well under VMEM.  Callers chunk batches.
MAX_FAMILIES = 1024

_LOG_TINY = 1e-30


def _sparse_score_kernel(
    ctot_ref, ptot_ref, cc_ref, rep_ref, fam_ref, acc_ref, comp_ref, *, alpha: float
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        comp_ref[...] = jnp.zeros_like(comp_ref)

    ctot = ctot_ref[...]  # (1, BM) f32 cell totals
    ptot = ptot_ref[...]  # (1, BM) f32 parent-run totals
    cc = cc_ref[...]      # (1, BM) f32 child cardinalities
    rep = rep_ref[...]    # (1, BM) f32 cell-representative mask
    fam = fam_ref[...]    # (1, BM) i32 family ids

    den = ptot + alpha * cc
    cp = (ctot + alpha) / jnp.where(den > 0, den, 1.0)
    term = ctot * jnp.log(jnp.maximum(cp, _LOG_TINY))
    contrib = jnp.where((rep > 0) & (ctot > 0), term, 0.0)

    b_pad = acc_ref.shape[1]
    bm = contrib.shape[1]
    fam_col = jnp.swapaxes(fam, 0, 1)  # (BM, 1)
    onehot = (
        fam_col == jax.lax.broadcasted_iota(jnp.int32, (bm, b_pad), 1)
    ).astype(jnp.float32)
    partial = jnp.dot(contrib, onehot, preferred_element_type=jnp.float32)

    # Kahan step: fold this tile's partial into the running (acc, comp) pair
    acc = acc_ref[...]
    y = partial - comp_ref[...]
    t = acc + y
    comp_ref[...] = (t - acc) - y
    acc_ref[...] = t


@functools.partial(jax.jit, static_argnames=("num_fams", "alpha", "interpret", "bm"))
def sparse_family_score_pallas(
    cell_tot: jax.Array,
    parent_tot: jax.Array,
    child_card: jax.Array,
    rep: jax.Array,
    fam: jax.Array,
    num_fams: int,
    alpha: float = 0.0,
    *,
    interpret: bool = False,
    bm: int = _BM,
) -> jax.Array:
    """Per-family ``sum(count * log cp)`` over a prepared COO stream.

    All five arrays are flat ``(N,)`` and co-indexed (see module docstring);
    returns ``(num_fams,)`` float32 log-likelihoods.  Padding elements must
    carry ``rep == 0`` (or ``cell_tot == 0``) so they contribute nothing;
    ``fam`` values of padding elements may be any in-range id.
    """
    if num_fams > MAX_FAMILIES:
        raise ValueError(
            f"sparse_family_score: {num_fams} families > {MAX_FAMILIES}; "
            "chunk the batch"
        )
    n = cell_tot.shape[0]
    b_pad = -(-num_fams // 128) * 128
    bm = min(bm, max(128, -(-n // 128) * 128))
    pad = -n % bm

    def prep(x, dtype):
        return jnp.pad(x.astype(dtype), (0, pad)).reshape(-1, bm)

    ctot = prep(cell_tot, jnp.float32)
    ptot = prep(parent_tot, jnp.float32)
    cc = prep(child_card, jnp.float32)
    repm = prep(rep, jnp.float32)
    famm = prep(fam, jnp.int32)

    acc, comp = pl.pallas_call(
        functools.partial(_sparse_score_kernel, alpha=float(alpha)),
        grid=(ctot.shape[0],),
        in_specs=[pl.BlockSpec((1, bm), lambda i: (i, 0))] * 5,
        out_specs=[pl.BlockSpec((1, b_pad), lambda i: (0, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, b_pad), jnp.float32)] * 2,
        interpret=interpret,
    )(ctot, ptot, cc, repm, famm)
    # Neumaier finish: the compensation buffer holds -(lost low-order bits)
    return (acc - comp)[0, :num_fams]
