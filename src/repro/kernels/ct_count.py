"""Pallas TPU kernel for GROUP BY COUNT — the count manager's hot loop.

The contingency-table problem (paper §IV) reduces to a histogram of
mixed-radix composite keys.  A scatter-add histogram is hostile to the TPU
memory system (random HBM updates); the MXU-native formulation instead
materializes, per (row-block × bin-block) tile, the one-hot comparison matrix
in VMEM and contracts it with a ones vector on the MXU:

    counts[j*BK : (j+1)*BK] += ones(1, BN) @ (keys_block[:, None] == bins[None, :])

The grid is (bins, rows) with the row dimension innermost so each bin block's
VMEM accumulator is revisited consecutively ("arbitrary" semantics — the
revolving output block stays in VMEM across the row sweep).

Counts are accumulated in float32 (exact below 2**24 per bin per sweep);
weighted counts (SUM(w) GROUP BY key) reuse the same contraction with the
one-hot scaled by the weight column.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: the one-hot tile (BN x BK) f32 = 1 MB of VMEM; the lane dim BK
# is a multiple of 128 for MXU alignment, BN a multiple of 8 for sublanes.
_BN = 2048
_BK = 128


def _ct_count_kernel(keys_ref, w_ref, out_ref, *, bk: int):
    j = pl.program_id(0)  # bin block
    i = pl.program_id(1)  # row block

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (BN, 1) int32
    bins = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    onehot = (keys == bins).astype(jnp.float32)  # (BN, BK)
    onehot = onehot * w_ref[...]  # weights broadcast (BN, 1)
    ones = jnp.ones((1, keys.shape[0]), jnp.float32)
    partial = jax.lax.dot_general(
        ones,
        onehot,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, BK)
    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("num_bins", "interpret", "bn", "bk"))
def ct_count_pallas(
    keys: jax.Array,
    num_bins: int,
    weights: jax.Array | None = None,
    *,
    interpret: bool = False,
    bn: int = _BN,
    bk: int = _BK,
) -> jax.Array:
    """Histogram of int32 ``keys`` into ``num_bins`` float32 counts.

    Keys outside ``[0, num_bins)`` (e.g. ``-1`` padding) are ignored.
    """
    n = keys.shape[0]
    bn = min(bn, max(8, n))
    n_pad = -n % bn
    keys2 = jnp.pad(keys.astype(jnp.int32), (0, n_pad), constant_values=-1)[:, None]
    if weights is None:
        w2 = jnp.ones((n + n_pad, 1), jnp.float32)
    else:
        w2 = jnp.pad(weights.astype(jnp.float32), (0, n_pad))[:, None]
    k_pad = -num_bins % bk
    kb = num_bins + k_pad

    out = pl.pallas_call(
        functools.partial(_ct_count_kernel, bk=bk),
        grid=(kb // bk, (n + n_pad) // bn),
        in_specs=[
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, kb), jnp.float32),
        interpret=interpret,
    )(keys2, w2)
    return out[0, :num_bins]
