"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels are validated against
these in ``tests/test_kernels.py`` over shape/dtype sweeps (interpret mode on
CPU, compiled on TPU).  The oracles are also the production fallback on
non-TPU backends (see :mod:`repro.kernels.ops`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_LOG_TINY = 1e-30


def ct_count_ref(
    keys: jax.Array, num_bins: int, weights: jax.Array | None = None
) -> jax.Array:
    """GROUP BY COUNT: histogram of ``keys`` over ``[0, num_bins)``.

    Out-of-range keys (e.g. the ``-1`` padding sentinel) are dropped.  With
    ``weights`` this is SUM(weight) GROUP BY key.  Returns float32 counts
    (exact for counts < 2**24; the ops wrapper casts to int32 for unweighted
    calls).
    """
    w = jnp.ones(keys.shape, jnp.float32) if weights is None else weights.astype(jnp.float32)
    valid = (keys >= 0) & (keys < num_bins)
    w = jnp.where(valid, w, 0.0)
    safe_keys = jnp.where(valid, keys, 0)
    return jnp.zeros((num_bins,), jnp.float32).at[safe_keys].add(w)


def ct_count_matmul(
    keys: jax.Array,
    num_bins: int,
    weights: jax.Array | None = None,
    *,
    chunk: int = 65536,
) -> jax.Array:
    """The MXU formulation of GROUP BY COUNT in plain XLA ops.

    Semantically identical to :func:`ct_count_ref`, but expressed as a scan
    of one-hot x weights matmuls — exactly the contraction the Pallas
    ``ct_count`` kernel performs in VMEM tiles.  This is the path the
    FactorBase dry-run lowers, so the compiled HLO carries the real MXU
    FLOPs of counting (a scatter-add would hide them).
    """
    n = keys.shape[0]
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    valid = (keys >= 0) & (keys < num_bins)
    w = jnp.where(valid, w, 0.0)
    k = jnp.where(valid, keys, num_bins)  # park invalid on a scratch bin

    pad = -n % chunk
    k = jnp.pad(k, (0, pad), constant_values=num_bins).reshape(-1, chunk)
    w = jnp.pad(w, (0, pad)).reshape(-1, chunk)

    def body(_, xs):
        kc, wc = xs
        onehot = jax.nn.one_hot(kc, num_bins, dtype=jnp.float32)  # (chunk, bins)
        return None, wc @ onehot

    # carry-free scan (stacked partials summed after) so the function works
    # unchanged inside shard_map (no varying-manual-axes carry mismatch)
    _, partials = jax.lax.scan(body, None, (k, w))
    return jnp.sum(partials, axis=0)


def coo_join_expand_ref(
    lo: jax.Array, cnt: jax.Array, total: int
) -> tuple[jax.Array, jax.Array]:
    """Expand a sort-merge join's match table into flat gather indices.

    ``lo[j]``/``cnt[j]`` locate probe key ``j``'s matches inside the sorted
    key column (first position / run length, from two ``searchsorted``
    passes); ``total`` is the static output length.  Pair ``p`` of the
    probe-major expansion is ``(idx_sorted[p], idx_probe[p])`` with

        ``idx_probe[p]  = searchsorted(cumsum(cnt), p, side="right")``
        ``idx_sorted[p] = lo[idx_probe[p]] + (p - start[idx_probe[p]])``

    — the semantic ground truth of the Pallas kernel in
    :mod:`repro.kernels.coo_join`.  Slots at ``p >= sum(cnt)`` (bucket
    padding) hold clamped garbage the caller slices off.
    """
    cum = jnp.cumsum(cnt.astype(jnp.int32))
    pos = jnp.arange(total, dtype=jnp.int32)
    idx_probe = jnp.searchsorted(cum, pos, side="right").astype(jnp.int32)
    start = (cum - cnt.astype(jnp.int32))[idx_probe]
    idx_sorted = lo.astype(jnp.int32)[idx_probe] + (pos - start)
    return idx_sorted, idx_probe


def sorted_segment_sum_ref(
    values: jax.Array, segment_ids: jax.Array, num_segments: int
) -> jax.Array:
    """Segment reduction for pre-sorted segment ids (scatter-add form).

    The aggregation step of the sparse CT backend's sort-then-segment-sum
    build: ``out[s] = sum over i with segment_ids[i] == s of values[i]``.
    """
    return jnp.zeros((num_segments,), values.dtype).at[segment_ids].add(values)


def mle_cpt_batched_ref(
    ct: jax.Array, child_mask: jax.Array, alpha: float = 0.0
) -> jax.Array:
    """Batched MLE CPTs over padded stacked families.

    ``ct`` is ``(B, P_max, C_max)`` — one padded ``(parent_configs,
    child_values)`` count matrix per family — and ``child_mask`` is
    ``(B, C_max)`` with 1.0 on each family's valid child values.  Lanes
    beyond a family's child cardinality are masked out of numerator and
    row sum (smoothing uses the *true* cardinality ``sum(mask)``), and
    padded parent rows behave like unrealized configurations: they get the
    uniform distribution and contribute nothing to any likelihood.
    """
    ct = ct.astype(jnp.float32)
    valid = child_mask[:, None, :] > 0
    ct = jnp.where(valid, ct, 0.0)
    n_child = jnp.sum(child_mask.astype(jnp.float32), axis=-1)[:, None, None]
    row = jnp.sum(ct, axis=-1, keepdims=True)
    denom = row + alpha * n_child
    uniform = 1.0 / jnp.maximum(n_child, 1.0)
    cpt = jnp.where(
        denom > 0, (ct + alpha) / jnp.where(denom > 0, denom, 1.0), uniform
    )
    return jnp.where(valid, cpt, 0.0)


def mle_cpt_ref(ct: jax.Array, alpha: float = 0.0) -> jax.Array:
    """Maximum-likelihood CPT from a (parent_configs, child_values) count table.

    cpt[p, c] = (ct[p, c] + alpha) / (sum_c ct[p, c] + alpha * C).
    Parent configurations never seen in the data (row sum 0, alpha == 0) get
    the uniform distribution — they contribute nothing to the likelihood but
    keep the factor table well-defined (paper Fig. 3(b) stores only realized
    combinations; a dense tensor must fill the rest).
    """
    ct = ct.astype(jnp.float32)
    n_child = ct.shape[-1]
    row = jnp.sum(ct, axis=-1, keepdims=True)
    denom = row + alpha * n_child
    uniform = jnp.full_like(ct, 1.0 / n_child)
    return jnp.where(denom > 0, (ct + alpha) / jnp.where(denom > 0, denom, 1.0), uniform)


def factor_loglik_ref(ct: jax.Array, cpt: jax.Array) -> jax.Array:
    """Log-likelihood contribution of one factor: sum(count * log(cp)).

    The SQL analogue (paper §V-C) is
    ``SELECT SUM(cpt.cp * ct.count) FROM CPT NATURAL JOIN CT`` computed over
    log-parameters.  Cells with count 0 contribute exactly 0 even when the
    parameter is 0 (0 * log 0 := 0, the standard convention).
    """
    ct = ct.astype(jnp.float32)
    logp = jnp.log(jnp.maximum(cpt.astype(jnp.float32), _LOG_TINY))
    return jnp.sum(jnp.where(ct > 0, ct * logp, 0.0))


def factor_loglik_batched_ref(ct: jax.Array, cpt: jax.Array) -> jax.Array:
    """Per-family log-likelihoods over stacked flat families.

    ``ct`` and ``cpt`` are co-indexed ``(B, M)`` arrays (each row one padded
    family); returns ``(B,)`` float32 logliks.  Padding cells carry count 0
    and therefore contribute exactly 0 (the 0*log0 := 0 convention), so the
    result per family is independent of how the batch is padded.
    """
    ct = ct.astype(jnp.float32)
    logp = jnp.log(jnp.maximum(cpt.astype(jnp.float32), _LOG_TINY))
    return jnp.sum(jnp.where(ct > 0, ct * logp, 0.0), axis=-1)


def sparse_family_score_ref(
    cell_tot: jax.Array,
    parent_tot: jax.Array,
    child_card: jax.Array,
    rep: jax.Array,
    fam: jax.Array,
    num_fams: int,
    alpha: float = 0.0,
) -> jax.Array:
    """Fused sparse family scoring over a prepared COO stream (oracle).

    Co-indexed flat arrays, one entry per COO element of a sorted
    concatenated family batch: ``cell_tot``/``parent_tot`` are the
    segment-summed totals of the element's cell and parent-configuration
    run, ``child_card`` its family's child cardinality, ``rep`` 1.0 on the
    first element of each cell run, ``fam`` the (non-decreasing) family id.
    Returns per-family ``sum(n * log cp)`` with
    ``cp = (n + alpha) / (N_parent + alpha * C)`` over realized cells only —
    the semantic ground truth of the Pallas kernel in
    :mod:`repro.kernels.sparse_score`.

    The arithmetic dtype follows ``parent_tot``: the ops wrapper passes
    float64 totals (under its local ``enable_x64`` scope), making the whole
    ``cp``/log/accumulate chain float64 — the same precision contract as the
    host path (``sparse_family_stats``), so scores agree to float64 rounding
    even for billion-grounding log-likelihoods.  float32 inputs degrade
    gracefully to float32 math (kernel-comparison tests).
    """
    acc = parent_tot.dtype
    ctot = cell_tot.astype(acc)
    den = parent_tot + alpha * child_card.astype(acc)
    cp = (ctot + alpha) / jnp.where(den > 0, den, 1.0)
    term = ctot * jnp.log(jnp.maximum(cp, _LOG_TINY))
    contrib = jnp.where((rep > 0) & (ctot > 0), term, 0.0)
    return jax.ops.segment_sum(
        contrib, fam.astype(jnp.int32), num_fams, indices_are_sorted=True
    )


def block_predict_ref(counts: jax.Array, log_cpt: jax.Array) -> jax.Array:
    """Block test-set scoring: scores[e, y] = sum_c counts[e, c] * log_cpt[c, y].

    This is the paper's §VI "block access" — adding the target-entity id to
    the GROUP BY turns per-instance scoring into one matmul over all test
    entities at once.
    """
    return counts.astype(jnp.float32) @ log_cpt.astype(jnp.float32)
