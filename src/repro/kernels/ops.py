"""Jitted public wrappers around the Pallas kernels with oracle fallback.

Dispatch policy (``impl``):
  * ``"auto"``   — Pallas (compiled) on TPU; pure-jnp oracle elsewhere.  The
                   interpret-mode Pallas path exists for *validation*, not
                   production CPU speed, so auto never picks it.
  * ``"pallas"`` — force the kernel (interpret=True off-TPU).  Used by tests.
  * ``"ref"``    — force the oracle.

All wrappers take/return plain arrays so they can be called inside pjit /
shard_map computations; the count manager's distributed path relies on that.
"""

from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp

from . import ref
from .block_predict import block_predict_pallas
from .ct_count import ct_count_pallas
from .factor_loglik import factor_loglik_batched_pallas, factor_loglik_pallas
from .mle_cpt import mle_cpt_batched_pallas, mle_cpt_pallas


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

#: Host-side tally of kernel-wrapper invocations, keyed by op name.  Each
#: public wrapper below is one device dispatch (one compiled kernel or oracle
#: computation per call), so this is the proxy the structure-search benchmarks
#: use for "device launches": the batched ScoreManager path must show an
#: order-of-magnitude fewer launches than per-candidate serial scoring.
_LAUNCHES: Counter = Counter()


def reset_launch_counts() -> None:
    """Zero the per-op launch tally (benchmark bracketing)."""
    _LAUNCHES.clear()


def launch_counts() -> dict[str, int]:
    """Snapshot of wrapper invocations since the last reset, by op name."""
    return dict(_LAUNCHES)


def total_launches() -> int:
    return sum(_LAUNCHES.values())


def kernel_impl(impl: str) -> str:
    """Map a count-manager ``impl`` to a kernel dispatch policy.

    ``"sparse"`` selects a CT *storage backend*, not a kernel variant; code
    paths that still hit dense kernels (e.g. the parents-free family in
    block prediction) fall back to ``"auto"``.
    """
    return "auto" if impl == "sparse" else impl


def _use_pallas(impl: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        return on_tpu, False
    if impl == "pallas":
        return True, not on_tpu
    if impl == "ref":
        return False, False
    raise ValueError(
        f"impl must be auto|pallas|ref (count-manager calls also accept "
        f"'sparse', and ct_count accepts 'matmul'), got {impl!r}"
    )


def ct_count(
    keys: jax.Array,
    num_bins: int,
    weights: jax.Array | None = None,
    *,
    impl: str = "auto",
) -> jax.Array:
    """GROUP BY COUNT.  Returns int32 counts (float32 when ``weights`` given).

    ``impl="matmul"`` selects the XLA-level MXU formulation (chunked one-hot
    contraction) — the dry-run path whose HLO carries counting's real FLOPs.
    """
    _LAUNCHES["ct_count"] += 1
    if impl == "matmul":
        out = ref.ct_count_matmul(keys, num_bins, weights)
        return out if weights is not None else out.astype(jnp.int32)
    use, interp = _use_pallas(impl)
    if use:
        out = ct_count_pallas(keys, num_bins, weights, interpret=interp)
    else:
        out = ref.ct_count_ref(keys, num_bins, weights)
    return out if weights is not None else out.astype(jnp.int32)


def sorted_segment_sum(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Segment-sum over pre-sorted ids — the sparse CT backend's aggregator.

    ``impl="auto"`` uses XLA's sorted segment reduction (``jax.ops.
    segment_sum`` with ``indices_are_sorted=True``); ``"ref"`` forces the
    scatter-add oracle.  Sortedness is the caller's contract (the sparse
    builder sorts composite codes first), letting XLA skip the scatter's
    conflict handling.
    """
    _LAUNCHES["sorted_segment_sum"] += 1
    if impl == "ref":
        return ref.sorted_segment_sum_ref(values, segment_ids, num_segments)
    return jax.ops.segment_sum(
        values, segment_ids, num_segments, indices_are_sorted=True
    )


def mle_cpt(ct: jax.Array, alpha: float = 0.0, *, impl: str = "auto") -> jax.Array:
    """Row-normalized CPT from a (parent_configs, child_values) count matrix."""
    _LAUNCHES["mle_cpt"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return mle_cpt_pallas(ct, alpha, interpret=interp)
    return ref.mle_cpt_ref(ct, alpha)


def mle_cpt_batched(
    ct: jax.Array,
    child_mask: jax.Array,
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Batched CPTs from stacked padded family counts — one launch per batch.

    ``ct`` is ``(B, P_max, C_max)``, ``child_mask`` ``(B, C_max)`` (1.0 on
    valid child lanes).  Returns ``(B, P_max, C_max)`` CPTs, zero outside
    each family's valid lanes.  The set-oriented twin of :func:`mle_cpt`:
    per-family values match the single-family kernel run on the unpadded
    ``(P_i, C_i)`` slice.
    """
    _LAUNCHES["mle_cpt_batched"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return mle_cpt_batched_pallas(ct, child_mask, alpha, interpret=interp)
    return ref.mle_cpt_batched_ref(ct, child_mask, alpha)


def factor_loglik(ct: jax.Array, cpt: jax.Array, *, impl: str = "auto") -> jax.Array:
    """sum(count * log cp) with the 0*log0 := 0 convention.  Scalar float32."""
    _LAUNCHES["factor_loglik"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return factor_loglik_pallas(ct, cpt, interpret=interp)
    return ref.factor_loglik_ref(ct, cpt)


def factor_loglik_batched(ct: jax.Array, cpt: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Per-family logliks over co-indexed ``(B, M)`` stacks — one launch.

    The §V-C ``Scores`` table computed set-at-a-time: row ``b`` is
    ``sum(ct[b] * log cp[b])`` under the 0*log0 := 0 convention, so padding
    cells (count 0) contribute nothing and per-family results match
    :func:`factor_loglik` on the unpadded slice.
    """
    _LAUNCHES["factor_loglik_batched"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return factor_loglik_batched_pallas(ct, cpt, interpret=interp)
    return ref.factor_loglik_batched_ref(ct, cpt)


def block_predict(counts: jax.Array, log_cpt: jax.Array, *, impl: str = "auto") -> jax.Array:
    """scores[e, y] = counts(E, C) @ log_cpt(C, Y) — §VI block access."""
    _LAUNCHES["block_predict"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return block_predict_pallas(counts, log_cpt, interpret=interp)
    return ref.block_predict_ref(counts, log_cpt)
