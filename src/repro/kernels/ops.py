"""Jitted public wrappers around the Pallas kernels with oracle fallback.

Dispatch policy (``impl``):
  * ``"auto"``   — Pallas (compiled) on TPU; pure-jnp oracle elsewhere.  The
                   interpret-mode Pallas path exists for *validation*, not
                   production CPU speed, so auto never picks it.
  * ``"pallas"`` — force the kernel (interpret=True off-TPU).  Used by tests.
  * ``"ref"``    — force the oracle.

The ``REPRO_KERNEL_IMPL`` environment variable overrides what ``"auto"``
resolves to (CI's kernel-dispatch leg sets ``pallas`` on CPU runners);
explicit per-call ``impl=`` always wins.

All wrappers take/return plain arrays so they can be called inside pjit /
shard_map computations; the count manager's distributed path relies on that.
"""

from __future__ import annotations

import functools
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from . import bucketing, ref
from .block_predict import block_predict_pallas
from .bucketing import (  # noqa: F401  (re-exported next to launch/transfer counters)
    compile_counts,
    reset_compile_counts,
    total_compiles,
)
from .coo_join import coo_join_expand_pallas
from .coo_sort import PALLAS_SORT_MAX_ROWS, coo_sort_aggregate
from .ct_count import ct_count_pallas
from .factor_loglik import factor_loglik_batched_pallas, factor_loglik_pallas
from .mle_cpt import mle_cpt_batched_pallas, mle_cpt_pallas
from .sparse_score import sparse_family_score_pallas

def _config():
    # lazy: core.config sits above the kernels layer in the import graph
    from ..core import config

    return config


def _env_impl() -> str:
    """The ``impl="auto"`` dispatch override (``REPRO_KERNEL_IMPL`` knob).

    CI sets ``REPRO_KERNEL_IMPL=pallas`` on a CPU-only leg so every auto
    call runs the interpret-mode kernels (dispatch-path coverage without a
    TPU); ``ref`` forces the oracles.  Explicit per-call ``impl=`` always
    wins.  Resolved through :mod:`repro.core.config` at call time (scoped
    via ``engine_config(kernel_impl=...)``), fail-loud on malformed values.
    """
    return _config().resolve("kernel_impl")


#: Engine policy for ``coo_aggregate``'s general (sort) path.  ``auto``
#: picks the fused Pallas bitonic sort+segment-sum kernel on TPU for rungs
#: it can hold in VMEM and the XLA ``sort_key_val`` path everywhere else;
#: ``xla`` forces the oracle, ``pallas`` forces the kernel (interpret mode
#: off-TPU — the CI sort-dispatch leg).  Same fail-loudly rule as
#: ``REPRO_KERNEL_IMPL``; env knob ``REPRO_SORT_IMPL``.
_SORT_IMPLS = ("auto", "xla", "pallas")


def set_sort_impl(mode: str) -> str:
    """Set the sort-engine policy (``auto|xla|pallas``); returns the old one.

    .. deprecated:: delegates to :mod:`repro.core.config`; prefer
       ``engine_config(sort_impl=...)`` for scoped use.
    """
    if mode not in _SORT_IMPLS:
        raise ValueError(f"sort impl must be one of {_SORT_IMPLS}, got {mode!r}")
    return _config().set_override("sort_impl", mode)


def sort_impl() -> str:
    """Current ``coo_aggregate`` sort-engine policy (``auto|xla|pallas``)."""
    return _config().resolve("sort_impl")


def _use_pallas_sort(n: int, code_dtype) -> tuple[bool, bool]:
    """-> (use_pallas_sort, interpret) for an ``n``-row aggregation.

    The kernel sorts int64 codes as split int32 lanes, so int32 streams
    stay on XLA under EVERY policy (including forced ``pallas`` — the CI
    dispatch leg covers the composite-key streams the kernel exists for);
    under ``auto`` on TPU, rungs past the VMEM cap fall back to XLA too.
    """
    if code_dtype != jnp.int64:
        return False, False
    mode = sort_impl()
    if mode == "pallas":
        return True, jax.default_backend() != "tpu"
    if mode == "xla":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    eligible = code_dtype == jnp.int64 and n <= PALLAS_SORT_MAX_ROWS
    return on_tpu and eligible, False


def count_acc_dtype():
    """Accumulation dtype for exact integer-count reductions.

    float64 whenever 64-bit types are enabled AND the backend can lower
    them (XLA:TPU cannot — there the paths below keep the float32
    accumulation they had before the precision contract, which is exact up
    to 2**24-count totals).  Read at trace time inside jitted programs.
    """
    if jax.config.jax_enable_x64 and jax.default_backend() != "tpu":
        return jnp.float64
    return jnp.float32


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

#: Host-side tally of kernel-wrapper invocations, keyed by op name.  Each
#: public wrapper below is one device dispatch (one compiled kernel or oracle
#: computation per call), so this is the proxy the structure-search benchmarks
#: use for "device launches": the batched ScoreManager path must show an
#: order-of-magnitude fewer launches than per-candidate serial scoring.
_LAUNCHES: Counter = Counter()


def reset_launch_counts() -> None:
    """Zero the per-op launch tally (benchmark bracketing)."""
    _LAUNCHES.clear()


def launch_counts() -> dict[str, int]:
    """Snapshot of wrapper invocations since the last reset, by op name."""
    return dict(_LAUNCHES)


def total_launches() -> int:
    return sum(_LAUNCHES.values())


# ---------------------------------------------------------------------------
# Host<->device transfer accounting
# ---------------------------------------------------------------------------

#: Byte tally of host<->device transfers at the count-stack seams (joint CT
#: residency, digit caches, sparse batch results).  Not every JAX-internal
#: transfer is visible from Python; this counts the explicit ones the count
#: manager issues through :func:`to_device` / :func:`to_host`, which is the
#: number the benchmarks use to show the device-resident sparse path stops
#: round-tripping the COO stream every sweep.
_TRANSFERS: Counter = Counter()


def reset_transfer_counts() -> None:
    _TRANSFERS.clear()


def transfer_bytes() -> dict[str, int]:
    """``{"h2d": bytes, "d2h": bytes}`` since the last reset."""
    return {"h2d": _TRANSFERS["h2d"], "d2h": _TRANSFERS["d2h"]}


def to_device(x) -> jax.Array:
    """``jnp.asarray`` with h2d byte accounting (no-op for device arrays)."""
    if isinstance(x, np.ndarray):
        _TRANSFERS["h2d"] += x.nbytes
    return jnp.asarray(x)


def to_host(x) -> np.ndarray:
    """``np.asarray`` with d2h byte accounting (no-op for host arrays)."""
    if isinstance(x, jax.Array):
        _TRANSFERS["d2h"] += x.size * x.dtype.itemsize
    return np.asarray(x)


def sync_scalar(x) -> int:
    """``int(x)`` with d2h byte accounting for device scalars.

    The device-side CT build occasionally needs a data-dependent size on
    host (join output lengths, compaction counts) to fix launch shapes.
    Each such sync moves one scalar — accounted here so the transfer tally
    stays honest about the *entire* traffic of the device build, not just
    the bulk column copies.
    """
    if isinstance(x, jax.Array):
        _TRANSFERS["d2h"] += x.dtype.itemsize
    return int(x)


def kernel_impl(impl: str) -> str:
    """Map a count-manager ``impl`` to a kernel dispatch policy.

    ``"sparse"`` selects a CT *storage backend*, not a kernel variant; code
    paths that still hit dense kernels (e.g. the parents-free family in
    block prediction) fall back to ``"auto"``.
    """
    return "auto" if impl == "sparse" else impl


def _use_pallas(impl: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    env_impl = _env_impl()
    if impl == "auto" and env_impl in ("pallas", "ref"):
        impl = env_impl
    if impl == "auto":
        return on_tpu, False
    if impl == "pallas":
        return True, not on_tpu
    if impl == "ref":
        return False, False
    raise ValueError(
        f"impl must be auto|pallas|ref (count-manager calls also accept "
        f"'sparse', and ct_count accepts 'matmul'), got {impl!r}"
    )


def ct_count(
    keys: jax.Array,
    num_bins: int,
    weights: jax.Array | None = None,
    *,
    impl: str = "auto",
) -> jax.Array:
    """GROUP BY COUNT.  Returns int32 counts (float32 when ``weights`` given).

    ``impl="matmul"`` selects the XLA-level MXU formulation (chunked one-hot
    contraction) — the dry-run path whose HLO carries counting's real FLOPs.
    """
    _LAUNCHES["ct_count"] += 1
    if impl == "matmul":
        out = ref.ct_count_matmul(keys, num_bins, weights)
        return out if weights is not None else out.astype(jnp.int32)
    use, interp = _use_pallas(impl)
    if use:
        out = ct_count_pallas(keys, num_bins, weights, interpret=interp)
    else:
        out = ref.ct_count_ref(keys, num_bins, weights)
    return out if weights is not None else out.astype(jnp.int32)


def sorted_segment_sum(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Segment-sum over pre-sorted ids — the sparse CT backend's aggregator.

    ``impl="auto"`` uses XLA's sorted segment reduction (``jax.ops.
    segment_sum`` with ``indices_are_sorted=True``); ``"ref"`` forces the
    scatter-add oracle.  Sortedness is the caller's contract (the sparse
    builder sorts composite codes first), letting XLA skip the scatter's
    conflict handling.
    """
    _LAUNCHES["sorted_segment_sum"] += 1
    if impl == "ref":
        return ref.sorted_segment_sum_ref(values, segment_ids, num_segments)
    return jax.ops.segment_sum(
        values, segment_ids, num_segments, indices_are_sorted=True
    )


def mle_cpt(ct: jax.Array, alpha: float = 0.0, *, impl: str = "auto") -> jax.Array:
    """Row-normalized CPT from a (parent_configs, child_values) count matrix."""
    _LAUNCHES["mle_cpt"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return mle_cpt_pallas(ct, alpha, interpret=interp)
    return ref.mle_cpt_ref(ct, alpha)


def mle_cpt_batched(
    ct: jax.Array,
    child_mask: jax.Array,
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Batched CPTs from stacked padded family counts — one launch per batch.

    ``ct`` is ``(B, P_max, C_max)``, ``child_mask`` ``(B, C_max)`` (1.0 on
    valid child lanes).  Returns ``(B, P_max, C_max)`` CPTs, zero outside
    each family's valid lanes.  The set-oriented twin of :func:`mle_cpt`:
    per-family values match the single-family kernel run on the unpadded
    ``(P_i, C_i)`` slice.
    """
    _LAUNCHES["mle_cpt_batched"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return mle_cpt_batched_pallas(ct, child_mask, alpha, interpret=interp)
    return ref.mle_cpt_batched_ref(ct, child_mask, alpha)


def factor_loglik(ct: jax.Array, cpt: jax.Array, *, impl: str = "auto") -> jax.Array:
    """sum(count * log cp) with the 0*log0 := 0 convention.  Scalar float32."""
    _LAUNCHES["factor_loglik"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return factor_loglik_pallas(ct, cpt, interpret=interp)
    return ref.factor_loglik_ref(ct, cpt)


def factor_loglik_batched(ct: jax.Array, cpt: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Per-family logliks over co-indexed ``(B, M)`` stacks — one launch.

    The §V-C ``Scores`` table computed set-at-a-time: row ``b`` is
    ``sum(ct[b] * log cp[b])`` under the 0*log0 := 0 convention, so padding
    cells (count 0) contribute nothing and per-family results match
    :func:`factor_loglik` on the unpadded slice.
    """
    _LAUNCHES["factor_loglik_batched"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return factor_loglik_batched_pallas(ct, cpt, interpret=interp)
    return ref.factor_loglik_batched_ref(ct, cpt)


def block_predict(counts: jax.Array, log_cpt: jax.Array, *, impl: str = "auto") -> jax.Array:
    """scores[e, y] = counts(E, C) @ log_cpt(C, Y) — §VI block access."""
    _LAUNCHES["block_predict"] += 1
    use, interp = _use_pallas(impl)
    if use:
        return block_predict_pallas(counts, log_cpt, interpret=interp)
    return ref.block_predict_ref(counts, log_cpt)


# ---------------------------------------------------------------------------
# Device-resident COO: aggregation + fused family scoring
# ---------------------------------------------------------------------------


def _coo_aggregate_impl(codes: jax.Array, weights: jax.Array):
    """Canonicalize a COO vector on device: sort, unique, segment-sum.

    Fixed-shape twin of the host ``aggregate_codes``: the output keeps the
    input length, with the unique codes compacted to an ascending prefix and
    the tail padded by ``segment_min``'s int-max fill (count 0) — dynamic
    compaction would break jit.  Zero-sum cells are retained (harmless: all
    COO consumers ignore zero counts).  Accumulates in float64 (exact for
    integer-valued counts) and stores the correctly-rounded float32 —
    bit-identical to the host aggregation.
    """
    codes, weights = jax.lax.sort_key_val(codes, weights)
    n = codes.shape[0]
    boundary = jnp.concatenate(
        [jnp.ones((1,), bool), codes[1:] != codes[:-1]]
    )
    seg = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    sums = jax.ops.segment_sum(
        weights.astype(count_acc_dtype()), seg, n, indices_are_sorted=True
    )
    uniq = jax.ops.segment_min(codes, seg, n, indices_are_sorted=True)
    return uniq, sums.astype(jnp.float32)


def _coo_aggregate_counted_impl(codes: jax.Array, weights: jax.Array):
    """Aggregation plus the fused non-pad count (one program, no extra op
    chain): ``n_valid`` is the number of output slots holding a real unique
    code — the scalar every build-side compaction needs, computed inside
    the same compiled program instead of by a separate eager reduction."""
    uniq, sums = _coo_aggregate_impl(codes, weights)
    return uniq, sums, jnp.sum(uniq != jnp.iinfo(codes.dtype).max)


_coo_aggregate_jit = jax.jit(_coo_aggregate_impl)
#: Donating twin: only ever fed the wrapper-owned padded temporaries (see
#: ``bucketing.donate_buffers`` — caller buffers are never donated).
_coo_aggregate_jit_donated = jax.jit(_coo_aggregate_impl, donate_argnums=(0, 1))
_coo_aggregate_counted_jit = jax.jit(_coo_aggregate_counted_impl)
_coo_aggregate_counted_jit_donated = jax.jit(
    _coo_aggregate_counted_impl, donate_argnums=(0, 1)
)


def _pallas_agg_impl(codes: jax.Array, weights: jax.Array, interpret: bool):
    """The fused Pallas sort+segment-sum engine (same contract, one launch)."""
    return coo_sort_aggregate(
        codes, weights, interpret=interpret, acc=count_acc_dtype()
    )


def _pallas_agg_counted_impl(codes, weights, interpret: bool):
    uniq, sums = _pallas_agg_impl(codes, weights, interpret)
    return uniq, sums, jnp.sum(uniq != jnp.iinfo(codes.dtype).max)


_pallas_agg_jit = jax.jit(_pallas_agg_impl, static_argnums=(2,))
_pallas_agg_counted_jit = jax.jit(_pallas_agg_counted_impl, static_argnums=(2,))

#: Histogram-aggregation engages when the (bucketed) code space fits under
#: the bin budget (f64 accumulator: 32 MB at the default 2^22).  Above it,
#: the general sort path runs.  Overridable for experiments via
#: ``REPRO_COO_HIST_BINS`` / ``engine_config(coo_hist_bins=...)`` (0
#: disables the histogram path entirely).  ``None`` defers to the config
#: resolution chain; tests monkeypatch this attribute directly (it is read
#: at call time).
_HIST_BINS_BUDGET: int | None = None

#: Streams below this many (bucketed) rows always take the sort path.  Two
#: reasons, both measured on XLA:CPU.  Compile diversity: every distinct
#: (row rung, bin rung) histogram signature costs a fixed ~0.2 s backend
#: compile (scatter machinery) however small the arrays, while ALL
#: sub-threshold sorts share one ~0.2 s program per row rung — and the
#: per-build ladder floor pins small builds to a single rung.  Runtime: a
#: sub-64k sort is ~5 ms, so hist's O(n) advantage over O(n log n) cannot
#: pay for even one extra compile at this scale.  The companion rule
#: ``bins <= rows`` (below) keeps hist off streams whose O(bins)
#: accumulator + compaction would dwarf the sort it replaces.
_HIST_MIN_ROWS = 1 << 16


def _hist_bins_budget() -> int:
    if _HIST_BINS_BUDGET is not None:
        return _HIST_BINS_BUDGET
    return _config().resolve("coo_hist_bins")


@functools.partial(jax.jit, static_argnames=("num_bins",))
def _coo_hist_jit(codes: jax.Array, weights: jax.Array, num_bins: int):
    """Dense-accumulator aggregation + compaction, ONE fused program.

    The O(n) twin of :func:`_coo_aggregate_impl` for streams whose code
    space is statically known and small: scatter-accumulate the weights
    into ``num_bins`` cells (float64 — exact for integer-valued counts,
    order-independent), round once to float32 (exactly the host
    aggregation's value), then COO-compact the dense vector in the same
    program — realized bins ascending, int-max / zero-count identity
    padding after.  Codes outside ``[0, num_bins)`` — the int-max padding
    sentinel — are routed to a sacrificial overflow bin and dropped.

    The compaction is cumsum + ``searchsorted`` rather than ``jnp.nonzero``
    — identical indices, but it lowers to compare/scan ops instead of the
    scatter machinery whose XLA:CPU compile alone cost ~0.2s per (bins,
    keep-rung) signature; fused here it also stops multiplying program
    count by the keep rung.  Returns ``(uniq, sums, n_realized)`` at full
    ``num_bins`` width; the dispatcher slices to the realized ladder rung
    after its one scalar sync.
    """
    in_range = (codes >= 0) & (codes < num_bins)
    seg = jnp.where(in_range, codes, num_bins).astype(jnp.int32)
    sums = jax.ops.segment_sum(
        weights.astype(count_acc_dtype()), seg, num_bins + 1
    )[:num_bins].astype(jnp.float32)
    nz = (sums != 0.0).astype(jnp.int32)
    cum = jnp.cumsum(nz)
    idx = jnp.searchsorted(
        cum, jnp.arange(1, num_bins + 1, dtype=jnp.int32), side="left"
    )
    valid = jnp.arange(num_bins, dtype=jnp.int32) < cum[-1]
    safe = jnp.minimum(idx, num_bins - 1)
    uniq = jnp.where(
        valid, safe.astype(codes.dtype), jnp.iinfo(codes.dtype).max
    )
    counts = jnp.where(valid, sums[safe], 0.0)
    return uniq, counts, cum[-1]


@functools.partial(jax.jit, static_argnames=("n_keep",))
def _slice2_jit(codes: jax.Array, counts: jax.Array, n_keep: int):
    """Tail-trim an aggregation result to its realized ladder rung."""
    return codes[:n_keep], counts[:n_keep]


@functools.partial(jax.jit, static_argnames=("n_pad",))
def _pad2_jit(codes: jax.Array, weights: jax.Array, pad_code: jax.Array, n_pad: int):
    """Pad a COO stream to ``n_pad`` rows in one program (not two eager concats)."""
    n = codes.shape[0]
    codes = jnp.concatenate([codes, jnp.full((n_pad - n,), pad_code, codes.dtype)])
    weights = jnp.concatenate([weights, jnp.zeros((n_pad - n,), weights.dtype)])
    return codes, weights


def _pad_coo_stream(codes: jax.Array, weights: jax.Array, pad_code) -> tuple:
    """Bucket-pad a COO stream with identity padding; -> (codes, weights, padded?).

    Padding entries carry ``pad_code`` and weight 0.  Aggregation callers
    pass the code dtype's int-max (sorts after every valid code, matches
    ``segment_min``'s fill, merges into the dead tail); the fused scorer
    passes 0 (codes must stay inside the family code space — zero-weight
    duplicates add exactly nothing to its segment sums).  Must run inside
    the caller's ``enable_x64`` scope when codes are int64.  The pad value
    rides in as a traced scalar so both pad flavors share one compiled
    program per (shape, rung) signature.
    """
    n = int(codes.shape[0])
    n_pad = bucketing.bucket_rows(n)
    if n_pad <= n:
        return codes, weights, False
    codes, weights = _pad2_jit(
        codes, weights, jnp.asarray(pad_code, codes.dtype), n_pad
    )
    return codes, weights, True


def coo_aggregate(
    codes: jax.Array,
    weights: jax.Array,
    *,
    num_bins: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """COO canonicalization, entirely on device.

    The device-resident analogue of the sparse backend's host
    ``aggregate_codes``.  ``codes`` may be int64 (mixed-radix composite
    keys run under a local ``enable_x64`` scope) or int32.

    Two engines, same bit-exact result (float64 accumulation over
    integer-valued float32 weights, one float32 rounding):

      * **sort**: ONE fused sort + segment reduction — the general path,
        any code space.  Returns ``(uniq_codes, sums)`` of the *bucketed*
        input length: ascending unique codes first, int-max / zero-count
        padding after (see :func:`_coo_aggregate_impl`).
      * **histogram**: when the caller knows the code space (``num_bins``),
        the stream is large (>= :data:`_HIST_MIN_ROWS` bucketed rows) and
        its bin rung fits both :data:`_HIST_BINS_BUDGET` and the stream's
        own row count, an O(n) unsorted segment-sum into a dense
        accumulator replaces the O(n log n) sort — the big win of the
        million-row scale leg, where streams are huge but code spaces
        tiny.  The result is compacted
        to the realized-bin ladder rung (ascending codes, identity pad
        tail — the sort path's canonical layout), at the cost of one
        accounted scalar sync.

    Inputs are bucket-padded to the ``bucketing`` row ladder (int-max
    codes, zero weights — identity padding) so every aggregation of a
    learning run compiles O(buckets) programs instead of one per
    data-dependent stream length; ``num_bins`` is bucketed to the ladder
    too, so the histogram programs are keyed by (row rung, bin rung).
    When padding created fresh temporaries and the donation policy
    allows, their buffers are donated to the compiled program.
    """
    return _aggregate_dispatch(codes, weights, num_bins, with_count=False)


def coo_aggregate_counted(
    codes: jax.Array,
    weights: jax.Array,
    *,
    num_bins: int | None = None,
) -> tuple[jax.Array, jax.Array, int]:
    """:func:`coo_aggregate` plus the synced count of realized unique codes.

    ``(uniq, sums, n_valid)`` where ``n_valid`` is the number of leading
    non-pad slots — the scalar every build-side compaction step needs.
    The count is computed *inside* the aggregation program (histogram:
    reusing the nonzero-bin count that engine syncs anyway; sort: one
    fused reduction over the output), so callers that previously ran a
    separate eager count-plus-sync pay zero extra launches here.
    """
    return _aggregate_dispatch(codes, weights, num_bins, with_count=True)


def _aggregate_dispatch(codes, weights, num_bins, *, with_count: bool):
    """Shared engine router behind the two public aggregation wrappers."""
    _LAUNCHES["coo_aggregate"] += 1
    with enable_x64():
        codes, weights = to_device(codes), to_device(weights)
        if int(codes.shape[0]) == 0:
            # empty stream: nothing to canonicalize (the fixed-shape
            # program below needs n >= 1), mirror the host guard
            out = codes, weights.astype(jnp.float32)
            return (*out, 0) if with_count else out
        pad_code = jnp.iinfo(codes.dtype).max
        codes, weights, padded = _pad_coo_stream(codes, weights, pad_code)
        n_pad = int(codes.shape[0])
        use_hist = (
            num_bins is not None
            and 0 < num_bins
            and n_pad >= _HIST_MIN_ROWS
            and bucketing.bucket_bins(num_bins) <= min(_hist_bins_budget(), n_pad)
        )
        if use_hist:
            bins = bucketing.bucket_bins(num_bins)
            uniq_full, sums_full, n_valid_dev = _coo_hist_jit(codes, weights, bins)
    if use_hist:
        # sync outside the x64 scope, per the scoping contract
        n_valid = sync_scalar(n_valid_dev)
        n_keep = min(bins, bucketing.bucket_rows(max(n_valid, 1), tight=True))
        if n_keep >= bins:
            # realized rung fills the whole accumulator: the slice would be
            # a no-op program — skip the launch (and its compile) entirely
            uniq, sums = uniq_full, sums_full
        else:
            with enable_x64():
                uniq, sums = _slice2_jit(uniq_full, sums_full, n_keep)
        return (uniq, sums, n_valid) if with_count else (uniq, sums)
    use_kernel, interpret = _use_pallas_sort(int(codes.shape[0]), codes.dtype)
    # both wrappers run the *counted* program — the fused count is one
    # extra reduction, and sharing a single compiled program per rung
    # beats keeping a count-free twin alive (it would double the sort-path
    # program count for no runtime win); the count scalar stays on device
    # unless the caller asked for it, so no extra sync either
    with enable_x64():
        if use_kernel:
            _LAUNCHES["coo_sort"] += 1
            out = _pallas_agg_counted_jit(codes, weights, interpret)
        else:
            donate = padded and bucketing.donate_buffers()
            fn = (
                _coo_aggregate_counted_jit_donated
                if donate
                else _coo_aggregate_counted_jit
            )
            out = fn(codes, weights)
    uniq, sums, n_valid_dev = out
    if with_count:
        return uniq, sums, sync_scalar(n_valid_dev)
    return uniq, sums


#: Key-column pad sentinel for bucketed joins: int32-max never collides with
#: a valid entity row id, sorts after every valid key, and is recognized on
#: the probe side (padded probes match nothing).  Shared with the sparse
#: build's message padding (``sparse_counts._PAD_ROW``).
PAD_KEY = np.iinfo(np.int32).max


@jax.jit
def _coo_join_probe_jit(sorted_keys: jax.Array, probe_keys: jax.Array):
    """Match table of a sort-merge join, one fused program per shape bucket.

    ``lo``/``cnt`` locate each probe key's match run inside the sorted
    column; :data:`PAD_KEY` probes (bucket padding of either the wrapper or
    an upstream message) are masked to zero matches — pad keys on the
    sorted side are never matched because every valid probe is <
    ``PAD_KEY``.  ``total`` is the int64 pair count (traced under the
    caller's ``enable_x64`` scope).
    """
    lo = jnp.searchsorted(sorted_keys, probe_keys, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(sorted_keys, probe_keys, side="right").astype(jnp.int32)
    cnt = jnp.where(probe_keys == PAD_KEY, 0, hi - lo)
    total = jnp.sum(cnt, dtype=jnp.int64)
    return lo, cnt, total


@functools.partial(jax.jit, static_argnames=("n",))
def _prefix_mask_jit(total: jax.Array, n: int) -> jax.Array:
    """``arange(n) < total`` — the valid-prefix mask of a bucketed result."""
    return jnp.arange(n, dtype=jnp.int32) < total


@functools.partial(jax.jit, static_argnames=("n_pad",))
def _pad_keys_jit(keys: jax.Array, n_pad: int) -> jax.Array:
    return jnp.concatenate(
        [keys, jnp.full((n_pad - keys.shape[0],), PAD_KEY, jnp.int32)]
    )


def _pad_keys(keys: jax.Array) -> jax.Array:
    """Bucket-pad an int32 key column with the :data:`PAD_KEY` sentinel."""
    n = int(keys.shape[0])
    n_pad = bucketing.bucket_rows(n)
    if n_pad <= n:
        return keys
    return _pad_keys_jit(keys, n_pad)


#: Jitted oracle expansion (the Pallas twin jits internally): without this,
#: the ref path's searchsorted+gathers compile as a handful of separate
#: eager programs per shape bucket.
_coo_join_expand_ref_jit = jax.jit(ref.coo_join_expand_ref, static_argnums=(2,))


def coo_join(
    sorted_keys: jax.Array,
    probe_keys: jax.Array,
    *,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """Sort-merge join: match every probe key against a sorted key column.

    The device-resident foreign-key join of the sparse CT build (paper §IV):
    ``sorted_keys`` is a COO message's (sorted, duplicate-legal) entity-row
    column, ``probe_keys`` a relationship table's FK column (any order).
    Both sides may carry a :data:`PAD_KEY` bucket-padding suffix (the
    wrapper tops them up to the ``bucketing`` row ladder either way):
    padded probes match nothing, padded sorted keys are never matched.

    Returns ``(idx_sorted, idx_probe, valid, total)``: ``total`` matched
    pairs (synced to host — the one accounted scalar d2h this join pays,
    needed for the overflow guard and downstream size bookkeeping), with
    the index arrays at the *bucketed* length ``bucket_rows(total)`` and
    ``valid`` the boolean prefix mask — pair ``p`` (where ``valid[p]``)
    joins ``sorted_keys[idx_sorted[p]]`` to ``probe_keys[idx_probe[p]]``,
    probe-major, so gathering through ``idx_probe`` preserves the probe
    side's order and per-probe match runs stay contiguous.  Slots past
    ``total`` hold clamped garbage indices: callers MUST mask everything
    gathered through them (weights to 0, codes to the pad sentinel).

    The match table (``lo``/``cnt`` per probe key) is one fused jitted
    program; ``impl`` picks the expansion: the Pallas rank/gather kernel
    (:mod:`repro.kernels.coo_join`) or the jitted jnp ``searchsorted``
    oracle.  With all three shapes (sorted, probe, expansion) on the
    bucket ladder, a whole learning run's joins compile O(buckets)
    programs.
    """
    sorted_keys = jnp.asarray(sorted_keys, jnp.int32)
    probe_keys = jnp.asarray(probe_keys, jnp.int32)
    # host constants: a jnp.zeros here would compile a fresh (trivial)
    # program on the first empty join of every process
    empty = np.zeros((0,), np.int32)
    no_match = (empty, empty, np.zeros((0,), bool), 0)
    if int(probe_keys.shape[0]) == 0 or int(sorted_keys.shape[0]) == 0:
        # no device work dispatched: keep the launch tally honest (it is
        # the bench's build-launch headline number)
        return no_match
    _LAUNCHES["coo_join"] += 1
    sorted_keys = _pad_keys(sorted_keys)
    probe_keys = _pad_keys(probe_keys)
    with enable_x64():
        lo, cnt, total_dev = _coo_join_probe_jit(sorted_keys, probe_keys)
    total = sync_scalar(total_dev)
    if total == 0:
        return no_match
    if total >= 2**31:
        raise OverflowError(
            f"sort-merge join expands to {total:.3g} pairs; beyond the int32 "
            "index space of the device build"
        )
    # bucket the data-dependent expansion length to stabilize launch shapes
    padded = bucketing.bucket_rows(total)
    use, interp = _use_pallas(impl)
    if use:
        ia, ib = coo_join_expand_pallas(lo, cnt, padded, interpret=interp)
    else:
        ia, ib = _coo_join_expand_ref_jit(lo, cnt, padded)
    valid = _prefix_mask_jit(np.int32(total), padded)
    return ia, ib, valid, total


def _fused_sparse_score_impl(
    codes: jax.Array,
    weights: jax.Array,
    bounds: jax.Array,
    child_cards: jax.Array,
    num_fams: int,
    alpha: float,
    use_pallas: bool,
    interpret: bool,
) -> jax.Array:
    """One fused device program: sort -> run totals -> score kernel/oracle.

    Precision mirrors the host path exactly: cell totals accumulate in
    float64 and are rounded to float32 (== the host-aggregated family CT
    cells, bitwise), parent totals are float64 sums over those rounded
    float32 cells (one per unique cell, == the host's ``reduceat``), and
    the oracle scores in float64.  The Pallas kernel path receives the
    same float32 cell/parent totals and is the compensated-float32
    best-effort (see ``sparse_score``).
    """
    codes, weights = jax.lax.sort_key_val(codes, weights)
    n = codes.shape[0]
    fam = jnp.clip(
        jnp.searchsorted(bounds, codes, side="right") - 1, 0, num_fams - 1
    ).astype(jnp.int32)
    off = bounds[fam]
    cc = jnp.maximum(child_cards[fam], 1)
    # Parent-configuration code: child is the minor radix digit, so the
    # parent prefix is the family-local code // child_card.  Offsetting by
    # the family base keeps the stream globally non-decreasing.
    pcode = off + (codes - off) // cc
    first = jnp.ones((1,), bool)
    rep = jnp.concatenate([first, codes[1:] != codes[:-1]])
    prep = jnp.concatenate([first, pcode[1:] != pcode[:-1]])
    cseg = jnp.cumsum(rep.astype(jnp.int32)) - 1
    pseg = jnp.cumsum(prep.astype(jnp.int32)) - 1
    acc = count_acc_dtype()
    cell_tot = jax.ops.segment_sum(
        weights.astype(acc), cseg, n, indices_are_sorted=True
    )[cseg].astype(jnp.float32)
    # each unique cell contributes its rounded float32 total exactly once
    cell_once = jnp.where(rep, cell_tot.astype(acc), 0.0)
    parent_tot = jax.ops.segment_sum(
        cell_once, pseg, n, indices_are_sorted=True
    )[pseg]
    repf = rep.astype(jnp.float32)
    if use_pallas:
        return sparse_family_score_pallas(
            cell_tot, parent_tot.astype(jnp.float32), cc.astype(jnp.float32),
            repf, fam, num_fams, alpha, interpret=interpret,
        )
    return ref.sparse_family_score_ref(
        cell_tot, parent_tot, cc.astype(acc), repf, fam, num_fams, alpha
    )


_SCORE_STATICS = ("num_fams", "alpha", "use_pallas", "interpret")
_fused_sparse_score_jit = jax.jit(
    _fused_sparse_score_impl, static_argnames=_SCORE_STATICS
)
#: Donating twin — fed only the wrapper-owned bucket-padded stream temps.
_fused_sparse_score_jit_donated = jax.jit(
    _fused_sparse_score_impl, static_argnames=_SCORE_STATICS, donate_argnums=(0, 1)
)


def sparse_family_score_batched(
    codes: jax.Array,
    weights: jax.Array,
    bounds: jax.Array,
    child_cards: jax.Array,
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Fused marginalize+score over a concatenated COO family batch.

    The device-resident sparse twin of the ``ct_count`` ->
    ``mle_cpt_batched`` -> ``factor_loglik_batched`` three-hop: ``codes``
    holds every joint cell re-encoded into each family's code space (family
    ``f``'s codes living in ``[bounds[f], bounds[f+1])``, child minor digit)
    and ``weights`` the matching cell counts.  One launch sorts the stream,
    derives cell/parent-run totals by sorted segment sums, and contracts the
    masked ``n * log cp`` terms per family (Pallas kernel or jnp oracle per
    ``impl``).  Returns ``(B,)`` float32 log-likelihoods, ``B =
    len(child_cards)``; free-parameter counts are static family metadata
    and stay with the caller.

    Duplicate codes are legal (pre-aggregation is NOT required); elements
    with zero weight contribute nothing, so batch padding is free.
    ``bounds[-1]`` must stay below 2**31 (int32 code space) — callers chunk.

    Runs under a local ``enable_x64`` scope so the jnp-oracle path can
    accumulate per-family sums in float64 (returning float64 scores, like
    the host path's ``np.sum(..., dtype=float64)``); the Pallas kernel path
    returns Kahan-compensated float32.  Structure search's walk-alignment
    margin covers both.
    """
    _LAUNCHES["sparse_family_score"] += 1
    use, interp = _use_pallas(impl)
    num_fams = int(child_cards.shape[0])
    if int(codes.shape[0]) == 0:
        # an empty COO stream scores every family to exactly 0.0 (no
        # realized cells); the fixed-shape program below needs n >= 1
        return jnp.zeros((num_fams,), jnp.float32)
    with enable_x64():
        # Bucket-pad the concatenated stream so per-sweep batches of any
        # size share O(buckets) compiled programs.  Pad elements carry
        # code 0 / weight 0: zero-weight duplicates are free by the fused
        # scorer's contract (they add exactly 0.0 to every segment sum).
        codes, weights, padded = _pad_coo_stream(
            jnp.asarray(codes), jnp.asarray(weights), 0
        )
        fn = (
            _fused_sparse_score_jit_donated
            if padded and bucketing.donate_buffers()
            else _fused_sparse_score_jit
        )
        return fn(
            codes, weights,
            jnp.asarray(bounds), jnp.asarray(child_cards),
            num_fams, float(alpha), use, interp,
        )


def sparse_family_score(
    codes: jax.Array,
    counts: jax.Array,
    child_card: int,
    code_space: int,
    alpha: float = 0.0,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Single-family fused sparse score (a batch of one).

    ``codes``/``counts`` are one family CT's COO cells (child minor digit,
    any order, duplicates legal); returns the scalar float32 log-likelihood
    — the device twin of :func:`repro.core.sparse_counts.
    sparse_family_stats`'s log-likelihood term.
    """
    bounds = jnp.asarray([0, int(code_space)], jnp.int32)
    cc = jnp.asarray([int(child_card)], jnp.int32)
    return sparse_family_score_batched(
        codes, counts, bounds, cc, alpha, impl=impl
    )[0]
