"""Pallas TPU kernel: fused bitonic COO sort + segment-sum + compaction.

``ops.coo_aggregate``'s general engine canonicalizes a COO stream by
*sort-then-segment-sum*.  The XLA path (`ops._coo_aggregate_impl`) is three
device ops — ``sort_key_val``, ``segment_sum``, ``segment_min``; this
module is the on-chip twin that makes the whole canonicalization ONE
launch (ROADMAP "kernel endgame"):

  1. **Split.**  int64 mixed-radix codes don't exist on the TPU VPU, so the
     wrapper splits each code into two int32 lanes: ``hi = code >> 32`` and
     ``lo = (code & 0xFFFFFFFF) - 2**31`` (the sign-bias trick: the biased
     low word compares as *signed* int32 in exactly unsigned-low-word
     order, so lexicographic ``(hi, lo)`` order == int64 code order).
  2. **Bitonic key-value sort** of ``(hi, lo)`` carrying the float weight —
     a compare-exchange network over the power-of-two padded stream.
     Partner lanes are circular shifts, direction bits come from a
     broadcasted iota: no gathers, no data-dependent control flow.
  3. **Segmented Hillis–Steele scan** of the weights over equal-key runs
     (log2 n steps), accumulated in ``acc`` dtype — float64 off-TPU (exact
     for integer-valued counts, matching the host aggregation bit-for-bit),
     float32 on TPU per ``ops.count_acc_dtype``.
  4. **Compaction by a second bitonic sort** on ``key2 = where(run_end,
     run_index, n)``: run totals travel to an ascending prefix (one slot
     per unique code, in code order) and every non-end element parks at the
     tail — the exact fixed-shape layout of the XLA path (ascending unique
     prefix, int-max / zero-count padding after).

**Compile discipline.**  The network is *loop-structured*, not unrolled:
``fori_loop`` over the (block, distance) stage schedule, so the compiled
program holds ONE compare-exchange body regardless of rung size (an
unrolled network is O(log^2 n) stage bodies and sends XLA's optimizer
superlinear — minutes of compile at even 128 lanes).  The loop makes the
shift distance a *traced* value; since every bitonic distance is a power
of two, the dynamic roll is a select over the log2(n) static single-bit
rolls (:func:`_select_roll`) — static rotates are the one shift Mosaic
lowers everywhere, and the select chain is branch-free VPU code.

The wrapper recombines ``(hi, lo)`` back to int64 *outside* the kernel (the
kernel body is pure int32/float — TPU-lowerable), masks the tail to the
``int64-max / 0`` identity padding, and slices back to the caller's length.

Dispatch and the XLA oracle live in :func:`repro.kernels.ops.coo_aggregate`
(``REPRO_SORT_IMPL = auto|xla|pallas``); equivalence is pinned by
``tests/test_coo_sort.py`` across duplicates, all-equal keys, pre-sorted /
reversed inputs and rung boundaries.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Lane floor of the kernel's power-of-two internal stream (one VPU row).
_MIN_LANES = 128

#: Rung cap for the ``auto`` dispatch policy: above this many rows the
#: working set (7 int32/float lanes plus compare-exchange temporaries)
#: stops fitting VMEM comfortably and the XLA sort takes over.
PALLAS_SORT_MAX_ROWS = 1 << 18


def _roll(x, shift: int):
    """Circular lane shift (static ``shift``; Mosaic lowers to a rotate)."""
    return jnp.roll(x, shift, axis=1)


def _select_roll(x, dist, sign: int, nbits: int):
    """Roll ``x`` by ``sign * dist`` lanes where ``dist`` is a *traced*
    power of two below ``2**nbits``: a branch-free select over the static
    single-bit rotates (exactly one arm matches)."""
    out = x
    for b in range(nbits):
        out = jnp.where(dist == (1 << b), _roll(x, sign * (1 << b)), out)
    return out


def _compare_exchange(idx, k, j, nbits: int, key_hi, key_lo, *payload):
    """One bitonic stage: exchange with the lane ``j`` away, direction by
    ``k`` (both traced int32 scalars).

    Keys compare lexicographically on ``(key_hi, key_lo)``.  The keep/swap
    decision uses ``<=`` on the low lane and ``<`` on the high side of each
    pair so the two partners always make *complementary* choices — equal
    keys keep their own payloads instead of duplicating one side's (the
    classic key-value bitonic tie bug).
    """
    bit0 = (idx & j) == 0
    up = (idx & k) == 0

    def partner(v):
        return jnp.where(
            bit0,
            _select_roll(v, j, -1, nbits),
            _select_roll(v, j, 1, nbits),
        )

    ph, plo = partner(key_hi), partner(key_lo)
    lt = (key_hi < ph) | ((key_hi == ph) & (key_lo < plo))
    le = (key_hi < ph) | ((key_hi == ph) & (key_lo <= plo))
    take_self = jnp.where(bit0 == up, le, ~lt)
    out = [jnp.where(take_self, key_hi, ph), jnp.where(take_self, key_lo, plo)]
    for v in payload:
        out.append(jnp.where(take_self, v, partner(v)))
    return out


def _bitonic_sort(idx, nbits: int, key_hi, key_lo, *payload):
    """Full bitonic sort network as two nested ``fori_loop``s over the
    (block ``k`` = 2^(p+1), distance ``j`` = 2^(p-q)) stage schedule —
    one compiled compare-exchange body, O(log^2 n) runtime steps."""

    def outer(p, carry):
        k = jnp.int32(2) << p

        def inner(q, carry):
            j = jnp.int32(1) << (p - q)
            return tuple(_compare_exchange(idx, k, j, nbits, *carry))

        return jax.lax.fori_loop(jnp.int32(0), p + 1, inner, carry)

    return jax.lax.fori_loop(
        jnp.int32(0), jnp.int32(nbits), outer, (key_hi, key_lo, *payload)
    )


def _sort_agg_kernel(hi_ref, lo_ref, w_ref, ohi_ref, olo_ref, osum_ref, okey_ref):
    n = hi_ref.shape[1]
    nbits = n.bit_length() - 1  # n is a power of two
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    hi, lo, w = hi_ref[...], lo_ref[...], w_ref[...]

    # 1. bitonic key-value sort by (hi, lo), weights riding along
    hi, lo, w = _bitonic_sort(idx, nbits, hi, lo, w)

    # 2. run boundaries + segmented inclusive scan of the weights: after
    #    the scan, the LAST element of every equal-key run holds its total
    b = (idx == 0) | (hi != _roll(hi, 1)) | (lo != _roll(lo, 1))

    def scan_body(i, carry):
        s, f, c = carry
        d = jnp.int32(1) << i
        live = idx >= d
        s_sh = jnp.where(live, _select_roll(s, d, 1, nbits), jnp.zeros_like(s))
        f_sh = jnp.where(live, _select_roll(f, d, 1, nbits), True)
        c_sh = jnp.where(live, _select_roll(c, d, 1, nbits), 0)
        return (
            jnp.where(f, s, s + s_sh),
            f | f_sh,
            c + c_sh,
        )

    s, _, c = jax.lax.fori_loop(
        jnp.int32(0), jnp.int32(nbits), scan_body, (w, b, b.astype(jnp.int32))
    )
    # run index = inclusive prefix count of boundaries, minus one
    seg = c - 1
    is_end = _roll(b, -1) | (idx == n - 1)

    # 3. compaction: run totals bitonic-sort to an ascending prefix keyed
    #    by run index; non-end elements park at the tail under key n
    key2 = jnp.where(is_end, seg, n)
    khi, klo, hi, lo, s = _bitonic_sort(
        idx, nbits, key2, jnp.zeros_like(key2), hi, lo, s
    )

    ohi_ref[...] = hi
    olo_ref[...] = lo
    osum_ref[...] = s
    okey_ref[...] = khi


@functools.partial(jax.jit, static_argnames=("interpret", "acc"))
def coo_sort_aggregate(
    codes: jax.Array,
    weights: jax.Array,
    *,
    interpret: bool = False,
    acc=jnp.float32,
):
    """Fused COO canonicalization: ONE kernel launch, XLA-path contract.

    Same output as ``ops._coo_aggregate_impl``: ``(uniq, sums)`` at the
    input length, ascending unique codes (including the int-max pad run,
    if the input carries one) as a prefix and ``int64-max / 0`` identity
    padding after.  ``acc`` is the weight accumulation dtype — float64 off
    TPU reproduces the host aggregation bit-for-bit for integer-valued
    counts; int64 codes only (the mixed-radix composite key dtype).

    Must run under the caller's ``enable_x64`` scope (the int64 split /
    recombine arithmetic); the kernel body itself is pure int32/float.
    """
    n = int(codes.shape[0])
    n2 = max(_MIN_LANES, 1 << (n - 1).bit_length())
    pad_code = jnp.iinfo(jnp.int64).max
    if n2 > n:
        codes = jnp.concatenate(
            [codes, jnp.full((n2 - n,), pad_code, codes.dtype)]
        )
        weights = jnp.concatenate([weights, jnp.zeros((n2 - n,), weights.dtype)])

    # int64 -> two int32 lanes; the sign-biased low word keeps (hi, lo)
    # lexicographic order == int64 order (module docstring)
    hi = (codes >> 32).astype(jnp.int32).reshape(1, n2)
    lo = ((codes & 0xFFFFFFFF) - (1 << 31)).astype(jnp.int32).reshape(1, n2)
    w = weights.astype(acc).reshape(1, n2)

    ohi, olo, osum, okey = pl.pallas_call(
        _sort_agg_kernel,
        in_specs=[pl.BlockSpec((1, n2), lambda: (0, 0))] * 3,
        out_specs=[pl.BlockSpec((1, n2), lambda: (0, 0))] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((1, n2), jnp.int32),
            jax.ShapeDtypeStruct((1, n2), jnp.int32),
            jax.ShapeDtypeStruct((1, n2), w.dtype),
            jax.ShapeDtypeStruct((1, n2), jnp.int32),
        ],
        interpret=interpret,
    )(hi, lo, w)

    valid = (okey < n2).reshape(-1)
    low_word = olo.astype(jnp.int64).reshape(-1) + jnp.int64(1 << 31)
    uniq = jnp.where(
        valid,
        (ohi.astype(jnp.int64).reshape(-1) << 32) | low_word,
        pad_code,
    )
    sums = jnp.where(valid, osum.reshape(-1).astype(jnp.float32), 0.0)
    return uniq[:n], sums[:n]
