"""Shape-bucketed device execution: the compile-budget layer.

Every device COO stream in this codebase has a data-dependent length — join
expansions, Möbius subtractions, aggregation compactions, per-sweep score
batches.  Left alone, each distinct length traces and compiles a fresh XLA
program: a cold device-side CT build pays hundreds of backend compiles
(seconds of wall time for milliseconds of actual compute), and a
production system serving many schemas would re-trace per join shape
forever.  This module is the fix, in three parts:

**1. The bucket ladder.**  :func:`bucket_rows` maps any row count onto a
small geometric ladder (``base * growth^k``, default 128 x 2.0).  The ops
wrappers pad every COO operand up to its rung with *identity padding* —
int-max sentinel codes / zero weights, which every COO consumer already
treats as absent — so all joins, subtractions and sweep scorings of a
learning run hit O(buckets) compiled programs instead of one per
data-dependent shape.  Results are unchanged: padding carries no mass and
sorts after every valid code.  Knobs: ``REPRO_BUCKET_BASE`` /
``REPRO_BUCKET_GROWTH`` env vars or :func:`set_bucket_ladder`.

**2. Compile accounting.**  A ``jax.monitoring`` duration listener on the
``backend_compile`` event counts *actual* XLA compiles (cache hits are
free), exposed as :func:`compile_counts` / :func:`reset_compile_counts`
next to the launch/transfer counters in :mod:`repro.kernels.ops`.  The
benchmarks record it per dataset and CI fails when it exceeds the
committed budget — recompile regressions fail the PR, not the next
profiling session.

**3. Warm starts.**  ``REPRO_JAX_CACHE_DIR`` (or
:func:`enable_persistent_cache`) wires JAX's persistent compilation cache
so bucketed programs survive process restarts, and
:func:`donate_buffers` gates input-buffer donation for the wrapper-owned
padded temporaries (``REPRO_DONATE=auto|0|1``; auto enables it off-CPU,
where XLA actually implements donation).
"""

from __future__ import annotations

import math

import jax

# The config module is the single owner of every REPRO_* env read and of
# the kwarg > context > setter > env > default precedence chain.  It is
# imported lazily (inside functions) because the core and kernels layers
# import each other's modules at import time and this file sits at the
# bottom of that graph.


def _config():
    from ..core import config

    return config


# ---------------------------------------------------------------------------
# The bucket ladder
# ---------------------------------------------------------------------------


def _validated_ladder(base: int, growth: float) -> tuple[int, float]:
    base, growth = int(base), float(growth)
    if base < 1:
        raise ValueError(f"bucket base must be >= 1, got {base}")
    if growth <= 1.0:
        # growth == 1 would make every row count its own "bucket" and
        # silently bring the per-shape recompile tax back
        raise ValueError(f"bucket growth must be > 1, got {growth}")
    return base, growth


def bucket_ladder() -> tuple[int, float]:
    """Current ``(base, growth)`` of the row-count bucket ladder."""
    cfg = _config()
    return cfg.resolve("bucket_base"), cfg.resolve("bucket_growth")


def set_bucket_ladder(
    base: int | None = None, growth: float | None = None
) -> tuple[int, float]:
    """Set the ladder; returns the previous ``(base, growth)``.

    Tests shrink the base to force padding on tiny inputs; production
    tuning widens ``growth`` to trade sort overhead (each stream is padded
    by at most one growth factor) against program count.

    .. deprecated:: delegates to :mod:`repro.core.config`; prefer
       ``engine_config(bucket_base=..., bucket_growth=...)`` for scoped use.
    """
    cfg = _config()
    old = bucket_ladder()
    new_base, new_growth = _validated_ladder(
        old[0] if base is None else base, old[1] if growth is None else growth
    )
    cfg.set_override("bucket_base", new_base)
    cfg.set_override("bucket_growth", new_growth)
    return old


#: Temporary minimum rung for *stream* padding (0 = off).  Raised by the
#: device build for its duration (``sparse_counts._build_ladder_floor``) so
#: every transient COO stream of a small build shares one shape; compaction
#: sites pass ``tight=True`` to keep *materialized* results — CTs that feed
#: quadratic cross products and every scoring sweep — at their natural rung.
_STREAM_FLOOR = 0


def stream_floor() -> int:
    """Current stream-padding floor in rows (``0`` = no floor)."""
    return _STREAM_FLOOR


def set_stream_floor(rows: int) -> int:
    """Set the stream-padding floor; returns the previous value.

    Callers should pass an existing ladder rung (``bucket_rows(n,
    tight=True)`` of their target) so floored and unfloored shape sets
    stay one consistent ladder.
    """
    global _STREAM_FLOOR
    old = _STREAM_FLOOR
    rows = int(rows)
    if rows < 0:
        raise ValueError(f"stream floor must be >= 0, got {rows}")
    _STREAM_FLOOR = rows
    return old


def bucket_rows(n: int, *, tight: bool = False) -> int:
    """Smallest ladder rung >= ``n`` (``0`` stays ``0``: empties never pad).

    Rungs are generated iteratively (``next = ceil(rung * growth)``) so the
    ladder is a single consistent set of sizes regardless of which ``n``
    asks — no floating-point boundary can put two callers on different
    rungs for the same count.

    When a stream floor is active (device builds), the result is raised to
    it — unless ``tight=True``, which compaction sites use to size
    *results* by their realized row count rather than the padding floor.
    """
    n = int(n)
    if n <= 0:
        return 0
    base, growth = bucket_ladder()
    rung = base
    while rung < n:
        rung = max(rung + 1, math.ceil(rung * growth))
    if not tight:
        rung = max(rung, _STREAM_FLOOR)
    return rung


#: Ladder for histogram-accumulator *bin* counts — deliberately much coarser
#: than the row ladder (growth 8 vs 2).  Bin rungs only size a dense scratch
#: accumulator, so over-allocating by up to 8x costs a few MB of device
#: memory at worst; what they DO multiply is the compiled-program count
#: (histogram aggregation compiles one program per (row rung, bin rung)
#: pair), which is exactly the cold-start tax the super-program build is
#: trying to kill.
_BIN_BASE = 256
_BIN_GROWTH = 8


def bucket_bins(n: int) -> int:
    """Smallest bin-ladder rung >= ``n`` (``0`` stays ``0``).

    The bin twin of :func:`bucket_rows`: used by ``ops.coo_aggregate`` to
    key its dense-accumulator (histogram) programs, trading accumulator
    over-allocation for ~3x fewer distinct compiled histogram programs.
    """
    n = int(n)
    if n <= 0:
        return 0
    rung = _BIN_BASE
    while rung < n:
        rung *= _BIN_GROWTH
    return rung


def shard_ranges(n: int, n_shards: int) -> list[tuple[int, int]]:
    """Split ``n`` rows into exactly ``n_shards`` contiguous ``(lo, hi)`` ranges.

    The row-sharding rule of the sharded COO build: every shard except the
    last gets the same ceil-divided size, so all leading shards share ONE
    bucket rung (their per-shard streams compile a single program, not one
    per shard) and only the tail shard can land on a different rung.  When
    ``n < n_shards``, trailing ranges are empty ``(n, n)`` — legal shards
    contributing no mass, which the partial merge must (and does) tolerate.
    """
    n, n_shards = int(n), int(n_shards)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    size = -(-n // n_shards) if n else 0
    return [
        (min(i * size, n), min((i + 1) * size, n)) if size else (n, n)
        for i in range(n_shards)
    ]


# ---------------------------------------------------------------------------
# Compile accounting
# ---------------------------------------------------------------------------

#: The jax.monitoring event fired once per actual XLA backend compile
#: (tracing and compilation-cache hits do NOT fire it) — the honest probe
#: behind the benchmarks' ``compiles`` field and the CI compile budget.
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_COMPILES = {"compiles": 0, "compile_secs": 0.0}


def _on_compile_event(event: str, duration: float, **kw) -> None:
    if event == _COMPILE_EVENT:
        _COMPILES["compiles"] += 1
        _COMPILES["compile_secs"] += duration


# getattr-guarded: jax.monitoring has carried this registration API since
# 0.4, but a missing symbol on some future version should degrade to
# compiles=0 (a lenient gate), never to an import error.
_register = getattr(jax.monitoring, "register_event_duration_secs_listener", None)
if _register is not None:
    _register(_on_compile_event)


def compile_probe_active() -> bool:
    """Whether the backend-compile listener could be registered at all.

    The compile-budget gate and the cache-warmth tests are meaningful only
    when this is True; on a JAX without the monitoring hook they degrade
    to lenient no-ops rather than false failures.
    """
    return _register is not None


def reset_compile_counts() -> None:
    """Zero the compile tally (benchmark bracketing)."""
    _COMPILES["compiles"] = 0
    _COMPILES["compile_secs"] = 0.0


def compile_counts() -> dict:
    """``{"compiles": n, "compile_secs": s}`` since the last reset."""
    return dict(_COMPILES)


def total_compiles() -> int:
    return _COMPILES["compiles"]


# ---------------------------------------------------------------------------
# Persistent compilation cache + donation policy
# ---------------------------------------------------------------------------


def enable_persistent_cache(cache_dir) -> None:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    With the bucket ladder bounding the set of program shapes, the cache
    makes even the *first* build of a process warm: every (op, rung)
    program compiled by any previous run is deserialized instead of
    recompiled.  Thresholds are zeroed so the small bucketed programs
    qualify (by default JAX only persists compiles >1s).
    """
    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)


# REPRO_JAX_CACHE_DIR wiring happens when repro.core.config is imported
# (it owns the env read); calling enable_persistent_cache directly remains
# the programmatic form of the same knob.

_DONATE_MODES = ("auto", "0", "1")


def set_donation(mode: str) -> str:
    """Set the donation policy (``auto|0|1``); returns the previous mode.

    .. deprecated:: delegates to :mod:`repro.core.config`; prefer
       ``engine_config(donation=...)`` for scoped use.
    """
    if mode not in _DONATE_MODES:
        raise ValueError(f"donation mode must be one of {_DONATE_MODES}, got {mode!r}")
    return _config().set_override("donation", mode)


def donate_buffers() -> bool:
    """Whether ops wrappers should donate their padded input temporaries.

    Donation is only ever applied to buffers the wrapper itself created by
    bucket-padding (never to caller arrays, whose identity must survive the
    call).  ``auto`` enables it away from CPU — XLA:CPU ignores donation
    and warns, so forcing it there (``REPRO_DONATE=1``) is for tests only.
    """
    mode = _config().resolve("donation")
    if mode == "1":
        return True
    if mode == "0":
        return False
    return jax.default_backend() != "cpu"
