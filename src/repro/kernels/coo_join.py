"""Pallas TPU kernel for the sort-merge join expansion (device CT builds).

The device-side sparse CT build (paper §IV; ``repro.core.sparse_counts``)
expresses every foreign-key join as a **sort-merge join on entity rows**:
one side is a COO message whose ``rows`` column is sorted, the other a
relationship table's foreign-key column probing it.  The match table is two
``searchsorted`` passes (``lo``/``hi`` per probe key, computed by the ops
wrapper in plain XLA); what remains — and what this kernel implements — is
the *expansion* of that match table into flat gather indices:

    for probe j, for m in [0, cnt[j]):  emit (lo[j] + m, j)

ordered probe-major, so the joined stream inherits the probe side's order.
The output length ``total = sum(cnt)`` is data-dependent; the caller syncs
it to host (one accounted scalar d2h) and pads it to a power-of-two bucket
so launch shapes stabilize.

Kernel formulation (TPU-native, no data-dependent control flow): with
``cum = cumsum(cnt)``, output slot ``p`` belongs to the probe key with
``rank[p] = #{k : cum[k] <= p}`` (a vectorized binary-search-by-counting
over probe chunks on the VPU), and the within-run offset is ``p -
start[rank[p]]`` where ``start = cum - cnt``.  The ``lo``/``start`` gathers
by ``rank`` are one-hot masked reductions over the same probe chunks —
gathers as compares+reduces, the same trick as ``ct_count``'s scatter.

The jnp oracle (`kernels.ref.coo_join_expand_ref`) computes the identical
indices with ``jnp.searchsorted`` + gathers; dispatch and accounting live
in :func:`repro.kernels.ops.coo_join`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Output elements per grid step (lane-tile of the expanded join stream).
_BM = 1024

#: Probe-table chunk width for the rank/gather sweeps (one VPU lane row).
_BK = 128

#: Padding value for the cumulative-count table: larger than any valid
#: output position (positions are int32), so padded probe slots never
#: contribute to a rank count.
_CUM_PAD = jnp.iinfo(jnp.int32).max


def _coo_join_expand_kernel(cum_ref, lo_ref, start_ref, ia_ref, ib_ref):
    i = pl.program_id(0)
    bm = ia_ref.shape[1]
    n_pad = cum_ref.shape[1]
    n_chunks = n_pad // _BK

    pos = i * bm + jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
    pos_col = jnp.swapaxes(pos, 0, 1)  # (bm, 1)

    # rank[p] = #{k : cum[k] <= p} — counting formulation of searchsorted
    # (cum is non-decreasing), accumulated chunk by chunk on the VPU.
    def rank_body(k, rank):
        chunk = cum_ref[:, pl.ds(k * _BK, _BK)]  # (1, BK)
        return rank + jnp.sum(
            (chunk <= pos_col).astype(jnp.int32), axis=1, keepdims=True
        )

    rank = jax.lax.fori_loop(
        0, n_chunks, rank_body, jnp.zeros((bm, 1), jnp.int32)
    )

    # Gather lo[rank] and start[rank] as one-hot masked reductions over the
    # same chunks (rank beyond the real probe count only occurs on output
    # padding slots, which the wrapper slices off).
    def gather_body(k, carry):
        lo_g, st_g = carry
        ids = k * _BK + jax.lax.broadcasted_iota(jnp.int32, (1, _BK), 1)
        onehot = rank == ids  # (bm, BK)
        lo_chunk = lo_ref[:, pl.ds(k * _BK, _BK)]
        st_chunk = start_ref[:, pl.ds(k * _BK, _BK)]
        lo_g = lo_g + jnp.sum(
            jnp.where(onehot, lo_chunk, 0), axis=1, keepdims=True
        )
        st_g = st_g + jnp.sum(
            jnp.where(onehot, st_chunk, 0), axis=1, keepdims=True
        )
        return lo_g, st_g

    zeros = jnp.zeros((bm, 1), jnp.int32)
    lo_g, st_g = jax.lax.fori_loop(0, n_chunks, gather_body, (zeros, zeros))

    ia_ref[...] = jnp.swapaxes(lo_g + (pos_col - st_g), 0, 1)
    ib_ref[...] = jnp.swapaxes(rank, 0, 1)


@functools.partial(jax.jit, static_argnames=("total", "interpret", "bm"))
def coo_join_expand_pallas(
    lo: jax.Array,
    cnt: jax.Array,
    total: int,
    *,
    interpret: bool = False,
    bm: int = _BM,
) -> tuple[jax.Array, jax.Array]:
    """Expand a sort-merge match table into ``(idx_sorted, idx_probe)``.

    ``lo[j]``/``cnt[j]`` are the first match position and match count of
    probe key ``j`` against the sorted key column; ``total`` is the (static,
    pre-synced) number of output pairs — callers pad it to a bucket and
    slice, so slots at positions ``>= sum(cnt)`` hold garbage indices that
    must be discarded.  Output ``idx_sorted[p]``/``idx_probe[p]`` index the
    sorted and probe sides of pair ``p``, probe-major.
    """
    n = lo.shape[0]
    n_pad = max(_BK, -(-n // _BK) * _BK)
    cum = jnp.cumsum(cnt.astype(jnp.int32))
    start = cum - cnt.astype(jnp.int32)
    cum = jnp.pad(cum, (0, n_pad - n), constant_values=_CUM_PAD).reshape(1, -1)
    lo2 = jnp.pad(lo.astype(jnp.int32), (0, n_pad - n)).reshape(1, -1)
    start = jnp.pad(start, (0, n_pad - n)).reshape(1, -1)

    bm = min(bm, max(128, -(-total // 128) * 128))
    n_tiles = -(-total // bm)

    ia, ib = pl.pallas_call(
        _coo_join_expand_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, n_pad), lambda i: (0, 0))] * 3,
        out_specs=[pl.BlockSpec((1, bm), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n_tiles, bm), jnp.int32)] * 2,
        interpret=interpret,
    )(cum, lo2, start)
    return ia.reshape(-1)[:total], ib.reshape(-1)[:total]
