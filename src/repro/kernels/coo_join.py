"""Pallas TPU kernel for the sort-merge join expansion (device CT builds).

The device-side sparse CT build (paper §IV; ``repro.core.sparse_counts``)
expresses every foreign-key join as a **sort-merge join on entity rows**:
one side is a COO message whose ``rows`` column is sorted, the other a
relationship table's foreign-key column probing it.  The match table is two
``searchsorted`` passes (``lo``/``hi`` per probe key, computed by the ops
wrapper in plain XLA); what remains — and what this kernel implements — is
the *expansion* of that match table into flat gather indices:

    for probe j, for m in [0, cnt[j]):  emit (lo[j] + m, j)

ordered probe-major, so the joined stream inherits the probe side's order.
The output length ``total = sum(cnt)`` is data-dependent; the caller syncs
it to host (one accounted scalar d2h) and pads it to a power-of-two bucket
so launch shapes stabilize.

Kernel formulation (TPU-native, no gathers, no data-dependent shapes):
with ``cum = cumsum(cnt)``, output slot ``p`` belongs to the probe with
``rank[p] = #{k : cum[k] <= p}``.  The wrapper first *compresses* the
match table to its nonzero-count probes — that makes ``cum`` strictly
increasing over real entries, so the ranks covered by one ``bm``-wide
output tile span at most ``bm`` consecutive probes.  Each grid step then:

  1. **binary-searches** the cumulative table for its first rank — log2
     (n_pad) *scalar* probes of the table (a traced-index element read per
     step), instead of the old counting sweep's O(n_pad / 128)
     compare-reduces per tile;
  2. loads the ``bm``-wide window of compressed probes at that base (one
     dynamic slice) and ranks all ``bm`` output slots against it with
     chunked compare-reduces — work per tile now depends only on the tile
     width, not on the probe-table size;
  3. gathers the per-probe offset and original probe index through the
     same window as one-hot masked reductions (gathers as compares+
     reduces, the same trick as ``ct_count``'s scatter).

``idx_sorted`` needs no second gather at all: the wrapper pre-folds
``lo - start`` into a single per-probe offset, so ``idx_sorted[p] =
off[rank[p]] + p``.

The jnp oracle (`kernels.ref.coo_join_expand_ref`) computes the identical
indices with ``jnp.searchsorted`` + gathers; dispatch and accounting live
in :func:`repro.kernels.ops.coo_join`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Output elements per grid step (lane-tile of the expanded join stream).
_BM = 1024

#: Window-chunk width for the rank/gather compare-reduces (one VPU lane row).
_BK = 128

#: Padding value for the cumulative-count table: larger than any valid
#: output position (positions are int32), so padded probe slots never
#: contribute to a rank count.
_CUM_PAD = jnp.iinfo(jnp.int32).max


def _coo_join_expand_kernel(ccum_ref, off_ref, cidx_ref, ia_ref, ib_ref):
    i = pl.program_id(0)
    bm = ia_ref.shape[1]
    n_pad = ccum_ref.shape[1]
    nbits = n_pad.bit_length() - 1  # n_pad is a power of two
    p0 = i * bm  # first output position of this tile

    # 1. scalar binary search: base = #{m : ccum[m] <= p0}, the rank of the
    #    tile's first slot.  Branchless power-of-two descent plus one final
    #    correction probe; each step is a single traced-index element read.
    def bs_body(s, base):
        half = jnp.int32(n_pad) >> (s + 1)
        v = ccum_ref[0, base + half - 1]
        return jnp.where(v <= p0, base + half, base)

    base = jax.lax.fori_loop(
        jnp.int32(0), jnp.int32(nbits), bs_body, jnp.int32(0)
    )
    base = jnp.where(ccum_ref[0, base] <= p0, base + 1, base)
    # Window start: the tile's ranks span < bm probes (strictly increasing
    # compressed ccum), clamped so the window stays in bounds.
    r0 = jnp.clip(base, 0, n_pad - bm)

    pos = p0 + jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
    pos_col = jnp.swapaxes(pos, 0, 1)  # (bm, 1)
    n_chunks = bm // _BK

    # 2. rank every slot against the window: rank[p] = r0 + #{k in window :
    #    ccum[r0+k] <= p}, accumulated in _BK-wide chunks.
    def rank_body(k, rank):
        chunk = ccum_ref[:, pl.ds(r0 + k * _BK, _BK)]  # (1, BK)
        return rank + jnp.sum(
            (chunk <= pos_col).astype(jnp.int32), axis=1, keepdims=True
        )

    rank_rel = jax.lax.fori_loop(
        0, n_chunks, rank_body, jnp.zeros((bm, 1), jnp.int32)
    )

    # 3. gather off[rank] and cidx[rank] through the same window as one-hot
    #    masked reductions (rank_rel lands outside [0, bm) only on output
    #    padding slots, which gather 0 and are sliced off by the wrapper).
    def gather_body(k, carry):
        off_g, ci_g = carry
        ids = k * _BK + jax.lax.broadcasted_iota(jnp.int32, (1, _BK), 1)
        onehot = rank_rel == ids  # (bm, BK)
        off_chunk = off_ref[:, pl.ds(r0 + k * _BK, _BK)]
        ci_chunk = cidx_ref[:, pl.ds(r0 + k * _BK, _BK)]
        off_g = off_g + jnp.sum(
            jnp.where(onehot, off_chunk, 0), axis=1, keepdims=True
        )
        ci_g = ci_g + jnp.sum(
            jnp.where(onehot, ci_chunk, 0), axis=1, keepdims=True
        )
        return off_g, ci_g

    zeros = jnp.zeros((bm, 1), jnp.int32)
    off_g, ci_g = jax.lax.fori_loop(0, n_chunks, gather_body, (zeros, zeros))

    ia_ref[...] = jnp.swapaxes(off_g + pos_col, 0, 1)
    ib_ref[...] = jnp.swapaxes(ci_g, 0, 1)


@functools.partial(jax.jit, static_argnames=("total", "interpret", "bm"))
def coo_join_expand_pallas(
    lo: jax.Array,
    cnt: jax.Array,
    total: int,
    *,
    interpret: bool = False,
    bm: int = _BM,
) -> tuple[jax.Array, jax.Array]:
    """Expand a sort-merge match table into ``(idx_sorted, idx_probe)``.

    ``lo[j]``/``cnt[j]`` are the first match position and match count of
    probe key ``j`` against the sorted key column; ``total`` is the (static,
    pre-synced) number of output pairs — callers pad it to a bucket and
    slice, so slots at positions ``>= sum(cnt)`` hold garbage indices that
    must be discarded.  Output ``idx_sorted[p]``/``idx_probe[p]`` index the
    sorted and probe sides of pair ``p``, probe-major.
    """
    n = int(lo.shape[0])
    cnt = cnt.astype(jnp.int32)

    # Compress to nonzero-count probes (fixed shape: value compression
    # only).  This is what licenses the kernel's windowed rank sweep: the
    # compressed cumulative table is strictly increasing over real entries,
    # so one bm-wide output tile can only span bm consecutive probes —
    # with zero-count probes left in, a single tile could straddle
    # arbitrarily many of them.
    nz = jnp.nonzero(cnt > 0, size=n, fill_value=n)[0].astype(jnp.int32)
    safe = jnp.minimum(nz, n - 1)
    real = nz < n
    ccnt = jnp.where(real, cnt[safe], 0)
    clo = jnp.where(real, lo.astype(jnp.int32)[safe], 0)
    ccum = jnp.cumsum(ccnt)
    # idx_sorted[p] = lo[j] + (p - start[j]): fold into one offset so the
    # kernel gathers a single value per output slot
    coff = clo - (ccum - ccnt)

    bm = min(bm, max(128, -(-total // 128) * 128))
    n_pad = max(bm, 1 << (n - 1).bit_length()) if n > 1 else bm
    ccum = jnp.pad(ccum, (0, n_pad - n), constant_values=_CUM_PAD).reshape(1, -1)
    coff = jnp.pad(coff, (0, n_pad - n)).reshape(1, -1)
    cidx = jnp.pad(jnp.where(real, nz, 0), (0, n_pad - n)).reshape(1, -1)

    n_tiles = -(-total // bm)

    ia, ib = pl.pallas_call(
        _coo_join_expand_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, n_pad), lambda i: (0, 0))] * 3,
        out_specs=[pl.BlockSpec((1, bm), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n_tiles, bm), jnp.int32)] * 2,
        interpret=interpret,
    )(ccum, coff, cidx)
    return ia.reshape(-1)[:total], ib.reshape(-1)[:total]
