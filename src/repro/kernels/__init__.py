"""Pallas TPU kernels for the FactorBase hot spots.

The paper's measured costs live in the count manager (GROUP BY COUNT over
joins), the parameter manager (CT -> CPT normalization), score computation
(count x log-parameter contraction) and block test-set prediction (the
grouped scoring matmul).  Each hot spot has a Pallas kernel (<name>.py), a
pure-jnp oracle (ref.py) and a jitted dispatching wrapper (ops.py).
"""

from .ops import (
    block_predict,
    coo_aggregate,
    ct_count,
    factor_loglik,
    factor_loglik_batched,
    mle_cpt,
    mle_cpt_batched,
    sorted_segment_sum,
    sparse_family_score,
    sparse_family_score_batched,
)

__all__ = [
    "block_predict", "coo_aggregate", "ct_count", "factor_loglik",
    "factor_loglik_batched", "mle_cpt", "mle_cpt_batched",
    "sorted_segment_sum", "sparse_family_score", "sparse_family_score_batched",
]
