"""Pallas TPU kernels for the FactorBase hot spots.

The paper's measured costs live in the count manager (GROUP BY COUNT over
joins), the parameter manager (CT -> CPT normalization), score computation
(count x log-parameter contraction) and block test-set prediction (the
grouped scoring matmul).  Each hot spot has a Pallas kernel (<name>.py), a
pure-jnp oracle (ref.py) and a jitted dispatching wrapper (ops.py).

``bucketing.py`` is the shape discipline under all of them: every device
COO stream is padded to a small geometric row ladder so a learning run
compiles O(buckets) XLA programs, with compile accounting (the CI budget's
probe) and persistent-cache/donation knobs alongside.
"""

from .bucketing import (
    bucket_ladder,
    bucket_rows,
    compile_counts,
    enable_persistent_cache,
    reset_compile_counts,
    set_bucket_ladder,
)
from .ops import (
    block_predict,
    coo_aggregate,
    ct_count,
    factor_loglik,
    factor_loglik_batched,
    mle_cpt,
    mle_cpt_batched,
    sorted_segment_sum,
    sparse_family_score,
    sparse_family_score_batched,
)

__all__ = [
    "block_predict", "bucket_ladder", "bucket_rows", "compile_counts",
    "coo_aggregate", "ct_count", "enable_persistent_cache", "factor_loglik",
    "factor_loglik_batched", "mle_cpt", "mle_cpt_batched",
    "reset_compile_counts", "set_bucket_ladder", "sorted_segment_sum",
    "sparse_family_score", "sparse_family_score_batched",
]
