"""Pallas TPU kernel for the factor log-likelihood contraction.

Paper §V-C computes the model log-likelihood as
``SELECT SUM(cpt.cp * ct.count) FROM CPT NATURAL JOIN CT`` per family; in
tensor form CT and CPT are dense co-indexed arrays, so the join is the
identity and the score is a fused masked log-dot-reduce:

    loglik = sum over cells ( count > 0 ? count * log(max(cp, tiny)) : 0 )

The kernel streams both arrays through VMEM in (8, 128)-aligned tiles and
accumulates a single scalar across the 1-D grid (revolving (1, 1) output
block).  The 0*log(0) := 0 convention is applied per cell so unrealized
parent configurations (uniform-filled CPT rows) never pollute the score.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BM = 8 * 2048  # cells per tile (reshaped to (8, 2048) in VMEM)
_LOG_TINY = 1e-30


def _loglik_kernel(ct_ref, cp_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ct = ct_ref[...]
    cp = cp_ref[...]
    logp = jnp.log(jnp.maximum(cp, _LOG_TINY))
    contrib = jnp.where(ct > 0, ct * logp, 0.0)
    out_ref[...] += jnp.sum(contrib)[None, None]


@functools.partial(jax.jit, static_argnames=("interpret", "bm"))
def factor_loglik_pallas(
    ct: jax.Array,
    cpt: jax.Array,
    *,
    interpret: bool = False,
    bm: int = _BM,
) -> jax.Array:
    """sum(count * log(cp)) over co-indexed flat arrays (any shape)."""
    ctf = ct.reshape(-1).astype(jnp.float32)
    cpf = cpt.reshape(-1).astype(jnp.float32)
    m = ctf.shape[0]
    # tile size must stay lane-aligned (multiple of 128) after shrinking
    bm = min(bm, max(8 * 128, -(-m // 128) * 128))
    pad = -m % bm
    # count padding 0 -> contributes 0 regardless of cp padding value
    ctf = jnp.pad(ctf, (0, pad)).reshape(-1, 128)
    cpf = jnp.pad(cpf, (0, pad), constant_values=1.0).reshape(-1, 128)
    rows_per_tile = bm // 128

    out = pl.pallas_call(
        _loglik_kernel,
        grid=(ctf.shape[0] // rows_per_tile,),
        in_specs=[
            pl.BlockSpec((rows_per_tile, 128), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(ctf, cpf)
    return out[0, 0]


def _loglik_batched_kernel(ct_ref, cp_ref, out_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ct = ct_ref[...]
    cp = cp_ref[...]
    logp = jnp.log(jnp.maximum(cp, _LOG_TINY))
    contrib = jnp.where(ct > 0, ct * logp, 0.0)
    out_ref[...] += jnp.sum(contrib)[None, None]


@functools.partial(jax.jit, static_argnames=("interpret", "bm"))
def factor_loglik_batched_pallas(
    ct: jax.Array,
    cpt: jax.Array,
    *,
    interpret: bool = False,
    bm: int = _BM,
) -> jax.Array:
    """Per-row ``sum(count * log(cp))`` over stacked flat families.

    ``ct`` and ``cpt`` are co-indexed ``(B, M)``; returns ``(B,)`` float32.
    The grid is (family, cell-tile) with the tile dimension innermost, so
    each family's (1, 1) accumulator block revolves in VMEM across its own
    cell sweep — B scalar reductions in a single launch instead of B
    single-family kernel launches (the set-oriented §V-C ``Scores`` build).
    """
    b, m = ct.shape
    ctf = ct.astype(jnp.float32)
    cpf = cpt.astype(jnp.float32)
    # tile size must stay lane-aligned (multiple of 128) after shrinking
    bm = min(bm, max(8 * 128, -(-m // 128) * 128))
    pad = -m % bm
    # count padding 0 -> contributes 0 regardless of cp padding value
    ctf = jnp.pad(ctf, ((0, 0), (0, pad))).reshape(b, -1, 128)
    cpf = jnp.pad(cpf, ((0, 0), (0, pad)), constant_values=1.0).reshape(b, -1, 128)
    rows_per_tile = bm // 128

    out = pl.pallas_call(
        _loglik_batched_kernel,
        grid=(b, ctf.shape[1] // rows_per_tile),
        in_specs=[
            pl.BlockSpec((1, rows_per_tile, 128), lambda bb, i: (bb, i, 0)),
            pl.BlockSpec((1, rows_per_tile, 128), lambda bb, i: (bb, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bb, i: (bb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(ctf, cpf)
    return out[:, 0]
