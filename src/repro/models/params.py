"""Analytic parameter counts per architecture (MODEL_FLOPS = 6*N*D needs N).

Counts mirror ``init_params`` exactly (tests assert the two agree leaf-for-
leaf on reduced configs).  ``active_only`` counts the parameters touched per
token for MoE archs (top-k experts + router + dense residual) — the N that
enters the 6*N*D "useful compute" convention.
"""

from __future__ import annotations


def _attn_params(cfg, cross: bool = False) -> int:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    n = d * h * hd + 2 * d * kh * hd + h * hd * d  # wq, wk, wv, wo
    if cfg.attn_bias and not cross:
        n += h * hd + 2 * kh * hd
    if cfg.qk_norm and not cross:
        n += 2 * hd
    return n


def _mlp_params(d: int, ff: int) -> int:
    return 3 * d * ff


def _moe_params(cfg, active_only: bool) -> int:
    e = cfg.top_k if active_only else cfg.n_experts
    n = cfg.d_model * cfg.n_experts  # router (always fully touched)
    n += e * 3 * cfg.d_model * cfg.d_ff
    if cfg.moe_dense_residual:
        n += _mlp_params(cfg.d_model, cfg.dense_ff or cfg.d_ff)
    return n


def _ssm_params(cfg) -> int:
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n
    total = cfg.d_model * (2 * din + 2 * n + h)   # w_in
    total += cfg.ssm_conv * conv_ch + conv_ch      # conv
    total += 3 * h                                  # a_log, d_skip, dt_bias
    total += din                                    # gate_norm
    total += din * cfg.d_model                      # w_out
    return total


def count_params_analytic(cfg, active_only: bool = False) -> int:
    d = cfg.d_model
    vp = cfg.vocab_padded
    n = vp * d  # embedding (pad-to-256 so vocab shards; see ModelConfig)
    if not cfg.tie_embeddings:
        n += d * vp  # lm_head
    n += d  # final norm

    if cfg.family == "ssm":
        n += cfg.n_layers * (_ssm_params(cfg) + d)  # + norm
        return n

    if cfg.hybrid:
        # 4 norms: ln1, ln2 and the per-path attn_norm / ssm_norm
        per = _attn_params(cfg) + _ssm_params(cfg) + _mlp_params(d, cfg.d_ff) + 4 * d
        n += cfg.n_layers * per
        return n

    if cfg.family == "vlm":
        n_cross = cfg.n_layers // (cfg.cross_attn_every + 1)
        n_self = cfg.n_layers - n_cross
        n += n_self * (_attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d)
        n += n_cross * (_attn_params(cfg, cross=True) + _mlp_params(d, cfg.d_ff) + 2 * d)
        return n

    if cfg.is_encdec:
        enc = cfg.encoder_layers * (_attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d)
        dec = cfg.n_layers * (
            _attn_params(cfg) + _attn_params(cfg, cross=True) + _mlp_params(d, cfg.d_ff) + 3 * d
        )
        return n + enc + dec + d  # + enc_norm

    if cfg.family == "moe":
        per = _attn_params(cfg) + _moe_params(cfg, active_only) + 2 * d
        n += cfg.n_layers * per
        return n

    # dense
    n += cfg.n_layers * (_attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d)
    return n
