"""Mamba-2 SSD (state-space duality) blocks — chunked train path + O(1) decode.

The SSD algorithm (Dao & Gu 2024) evaluates the selective state-space
recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t        y_t = C_t h_t + D x_t

as chunked matmuls: within a chunk of Q tokens the output is a masked
(C B^T)-attention-like product (two MXU matmuls); across chunks the state is
carried by an associative scan over (decay, state) pairs.  This is the
MXU-native formulation — the reason mamba2 maps well to TPU — and the decode
path is a rank-1 state update with no KV cache, which is what makes
long_500k (524k context) feasible for the ssm/hybrid families.

Shapes: heads H = d_inner / ssm_head_dim (P = head dim), state N = ssm_state,
single B/C group (G=1, as in mamba2-130m).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import init_dense, rms_norm

Params = dict[str, Any]


def init_ssm(key, cfg, dtype) -> Params:
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n  # conv runs over [x, B, C]
    ks = jax.random.split(key, 4)
    return {
        # in_proj emits [z (din), x (din), B (N), C (N), dt (H)]
        "w_in": init_dense(ks[0], d, 2 * din + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),       # A = -exp(a_log)  (init -1)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus(-2) ~ 0.12
        "gate_norm": jnp.ones((din,), dtype),
        "w_out": init_dense(ks[2], din, d, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, L, C) with kernel (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b


def _split_proj(cfg, proj: jax.Array):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    xbc = proj[..., din : 2 * din + 2 * n]
    dt = proj[..., 2 * din + 2 * n :]
    return z, xbc, dt


def ssm_block(p: Params, x_in: jax.Array, cfg) -> jax.Array:
    """Train/prefill path: (B, L, D) -> (B, L, D) via chunked SSD."""
    bsz, l, _ = x_in.shape
    din, n, h, pdim, q = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_chunk
    proj = x_in @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x = xbc[..., :din].reshape(bsz, l, h, pdim)
    bmat = xbc[..., din : din + n]          # (B, L, N)
    cmat = xbc[..., din + n :]              # (B, L, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    a = -jnp.exp(p["a_log"])                # (H,)

    assert l % q == 0, (l, q)
    nc = l // q
    xc = x.reshape(bsz, nc, q, h, pdim).astype(jnp.float32)
    bc = bmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h)

    da = dtc * a  # (B, NC, Q, H)
    cum = jnp.cumsum(da, axis=2)

    # --- intra-chunk (diagonal blocks): masked CB^T attention ---------------
    # decay L[q1, q2] = exp(cum[q1] - cum[q2]) for q1 >= q2.  Because cum is
    # monotonically decreasing (dt > 0, A < 0), the decay FACTORS stably:
    #   exp(cum_q - cum_k) = exp(cum_q - m) * exp(m - cum_k),  m = cum[-1]
    # with both factors bounded by exp(|chunk decay range|).  Folding the
    # factors into C and (dt*B) turns the former (B,NC,Q,Q,H) broadcast
    # chain into one MXU matmul — §Perf iteration "ssd-factor": memory term
    # of the ssm/hybrid train cells drops ~2.5x.
    m = cum[:, :, -1:, :]                                   # (B,NC,1,H)
    cph = cc[..., None] * jnp.exp(cum - m)[:, :, :, None, :]          # (B,NC,Q,N,H)
    bph = bc[..., None] * (jnp.exp(m - cum) * dtc)[:, :, :, None, :]  # (B,NC,K,N,H)
    scores = jnp.einsum("bcqnh,bcknh->bchqk", cph, bph)     # (B,NC,H,Q,Q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # --- chunk states --------------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,NC,Q,H)
    sstate = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchnp", dtc * decay_to_end, bc, xc
    )  # (B,NC,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)

    # --- inter-chunk recurrence: associative scan over (decay, state) -------
    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s2 + s1 * d2[..., None, None]

    dscan, sscan = jax.lax.associative_scan(
        combine, (chunk_decay, sstate), axis=1
    )
    # state entering chunk c = scanned state of chunk c-1 (zero for c=0)
    h_in = jnp.concatenate(
        [jnp.zeros_like(sscan[:, :1]), sscan[:, :-1]], axis=1
    )  # (B,NC,H,N,P)

    # --- off-diagonal contribution ------------------------------------------
    y_off = jnp.einsum(
        "bcqn,bchnp->bcqhp", cc, h_in
    ) * jnp.exp(cum)[..., None]

    y = (y_diag + y_off).reshape(bsz, l, h, pdim)
    y = y + xc.reshape(bsz, l, h, pdim) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, din).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["w_out"]


# ---------------------------------------------------------------------------
# Decode path: O(1) per token
# ---------------------------------------------------------------------------


def init_ssm_state(cfg, batch: int) -> Params:
    h, n, pdim = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * n
    conv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "h": jnp.zeros((batch, h, n, pdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), conv_dtype),
    }


def ssm_decode(p: Params, state: Params, x_in: jax.Array, cfg):
    """x_in (B, 1, D) -> (y (B, 1, D), new_state)."""
    bsz = x_in.shape[0]
    din, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_in @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)

    # conv ring: concat history (K-1) + current token, then dot with kernel
    hist = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :].astype(state["conv"].dtype)

    x = xbc1[..., :din].reshape(bsz, h, pdim).astype(jnp.float32)
    bvec = xbc1[:, 0, din : din + n].astype(jnp.float32)
    cvec = xbc1[:, 0, din + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])

    decay = jnp.exp(dt * a)  # (B,H)
    hs = state["h"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bvec, x
    )
    y = jnp.einsum("bn,bhnp->bhp", cvec, hs) + x * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, din).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["w_out"], {"h": hs, "conv": new_conv}
