"""Mixture-of-Experts FFN with capacity-bucketed dispatch (EP-shardable).

Dispatch is the compile-friendly scatter form: tokens are assigned a slot
(expert, position) by a cumulative-count over the top-k assignments and
scattered into a dense (E, capacity, D) buffer; expert FFNs run as one
batched einsum over the expert axis (sharded over the ``model`` mesh axis =
expert parallelism); results gather back with the router weights.  Tokens
beyond capacity are dropped (standard Switch/GShard semantics, capacity
factor configurable).

FactorBase tie-in: expert assignment counts are *sufficient statistics* — a
GROUP BY (expert) over the token stream.  They are computed with the count
manager's histogram kernel (``repro.kernels.ct_count``) and feed both the
load-balance auxiliary loss and the routing-telemetry the serving stack
exports.  This is the paper's count-manager service embedded in the LM stack
(DESIGN.md §4).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

import math

from ..kernels import ops
from ..parallel.constraints import act
from .layers import init_dense

Params = dict[str, Any]


def init_moe(key, cfg, dtype) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "w_router": init_dense(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) / math.sqrt(d)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, ff, d), jnp.float32) / math.sqrt(ff)).astype(dtype),
    }
    if cfg.moe_dense_residual:
        from .layers import init_mlp

        p["dense"] = init_mlp(ks[4], d, cfg.dense_ff or cfg.d_ff, dtype)
    return p


def moe_ffn(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, dict]:
    """x (B, S, D) -> (out (B, S, D), stats {aux_loss, expert_counts}).

    Shard-local dispatch (§Perf iteration "moe-local-dispatch"): tokens are
    regrouped as (G, T/G) with G = the mesh's data-parallel way count, slot
    positions are computed *within* each group (axis-1 cumsum stays local
    under GSPMD), and the dispatch buffer carries an explicit group axis
    sharded over dp: (E, G, cap_local, D) with E over 'model'.  Building the
    buffer then requires no cross-dp communication at all; the only
    collective left is the expert-shard gather at combine time — measured on
    phi3.5-moe train_4k this replaced 1.3 TB/device of buffer all-reduces
    with ~50 GB of gathers (see EXPERIMENTS.md §Perf).
    """
    from ..parallel.constraints import dp_size

    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = bsz * s
    g = math.gcd(dp_size(), t)  # dp groups (1 on a single device)
    tl = t // g
    xt = x.reshape(t, d)
    xg = act(x.reshape(g, tl, d), ("dp", None, None))

    logits = (xg.astype(jnp.float32) @ p["w_router"])  # (G,Tl,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G,Tl,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- slot assignment: position within (group, expert) -------------------
    ge_idx = gate_idx.reshape(g, tl * k)  # k-major per token
    onehot = jax.nn.one_hot(ge_idx, e, dtype=jnp.int32)  # (G, Tl*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot  # exclusive, group-local
    pos = jnp.take_along_axis(pos_in_e, ge_idx[..., None], axis=2)[..., 0]  # (G,Tl*k)

    cap_l = max(1, int(cfg.capacity_factor * tl * k / e))
    keep = pos < cap_l

    # --- sufficient statistics: GROUP BY expert (count manager kernel) ------
    counts = ops.ct_count(ge_idx.reshape(-1), e)   # (E,) pre-drop assignments
    kept_counts = ops.ct_count(jnp.where(keep, ge_idx, -1).reshape(-1), e)

    # --- dispatch + expert FFN + combine ------------------------------------
    # Two implementations:
    #  * shard_map (mesh present, divisible): every (dp=i, model=j) device
    #    scatters ONLY the assignments that target its local expert shard
    #    into a purely local (E/16, cap, D) buffer — zero dispatch
    #    communication — runs its local experts, and the combine is a single
    #    psum over 'model'.  This replaced 1.3 TB/device of GSPMD dispatch
    #    all-reduces on phi3.5-moe train_4k (§Perf iteration
    #    "moe-shardmap-dispatch"; the pure-GSPMD "group-local scatter"
    #    attempt was REFUTED — dynamic expert ids defeat locality proofs).
    #  * pure-jit fallback (no mesh / smoke tests): dense scatter as before.
    from ..parallel.constraints import _mesh

    mesh = _mesh()
    model_n = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1) if mesh else 1
    use_sm = (
        mesh is not None and model_n > 1 and e % model_n == 0 and g > 1
    )
    gv_flat = gate_vals.reshape(g, tl * k)
    safe_pos = jnp.where(keep, pos, 0)

    if use_sm:
        from jax.sharding import PartitionSpec as P

        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        e_l = e // model_n

        def local(w_gate, w_up, w_down, x_l, ge_l, pos_l, keep_l, gv_l):
            j = jax.lax.axis_index("model")
            x2 = x_l.reshape(-1, d)            # (Tl, D)
            ge2 = ge_l.reshape(-1)             # (Tl*k,)
            pos2 = pos_l.reshape(-1)
            keep2 = keep_l.reshape(-1) & (ge2 // e_l == j)
            le = jnp.where(keep2, ge2 - j * e_l, 0)
            sp = jnp.where(keep2, pos2, 0)
            src = jnp.repeat(x2, k, axis=0)
            contrib = jnp.where(keep2[:, None], src, 0)
            buf = jnp.zeros((e_l, cap_l, d), x2.dtype).at[le, sp].add(contrib)
            hh = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
                "ecd,edf->ecf", buf, w_up
            )
            ob = jnp.einsum("ecf,efd->ecd", hh, w_down)
            gathered = jnp.where(keep2[:, None], ob[le, sp], 0)
            wv = gv_l.reshape(-1)[:, None].astype(gathered.dtype)
            y = jnp.sum((gathered * wv).reshape(-1, k, d), axis=1)  # (Tl, D)
            y = jax.lax.psum(y, "model")
            return y[None]

        out = jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(
                P("model", None, None), P("model", None, None), P("model", None, None),
                P(dp_axes, None, None), P(dp_axes, None), P(dp_axes, None),
                P(dp_axes, None), P(dp_axes, None),
            ),
            out_specs=P(dp_axes, None, None),
        )(p["w_gate"], p["w_up"], p["w_down"], xg, ge_idx, safe_pos, keep, gv_flat)
        out = out.reshape(t, d)
    else:
        buf = jnp.zeros((e, g, cap_l, d), x.dtype)
        src = jnp.repeat(xg, k, axis=1)  # (G, Tl*k, D)
        gidx = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None], (g, tl * k))
        contrib = jnp.where(keep[..., None], src, 0)
        buf = buf.at[ge_idx, gidx, safe_pos].add(contrib)
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", buf, p["w_gate"])) * jnp.einsum(
            "egcd,edf->egcf", buf, p["w_up"]
        )
        out_buf = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
        gathered = out_buf[ge_idx, gidx, safe_pos]  # (G, Tl*k, D)
        gathered = jnp.where(keep[..., None], gathered, 0)
        w = gv_flat[..., None].astype(gathered.dtype)
        out = jnp.sum((gathered * w).reshape(g, tl, k, d), axis=2).reshape(t, d)

    # --- load-balance auxiliary loss (Switch-style) --------------------------
    frac_tokens = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
    frac_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_prob)

    if cfg.moe_dense_residual:
        from .layers import swiglu_mlp

        out = out + swiglu_mlp(p["dense"], xt).astype(out.dtype)

    stats = {"aux_loss": aux, "expert_counts": counts, "kept_counts": kept_counts}
    return out.reshape(bsz, s, d).astype(x.dtype), stats
