"""Model assembly: init / forward / loss / cache / decode for every family.

All deep stacks are ``lax.scan`` over stacked layer parameters (leading axis
= layer), which keeps the HLO compact (one traced block) — essential for the
512-partition dry-run compiles — and gives remat a natural boundary: the
scan body is wrapped in ``jax.checkpoint`` for the train path.

Families:
  dense / moe      — homogeneous decoder-only scan (GQA + SwiGLU or MoE FFN)
  ssm              — mamba2 SSD blocks (no attention)
  hybrid (hymba)   — parallel attn+SSM blocks; 3 global-attention layers at
                     {0, mid, last} kept *outside* the scan so the SWA
                     segments have a static window (and tiny decode caches)
  vlm (llama-3.2v) — superblock scan: k self layers + 1 cross-attn layer
                     attending to stub patch embeddings
  audio (whisper)  — encoder scan (non-causal) + decoder scan (self + cross),
                     stub frame embeddings

Decode paths carry explicit caches as pytrees: dense KV ring buffers
(window-bounded for SWA), SSM states, cross-attention KV precomputed once.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (
    attention,
    attn_block,
    attn_project_qkv,
    cross_attn_block,
    init_attn,
    init_dense,
    init_mlp,
    rms_norm,
    swiglu_mlp,
)
from .moe import init_moe, moe_ffn
from .ssm import init_ssm, ssm_block

Params = dict[str, Any]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Layer init (one layer; stacks built with vmap over keys)
# ---------------------------------------------------------------------------


def _init_layer(key, cfg, kind: str) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    p: Params = {"ln1": jnp.ones((d,), dt)}
    if kind == "dense":
        p["attn"] = init_attn(ks[0], cfg, dt, out_scale=out_scale)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dt, out_scale=out_scale)
        p["ln2"] = jnp.ones((d,), dt)
    elif kind == "moe":
        p["attn"] = init_attn(ks[0], cfg, dt, out_scale=out_scale)
        p["moe"] = init_moe(ks[1], cfg, dt)
        p["ln2"] = jnp.ones((d,), dt)
    elif kind == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg, dt)
    elif kind == "hybrid":
        p["attn"] = init_attn(ks[0], cfg, dt, out_scale=out_scale)
        p["ssm"] = init_ssm(ks[1], cfg, dt)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, dt, out_scale=out_scale)
        p["ln2"] = jnp.ones((d,), dt)
        p["attn_norm"] = jnp.ones((d,), dt)
        p["ssm_norm"] = jnp.ones((d,), dt)
    elif kind == "cross":
        p["attn"] = init_attn(ks[0], cfg, dt, out_scale=out_scale)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dt, out_scale=out_scale)
        p["ln2"] = jnp.ones((d,), dt)
    elif kind == "encdec_dec":
        p["attn"] = init_attn(ks[0], cfg, dt, out_scale=out_scale)
        p["cross"] = init_attn(ks[1], cfg, dt, out_scale=out_scale)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, dt, out_scale=out_scale)
        p["ln2"] = jnp.ones((d,), dt)
        p["ln3"] = jnp.ones((d,), dt)
    else:
        raise ValueError(kind)
    return p


def _stack_layers(key, cfg, kind: str, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, kind))(keys)


# ---------------------------------------------------------------------------
# Blocks (single layer application)
# ---------------------------------------------------------------------------


def _dense_block(lp: Params, x, cfg, positions, *, window: int = 0):
    h = x + attn_block(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, positions, window=window)
    return h + swiglu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))


def _moe_block(lp: Params, x, cfg, positions):
    h = x + attn_block(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, positions)
    out, stats = moe_ffn(lp["moe"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
    return h + out, stats


def _ssm_block(lp: Params, x, cfg):
    return x + ssm_block(lp["ssm"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg)


def _hybrid_block(lp: Params, x, cfg, positions, *, window: int):
    """Hymba: attention heads and SSM heads in parallel on the same input."""
    xin = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a = attn_block(lp["attn"], xin, cfg, positions, window=window)
    s = ssm_block(lp["ssm"], xin, cfg)
    mixed = 0.5 * (
        rms_norm(a, lp["attn_norm"], cfg.norm_eps) + rms_norm(s, lp["ssm_norm"], cfg.norm_eps)
    )
    h = x + mixed
    return h + swiglu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))


def _cross_block(lp: Params, x, memory, cfg):
    h = x + cross_attn_block(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), memory, cfg)
    return h + swiglu_mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))


# ---------------------------------------------------------------------------
# init_params
# ---------------------------------------------------------------------------


def hymba_layout(cfg) -> tuple[int, int, int]:
    """(global indices are {0, mid, last}); returns (mid, len_seg_a, len_seg_b)."""
    mid = cfg.n_layers // 2
    return mid, mid - 1, cfg.n_layers - mid - 2


def init_params(cfg, key) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 8)
    vp = cfg.vocab_padded
    p: Params = {
        "embed": (jax.random.normal(keys[0], (vp, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(keys[1], cfg.d_model, vp, dt)

    if cfg.family == "ssm":
        p["layers"] = _stack_layers(keys[2], cfg, "ssm", cfg.n_layers)
    elif cfg.hybrid:
        mid, na, nb = hymba_layout(cfg)
        p["global_layers"] = _stack_layers(keys[2], cfg, "hybrid", 3)
        p["seg_a"] = _stack_layers(keys[3], cfg, "hybrid", na)
        p["seg_b"] = _stack_layers(keys[4], cfg, "hybrid", nb)
    elif cfg.family == "vlm":
        k = cfg.cross_attn_every
        nsb = cfg.n_layers // (k + 1)
        sb_keys = jax.random.split(keys[2], nsb)

        def init_sb(kk):
            k1, k2 = jax.random.split(kk)
            return {
                "self": jax.vmap(lambda q: _init_layer(q, cfg, "dense"))(jax.random.split(k1, k)),
                "cross": _init_layer(k2, cfg, "cross"),
            }

        p["superblocks"] = jax.vmap(init_sb)(sb_keys)
    elif cfg.is_encdec:
        p["encoder"] = _stack_layers(keys[2], cfg, "dense", cfg.encoder_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        p["layers"] = _stack_layers(keys[3], cfg, "encdec_dec", cfg.n_layers)
    elif cfg.family == "moe":
        p["layers"] = _stack_layers(keys[2], cfg, "moe", cfg.n_layers)
    else:
        p["layers"] = _stack_layers(keys[2], cfg, "dense", cfg.n_layers)
    return p


def count_params(params: Params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# forward (train / prefill): tokens -> logits
# ---------------------------------------------------------------------------


def _lm_head(p: Params, cfg, x) -> jax.Array:
    from ..parallel.constraints import act

    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        # mask pad-vocab logits out of the softmax
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    # vocab-sharded logits: the CE loss reduces over the sharded axis via
    # psum instead of all-gathering a (B,S,V) f32 monster (§Perf iter. 1)
    return act(logits, ("dp",) + (None,) * (logits.ndim - 2) + ("model",))


def forward(
    params: Params,
    cfg,
    tokens: jax.Array,
    *,
    memory: jax.Array | None = None,
    remat: bool = True,
) -> tuple[jax.Array, dict]:
    """tokens (B,S) [+ memory (B,M,D) for vlm/audio] -> (logits f32, stats)."""
    from ..parallel.constraints import act

    b, s = tokens.shape
    x = act(params["embed"][tokens], ("dp", None, None))
    positions = jnp.arange(s)[None, :]
    stats: dict = {}
    ck = functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else (lambda f: f)

    if cfg.family == "ssm":
        def body(h, lp):
            return _ssm_block(lp, h, cfg), None
        x, _ = jax.lax.scan(ck(body), x, params["layers"])

    elif cfg.hybrid:
        w = cfg.sliding_window
        gl = params["global_layers"]
        g = lambda i: jax.tree.map(lambda a: a[i], gl)

        def swa_body(h, lp):
            return _hybrid_block(lp, h, cfg, positions, window=w), None

        x = _hybrid_block(g(0), x, cfg, positions, window=0)
        x, _ = jax.lax.scan(ck(swa_body), x, params["seg_a"])
        x = _hybrid_block(g(1), x, cfg, positions, window=0)
        x, _ = jax.lax.scan(ck(swa_body), x, params["seg_b"])
        x = _hybrid_block(g(2), x, cfg, positions, window=0)

    elif cfg.family == "vlm":
        assert memory is not None, "vlm needs patch-embedding memory"
        k = cfg.cross_attn_every

        def sb_body(h, sb):
            for i in range(k):
                lp = jax.tree.map(lambda a: a[i], sb["self"])
                h = _dense_block(lp, h, cfg, positions)
            return _cross_block(sb["cross"], h, memory, cfg), None

        x, _ = jax.lax.scan(ck(sb_body), x, params["superblocks"])

    elif cfg.is_encdec:
        assert memory is not None, "enc-dec needs frame-embedding memory"
        m = memory.shape[1]
        mpos = jnp.arange(m)[None, :]

        def enc_body(h, lp):
            hh = h + attention(
                *attn_project_qkv(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, mpos),
                causal=False,
            ).reshape(h.shape[0], m, -1) @ lp["attn"]["wo"]
            return hh + swiglu_mlp(lp["mlp"], rms_norm(hh, lp["ln2"], cfg.norm_eps)), None

        enc, _ = jax.lax.scan(ck(enc_body), memory.astype(x.dtype), params["encoder"])
        enc = rms_norm(enc, params["enc_norm"], cfg.norm_eps)

        def dec_body(h, lp):
            hh = h + attn_block(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, positions)
            hh = hh + cross_attn_block(lp["cross"], rms_norm(hh, lp["ln3"], cfg.norm_eps), enc, cfg)
            return hh + swiglu_mlp(lp["mlp"], rms_norm(hh, lp["ln2"], cfg.norm_eps)), None

        x, _ = jax.lax.scan(ck(dec_body), x, params["layers"])

    elif cfg.family == "moe":
        def body(h, lp):
            out, st = _moe_block(lp, h, cfg, positions)
            return out, (st["aux_loss"], st["expert_counts"])
        x, (aux, counts) = jax.lax.scan(ck(body), x, params["layers"])
        stats["aux_loss"] = jnp.mean(aux)
        stats["expert_counts"] = counts  # (L, E) routing sufficient statistics

    else:
        def body(h, lp):
            return _dense_block(lp, h, cfg, positions), None
        x, _ = jax.lax.scan(ck(body), x, params["layers"])

    return _lm_head(params, cfg, x), stats


def loss_fn(params: Params, cfg, batch: dict, *, remat: bool = True):
    """Next-token cross entropy (+ MoE aux).  batch: tokens, labels [,memory]."""
    logits, stats = forward(params, cfg, batch["tokens"], memory=batch.get("memory"), remat=remat)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll
    if "aux_loss" in stats:
        loss = loss + 0.01 * stats["aux_loss"]
    metrics = {"nll": nll, **{k: v for k, v in stats.items() if k == "aux_loss"}}
    return loss, metrics
