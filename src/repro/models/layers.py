"""Core transformer layers: norms, RoPE, GQA attention (full/chunked/SWA),
SwiGLU MLP, cross-attention.  Pure functions over parameter pytrees.

Attention is implemented with a chunked-query streaming softmax so that no
(S x S) score matrix is ever materialized: per query chunk the scores are
(B, H, C, S) — this is what lets prefill_32k fit v5e HBM and is the pure-JAX
analogue of flash attention (the MXU does the two matmuls; XLA fuses the
masking).  A sliding-window variant slices only the in-window keys per chunk,
giving the O(S * W) cost that long_500k relies on (hymba).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..parallel.constraints import act

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Norms and embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,K,G,hd), k (B,Sk,K,hd) -> (B,K,G,Sq,Sk) float32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,K,G,Sq,Sk), v (B,Sk,K,hd) -> (B,Sq,K,G,hd).

    Probabilities are cast to the value dtype (bf16 on TPU) for the PV
    matmul — halves the attention working set; accumulation stays f32.
    """
    return jnp.einsum(
        "bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jax.Array:
    """GQA attention.  q (B,Sq,H,hd); k,v (B,Sk,K,hd); H % K == 0.

    Chunked over queries when Sq > chunk; with ``window`` only the in-window
    key slice is read per chunk (O(S*W) work).  ``q_offset`` is the absolute
    position of q[0] relative to k[0] (prefill continuation / decode).
    """
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kh, g, hd) * scale

    def full_path():
        scores = _gqa_scores(qg, k)
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(p, v).reshape(b, sq, h, hd).astype(q.dtype)

    if sq <= chunk:
        return full_path()

    if sq % chunk != 0:
        # pick the largest divisor of sq at most `chunk` (e.g. whisper's 1500
        # encoder frames -> 750); degenerate to the full path if none useful
        divs = [d for d in range(chunk, 0, -1) if sq % d == 0]
        chunk = divs[0] if divs else sq
        if chunk == sq or chunk < 128:
            return full_path()

    n_chunks = sq // chunk

    # Per-chunk bodies are fully rematerialized: without this, the scan VJP
    # stacks every chunk's (chunk x Sk) probabilities — the full S^2 f32
    # score matrix — as backward residuals (measured: 34 GB/device for
    # qwen3-4b train_4k; see EXPERIMENTS.md §Perf iteration 1).
    remat_body = functools.partial(
        jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable
    )

    if window > 0 and window + chunk < sk:
        # pad keys on the left so each chunk reads a static (window+chunk) slice
        span = window + chunk
        kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

        def win_body(_, ci):
            start = ci * chunk  # k-slice begins at (start - window) in unpadded coords
            qc = jax.lax.dynamic_slice_in_dim(qg, start, chunk, axis=1)
            kc = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            scores = _gqa_scores(qc, kc)
            qpos = q_offset + start + jnp.arange(chunk)[:, None]
            kpos = start - window + jnp.arange(span)[None, :]
            mask = (kpos >= 0) & (qpos - kpos < window)
            if causal:
                mask &= qpos >= kpos
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            p = jax.nn.softmax(scores, axis=-1)
            out = _gqa_out(p, vc).reshape(b, chunk, h, hd).astype(q.dtype)
            return None, out

        _, outs = jax.lax.scan(remat_body(win_body), None, jnp.arange(n_chunks))
        return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)

    def body(_, ci):
        start = ci * chunk
        qc = jax.lax.dynamic_slice_in_dim(qg, start, chunk, axis=1)
        scores = _gqa_scores(qc, k)  # (B,K,G,chunk,Sk)
        qpos = q_offset + start + jnp.arange(chunk)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = jnp.ones((chunk, sk), bool)
        if causal:
            mask = qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(p, v).reshape(b, chunk, h, hd).astype(q.dtype)
        return None, out

    _, outs = jax.lax.scan(remat_body(body), None, jnp.arange(n_chunks))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, valid_len
) -> jax.Array:
    """One-token decode: q (B,1,H,hd) against a (B,W,K,hd) cache.

    ``valid_len`` masks ring-buffer slots not yet written (scalar or (B,)).
    Keys are stored post-RoPE, so slot order inside the ring is irrelevant
    to the softmax (set membership is what matters).
    """
    b, _, h, hd = q.shape
    _, w, kh, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd) * (1.0 / math.sqrt(hd))
    scores = _gqa_scores(qg, k_cache)  # (B,K,G,1,W)
    slot = jnp.arange(w)[None, :]
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = jnp.broadcast_to(vl, (b,))
    mask = slot < vl[:, None]  # (B,W)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(p, v_cache).reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + qk-norm + rope) and MLP
# ---------------------------------------------------------------------------


def attn_project_qkv(p: Params, x: jax.Array, cfg, positions: jax.Array):
    """x (B,S,D) -> roped q (B,S,H,hd), k,v (B,S,K,hd)."""
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = act(q.reshape(b, s, h, hd), ("dp", None, "model", None))
    k = act(k.reshape(b, s, kh, hd), ("dp", None, "model", None))
    v = act(v.reshape(b, s, kh, hd), ("dp", None, "model", None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(
    p: Params, x: jax.Array, cfg, positions: jax.Array, *, window: int = 0
) -> jax.Array:
    q, k, v = attn_project_qkv(p, x, cfg, positions)
    o = attention(q, k, v, causal=True, window=window)
    o = act(o, ("dp", None, "model", None))
    out = o.reshape(x.shape[0], x.shape[1], -1) @ p["wo"]
    return act(out, ("dp", None, None))


def swiglu_mlp(p: Params, x: jax.Array) -> jax.Array:
    h = act(jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"]), ("dp", None, "model"))
    return act(h @ p["w_down"], ("dp", None, None))


def cross_attn_block(p: Params, x: jax.Array, memory: jax.Array, cfg) -> jax.Array:
    """Non-causal attention from x (B,S,D) into memory (B,M,D)."""
    b, s, _ = x.shape
    m = memory.shape[1]
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (memory @ p["wk"]).reshape(b, m, kh, hd)
    v = (memory @ p["wv"]).reshape(b, m, kh, hd)
    o = attention(q, k, v, causal=False)
    return o.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# Parameter initializers for the above
# ---------------------------------------------------------------------------


def init_attn(key, cfg, dtype, *, out_scale: float = 1.0) -> Params:
    ks = jax.random.split(key, 8)
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p: Params = {
        "wq": init_dense(ks[0], d, h * hd, dtype),
        "wk": init_dense(ks[1], d, kh * hd, dtype),
        "wv": init_dense(ks[2], d, kh * hd, dtype),
        "wo": init_dense(ks[3], h * hd, d, dtype, scale=out_scale / math.sqrt(h * hd)),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kh * hd,), dtype)
        p["bv"] = jnp.zeros((kh * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_mlp(key, d: int, ff: int, dtype, *, out_scale: float = 1.0) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], d, ff, dtype),
        "w_up": init_dense(ks[1], d, ff, dtype),
        "w_down": init_dense(ks[2], ff, d, dtype, scale=out_scale / math.sqrt(ff)),
    }
