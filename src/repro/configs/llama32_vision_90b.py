"""llama-3.2-vision-90b [vlm] — cross-attention image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-90B-Vision].  Cross-attn every 5th layer (20 cross
+ 80 self = 100).  The vision tower is a STUB: input_specs() supplies
precomputed patch embeddings (B, vision_tokens, d_model).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=5e5,
    cross_attn_every=4,   # 4 self layers then 1 cross layer per superblock
    vision_tokens=1600,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    cross_attn_every=4,
    vision_tokens=16,
)
