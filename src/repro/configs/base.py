"""Model/run configuration system.

One frozen dataclass describes every assigned architecture; per-arch modules
(``src/repro/configs/<id>.py``) export ``CONFIG`` (the exact assigned
configuration) and ``SMOKE`` (a reduced same-family config for CPU smoke
tests).  ``registry()`` resolves ``--arch <id>`` for the launcher, dry-run
and benchmarks.

Input shapes are a separate small registry (the assignment's four shapes),
with per-arch applicability rules (decode for decoder-bearing archs only;
long-context only for sub-quadratic attention families) — see
``cells()`` which enumerates the (arch x shape) dry-run matrix.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False       # qwen3: RMSNorm on per-head q/k
    attn_bias: bool = False     # qwen1.5: bias on QKV projections
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    dense_ff: int = 0

    # SSM (mamba2 SSD) — also used by the hybrid family
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_n_groups: int = 1

    # hybrid (hymba): parallel attention + SSM heads in each block
    hybrid: bool = False

    # attention windowing (hymba SWA; enables long-context decode)
    sliding_window: int = 0      # 0 = full attention
    n_global_layers: int = 0     # hymba: first/middle/last layers stay global

    # vlm (llama-3.2-vision): cross-attention to precomputed patch embeddings
    cross_attn_every: int = 0    # a cross-attn layer every k-th layer
    vision_tokens: int = 0

    # audio enc-dec (whisper): encoder self-attn stack + decoder cross-attn;
    # the conv/mel frontend is a stub — input_specs provides frame embeddings
    encoder_layers: int = 0
    audio_frames: int = 0

    # numerics
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/lm_head can
        shard over a 16-way model axis (odd vocabs like minicpm's 122753
        otherwise replicate a GB-scale matrix on every device).  Logits in
        the pad region are masked to -inf; tokens never index pad rows."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context?  (ssm state or SWA)."""
        return self.family == "ssm" or (self.hybrid and self.sliding_window > 0)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks); used for 6ND."""
        from ..models.params import count_params_analytic

        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from ..models.params import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

def pad_heads(cfg: ModelConfig, multiple: int = 16) -> ModelConfig:
    """Pad MHA head counts up to ``multiple`` so attention shards over the
    model axis instead of replicating (qwen1.5's 40 heads and minicpm's 36
    otherwise put the FULL (B,H,S,S) score tensor on every device — the
    measured cause of their memory-bound roofline cells; §Perf iteration
    "pad-heads").  Padded head weights are regular parameters initialized
    like the rest; zero-initialized output rows make them exact no-ops at
    step 0 and they train as ordinary capacity afterwards.  Only applies to
    MHA (n_heads == n_kv_heads); GQA group structure is never altered.
    """
    if cfg.n_heads != cfg.n_kv_heads or cfg.n_heads % multiple == 0 or cfg.n_heads == 0:
        return cfg
    hp = -(-cfg.n_heads // multiple) * multiple
    return dataclasses.replace(
        cfg, n_heads=hp, n_kv_heads=hp, head_dim=cfg.hd,
        name=cfg.name + f"+padheads{hp}",
    )


ARCH_IDS = (
    "mamba2_130m",
    "llama32_vision_90b",
    "hymba_1_5b",
    "qwen3_4b",
    "granite_8b",
    "qwen15_32b",
    "minicpm_2b",
    "whisper_medium",
    "phi35_moe",
    "arctic_480b",
)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def registry() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 524k decode needs sub-quadratic attention"
    return True, ""


def cells() -> list[tuple[str, str, bool, str]]:
    """The 40-cell (arch x shape) matrix: (arch, shape, runs, skip_reason)."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            runs, why = shape_applicable(cfg, s)
            out.append((a, s.name, runs, why))
    return out
