"""qwen3-4b [dense] — qk_norm + GQA.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936 head_dim=128
[hf:Qwen/Qwen3-4B].
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab=256,
    qk_norm=True,
)
