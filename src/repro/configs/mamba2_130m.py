"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060]
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=256,
    tie_embeddings=True,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
)
