"""qwen1.5-32b [dense] — QKV bias, MHA-style GQA (kv == heads).

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064
[hf:Qwen/Qwen1.5-32B]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    attn_bias=True,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen15-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab=256,
    attn_bias=True,
)
