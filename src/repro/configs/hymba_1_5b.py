"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16
[arXiv:2411.13676].  Sliding-window attention (1024) everywhere except the
3 global-attention layers at {first, middle, last} — which is what makes
long_500k feasible (O(W) attention + O(1) SSM state).
head_dim 64 (25 x 64 = 1600).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    hybrid=True,
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    sliding_window=1024,
    n_global_layers=3,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    hybrid=True,
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    ssm_state=8,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    sliding_window=32,
    n_global_layers=3,
)
