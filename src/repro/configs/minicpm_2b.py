"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753  [arXiv:2404.06395]
The WSD (warmup-stable-decay) schedule is in repro.training.schedules and
selected by this config's train recipe.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="minicpm-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=4,
    n_kv_heads=4,
    d_ff=144,
    vocab=256,
    tie_embeddings=True,
)
