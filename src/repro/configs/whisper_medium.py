"""whisper-medium [audio] — encoder-decoder with (stubbed) conv frontend.

24L d_model=1024 16H d_ff=4096 vocab=51865  [arXiv:2212.04356]
24 encoder + 24 decoder layers; the mel/conv frontend is a STUB:
input_specs() provides precomputed frame embeddings (B, 1500, d_model).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    audio_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    audio_frames=32,
)
