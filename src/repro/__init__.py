"""repro: FactorBase's SQL-driven multi-relational learning on JAX/Pallas.

Public surface (everything else is engine internals)::

    import repro

    model = repro.learn(db)                       # schema → counts → BN → CPTs
    repro.save_model(model, "model.npz")          # durable versioned artifact
    model = repro.load_model("model.npz")         # device-resident, no re-learn
    result = repro.predict(db, model, target)     # §VI block path, whole test set

    with repro.engine_config(kernel_impl="pallas", bucket_base=256):
        svc = repro.PredictService(db, model, target)   # micro-batched serving
        svc.warmup()
        probs = svc.predict([3, 14, 15]).probs

Attribute access is lazy (PEP 562): importing :mod:`repro` pulls in
nothing heavy, so launch scripts can still set ``XLA_FLAGS`` /
``REPRO_*`` environment variables *before* the first attribute touch
triggers the underlying ``jax`` import.
"""

from __future__ import annotations

__all__ = [
    "EngineConfig",
    "LearnedModel",
    "ModelStoreError",
    "PredictService",
    "PredictionResult",
    "current_config",
    "engine_config",
    "learn",
    "load_model",
    "predict",
    "save_model",
]

# attribute name -> submodule that defines it; resolved on first access
_EXPORTS = {
    "EngineConfig": "repro.core.config",
    "current_config": "repro.core.config",
    "engine_config": "repro.core.config",
    "LearnedModel": "repro.core.model_store",
    "ModelStoreError": "repro.core.model_store",
    "load_model": "repro.core.model_store",
    "save_model": "repro.core.model_store",
    "PredictionResult": "repro.core.predict",
    "learn": "repro.api",
    "predict": "repro.api",
    "PredictService": "repro.serving.predict_service",
}


def __getattr__(name: str):
    try:
        module_name = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module_name), name)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
