"""FactorBase quickstart: learn a Bayesian network for a whole database.

Reproduces the paper's running example end-to-end on the University
database of Figure 2:

  schema analyzer (VDB)  ->  count manager (CDB, Möbius virtual join)
  -> structure learning (learn-and-join)  ->  parameter manager (CPTs)
  -> model scores (AIC)  ->  block test-set prediction (§VI)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ScoreManager,
    learn_and_join,
    learn_parameters,
    predict_block,
    score_structure,
    university_db,
)


def main() -> None:
    db = university_db()
    print("== VDB: par-RVs discovered by the schema analyzer ==")
    for v in db.catalog.par_rvs:
        print(f"  {v.vid:35s} kind={v.kind:12s} domain={v.domain}")

    print("\n== CDB: joint contingency table (pre-counting) ==")
    cache = ScoreManager(db, mode="precount")
    jt = cache.joint
    print(f"  par-RVs={len(jt.rvs)} cells={jt.n_cells} "
          f"sufficient statistics (nonzero)={jt.n_nonzero()} total={float(jt.total()):.0f}")

    print("\n== Structure learning (learn-and-join, AIC, batched scoring) ==")
    res = learn_and_join(db, cache, score="aic", max_parents=2, max_chain=1)
    for p, c in res.bn.edges():
        print(f"  {p}  ->  {c}")
    print(f"  lattice nodes={res.n_lattice_nodes} families scored={res.n_candidates_scored} "
          f"in {res.seconds:.2f}s ({cache.n_score_batches} set-oriented score batches)")

    print("\n== MDB: parameters + scores ==")
    factors = learn_parameters(res.bn, cache, alpha=0.0)
    scores = score_structure(res.bn, cache)
    print(f"  log-likelihood={scores.loglik:.3f}  #params={scores.n_params}  "
          f"AIC={scores.aic:.3f}")
    cap = factors["capability(prof0,student0)"]
    print(f"  CPT for capability(P,S): parents={cap.parents} table shape={cap.table.shape}")

    print("\n== §VI block prediction: P(intelligence(S) | rest) ==")
    target = "intelligence(student0)"
    pred = predict_block(db, res.bn, factors, target)
    true = np.asarray(db.entities["student"].attrs["intelligence"])
    print("  probs:")
    for i, row in enumerate(np.asarray(pred.probs)):
        print(f"   student {i}: {np.round(row, 3)}  (true code {true[i]})")
    print(f"  accuracy={pred.accuracy(jnp.asarray(true)):.3f}  "
          f"CLL={pred.conditional_loglik(jnp.asarray(true)):.3f}")

    serving_demo(db, res.bn, factors, target)
    mgr = sparse_device_demo(db)
    incremental_demo(mgr)


def serving_demo(db, bn, factors, target) -> None:
    """Durable store + micro-batched serving through the public facade.

    The learned model becomes one versioned artifact (``repro.save_model``)
    and is served from its reloaded copy: requests coalesce in the
    micro-batcher, ride the bucket ladder onto the same ``block_predict``
    programs the offline path uses, and come back *bitwise* equal to the
    single-instance oracle — with zero XLA compiles after warmup.
    """
    import os
    import tempfile

    import repro
    from repro.core.predict import predict_single_loop

    print("\n== Serving: save -> load -> micro-batched block prediction ==")
    model = repro.LearnedModel(schema=db.schema, bn=bn, factors=factors,
                               meta={"example": "quickstart"})
    oracle = predict_single_loop(db, bn, factors, target)
    with tempfile.TemporaryDirectory() as td:
        path = repro.save_model(model, os.path.join(td, "university.npz"))
        print(f"  artifact: {os.path.getsize(path)} bytes (schema + BN + CPTs)")
        loaded = repro.load_model(path)
    with repro.PredictService(db, loaded, target, flush_ms=1.0) as svc:
        warm = svc.warmup()
        futs = [svc.submit([i % svc.n_entities]) for i in range(12)]
        results = [f.result(timeout=30) for f in futs]
        exact = all(
            np.array_equal(r.probs, np.asarray(oracle.probs)[r.entity_ids])
            for r in results
        )
        st = svc.stats()
        print(f"  warmed {len(warm['rungs'])} rung(s); served {st['answered']} "
              f"requests in {st['batches']} micro-batches "
              f"(p50={st['p50_ms']:.1f} ms)")
        print(f"  bitwise == single-instance oracle: {exact}; "
              f"warm compiles: {st['warm_compiles']}")


def sparse_device_demo(db):
    """Device-resident sparse learn-and-join (the COO hot path).

    ``mode="sparse"`` pre-counts the joint CT as COO sufficient statistics
    (no dense-cell cap — the only mode that works past DENSE_CELL_BUDGET)
    and ``device_resident=True`` parks it on the device: every hill-climb
    sweep is then scored by ONE fused ``sparse_family_score`` launch
    (device sort + segment totals + the SUM(count * log cp) contraction)
    with no host sort and nothing but the per-family score row coming back.

    The University toy DB sits far below the measured host/device build
    crossover, so production routing (``REPRO_DEVICE_MIN_ROWS``) would
    quietly serve it from the host builder; the demo zeroes the threshold
    to show the device build itself.
    """
    from repro.core import DeviceSparseCT
    from repro.core.counts import set_device_min_rows
    from repro.kernels import ops

    print("\n== Device-resident sparse counting (COO joint on device) ==")
    ops.reset_launch_counts()
    ops.reset_transfer_counts()
    old_min_rows = set_device_min_rows(0)
    try:
        mgr = ScoreManager(db, mode="sparse", device_resident=True)
    finally:
        set_device_min_rows(old_min_rows)
    assert isinstance(mgr.joint, DeviceSparseCT)
    build_tr = ops.transfer_bytes()
    print(f"  joint: #SS={mgr.joint.n_nonzero()} of {mgr.joint.n_cells} dense cells, "
          f"codes dtype={mgr.joint.codes.dtype} on {list(mgr.joint.codes.devices())[0]}")
    print(f"  built ON device: {ops.total_launches()} launches, "
          f"h2d={build_tr['h2d']} B (no COO upload), "
          f"d2h={build_tr['d2h']} B (scalar size syncs)")

    ops.reset_launch_counts()
    ops.reset_transfer_counts()
    res = learn_and_join(db, mgr, score="aic", max_parents=2, max_chain=1)
    launches = ops.total_launches()
    transfers = ops.transfer_bytes()
    print(f"  learned {res.bn.n_edges} edges in {res.seconds:.2f}s: "
          f"{launches} fused launches over {res.n_sweeps} sweeps "
          f"({launches / max(res.n_sweeps, 1):.2f}/sweep), "
          f"d2h traffic {transfers['d2h']} bytes (score rows only)")
    return mgr


def incremental_demo(mgr) -> None:
    """Insert one relationship row, delta-apply, re-score — no rebuild.

    ``ScoreManager.apply_delta`` propagates a signed ΔCT through the join
    tree (cost proportional to the delta, not the database), merges it into
    the device-resident joint, and evicts only the families whose RV set
    touches the changed relationship; every other family keeps serving its
    memoized score.  A from-scratch joint rebuild is timed alongside for the
    latency ratio — on real-scale data (see ``benchmarks/bench_incremental``)
    the gap is orders of magnitude.
    """
    from repro.core.counts import joint_contingency_table, set_device_min_rows

    print("\n== Incremental maintenance: insert 1 RA row, O(Δ) re-score ==")
    # Pick a (prof, student) pair with no RA row yet: each pair grounds R
    # exactly once (true or false), so inserting an already-present pair
    # would be invalid data, not a delta.
    rel = mgr.db.relationships["RA"]
    decl = mgr.db.schema.relationship("RA")
    taken = {(int(i), int(j)) for i, j in zip(np.asarray(rel.fk1),
                                              np.asarray(rel.fk2))}
    n1 = mgr.db.entities[decl.entities[0]].n_rows
    n2 = mgr.db.entities[decl.entities[1]].n_rows
    i, j = next((i, j) for i in range(n1) for j in range(n2)
                if (i, j) not in taken)
    row = {"fk1": [i], "fk2": [j], "attrs": {a: [1] for a in rel.attrs}}
    old_min_rows = set_device_min_rows(0)
    try:
        _, rebuild_s = _timed(lambda: joint_contingency_table(
            mgr.db, impl="sparse", device_resident=True))
    finally:
        set_device_min_rows(old_min_rows)
    # Production routing: a 1-tuple delta sits far below
    # REPRO_DEVICE_MIN_ROWS, so the ΔCT is contracted on the host and only
    # the rung-padded merge into the device-resident joint runs on device.
    # Prime both signed halves (insert, then delete it again), then time a
    # warm insert — every device program is already compiled and cached.
    stats = mgr.apply_delta("RA", inserted_rows=row)
    mgr.apply_delta("RA", deleted_rows=[mgr.db.relationships["RA"].n_rows - 1])
    _, delta_s = _timed(lambda: mgr.apply_delta("RA", inserted_rows=row))
    print(f"  delta apply {delta_s * 1e3:.1f} ms vs full rebuild "
          f"{rebuild_s * 1e3:.1f} ms  ({rebuild_s / max(delta_s, 1e-9):.1f}x "
          f"on this toy DB; see benchmarks/bench_incremental for real scale)")
    print(f"  families re-scored={stats['n_dirty_families']} "
          f"preserved from memo={stats['n_preserved_families']}")
    res = learn_and_join(mgr.db, mgr, score="aic", max_parents=2, max_chain=1)
    print(f"  re-learned on the updated joint: {res.bn.n_edges} edges "
          f"in {res.seconds:.2f}s")


def _timed(fn):
    import time

    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


if __name__ == "__main__":
    main()
