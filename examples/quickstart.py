"""FactorBase quickstart: learn a Bayesian network for a whole database.

Reproduces the paper's running example end-to-end on the University
database of Figure 2:

  schema analyzer (VDB)  ->  count manager (CDB, Möbius virtual join)
  -> structure learning (learn-and-join)  ->  parameter manager (CPTs)
  -> model scores (AIC)  ->  block test-set prediction (§VI)

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ScoreManager,
    learn_and_join,
    learn_parameters,
    predict_block,
    score_structure,
    university_db,
)


def main() -> None:
    db = university_db()
    print("== VDB: par-RVs discovered by the schema analyzer ==")
    for v in db.catalog.par_rvs:
        print(f"  {v.vid:35s} kind={v.kind:12s} domain={v.domain}")

    print("\n== CDB: joint contingency table (pre-counting) ==")
    cache = ScoreManager(db, mode="precount")
    jt = cache.joint
    print(f"  par-RVs={len(jt.rvs)} cells={jt.n_cells} "
          f"sufficient statistics (nonzero)={jt.n_nonzero()} total={float(jt.total()):.0f}")

    print("\n== Structure learning (learn-and-join, AIC, batched scoring) ==")
    res = learn_and_join(db, cache, score="aic", max_parents=2, max_chain=1)
    for p, c in res.bn.edges():
        print(f"  {p}  ->  {c}")
    print(f"  lattice nodes={res.n_lattice_nodes} families scored={res.n_candidates_scored} "
          f"in {res.seconds:.2f}s ({cache.n_score_batches} set-oriented score batches)")

    print("\n== MDB: parameters + scores ==")
    factors = learn_parameters(res.bn, cache, alpha=0.0)
    scores = score_structure(res.bn, cache)
    print(f"  log-likelihood={scores.loglik:.3f}  #params={scores.n_params}  "
          f"AIC={scores.aic:.3f}")
    cap = factors["capability(prof0,student0)"]
    print(f"  CPT for capability(P,S): parents={cap.parents} table shape={cap.table.shape}")

    print("\n== §VI block prediction: P(intelligence(S) | rest) ==")
    target = "intelligence(student0)"
    pred = predict_block(db, res.bn, factors, target)
    true = np.asarray(db.entities["student"].attrs["intelligence"])
    print("  probs:")
    for i, row in enumerate(np.asarray(pred.probs)):
        print(f"   student {i}: {np.round(row, 3)}  (true code {true[i]})")
    print(f"  accuracy={pred.accuracy(jnp.asarray(true)):.3f}  "
          f"CLL={pred.conditional_loglik(jnp.asarray(true)):.3f}")

    sparse_device_demo(db)


def sparse_device_demo(db) -> None:
    """Device-resident sparse learn-and-join (the COO hot path).

    ``mode="sparse"`` pre-counts the joint CT as COO sufficient statistics
    (no dense-cell cap — the only mode that works past DENSE_CELL_BUDGET)
    and ``device_resident=True`` parks it on the device: every hill-climb
    sweep is then scored by ONE fused ``sparse_family_score`` launch
    (device sort + segment totals + the SUM(count * log cp) contraction)
    with no host sort and nothing but the per-family score row coming back.

    The University toy DB sits far below the measured host/device build
    crossover, so production routing (``REPRO_DEVICE_MIN_ROWS``) would
    quietly serve it from the host builder; the demo zeroes the threshold
    to show the device build itself.
    """
    from repro.core import DeviceSparseCT
    from repro.core.counts import set_device_min_rows
    from repro.kernels import ops

    print("\n== Device-resident sparse counting (COO joint on device) ==")
    ops.reset_launch_counts()
    ops.reset_transfer_counts()
    old_min_rows = set_device_min_rows(0)
    try:
        mgr = ScoreManager(db, mode="sparse", device_resident=True)
    finally:
        set_device_min_rows(old_min_rows)
    assert isinstance(mgr.joint, DeviceSparseCT)
    build_tr = ops.transfer_bytes()
    print(f"  joint: #SS={mgr.joint.n_nonzero()} of {mgr.joint.n_cells} dense cells, "
          f"codes dtype={mgr.joint.codes.dtype} on {list(mgr.joint.codes.devices())[0]}")
    print(f"  built ON device: {ops.total_launches()} launches, "
          f"h2d={build_tr['h2d']} B (no COO upload), "
          f"d2h={build_tr['d2h']} B (scalar size syncs)")

    ops.reset_launch_counts()
    ops.reset_transfer_counts()
    res = learn_and_join(db, mgr, score="aic", max_parents=2, max_chain=1)
    launches = ops.total_launches()
    transfers = ops.transfer_bytes()
    print(f"  learned {res.bn.n_edges} edges in {res.seconds:.2f}s: "
          f"{launches} fused launches over {res.n_sweeps} sweeps "
          f"({launches / max(res.n_sweeps, 1):.2f}/sweep), "
          f"d2h traffic {transfers['d2h']} bytes (score rows only)")


if __name__ == "__main__":
    main()
