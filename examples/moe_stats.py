"""MoE routing statistics via the FactorBase count manager.

The paper's thesis — sufficient statistics as first-class managed objects —
applied to the LM stack: expert-assignment counts are a GROUP BY (layer,
expert) over the token stream, computed inside the forward pass by the same
``ct_count`` kernel that builds contingency tables.  This demo runs the
phi3.5-moe smoke config, extracts the (layer, expert) count table, derives
the load-balance loss from it, and shows the count table *is* a FactorBase
contingency table (it round-trips through ContingencyTable and its marginal
GROUP BY API).

Run:  PYTHONPATH=src python examples/moe_stats.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.counts import ContingencyTable
from repro.models.transformer import forward, init_params


def main() -> None:
    cfg = get_config("phi35_moe", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (4, 128), 0, cfg.vocab)

    logits, stats = forward(params, cfg, tokens, remat=False)
    counts = stats["expert_counts"]  # (L, E) int32 — GROUP BY (layer, expert)
    print(f"expert-count sufficient statistics: shape {counts.shape}")
    print(np.asarray(counts))

    # the count table is a FactorBase contingency table over two par-RVs
    ct = ContingencyTable(("layer", "expert"), counts.astype(jnp.float32))
    per_expert = ct.marginal(("expert",))     # GROUP BY expert
    per_layer = ct.marginal(("layer",))       # GROUP BY layer
    print("per-expert totals:", np.asarray(per_expert.table).astype(int))
    print("tokens routed per layer:", np.asarray(per_layer.table).astype(int),
          f"(= batch*seq*top_k = {4*128*cfg.top_k})")

    frac = per_expert.table / per_expert.table.sum()
    e = cfg.n_experts
    print(f"load imbalance (E * sum f^2, 1.0 = uniform): "
          f"{float(e * jnp.sum(frac**2)):.3f}")
    print(f"aux loss from forward: {float(stats['aux_loss']):.4f}")
    assert int(per_layer.table[0]) == 4 * 128 * cfg.top_k


if __name__ == "__main__":
    main()
