"""Batched serving demo: prefill + continuous batched decode with KV caches.

Shows the serving substrate on CPU with a small dense model: per-sequence
positions (ring-buffer KV caches), batched single-token decode_step, and a
tiny continuous-batching scheduler that retires finished sequences and
admits queued requests into freed slots — the logic that the decode_32k
dry-run cells lower at production scale.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import init_params
from repro.serving.decode import decode_step, init_cache

CFG = ModelConfig(
    name="serve-demo-10m", family="dense", n_layers=4, d_model=192,
    n_heads=6, n_kv_heads=2, d_ff=512, vocab=4096, tie_embeddings=True,
)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4, help="concurrent batch slots")
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--context", type=int, default=128)
    a = p.parse_args()

    key = jax.random.PRNGKey(0)
    params = init_params(CFG, key)
    step = jax.jit(lambda pp, c, t: decode_step(pp, CFG, c, t))

    rng = np.random.default_rng(0)
    queue = [rng.integers(1, CFG.vocab, size=a.prompt_len).tolist()
             for _ in range(a.requests)]
    results: dict[int, list[int]] = {}

    cache = init_cache(CFG, a.slots, a.context)
    slot_req = [-1] * a.slots          # request id per slot (-1 = free)
    slot_remaining = [0] * a.slots
    slot_prompt: list[list[int]] = [[] for _ in range(a.slots)]
    next_req = 0
    tokens = jnp.zeros((a.slots, 1), jnp.int32)

    t0 = time.perf_counter()
    n_steps = 0
    while next_req < a.requests or any(r >= 0 for r in slot_req):
        # admit new requests into free slots (prefill = feeding the prompt
        # token-by-token through the same decode step; a production server
        # would use a separate chunked-prefill kernel)
        for s in range(a.slots):
            if slot_req[s] < 0 and next_req < a.requests:
                slot_req[s] = next_req
                slot_prompt[s] = list(queue[next_req])
                slot_remaining[s] = a.max_new
                results[next_req] = []
                # reset this slot's cache lane
                cache["pos"] = cache["pos"].at[s].set(0)
                cache["k"] = cache["k"].at[:, s].set(0)
                cache["v"] = cache["v"].at[:, s].set(0)
                next_req += 1

        # assemble this step's token per slot (prompt feed or last sample)
        step_tok = np.zeros((a.slots, 1), np.int32)
        for s in range(a.slots):
            if slot_req[s] < 0:
                continue
            if slot_prompt[s]:
                step_tok[s, 0] = slot_prompt[s].pop(0)
            else:
                step_tok[s, 0] = results[slot_req[s]][-1]
        logits, cache = step(params, cache, jnp.asarray(step_tok))
        n_steps += 1
        sampled = np.asarray(jnp.argmax(logits, axis=-1))

        for s in range(a.slots):
            if slot_req[s] < 0:
                continue
            if not slot_prompt[s]:  # past prefill: collect a generated token
                results[slot_req[s]].append(int(sampled[s]))
                slot_remaining[s] -= 1
                if slot_remaining[s] <= 0:
                    slot_req[s] = -1  # retire -> slot becomes admittable

    dt = time.perf_counter() - t0
    total_new = sum(len(v) for v in results.values())
    print(f"served {a.requests} requests on {a.slots} slots: "
          f"{total_new} tokens in {n_steps} batched steps, {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s)")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid][:12]}...")


if __name__ == "__main__":
    main()
