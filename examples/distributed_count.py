"""Distributed FactorBase: GROUP BY COUNT on a (fake) device mesh.

The paper's count manager pushed onto a data-parallel mesh via shard_map:
relationship rows are sharded across devices, each device histograms its
shard with the count-manager kernel, and a psum yields the global
contingency table — validated cell-exactly against the single-device
Möbius pipeline.  Block prediction shards the *test entities* instead
(zero collectives).

Run:  PYTHONPATH=src python examples/distributed_count.py
(uses XLA_FLAGS to fake an 8-device host; the same shard_map code lowers
for the 512-chip production mesh in the dry-run)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.core.counts import contingency_table
from repro.core.distributed import sharded_block_predict, single_rel_ct_sharded
from repro.data.relational import MOVIELENS, generate
from repro.launch.mesh import make_mesh_from_shape


def main() -> None:
    mesh = make_mesh_from_shape((4, 2))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"on {mesh.devices.size} devices")

    spec = MOVIELENS.scaled(0.05)
    db = generate(spec, seed=11)
    print(f"database: {spec.name} with {db.total_tuples} tuples "
          f"({db.relationships['rated'].n_rows} facts)")

    rvs = ("rated(user0,movie0)", "rating(user0,movie0)", "age(user0)",
           "genre(movie0)")
    t0 = time.perf_counter()
    ct_d = single_rel_ct_sharded(db, "rated", rvs, mesh)
    jax.block_until_ready(ct_d.table)
    t_d = time.perf_counter() - t0

    ct_s = contingency_table(db, rvs)
    same = np.allclose(np.asarray(ct_d.table), np.asarray(ct_s.table))
    print(f"distributed CT {ct_d.table.shape}: total={float(ct_d.table.sum()):.0f} "
          f"in {t_d:.3f}s; matches single-device pipeline: {same}")
    assert same

    # sharded block scoring: entities over the data axis
    rng = np.random.default_rng(0)
    counts = rng.random((512, 96)).astype(np.float32)
    log_cpt = rng.standard_normal((96, 3)).astype(np.float32)
    scores = sharded_block_predict(
        jax.numpy.asarray(counts), jax.numpy.asarray(log_cpt), mesh
    )
    ok = np.allclose(np.asarray(scores), counts @ log_cpt, atol=1e-4)
    print(f"sharded block prediction (512 entities x 3 classes): exact={ok}")
    assert ok


if __name__ == "__main__":
    main()
