"""End-to-end training driver: train a small LM for a few hundred steps.

Demonstrates the full substrate on CPU: config system, deterministic data
pipeline, jitted train step (AdamW + grad accumulation + remat), atomic
async checkpointing with resume, and the fault-tolerance path (optional
--inject-fault).  On real hardware the same driver takes --arch qwen3_4b
(or any assigned arch) and the production mesh.

Run (CPU, ~2-4 min):
  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 300 --resume   # continue
  PYTHONPATH=src python examples/train_lm.py --preset 100m          # hardware
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig, get_config
from repro.data.pipeline import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer, TrainerConfig

# ~19M params: a real (if small) qwen3-family transformer; trains visibly
# on the synthetic Markov+motif stream within a few hundred CPU steps.
CPU_SMALL = ModelConfig(
    name="cpu-small-20m", family="dense", n_layers=4, d_model=256,
    n_heads=8, n_kv_heads=4, d_ff=1024, vocab=32768, qk_norm=True,
    tie_embeddings=True,
)

PRESETS = {"cpu-small": CPU_SMALL}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--preset", default="cpu-small",
                   help="cpu-small | 100m | any --arch id")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    p.add_argument("--resume", action="store_true")
    p.add_argument("--inject-fault", type=int, default=-1,
                   help="raise a fake node failure at this step once")
    a = p.parse_args()

    if a.preset in PRESETS:
        cfg = PRESETS[a.preset]
    elif a.preset == "100m":
        cfg = dataclasses.replace(
            CPU_SMALL, name="repro-100m", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=50304,
        )
    else:
        cfg = get_config(a.preset, smoke=False)
    print(f"model: {cfg.name}  params={cfg.n_params()/1e6:.1f}M")

    data = DataConfig(vocab=cfg.vocab, seq_len=a.seq_len, global_batch=a.batch)
    tc = TrainerConfig(
        steps=a.steps, ckpt_every=max(a.steps // 10, 1), log_every=10,
        ckpt_dir=a.ckpt_dir, accum_steps=a.accum,
        schedule="cosine", warmup=max(a.steps // 20, 1),
        opt=AdamWConfig(lr=a.lr),
    )

    faults = {a.inject_fault} if a.inject_fault >= 0 else set()

    def fault_hook(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError(f"injected failure at step {step}")

    tr = Trainer(cfg, data, tc, fault_hook=fault_hook if faults else None)
    res = tr.run(resume=a.resume)
    n = len(res.losses)
    print(f"\nfinished step {res.final_step}: loss {res.losses[0]:.4f} -> "
          f"{res.losses[-1]:.4f} (min {min(res.losses):.4f}) "
          f"restarts={res.restarts} wall={res.seconds:.1f}s")
    assert res.losses[-1] < res.losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
