#!/usr/bin/env python
"""Markdown link checker for the repo docs (CI docs job).

Walks the given markdown files/directories, extracts inline links and
images ``[text](target)``, and verifies that every *relative* target
resolves to an existing file or directory (anchors are stripped; external
``http(s)``/``mailto`` links are not fetched — this guards against moved or
renamed files, not the public internet).  Exits non-zero listing every
broken link.  Stdlib-only so the CI docs job needs no installs.

**Absolute paths are warn-only.**  A target starting with ``/`` points
outside the repository checkout (e.g. a ``/root/...`` scratch directory on
the authoring machine) and cannot be expected to exist on a CI runner or
another clone — the checker prints a warning naming each one instead of
failing, so docs can reference optional external material without breaking
the gate.  Prefer qualifying such references as external/optional in prose.

Usage: python tools/check_links.py README.md ROADMAP.md docs
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

#: inline links/images: [text](target "title") — target up to space or ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)>\s]+)>?(?:\s+\"[^\"]*\")?\s*\)")

_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def links_of(md: Path) -> list[str]:
    # drop fenced code blocks so example snippets can't trip the checker
    text = re.sub(r"```.*?```", "", md.read_text(), flags=re.S)
    return _LINK.findall(text)


def check(paths: list[str]) -> int:
    files: list[Path] = []
    for p in map(Path, paths):
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
        else:
            print(f"warning: skipping non-markdown arg {p}", file=sys.stderr)

    broken: list[tuple[Path, str]] = []
    absolute: list[tuple[Path, str]] = []
    n_checked = 0
    for md in files:
        for target in links_of(md):
            if target.startswith(_SKIP_PREFIXES):
                continue
            n_checked += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if rel.startswith("/"):
                # out-of-repo path: unverifiable on other machines/CI —
                # warn, never fail (see module docstring)
                absolute.append((md, target))
                continue
            if not (md.parent / rel).exists():
                broken.append((md, target))

    for md, target in absolute:
        print(
            f"WARNING: absolute out-of-repo path (not checked): "
            f"{md}: ({target})",
            file=sys.stderr,
        )
    for md, target in broken:
        print(f"BROKEN LINK: {md}: ({target})", file=sys.stderr)
    print(
        f"checked {n_checked} relative links in {len(files)} markdown files; "
        f"{len(broken)} broken, {len(absolute)} absolute (warn-only)"
    )
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(check(sys.argv[1:] or ["README.md", "ROADMAP.md", "docs"]))
