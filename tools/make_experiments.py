"""Regenerate the dry-run / roofline tables of EXPERIMENTS.md from the JSON
cache.  Usage: PYTHONPATH=src python tools/make_experiments.py [--print]
(prints markdown to stdout; EXPERIMENTS.md embeds the output manually with
commentary around it)."""

from __future__ import annotations

import glob
import json
from pathlib import Path

DRYRUN = Path("results/dryrun")

ARCHS = (
    "mamba2_130m", "llama32_vision_90b", "hymba_1_5b", "qwen3_4b",
    "granite_8b", "qwen15_32b", "minicpm_2b", "whisper_medium",
    "phi35_moe", "arctic_480b",
)
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def recs():
    out = {}
    for f in glob.glob(str(DRYRUN / "*.json")):
        r = json.loads(Path(f).read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def dryrun_table(r):
    lines = [
        "| arch | shape | single-pod (256) | multi-pod (512) | HBM GB/dev | collective schedule (single-pod, GB/dev) |",
        "|---|---|---|---|---|---|",
    ]
    for a in ARCHS:
        for s in SHAPES:
            rs = r.get((a, s, "single"))
            rm = r.get((a, s, "multi"))
            if rs is None:
                continue
            if rs["status"] == "skip":
                lines.append(f"| {a} | {s} | skip | skip | — | {rs['reason'][:58]} |")
                continue

            def cell(x):
                if x is None:
                    return "—"
                if x["status"] == "ok":
                    return f"ok ({x['compile_s']:.0f}s)"
                return x["status"].upper()

            mem = rs.get("memory_analysis", {}).get("peak_bytes_est", 0) / 1e9 \
                if rs["status"] == "ok" else 0
            coll = rs.get("collectives", {}) if rs["status"] == "ok" else {}
            coll_s = " ".join(f"{k.replace('all-','A').replace('reduce-scatter','RS').replace('collective-permute','CP')}:{v/1e9:.1f}"
                              for k, v in sorted(coll.items(), key=lambda kv: -kv[1]))
            lines.append(f"| {a} | {s} | {cell(rs)} | {cell(rm)} | {mem:.1f} | {coll_s} |")
    return "\n".join(lines)


def roofline_table(r):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | MODEL_FLOPS | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCHS:
        for s in SHAPES:
            x = r.get((a, s, "single"))
            if x is None or x["status"] == "skip":
                continue
            if x["status"] != "ok":
                lines.append(f"| {a} | {s} | ERROR | | | | | | |")
                continue
            rf = x["roofline"]
            lines.append(
                f"| {a} | {s} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
                f"{rf['collective_s']:.3f} | **{rf['bottleneck']}** | "
                f"{rf['model_flops']:.3g} | {rf['useful_ratio']:.3f} | "
                f"{rf['roofline_fraction']:.4f} |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    r = recs()
    n_ok = sum(1 for x in r.values() if x["status"] == "ok")
    n_skip = sum(1 for x in r.values() if x["status"] == "skip")
    n_err = sum(1 for x in r.values() if x["status"] not in ("ok", "skip"))
    print(f"<!-- cells: ok={n_ok} skip={n_skip} err={n_err} -->\n")
    print("### Dry-run matrix\n")
    print(dryrun_table(r))
    print("\n### Roofline (single-pod, per-device terms)\n")
    print(roofline_table(r))
