#!/usr/bin/env python
"""CI lint: repro.core.config is the single owner of REPRO_* env reads.

Every engine knob resolves through the kwarg > context > setter > env >
default chain in ``src/repro/core/config.py``; a direct
``os.environ[...]`` / ``os.getenv(...)`` read of a ``REPRO_*`` variable
anywhere else would silently bypass ``engine_config()`` scoping and the
setter overrides.  This scanner walks the AST of every Python file under
``src/``, ``benchmarks/`` and ``tools/`` and fails on any such read
outside the allowlist.

Allowlisted:

  * ``src/repro/core/config.py`` — the owner.
  * ``src/repro/launch/`` — launcher scripts must read/alter the
    environment (``XLA_FLAGS``, dry-run device counts) *before* the first
    ``jax`` import, ahead of any config machinery.
  * ``tests/`` is not scanned — tests legitimately set and read env vars
    through monkeypatch.

Stdlib-only on purpose: the CI lint job runs it without installing the
package.
"""

from __future__ import annotations

import ast
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "benchmarks", "tools")
ALLOW = (
    Path("src/repro/core/config.py"),
    Path("src/repro/launch"),
)


def _is_environ(node: ast.expr) -> bool:
    """``os.environ`` / ``environ`` (from-imported)."""
    if isinstance(node, ast.Attribute) and node.attr == "environ":
        return True
    return isinstance(node, ast.Name) and node.id == "environ"


def _repro_key(node: ast.expr) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        if node.value.startswith("REPRO_"):
            return node.value
    return None


def _violations(path: Path, tree: ast.AST) -> list[tuple[int, str]]:
    out = []
    for node in ast.walk(tree):
        # os.environ["REPRO_X"] / os.environ.get("REPRO_X", ...)
        if isinstance(node, ast.Subscript) and _is_environ(node.value):
            key = _repro_key(node.slice)
            if key:
                out.append((node.lineno, f"os.environ[{key!r}]"))
        if isinstance(node, ast.Call):
            fn = node.func
            # os.environ.get(...) / environ.get(...)
            if (
                isinstance(fn, ast.Attribute)
                and fn.attr in ("get", "pop", "setdefault")
                and _is_environ(fn.value)
                and node.args
            ):
                key = _repro_key(node.args[0])
                if key:
                    out.append((node.lineno, f"os.environ.{fn.attr}({key!r})"))
            # os.getenv("REPRO_X") / getenv("REPRO_X")
            if (
                (isinstance(fn, ast.Attribute) and fn.attr == "getenv")
                or (isinstance(fn, ast.Name) and fn.id == "getenv")
            ) and node.args:
                key = _repro_key(node.args[0])
                if key:
                    out.append((node.lineno, f"getenv({key!r})"))
    return out


def main() -> int:
    failed = []
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            rel = path.relative_to(ROOT)
            if any(rel == a or a in rel.parents for a in ALLOW):
                continue
            try:
                tree = ast.parse(path.read_text(), filename=str(rel))
            except SyntaxError as e:
                failed.append((rel, e.lineno or 0, f"syntax error: {e.msg}"))
                continue
            for lineno, what in _violations(rel, tree):
                failed.append((rel, lineno, what))
    if failed:
        print(
            "REPRO_* environment reads outside repro.core.config "
            "(route them through config.resolve / engine_config):"
        )
        for rel, lineno, what in failed:
            print(f"  {rel}:{lineno}: {what}")
        return 1
    print("env-read lint OK: config.py owns every REPRO_* read")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
