#!/usr/bin/env python
"""Per-metric delta table between two BENCH_structure.json documents.

CI's bench-trend job downloads the base branch's latest ``BENCH_structure``
artifact and this run's one, then pipes this tool's markdown into
``$GITHUB_STEP_SUMMARY`` so every PR shows how the structure-search
metrics moved.  Regressions **warn, never fail**: wall-clock metrics that
regress by more than :data:`WALL_CLOCK_WARN_PCT` emit GitHub ``::warning``
annotations (runner-to-runner noise makes a hard gate unfair; the compile
budget and equivalence flags are the hard gates, in ``benchmarks/run.py``).

Stdlib-only on purpose — the trend job runs it without installing the
package.

Usage: ``python tools/bench_diff.py base.json head.json``
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

#: Metrics shown in the delta table, in order: (key, label, lower_is_better).
METRICS = [
    ("cands_per_sec_batched", "candidates/sec (batched)", False),
    ("speedup", "batched vs serial speedup", False),
    ("sweep_ms_batched", "sweep ms (batched)", True),
    ("batched_launches", "launches (batched)", True),
    ("sparse_launches_per_sweep", "fused launches/sweep", True),
    ("compiles", "compiles (cold device leg)", True),
    ("compiles_warm", "compiles (warm device leg)", True),
    ("sparse_device_build_ms_warm", "device build ms (warm)", True),
    ("sparse_device_build_ms_cold", "device build ms (cold)", True),
    ("sparse_device_seconds", "device search s (warm)", True),
    ("sparse_device_speedup", "device vs host-sparse (warm)", False),
]

#: Scale-leg metrics (the ``bench_scale`` key: million-row synthetic star
#: schemas, host vs sharded-device sparse joint builds), same format.
SCALE_METRICS = [
    ("sparse_device_speedup", "device vs host build (warm)", False),
    ("host_build_ms", "host build ms", True),
    ("device_build_ms_warm", "device build ms (warm)", True),
    ("device_build_ms_cold", "device build ms (cold)", True),
    ("sharded2_build_ms", "sharded build ms (2 shards)", True),
    ("sharded4_build_ms", "sharded build ms (4 shards)", True),
    ("compiles", "compiles (cold build)", True),
]

#: Incremental-leg metrics (the ``bench_incremental`` key: O(Δ) signed-delta
#: maintenance of the device-resident joint vs warm full rebuilds).
INCREMENTAL_METRICS = [
    ("delta1_speedup", "delta apply vs rebuild (1 row)", False),
    ("delta1_apply_ms", "delta apply ms (1 row, warm)", True),
    ("delta100_apply_ms", "delta apply ms (100 rows, warm)", True),
    ("delta10000_apply_ms", "delta apply ms (10k rows, warm)", True),
    ("rebuild_warm_ms", "full rebuild ms (warm)", True),
    ("delta1_compiles_warm", "compiles (warm 1-row apply)", True),
    ("n_preserved_families", "score-memo families preserved", False),
]

#: Wall-clock metrics whose >25% regressions emit ::warning annotations.
WALL_CLOCK = {
    "sweep_ms_batched",
    "sparse_device_build_ms_warm",
    "sparse_device_seconds",
    "device_build_ms_warm",
    "delta1_apply_ms",
}
WALL_CLOCK_WARN_PCT = 25.0


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:,.3g}" if abs(v) < 1000 else f"{v:,.0f}"
    return str(v)


def _delta_pct(base, head) -> float | None:
    if base is None or head is None:
        return None
    try:
        base, head = float(base), float(head)
    except (TypeError, ValueError):
        return None
    if base == 0.0:
        return None
    return (head - base) / abs(base) * 100.0


def _section(base: dict, head: dict, group: str, metrics,
             lines: list[str], warnings: list[str]) -> int:
    """Append one group's per-entry delta tables; -> entries rendered."""
    # "_"-prefixed entries are run-level records (routing summaries), not
    # per-dataset metric dicts
    names = [
        n for n in head.get(group, {})
        if n in base.get(group, {}) and not n.startswith("_")
    ]
    for name in names:
        b, h = base[group][name], head[group][name]
        lines += [f"### {name}", "",
                  "| metric | base | head | delta |",
                  "|---|---:|---:|---:|"]
        for key, label, lower_better in metrics:
            bv, hv = b.get(key), h.get(key)
            if bv is None and hv is None:
                continue
            pct = _delta_pct(bv, hv)
            if pct is None:
                delta = "—"
            else:
                arrow = "" if abs(pct) < 1e-9 else (
                    # green direction depends on the metric's polarity
                    "🟢" if (pct < 0) == lower_better else "🔴"
                )
                delta = f"{pct:+.1f}% {arrow}".strip()
            lines.append(f"| {label} | {_fmt(bv)} | {_fmt(hv)} | {delta} |")
            if (
                key in WALL_CLOCK
                and pct is not None
                and pct > WALL_CLOCK_WARN_PCT
            ):
                warnings.append(
                    f"{name}: {label} regressed {pct:+.1f}% "
                    f"({_fmt(bv)} -> {_fmt(hv)})"
                )
        lines.append("")
    return len(names)


def diff_tables(base: dict, head: dict) -> tuple[str, list[str]]:
    """-> (markdown, warnings): the per-dataset delta tables + regressions."""
    lines: list[str] = ["## Bench trend: base vs this run", ""]
    warnings: list[str] = []
    n = _section(base, head, "datasets", METRICS, lines, warnings)
    n += _section(base, head, "bench_scale", SCALE_METRICS, lines, warnings)
    n += _section(
        base, head, "bench_incremental", INCREMENTAL_METRICS, lines, warnings
    )
    if not n:
        lines.append("_No overlapping datasets between base and head runs._")
        return "\n".join(lines) + "\n", warnings
    if warnings:
        lines += ["> ⚠️ wall-clock regressions over "
                  f"{WALL_CLOCK_WARN_PCT:.0f}% (warn-only):"]
        lines += [f"> - {w}" for w in warnings]
        lines.append("")
    return "\n".join(lines) + "\n", warnings


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("base", type=Path, help="base branch BENCH_structure.json")
    p.add_argument("head", type=Path, help="this run's BENCH_structure.json")
    a = p.parse_args(argv)
    base = json.loads(a.base.read_text())
    head = json.loads(a.head.read_text())
    markdown, warnings = diff_tables(base, head)
    print(markdown)
    for w in warnings:
        # GitHub annotation (shows on the workflow run); the job still passes
        print(f"::warning title=bench regression::{w}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
