#!/usr/bin/env python3
"""Replay one schema-fuzzer draw and greedily shrink the failing database.

``tests/test_schema_fuzz.py`` prints a ready-to-run invocation on every
failure::

    python tools/shrink_schema.py --seed 1234 --spec '{"n_entities": 2, ...}'

The tool regenerates the draw, confirms the differential-oracle divergence,
then exports the database to the declarative spec form
(``repro.data.ingest.export_spec``) and greedily deletes pieces — whole
relationship tables, attribute columns, then individual relationship rows —
re-running the oracles after each candidate deletion and keeping it only
while the divergence persists.  The minimized spec is printed (and written
with ``--out``) as a self-contained JSON reproducer: feed it back through
``repro.data.ingest.ingest_database`` in a regression test.
"""

from __future__ import annotations

import argparse
import copy
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # for tests.bruteforce

import numpy as np  # noqa: E402

from repro.core import counts  # noqa: E402
from repro.data.ingest import export_spec, ingest_database  # noqa: E402
from repro.data.schema_gen import SchemaSpec, generate_database  # noqa: E402
from tests.bruteforce import as_dense_array, brute_force_ct  # noqa: E402


def diverges(spec: dict) -> bool:
    """True when any static differential oracle fails on ``spec``'s db."""
    try:
        db = ingest_database(spec)
        rvs = tuple(v.vid for v in db.catalog.par_rvs)
        host = counts.contingency_table(db, rvs, impl="sparse")
        bf = brute_force_ct(db, rvs)
        np.testing.assert_array_equal(as_dense_array(host).astype(np.int64), bf)
        dense = counts.contingency_table(db, rvs, impl="ref")
        np.testing.assert_array_equal(as_dense_array(dense), as_dense_array(host))
        dev = counts.contingency_table(db, rvs, impl="sparse", device_resident=True)
        np.testing.assert_array_equal(dev.to_host().codes, host.codes)
        np.testing.assert_array_equal(dev.to_host().counts, host.counts)
    except Exception:  # noqa: BLE001 — any crash/mismatch counts as divergence
        return True
    return False


def _candidates(spec: dict):
    """Yield (description, shrunken-copy) candidates, coarsest first."""
    tables = spec["tables"]
    rel_names = [n for n, d in tables.items() if d.get("foreign_keys")]
    # 1) drop a whole relationship table
    for name in rel_names:
        out = copy.deepcopy(spec)
        del out["tables"][name]
        yield f"drop relationship {name!r}", out
    # 2) drop one attribute column (entity attrs need the entity to survive
    #    attribute-less, which the spec form supports via n_rows)
    for name, decl in tables.items():
        for col in decl.get("columns", {}):
            out = copy.deepcopy(spec)
            odecl = out["tables"][name]
            del odecl["columns"][col]
            rows = odecl.get("rows", {})
            n = len(rows.get(col, []))
            rows.pop(col, None)
            if not decl.get("foreign_keys") and not odecl["columns"]:
                odecl.pop("rows", None)
                odecl["n_rows"] = n
            yield f"drop column {name}.{col}", out
    # 3) drop one relationship row
    for name in rel_names:
        rows = tables[name].get("rows", {})
        n = len(rows.get("fk1", []))
        for i in range(n):
            out = copy.deepcopy(spec)
            orows = out["tables"][name]["rows"]
            for col, vals in orows.items():
                del vals[i]
            yield f"drop row {i} of {name!r}", out


def shrink(spec: dict) -> dict:
    """Greedy fixed-point deletion: keep any shrink that still diverges."""
    progress = True
    while progress:
        progress = False
        for desc, cand in _candidates(spec):
            if diverges(cand):
                print(f"  kept shrink: {desc}")
                spec = cand
                progress = True
                break
    return spec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, required=True,
                    help="generator seed of the failing draw")
    ap.add_argument("--spec", default="{}",
                    help="JSON of the SchemaSpec fields (from the failure note)")
    ap.add_argument("--out", default="",
                    help="write the minimized spec JSON here")
    args = ap.parse_args()

    counts.set_device_min_rows(0)  # fuzz draws are tiny; force the device path
    spec = SchemaSpec(**json.loads(args.spec))
    print(f"replaying seed={args.seed} {spec!r}")
    db = generate_database(spec, args.seed)
    full = export_spec(db)
    if not diverges(full):
        print("draw passes every static oracle — nothing to shrink "
              "(was the failure in the sharded or delta oracle? those need "
              "the full test, not this tool)")
        return 1

    print("divergence confirmed; shrinking...")
    minimal = shrink(full)
    blob = json.dumps(minimal, indent=1)
    print("\nminimal reproducer spec:\n" + blob)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(blob + "\n")
        print(f"\nwritten to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
