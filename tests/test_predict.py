"""§VI prediction: block == single-instance loop, proper probabilities,
CLL/accuracy metrics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cpt import learn_parameters
from repro.core.database import university_db
from repro.core.predict import predict_block, predict_single_loop
from repro.core.structure import CountCache, learn_and_join

from .bruteforce import random_db


def _learned(db):
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(db, cache, score="aic", max_parents=2, max_chain=1, impl="ref")
    return res.bn, learn_parameters(res.bn, cache, alpha=0.1, impl="ref")


def test_block_equals_single_university():
    db = university_db()
    bn, factors = _learned(db)
    for target in ("intelligence(student0)", "popularity(prof0)"):
        pb = predict_block(db, bn, factors, target, impl="ref")
        ps = predict_single_loop(db, bn, factors, target, impl="ref")
        np.testing.assert_allclose(
            np.asarray(pb.log_scores), np.asarray(ps.log_scores), atol=1e-4
        )
        np.testing.assert_allclose(np.asarray(pb.probs).sum(1), 1.0, rtol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_block_equals_single_random(seed):
    db = random_db(seed, n_entities=(3, 3), n_rel_rows=4)
    bn, factors = _learned(db)
    target = "a1(alpha0)"
    pb = predict_block(db, bn, factors, target, impl="ref")
    ps = predict_single_loop(db, bn, factors, target, impl="ref")
    np.testing.assert_allclose(
        np.asarray(pb.log_scores), np.asarray(ps.log_scores), atol=1e-3
    )


def test_metrics():
    db = university_db()
    bn, factors = _learned(db)
    pred = predict_block(db, bn, factors, "intelligence(student0)", impl="ref")
    true = jnp.asarray(np.asarray(db.entities["student"].attrs["intelligence"]))
    acc = pred.accuracy(true)
    cll = pred.conditional_loglik(true)
    assert 0.0 <= acc <= 1.0
    assert cll <= 0.0
