"""Declarative schema ingestion (`data/ingest.py`) + the seeded generator
(`data/schema_gen.py`): spec validation fail-louds, database round-trips,
and draw determinism — the input side of the schema contract
(docs/ARCHITECTURE.md)."""

import json

import numpy as np
import pytest

from repro.data.ingest import (
    SchemaSpecError,
    export_spec,
    ingest_database,
    ingest_schema,
    load_spec,
)
from repro.data.schema_gen import SPEC_CORPUS, SchemaSpec, generate_database

UNIVERSITY_SPEC = {
    "tables": {
        "prof": {"columns": {"pop": ["low", "high"]}},
        "student": {"columns": {"intel": ["1", "2", "3"]}},
        "advises": {
            "foreign_keys": {"advisor": "prof", "advisee": "student"},
            "columns": {"strength": ["weak", "strong"]},
        },
    }
}


def test_ingest_schema_happy_path():
    schema = ingest_schema(UNIVERSITY_SPEC)
    assert [e.name for e in schema.entities] == ["prof", "student"]
    rel = schema.relationship("advises")
    # FK declaration order fixes the fk1/fk2 roles
    assert rel.entities == ("prof", "student")
    assert dict(rel.attributes)["strength"] == ("weak", "strong")


def test_ingest_self_referencing_fk():
    spec = {"tables": {
        "person": {"columns": {"age": ["young", "old"]}},
        "mentors": {"foreign_keys": {"mentor": "person", "mentee": "person"}},
    }}
    schema = ingest_schema(spec)
    assert schema.relationship("mentors").is_self


@pytest.mark.parametrize("mutate,match", [
    (lambda s: s.pop("tables"), "tables"),
    (lambda s: s.update(extra=1), "unknown top-level"),
    (lambda s: s["tables"]["advises"]["foreign_keys"].pop("advisee"), "binary"),
    (lambda s: s["tables"]["advises"]["foreign_keys"].update(third="prof"),
     "binary"),
    (lambda s: s["tables"]["advises"]["foreign_keys"].update(advisee="nope"),
     "unknown"),
    (lambda s: s["tables"]["advises"]["foreign_keys"].update(advisee="advises"),
     "entity tables"),
    (lambda s: s["tables"]["prof"]["columns"].update(pop=["solo"]), ">= 2"),
    (lambda s: s["tables"]["prof"]["columns"].update(pop=["a", "a"]),
     "duplicate"),
    (lambda s: s["tables"]["prof"]["columns"].update(pop=["a", "n/a"]), "n/a"),
    (lambda s: s["tables"].update({"bad name": {"columns": {}}}), "identifier"),
    (lambda s: s["tables"]["prof"].update(typo=1), "unknown keys"),
])
def test_ingest_schema_fail_loud(mutate, match):
    spec = json.loads(json.dumps(UNIVERSITY_SPEC))  # deep copy
    mutate(spec)
    with pytest.raises(SchemaSpecError, match=match):
        ingest_schema(spec)


def _with_rows():
    spec = json.loads(json.dumps(UNIVERSITY_SPEC))
    spec["tables"]["prof"]["rows"] = {"pop": ["low", "high", "high"]}
    spec["tables"]["student"]["rows"] = {"intel": ["1", "3"]}
    spec["tables"]["advises"]["rows"] = {
        "advisor": [0, 2], "advisee": [1, 1], "strength": ["weak", "strong"],
    }
    return spec


def test_ingest_database_and_export_round_trip():
    db = ingest_database(_with_rows())
    assert db.entities["prof"].n_rows == 3
    assert db.relationships["advises"].n_rows == 2
    # stored rel-attr codes live in the n/a-augmented domain (>= 1)
    np.testing.assert_array_equal(
        np.asarray(db.relationships["advises"].attrs["strength"]), [1, 2]
    )
    spec2 = export_spec(db)
    db2 = ingest_database(spec2)
    assert export_spec(db2) == spec2  # fixed point
    for name, t in db.entities.items():
        for attr, col in t.attrs.items():
            np.testing.assert_array_equal(
                np.asarray(col), np.asarray(db2.entities[name].attrs[attr])
            )
    for name, t in db.relationships.items():
        t2 = db2.relationships[name]
        np.testing.assert_array_equal(np.asarray(t.fk1), np.asarray(t2.fk1))
        np.testing.assert_array_equal(np.asarray(t.fk2), np.asarray(t2.fk2))


def test_ingest_attributeless_entity_needs_n_rows():
    """Regression (found by the shrinker): an entity stripped of every
    attribute column must keep its population via ``n_rows`` — and the
    round-trip through ``from_labels`` must not collapse it to 0 rows."""
    spec = {"tables": {
        "e": {"columns": {}, "n_rows": 3},
        "f": {"columns": {"y": ["0", "1"]}, "rows": {"y": ["0", "1"]}},
        "r": {"foreign_keys": {"fk1": "e", "fk2": "f"},
              "columns": {},
              "rows": {"fk1": [0, 2], "fk2": [0, 1]}},
    }}
    db = ingest_database(spec)
    assert db.entities["e"].n_rows == 3
    db.validate()
    # without n_rows it must fail loud, not silently produce 0 rows
    del spec["tables"]["e"]["n_rows"]
    with pytest.raises(SchemaSpecError, match="n_rows"):
        ingest_database(spec)


@pytest.mark.parametrize("mutate,exc,match", [
    (lambda s: s["tables"]["advises"]["rows"].update(advisor=[0, 9]),
     SchemaSpecError, "out of\\s+range"),
    (lambda s: s["tables"]["advises"]["rows"].update(
        advisor=[0, 0], advisee=[1, 1]), SchemaSpecError, "duplicate"),
    (lambda s: s["tables"]["advises"]["rows"].pop("strength"),
     SchemaSpecError, "missing"),
    (lambda s: s["tables"]["advises"]["rows"].update(strength=["weak"]),
     SchemaSpecError, "expected 2 rows"),
    (lambda s: s["tables"]["prof"]["rows"].update(pop=["low", "mid", "hi"]),
     SchemaSpecError, "not in domain"),
    (lambda s: s["tables"]["prof"]["rows"].update(zz=["low"]),
     SchemaSpecError, "undeclared"),
])
def test_ingest_database_fail_loud(mutate, exc, match):
    spec = _with_rows()
    mutate(spec)
    with pytest.raises(exc, match=match):
        ingest_database(spec)


def test_load_spec_round_trip(tmp_path):
    path = tmp_path / "spec.json"
    path.write_text(json.dumps(_with_rows()))
    db = ingest_database(load_spec(str(path)))
    assert db.relationships["advises"].n_rows == 2
    path.write_text("[1, 2]")
    with pytest.raises(SchemaSpecError, match="object"):
        load_spec(str(path))


# ---------------------------------------------------------------------------
# schema_gen: determinism + shape coverage
# ---------------------------------------------------------------------------


def test_generate_database_is_deterministic():
    spec = SPEC_CORPUS[0]
    a = generate_database(spec, 42)
    b = generate_database(spec, 42)
    assert export_spec(a) == export_spec(b)
    c = generate_database(spec, 43)
    assert export_spec(a) != export_spec(c)


def test_generated_db_exports_and_reingests():
    """Every corpus corner survives export -> ingest -> export fixed point."""
    for i, spec in enumerate(SPEC_CORPUS):
        db = generate_database(spec, 100 + i)
        spec2 = export_spec(db)
        assert export_spec(ingest_database(spec2)) == spec2, (i, spec)


def test_corpus_covers_adversarial_shapes():
    dual_self = generate_database(SPEC_CORPUS[1], 0)
    assert all(r.is_self for r in dual_self.schema.relationships)
    parallel = generate_database(SPEC_CORPUS[2], 0)
    pairs = [r.entities for r in parallel.schema.relationships]
    assert len(pairs) > len(set(pairs))  # at least one duplicated pair
    ring = generate_database(SPEC_CORPUS[3], 0)
    assert sorted(r.entities for r in ring.schema.relationships) == [
        ("e0", "e1"), ("e1", "e2"), ("e2", "e0")]


def test_loop_free_self_rel_spec():
    spec = SPEC_CORPUS[5]
    assert not spec.allow_self_pairs
    for seed in range(5):
        db = generate_database(spec, seed)
        for r in db.schema.relationships:
            if r.is_self:
                t = db.relationships[r.name]
                assert not np.any(np.asarray(t.fk1) == np.asarray(t.fk2))


@pytest.mark.parametrize("kw", [
    {"n_entities": 0}, {"min_domain": 1}, {"min_rows": 0},
    {"min_rows": 5, "max_rows": 4},
])
def test_schema_spec_validates(kw):
    with pytest.raises(ValueError):
        SchemaSpec(**kw)
