"""Scale leg: the synthetic star-schema generator and the sharded COO build.

Pins the three contracts the million-row engine rests on:

  * the generator is deterministic by seed and respects the float32-exact
    counting envelope (``repro.data.synth``);
  * the sharded device build — fact rows split by
    ``bucketing.shard_ranges``, per-shard contraction, one signed-aggregate
    merge — is **bit-identical** (codes AND float32 counts) to the
    single-device build for 1/2/4 shards, including empty and skewed
    shards;
  * the adaptive batch/serial router in ``ScoreManager.score_batch``
    (the movielens batched<serial fix) routes small memo-missing batches
    serially, honors ``REPRO_BATCH_MIN_CANDIDATES``, and both routes
    produce identical scores and identical hill-climb edges.

The multi-device leg (4 fake CPU devices via ``XLA_FLAGS``) runs in a
subprocess like ``tests/test_sharding.py`` so the main process keeps one
device.
"""

import subprocess
import sys

import numpy as np
import pytest

from repro.core.sparse_counts import (
    as_host,
    device_sparse_contingency_table,
    sparse_contingency_table,
)
from repro.core.structure import ScoreManager, hill_climb, learn_and_join
from repro.data.synth import SCALE_PRESETS, ScaleSpec, generate_scale
from repro.kernels.bucketing import shard_ranges

# Small enough for the fast suite, big enough that 4 shards are non-trivial.
SPEC = ScaleSpec("synth-test", n_facts=3_000, n_src=300, n_dst=300)
# Fewer fact rows than shards: forces empty `(n, n)` tail ranges.
TINY = ScaleSpec("synth-tiny", n_facts=3, n_src=16, n_dst=16)


def _all_rvs(db):
    return tuple(v.vid for v in db.catalog.par_rvs)


def _host_coo(ct):
    h = as_host(ct)
    return h.rvs, np.asarray(h.codes), np.asarray(h.counts)


# ---------------------------------------------------------------------------
# generator
# ---------------------------------------------------------------------------


def test_generator_deterministic_by_seed():
    a, b = generate_scale(SPEC, seed=11), generate_scale(SPEC, seed=11)
    c = generate_scale(SPEC, seed=12)
    ra, rb, rc = (d.relationships["fact"] for d in (a, b, c))
    assert np.array_equal(np.asarray(ra.fk1), np.asarray(rb.fk1))
    assert np.array_equal(np.asarray(ra.fk2), np.asarray(rb.fk2))
    assert np.array_equal(np.asarray(ra.attrs["ra"]), np.asarray(rb.attrs["ra"]))
    for ent in ("src", "dst"):
        for attr, col in a.entities[ent].attrs.items():
            assert np.array_equal(
                np.asarray(col), np.asarray(b.entities[ent].attrs[attr])
            )
    # a different seed must actually change the draw
    assert not np.array_equal(np.asarray(ra.fk1), np.asarray(rc.fk1))


def test_generator_distinct_pairs_and_domains():
    db = generate_scale(SPEC, seed=3)
    rel = db.relationships["fact"]
    pair = np.asarray(rel.fk1, np.int64) * SPEC.n_dst + np.asarray(rel.fk2)
    assert len(np.unique(pair)) == SPEC.n_facts  # no duplicate groundings
    ra = np.asarray(rel.attrs["ra"])
    assert ra.min() >= 1  # code 0 is the n/a value, never sampled as true
    assert ra.max() <= SPEC.rel_attrs[0][1]


def test_presets_cover_the_acceptance_scale():
    assert SCALE_PRESETS["synth-1m"].n_facts >= 10**6
    assert SCALE_PRESETS["synth-10m"].n_facts >= 10**7
    # .scaled() shrinks facts linearly, entities by sqrt
    s = SCALE_PRESETS["synth-1m"].scaled(0.01)
    assert s.n_facts == 10_000 and s.n_src == 2_000


# ---------------------------------------------------------------------------
# shard_ranges
# ---------------------------------------------------------------------------


def test_shard_ranges_cover_and_share_sizes():
    for n, k in [(10, 3), (12, 4), (0, 3), (1, 4), (7, 1), (3, 4)]:
        ranges = shard_ranges(n, k)
        assert len(ranges) == k
        # contiguous cover of [0, n)
        assert ranges[0][0] == (0 if n else n)
        assert ranges[-1][1] == n
        for (lo, hi), (lo2, _) in zip(ranges, ranges[1:]):
            assert hi == lo2 and lo <= hi
        # all non-tail shards share one size (one bucket rung)
        sizes = {hi - lo for lo, hi in ranges[:-1] if hi > lo}
        assert len(sizes) <= 1


def test_shard_ranges_rejects_zero_shards():
    with pytest.raises(ValueError):
        shard_ranges(10, 0)


# ---------------------------------------------------------------------------
# sharded device build: bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_build_bit_identical(shards):
    db = generate_scale(SPEC, seed=5)
    rvs = _all_rvs(db)
    base = _host_coo(device_sparse_contingency_table(db, rvs, shards=1))
    got = _host_coo(device_sparse_contingency_table(db, rvs, shards=shards))
    assert base[0] == got[0]
    assert np.array_equal(base[1], got[1])
    assert np.array_equal(base[2], got[2])


def test_sharded_build_matches_host_oracle():
    db = generate_scale(SPEC, seed=5)
    rvs = _all_rvs(db)
    host = sparse_contingency_table(db, rvs)
    dev = _host_coo(device_sparse_contingency_table(db, rvs, shards=3))
    assert host.rvs == dev[0]
    assert np.array_equal(np.asarray(host.codes), dev[1])
    assert np.array_equal(np.asarray(host.counts), dev[2])


def test_sharded_build_empty_and_skewed_shards():
    # 3 fact rows over 4 shards: shard_ranges yields an empty tail range,
    # and the leading shards are maximally skewed (1 row each)
    db = generate_scale(TINY, seed=9)
    rvs = _all_rvs(db)
    base = _host_coo(device_sparse_contingency_table(db, rvs, shards=1))
    for shards in (2, 4, 8):
        got = _host_coo(device_sparse_contingency_table(db, rvs, shards=shards))
        assert base[0] == got[0]
        assert np.array_equal(base[1], got[1])
        assert np.array_equal(base[2], got[2])


def test_env_knob_coo_shards(monkeypatch):
    from repro.core.sparse_counts import coo_shards

    monkeypatch.delenv("REPRO_COO_SHARDS", raising=False)
    assert coo_shards() == 1
    monkeypatch.setenv("REPRO_COO_SHARDS", "4")
    assert coo_shards() == 4
    monkeypatch.setenv("REPRO_COO_SHARDS", "zero")
    with pytest.raises(ValueError):
        coo_shards()
    monkeypatch.setenv("REPRO_COO_SHARDS", "0")
    with pytest.raises(ValueError):
        coo_shards()


# ---------------------------------------------------------------------------
# adaptive batch/serial router (the movielens batched<serial fix)
# ---------------------------------------------------------------------------


def test_router_small_batches_go_serial():
    db = generate_scale(TINY, seed=2)
    mgr = ScoreManager(db, mode="sparse")
    assert mgr.batch_min_candidates == 8
    rvs = _all_rvs(db)
    fams = [(rvs[0], ()), (rvs[1], (rvs[0],))]
    mgr.score_batch(fams)  # 2 < 8: movielens-shaped sweep -> serial route
    assert mgr.n_serial_routed == len(fams)
    assert mgr.n_batched_routed == 0
    # memo-complete re-request costs nothing and routes nowhere
    mgr.score_batch(fams)
    assert mgr.n_serial_routed == len(fams)


def test_router_threshold_env_knob(monkeypatch):
    from repro.core.score_manager import batch_min_candidates

    monkeypatch.setenv("REPRO_BATCH_MIN_CANDIDATES", "0")
    assert batch_min_candidates() == 0
    db = generate_scale(TINY, seed=2)
    mgr = ScoreManager(db, mode="sparse")
    rvs = _all_rvs(db)
    mgr.score_batch([(rvs[0], ()), (rvs[1], (rvs[0],))])
    assert mgr.n_serial_routed == 0  # 0 disables the serial route entirely
    assert mgr.n_batched_routed == 2
    monkeypatch.setenv("REPRO_BATCH_MIN_CANDIDATES", "many")
    with pytest.raises(ValueError):
        batch_min_candidates()
    monkeypatch.setenv("REPRO_BATCH_MIN_CANDIDATES", "-1")
    with pytest.raises(ValueError):
        batch_min_candidates()


def test_router_routes_are_score_identical():
    db = generate_scale(TINY, seed=4)
    serial_mgr = ScoreManager(db, mode="sparse")
    batched_mgr = ScoreManager(db, mode="sparse")
    batched_mgr.batch_min_candidates = 0  # force the set-oriented engine
    rvs = _all_rvs(db)
    fams = [(c, tuple(p for p in rvs[:2] if p != c)) for c in rvs]
    a = serial_mgr.score_batch(fams)
    b = batched_mgr.score_batch(fams)
    assert serial_mgr.n_serial_routed == len(fams)
    assert batched_mgr.n_batched_routed == len(fams)
    for fa, fb in zip(a, b):
        assert fa.n_params == fb.n_params
        assert fa.loglik == pytest.approx(fb.loglik, rel=1e-6, abs=1e-6)


def test_router_walks_identical_edges():
    """The regression pin: movielens-shaped small sweeps take the serial
    route and walk the same edges as the forced-batched engine."""
    db = generate_scale(TINY, seed=8)
    rvs = _all_rvs(db)
    routed = ScoreManager(db, mode="sparse")
    forced = ScoreManager(db, mode="sparse")
    forced.batch_min_candidates = 0
    # hill_climb directly (no lattice prefetch): the opening 6-family batch
    # sits under the default threshold of 8, so the router must fire
    res_r = hill_climb(rvs, routed, score="aic", max_parents=2)
    res_f = hill_climb(rvs, forced, score="aic", max_parents=2)
    assert sorted(res_r.bn.edges()) == sorted(res_f.bn.edges())
    assert res_r.n_sweeps == res_f.n_sweeps
    assert routed.n_serial_routed > 0  # small batches actually took the route
    assert forced.n_serial_routed == 0
    assert forced.n_batched_routed > 0

    # and the full lattice search stays edge-identical across routes
    res_lr = learn_and_join(
        db, ScoreManager(db, mode="sparse"), score="aic",
        max_parents=2, max_chain=1,
    )
    fmgr = ScoreManager(db, mode="sparse")
    fmgr.batch_min_candidates = 0
    res_lf = learn_and_join(db, fmgr, score="aic", max_parents=2, max_chain=1)
    assert sorted(res_lr.bn.edges()) == sorted(res_lf.bn.edges())


# ---------------------------------------------------------------------------
# multi-device leg (forced 4-device CPU, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_build_multidevice():
    """Mesh-sharded COO aggregation + CT build under 4 fake CPU devices."""
    code = """
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.distributed import (
    pad_rows, sharded_coo_aggregate, sharded_sparse_contingency_table,
)
from repro.core.sparse_counts import as_host, device_sparse_contingency_table
from repro.data.synth import ScaleSpec, generate_scale
from repro.kernels import ops

assert jax.device_count() == 4, jax.devices()
mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))

# raw stream aggregation: sharded vs single-device, bit-identical
import jax.numpy as jnp
from jax.experimental import enable_x64
rng = np.random.default_rng(0)
codes = rng.integers(0, 97, size=1000).astype(np.int64)
weights = rng.integers(1, 5, size=1000).astype(np.float32)
with enable_x64():  # int64 codes must survive the device transfer
    dc, dw = jnp.asarray(codes), jnp.asarray(weights)
    pad_c = pad_rows(dc, 4, jnp.iinfo(jnp.int64).max)
    pad_w = pad_rows(dw, 4, 0.0)
u, s = sharded_coo_aggregate(pad_c, pad_w, mesh)
u1, s1 = ops.coo_aggregate(dc, dw)
n = int(np.searchsorted(np.asarray(u), np.iinfo(np.int64).max))
n1 = int(np.searchsorted(np.asarray(u1), np.iinfo(np.int64).max))
assert np.array_equal(np.asarray(u)[:n], np.asarray(u1)[:n1])
assert np.array_equal(np.asarray(s)[:n], np.asarray(s1)[:n1])

# full CT build through the mesh wrapper vs the single-device build
db = generate_scale(ScaleSpec("t", n_facts=2000, n_src=200, n_dst=200), seed=1)
rvs = tuple(v.vid for v in db.catalog.par_rvs)
a = as_host(sharded_sparse_contingency_table(db, rvs, mesh))
b = as_host(device_sparse_contingency_table(db, rvs, shards=1))
assert a.rvs == b.rvs
assert np.array_equal(np.asarray(a.codes), np.asarray(b.codes))
assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
print("multidevice sharded build matches single-device: True")
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "matches single-device: True" in r.stdout
