"""Sharding rules: divisibility fallback, FSDP+TP placement, cache specs,
and the multi-device distributed-counting path (run in a subprocess with
fake devices so the main test process keeps a single CPU device)."""

import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import div, param_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape  # dict axis -> size
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_div_fallback():
    assert div(MESH, 64, "model") == "model"
    assert div(MESH, 25, "model") is None
    assert div(MESH3, 256, ("pod", "data")) == ("pod", "data")
    assert div(MESH3, 100, ("pod", "data")) is None


def test_param_specs():
    # FSDP+TP on an MLP gate: (L, D, F)
    assert param_spec(MESH, "layers/mlp/w_gate", (36, 2560, 9728)) == \
        P(None, ("data",), "model")
    # output projection transposed
    assert param_spec(MESH, "layers/mlp/w_down", (36, 9728, 2560)) == \
        P(None, "model", ("data",))
    # embedding: vocab over model when divisible
    assert param_spec(MESH, "embed", (151936, 2560)) == P("model", ("data",))
    # odd vocab -> replicate vocab dim
    assert param_spec(MESH, "embed", (122753, 2304)) == P(None, ("data",))
    # norms replicate
    assert param_spec(MESH, "layers/ln1", (36, 2560)) == P(None, None)
    # MoE experts over model
    assert param_spec(MESH, "layers/moe/w_gate", (35, 128, 7168, 4864)) == \
        P(None, "model", ("data",), None)
    # multi-pod FSDP spans pod+data
    assert param_spec(MESH3, "layers/attn/wq", (36, 2560, 4096)) == \
        P(None, ("pod", "data"), "model")


def test_cache_specs_shard_sequence_over_model():
    from repro.parallel.sharding import cache_shardings
    import jax.numpy as jnp

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cache = {
        "pos": jax.ShapeDtypeStruct((128,), jnp.int32),
        "k": jax.ShapeDtypeStruct((36, 128, 32768, 8, 128), jnp.bfloat16),
        "ssm": {"h": jax.ShapeDtypeStruct((24, 128, 24, 128, 64), jnp.float32)},
    }
    sh = cache_shardings(mesh, cache)
    assert sh["k"].spec == P(None, ("data",), "model", None, None)
    assert sh["ssm"]["h"].spec == P(None, ("data",), None, None, None)


@pytest.mark.slow
def test_distributed_counting_multidevice():
    """Runs the shard_map counting example under 8 fake devices."""
    r = subprocess.run(
        [sys.executable, "examples/distributed_count.py"],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "matches single-device pipeline: True" in r.stdout


@pytest.mark.slow
def test_dryrun_mechanism_small_mesh():
    """Full dry-run cell on a 4x2 fake mesh: lower+compile+roofline JSON."""
    import json
    import tempfile
    from pathlib import Path

    with tempfile.TemporaryDirectory() as td:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3_4b",
             "--shape", "decode_32k", "--mesh", "single", "--out", td],
            capture_output=True, text=True, timeout=900,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
                 "REPRO_DRYRUN_DEVICES": "8", "REPRO_DRYRUN_MESH": "4,2"},
            cwd="/root/repo",
        )
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.loads((Path(td) / "qwen3_4b--decode_32k--single.json").read_text())
        assert rec["status"] == "ok"
        assert rec["roofline"]["flops_per_device"] > 0
