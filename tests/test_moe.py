"""MoE dispatch correctness + count-manager integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.moe import init_moe, moe_ffn
from repro.models.transformer import _dtype


def _cfg(**kw):
    base = get_config("phi35_moe", smoke=True)
    return dataclasses.replace(base, dtype="float32", **kw)


def test_top1_ample_capacity_equals_direct():
    """top-1 routing with ample capacity == computing each token's expert
    FFN directly (dispatch/combine is an exact permutation)."""
    cfg = _cfg(top_k=1, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, _dtype(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    out, stats = moe_ffn(p, x, cfg)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["w_router"]
    eidx = np.asarray(jnp.argmax(logits, axis=1))
    direct = []
    for t in range(xt.shape[0]):
        e = int(eidx[t])
        h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
        direct.append(h @ p["w_down"][e])
    direct = jnp.stack(direct).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct), rtol=2e-4, atol=2e-4)


def test_expert_counts_are_group_by():
    cfg = _cfg(top_k=2, capacity_factor=2.0)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, _dtype(cfg))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    out, stats = moe_ffn(p, x, cfg)
    counts = np.asarray(stats["expert_counts"])
    assert counts.sum() == 2 * 32 * cfg.top_k
    assert (np.asarray(stats["kept_counts"]) <= counts).all()
    assert float(stats["aux_loss"]) >= 1.0 - 1e-3  # E*sum(f*p) >= 1 at optimum


def test_capacity_drops_tokens():
    cfg = _cfg(top_k=2, capacity_factor=0.25)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, _dtype(cfg))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model))
    out, stats = moe_ffn(p, x, cfg)
    assert int(np.asarray(stats["kept_counts"]).sum()) < int(np.asarray(stats["expert_counts"]).sum())
    assert bool(jnp.all(jnp.isfinite(out)))


def test_dense_residual_path():
    cfg = _cfg(top_k=1, capacity_factor=4.0, moe_dense_residual=True, dense_ff=96)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, _dtype(cfg))
    assert "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
    out, _ = moe_ffn(p, x, cfg)
    # removing the dense residual changes the output
    p2 = dict(p)
    from repro.models.layers import swiglu_mlp
    resid = swiglu_mlp(p["dense"], x.reshape(-1, cfg.d_model)).reshape(x.shape)
    cfg_nores = dataclasses.replace(cfg, moe_dense_residual=False)
    out2, _ = moe_ffn(p, x, cfg_nores)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2 + resid), rtol=2e-4, atol=2e-4)
