"""Durable model store: save → load round trips are bit-identical.

The artifact contract (``repro.core.model_store``): a saved model reloads
— in the same process or a fresh one — with the same schema, the same BN,
and float32 CPTs equal to the last ulp, so every downstream posterior is
bitwise reproducible from the artifact alone.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import zipfile
from pathlib import Path

import numpy as np
import pytest

import repro
from repro.core.cpt import learn_parameters
from repro.core.database import university_db
from repro.core.model_store import (
    FORMAT,
    VERSION,
    LearnedModel,
    ModelStoreError,
    load_model,
    save_model,
    schema_spec,
)
from repro.core.predict import predict_block
from repro.core.structure import CountCache, learn_and_join
from repro.data.ingest import ingest_schema
from repro.kernels import ops


@pytest.fixture(scope="module")
def model():
    db = university_db()
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(
        db, cache, score="aic", max_parents=2, max_chain=1, impl="ref"
    )
    factors = learn_parameters(res.bn, cache, alpha=0.1, impl="ref")
    return db, LearnedModel(
        schema=db.schema, bn=res.bn, factors=factors,
        meta={"dataset": "university", "alpha": 0.1},
    )


def test_round_trip_identity(model, tmp_path):
    db, m = model
    path = save_model(m, tmp_path / "model.npz")
    m2 = load_model(path)
    assert m2.schema == m.schema
    assert m2.bn == m.bn
    assert set(m2.factors) == set(m.factors)
    for child in m.factors:
        assert m2.factors[child].parents == m.factors[child].parents
        assert np.array_equal(
            np.asarray(ops.to_host(m2.factors[child].table)),
            np.asarray(ops.to_host(m.factors[child].table)),
        )
    assert dict(m2.meta) == dict(m.meta)


def test_round_trip_predictions_bitwise(model, tmp_path):
    db, m = model
    m2 = load_model(save_model(m, tmp_path / "model.npz"))
    target = "intelligence(student0)"
    r1 = predict_block(db, m.bn, m.factors, target, impl="ref")
    r2 = predict_block(db, m2.bn, m2.factors, target, impl="ref")
    assert np.array_equal(np.asarray(r1.log_scores), np.asarray(r2.log_scores))
    assert np.array_equal(np.asarray(r1.probs), np.asarray(r2.probs))


def test_fresh_process_round_trip(model, tmp_path):
    """save → NEW interpreter → load → predict, bitwise vs this process."""
    db, m = model
    path = save_model(m, tmp_path / "model.npz")
    target = "intelligence(student0)"
    want = np.asarray(predict_block(db, m.bn, m.factors, target, impl="ref").probs)
    np.save(tmp_path / "want.npy", want)

    script = f"""
import numpy as np
import repro
from repro.core.database import university_db
model = repro.load_model({str(path)!r})
r = repro.predict(university_db(), model, {target!r}, impl="ref")
want = np.load({str(tmp_path / "want.npy")!r})
assert np.array_equal(np.asarray(r.probs), want), "probs drifted across processes"
print("fresh-process OK")
"""
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "fresh-process OK" in proc.stdout


def test_schema_spec_round_trips(model):
    db, _ = model
    assert ingest_schema(schema_spec(db.schema)) == db.schema


def test_device_resident_flag(model, tmp_path):
    _, m = model
    path = save_model(m, tmp_path / "model.npz")
    host = load_model(path, device_resident=False)
    for f in host.factors.values():
        assert isinstance(f.table, np.ndarray)


def test_meta_rides_along(model, tmp_path):
    _, m = model
    m2 = load_model(save_model(m, tmp_path / "model.npz"))
    assert m2.meta["dataset"] == "university"
    assert m2.meta["alpha"] == 0.1


def test_unserializable_meta_fails_loud(model, tmp_path):
    db, m = model
    bad = LearnedModel(
        schema=m.schema, bn=m.bn, factors=m.factors, meta={"fn": object()}
    )
    with pytest.raises(ModelStoreError, match="JSON-serializable"):
        save_model(bad, tmp_path / "bad.npz")


def test_missing_factor_fails_validation(model, tmp_path):
    _, m = model
    some_child = next(iter(m.factors))
    partial = {c: f for c, f in m.factors.items() if c != some_child}
    broken = LearnedModel(schema=m.schema, bn=m.bn, factors=partial)
    with pytest.raises(ModelStoreError, match="missing CPTs"):
        save_model(broken, tmp_path / "broken.npz")


def test_not_an_artifact_rejected(tmp_path):
    path = tmp_path / "random.npz"
    np.savez(path, stuff=np.zeros(3))
    with pytest.raises(ModelStoreError, match="missing"):
        load_model(path)


def test_wrong_version_rejected(model, tmp_path):
    _, m = model
    path = save_model(m, tmp_path / "model.npz")
    # rewrite the meta block with a bumped version, keeping the zip valid
    with np.load(path) as archive:
        arrays = {k: archive[k] for k in archive.files}
    meta = json.loads(bytes(arrays["__meta__"].tobytes()).decode())
    meta["version"] = VERSION + 1
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8
    )
    np.savez(path, **arrays)
    with pytest.raises(ModelStoreError, match="version"):
        load_model(path)


def test_wrong_format_tag_rejected(model, tmp_path):
    _, m = model
    path = save_model(m, tmp_path / "model.npz")
    with np.load(path) as archive:
        arrays = {k: archive[k] for k in archive.files}
    meta = json.loads(bytes(arrays["__meta__"].tobytes()).decode())
    meta["format"] = "something-else"
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8
    )
    np.savez(path, **arrays)
    with pytest.raises(ModelStoreError, match=FORMAT):
        load_model(path)


def test_artifact_is_a_plain_npz(model, tmp_path):
    """The store writes a standard zip/npz — inspectable without repro."""
    _, m = model
    path = save_model(m, tmp_path / "model.npz")
    assert zipfile.is_zipfile(path)
    with np.load(path) as archive:
        names = set(archive.files)
    assert "__meta__" in names
    assert any(n.startswith("factor_") for n in names)


def test_repro_public_api_aliases(model, tmp_path):
    _, m = model
    assert repro.save_model is save_model
    assert repro.load_model is load_model
    assert repro.LearnedModel is LearnedModel
