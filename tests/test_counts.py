"""Count-manager correctness: Möbius virtual join, grouped/block access,
restricted (single-instance) queries — all vs the int64 brute-force oracle,
including hypothesis sweeps over random databases.  Every oracle check runs
for both CT backends (``impl="ref"`` dense, ``impl="sparse"`` COO)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import counts
from repro.core.database import university_db

from .bruteforce import CT_IMPLS, as_dense_array, brute_force_ct, random_db


@pytest.mark.parametrize("impl", CT_IMPLS)
def test_university_fig3c(impl):
    """Paper Fig. 3(c): CT for (RA, Capability, Salary) on the toy instance."""
    db = university_db()
    rvs = ("RA(prof0,student0)", "capability(prof0,student0)", "salary(prof0,student0)")
    ct = counts.contingency_table(db, rvs, impl=impl)
    bf = brute_force_ct(db, rvs)
    np.testing.assert_array_equal(as_dense_array(ct).astype(np.int64), bf)
    cap = db.catalog["capability(prof0,student0)"]
    sal = db.catalog["salary(prof0,student0)"]
    # count(RA=T, cap=3, salary=high) == 1  (jack, oliver)
    assert bf[1, cap.code("3"), sal.code("high")] == 1
    # F-mass sits entirely at (n/a, n/a)
    assert bf[0].sum() == bf[0, 0, 0] == 9 - 4


@pytest.mark.parametrize("impl", CT_IMPLS)
def test_joint_ct_university(impl):
    db = university_db()
    jt = counts.joint_contingency_table(db, impl=impl)
    bf = brute_force_ct(db, jt.rvs)
    np.testing.assert_array_equal(as_dense_array(jt).astype(np.int64), bf)
    assert jt.n_nonzero() == 9  # 3x3 grounding pairs, all distinct rows


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), self_rel=st.booleans())
@pytest.mark.parametrize("impl", CT_IMPLS)
def test_ct_matches_bruteforce_random_dbs(impl, seed, self_rel):
    """Property: CT == int64 brute force on random DBs (incl. self-rel)."""
    db = random_db(seed, self_rel=self_rel)
    cat = db.catalog
    rvs = tuple(v.vid for v in cat.par_rvs)
    ct = counts.contingency_table(db, rvs, impl=impl)
    bf = brute_force_ct(db, rvs)
    np.testing.assert_array_equal(as_dense_array(ct).astype(np.int64), bf)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
@pytest.mark.parametrize("impl", CT_IMPLS)
def test_marginal_consistency(impl, seed):
    """GROUP BY marginals of the joint == directly-counted local CTs."""
    db = random_db(seed)
    cat = db.catalog
    all_rvs = tuple(v.vid for v in cat.par_rvs)
    joint = counts.contingency_table(db, all_rvs, impl=impl)
    sub = (all_rvs[0], all_rvs[3], all_rvs[2])
    local = counts.contingency_table(db, sub, impl=impl)
    np.testing.assert_allclose(
        as_dense_array(joint.marginal(sub)), as_dense_array(local)
    )


@pytest.mark.parametrize("impl", CT_IMPLS)
def test_grouped_and_restricted_vs_bruteforce(impl):
    db = random_db(7)
    rvs = ("b1(beta0)", "R(alpha0,beta0)", "ra(alpha0,beta0)")
    g = counts.contingency_table(db, rvs, impl=impl, group_fovar="alpha0")
    bf = brute_force_ct(db, rvs, group_fovar="alpha0")
    np.testing.assert_array_equal(as_dense_array(g).astype(np.int64), bf)
    # restricted query == one slice of the grouped CT
    for e in range(db.entities["alpha"].n_rows):
        r = counts.contingency_table(db, rvs, impl=impl, restrict={"alpha0": e})
        np.testing.assert_array_equal(as_dense_array(r).astype(np.int64), bf[e])


@pytest.mark.parametrize("impl", CT_IMPLS)
def test_total_is_population_cross_product(impl):
    db = random_db(3)
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    ct = counts.contingency_table(db, rvs, impl=impl)
    n = db.entities["alpha"].n_rows * db.entities["beta"].n_rows
    assert float(ct.total()) == n
    assert float(as_dense_array(ct).min()) >= 0  # Möbius never goes negative


def test_mixed_radix_roundtrip():
    import jax.numpy as jnp

    cards = [3, 4, 2, 5]
    strides = counts.radix_strides(cards)
    rng = np.random.default_rng(0)
    cols = [jnp.asarray(rng.integers(0, c, 50).astype(np.int32)) for c in cards]
    keys = np.asarray(counts.encode_columns(cols, cards))
    assert keys.max() < np.prod(cards)
    # decode and compare
    for i, (c, s) in enumerate(zip(cards, strides)):
        np.testing.assert_array_equal((keys // s) % c, np.asarray(cols[i]))


@pytest.mark.parametrize("impl", CT_IMPLS)
def test_parallel_relationships_match_bruteforce(impl):
    """Regression (schema fuzzer): two relationships over the same entity
    pair make the join graph cyclic — no leaf-elimination order exists, and
    the planner used to raise ``NotImplementedError`` here.  The ground-join
    fallback now computes it; this shrunken two-pair fixture pins the full
    Möbius CT and the both-true conditional slice against brute force."""
    from repro.core.database import from_labels
    from repro.core.schema import make_schema

    schema = make_schema(
        entities={"a": {"x": ("0", "1")}, "b": {"y": ("0", "1")}},
        relationships={
            "r1": (("a", "b"), {}),
            "r2": (("a", "b"), {}),
        },
    )
    db = from_labels(
        schema,
        {"a": {"x": ["0", "1"]}, "b": {"y": ["1", "0"]}},
        {"r1": {"fk1": [0, 1], "fk2": [1, 0], "attrs": {}},
         "r2": {"fk1": [0], "fk2": [1], "attrs": {}}},
    )
    rvs = ("x(a0)", "r1(a0,b0)", "r2(a0,b0)", "y(b0)")
    bf = brute_force_ct(db, rvs)
    ct = counts.contingency_table(db, rvs, impl=impl)
    np.testing.assert_array_equal(as_dense_array(ct).astype(np.int64), bf)
    # the conditional the old planner refused: both parallel rels true
    cond = counts.ct_conditional(db, ("x(a0)",), ("r1", "r2"), impl=impl)
    want = bf[:, 1, 1, :].sum(axis=-1)
    assert want.sum() > 0  # the shape exercises a non-empty cyclic join
    np.testing.assert_array_equal(as_dense_array(cond).astype(np.int64), want)
