"""Per-kernel validation: Pallas (interpret on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import ref


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 5000])
@pytest.mark.parametrize("bins", [1, 3, 128, 300, 1000])
def test_ct_count_shapes(n, bins):
    rng = np.random.default_rng(n * 1000 + bins)
    keys = rng.integers(-1, bins, size=n).astype(np.int32)
    out_p = ops.ct_count(jnp.asarray(keys), bins, impl="pallas")
    out_r = ops.ct_count(jnp.asarray(keys), bins, impl="ref")
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))
    assert int(out_p.sum()) == int((keys >= 0).sum())


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_ct_count_weighted(dtype):
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 50, size=3000).astype(np.int32)
    w = rng.random(3000).astype(dtype)
    out_p = ops.ct_count(jnp.asarray(keys), 50, jnp.asarray(w), impl="pallas")
    out_r = ops.ct_count(jnp.asarray(keys), 50, jnp.asarray(w), impl="ref")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=1e-5, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(min_value=-2, max_value=40), min_size=1, max_size=500),
    st.integers(min_value=1, max_value=41),
)
def test_ct_count_property(keys, bins):
    """counts == exact int histogram; out-of-range dropped (property test)."""
    arr = np.array(keys, np.int32)
    out = np.asarray(ops.ct_count(jnp.asarray(arr), bins, impl="pallas"))
    expect = np.zeros(bins, np.int64)
    for k in keys:
        if 0 <= k < bins:
            expect[k] += 1
    np.testing.assert_array_equal(out, expect)


@pytest.mark.parametrize("n,segs", [(1, 1), (100, 7), (4096, 300)])
def test_sorted_segment_sum(n, segs):
    """XLA sorted-segment reduction vs scatter-add oracle (sparse CT agg)."""
    rng = np.random.default_rng(n + segs)
    ids = np.sort(rng.integers(0, segs, n)).astype(np.int32)
    vals = rng.random(n).astype(np.float32)
    out_a = ops.sorted_segment_sum(jnp.asarray(vals), jnp.asarray(ids), segs)
    out_r = ops.sorted_segment_sum(jnp.asarray(vals), jnp.asarray(ids), segs, impl="ref")
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_r), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(float(out_a.sum()), float(vals.sum()), rtol=1e-5)


@pytest.mark.parametrize("p,c", [(1, 2), (5, 3), (64, 7), (130, 9), (513, 2)])
@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_mle_cpt(p, c, alpha):
    rng = np.random.default_rng(p * 10 + c)
    ct = rng.integers(0, 20, size=(p, c)).astype(np.float32)
    ct[0] = 0  # unrealized parent config
    out_p = ops.mle_cpt(jnp.asarray(ct), alpha, impl="pallas")
    out_r = ops.mle_cpt(jnp.asarray(ct), alpha, impl="ref")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_p).sum(axis=1), 1.0, rtol=1e-5)


@pytest.mark.parametrize("shape", [(10,), (64, 5), (7, 9, 3), (4096,), (200, 7)])
def test_factor_loglik(shape):
    rng = np.random.default_rng(42)
    ct = rng.integers(0, 30, size=shape).astype(np.float32)
    cpt = np.asarray(ops.mle_cpt(jnp.asarray(ct.reshape(-1, shape[-1])), 0.3, impl="ref")).reshape(shape)
    out_p = float(ops.factor_loglik(jnp.asarray(ct), jnp.asarray(cpt), impl="pallas"))
    out_r = float(ops.factor_loglik(jnp.asarray(ct), jnp.asarray(cpt), impl="ref"))
    np.testing.assert_allclose(out_p, out_r, rtol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_mle_cpt_batched_matches_serial(impl, alpha):
    """Each padded stack member == the single-family kernel on its slice."""
    rng = np.random.default_rng(3)
    metas = [(10, 7), (3, 4), (1, 2), (6, 7), (513, 3)]
    p_max = max(p for p, _ in metas)
    c_max = max(c for _, c in metas)
    stack = np.zeros((len(metas), p_max, c_max), np.float32)
    mask = np.zeros((len(metas), c_max), np.float32)
    fams = []
    for i, (p, c) in enumerate(metas):
        t = rng.integers(0, 20, (p, c)).astype(np.float32)
        t[0] = 0  # unrealized parent config
        stack[i, :p, :c] = t
        mask[i, :c] = 1.0
        fams.append(t)
    out = np.asarray(
        ops.mle_cpt_batched(jnp.asarray(stack), jnp.asarray(mask), alpha, impl=impl)
    )
    for i, (p, c) in enumerate(metas):
        ser = np.asarray(ops.mle_cpt(jnp.asarray(fams[i]), alpha, impl=impl))
        np.testing.assert_allclose(out[i, :p, :c], ser, rtol=1e-6, atol=1e-6)
        # padded child lanes are zeroed, so row sums stay 1 over valid lanes
        np.testing.assert_array_equal(out[i, :, c:], 0.0)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_factor_loglik_batched_matches_serial(impl):
    rng = np.random.default_rng(11)
    metas = [(10, 7), (3, 4), (1, 2), (200, 5)]
    p_max = max(p for p, _ in metas)
    c_max = max(c for _, c in metas)
    stack = np.zeros((len(metas), p_max, c_max), np.float32)
    mask = np.zeros((len(metas), c_max), np.float32)
    for i, (p, c) in enumerate(metas):
        stack[i, :p, :c] = rng.integers(0, 30, (p, c)).astype(np.float32)
        mask[i, :c] = 1.0
    cpts = np.asarray(
        ops.mle_cpt_batched(jnp.asarray(stack), jnp.asarray(mask), 0.3, impl="ref")
    )
    b = len(metas)
    lls = np.asarray(
        ops.factor_loglik_batched(
            jnp.asarray(stack.reshape(b, -1)), jnp.asarray(cpts.reshape(b, -1)),
            impl=impl,
        )
    )
    assert lls.shape == (b,)
    for i in range(b):
        ser = float(
            ops.factor_loglik(jnp.asarray(stack[i]), jnp.asarray(cpts[i]), impl=impl)
        )
        np.testing.assert_allclose(lls[i], ser, rtol=1e-5)


def test_factor_loglik_batched_zero_convention():
    """Padding cells (count 0) contribute exactly 0 even where cp == 0."""
    ct = jnp.asarray([[0.0, 2.0, 0.0, 0.0]])
    cpt = jnp.asarray([[0.0, 0.5, 0.0, 0.0]])
    for impl in ("ref", "pallas"):
        v = np.asarray(ops.factor_loglik_batched(ct, cpt, impl=impl))
        np.testing.assert_allclose(v, [2.0 * np.log(0.5)], rtol=1e-6)


def test_factor_loglik_zero_convention():
    """count 0 contributes 0 even where cp == 0 (0*log0 := 0)."""
    ct = jnp.asarray([0.0, 2.0])
    cpt = jnp.asarray([0.0, 0.5])
    v = float(ops.factor_loglik(ct, cpt, impl="pallas"))
    np.testing.assert_allclose(v, 2.0 * np.log(0.5), rtol=1e-6)


@pytest.mark.parametrize("e,c,y", [(1, 1, 1), (23, 190, 7), (256, 512, 3), (65, 33, 130)])
def test_block_predict(e, c, y):
    rng = np.random.default_rng(e + c + y)
    a = rng.random((e, c)).astype(np.float32)
    l = rng.standard_normal((c, y)).astype(np.float32)
    out_p = ops.block_predict(jnp.asarray(a), jnp.asarray(l), impl="pallas")
    out_r = ops.block_predict(jnp.asarray(a), jnp.asarray(l), impl="ref")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_r), a @ l, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# O(n) histogram aggregation engine: budget boundary
# ---------------------------------------------------------------------------


def _hist_calls(monkeypatch):
    """Count engagements of the fused histogram program."""
    calls = []
    real = ops._coo_hist_jit

    def probe(codes, weights, num_bins):
        calls.append(num_bins)
        return real(codes, weights, num_bins)

    monkeypatch.setattr(ops, "_coo_hist_jit", probe)
    return calls


def test_hist_engine_budget_boundary(monkeypatch):
    """Bins budget exactly at / one below the code-space rung.

    ``_HIST_BINS_BUDGET`` (the ``REPRO_COO_HIST_BINS`` knob) is read at
    call time: at exactly the bin rung the O(n) histogram engine engages;
    one below it the stream falls back to the sort engine.  Both must be
    bit-identical to the host ``aggregate_codes`` oracle.
    """
    from repro.core.sparse_counts import aggregate_codes
    from repro.kernels import bucketing

    n = 1 << 16  # _HIST_MIN_ROWS: smallest stream the engine accepts
    num_bins = 300
    rung = bucketing.bucket_bins(num_bins)
    rng = np.random.default_rng(42)
    codes = rng.integers(0, num_bins, n).astype(np.int64)
    weights = rng.integers(1, 5, n).astype(np.float32)
    exp_codes, exp_counts = aggregate_codes(codes, weights)
    calls = _hist_calls(monkeypatch)

    monkeypatch.setattr(ops, "_HIST_BINS_BUDGET", rung)  # exactly at budget
    u, s, nv = ops.coo_aggregate_counted(
        jnp.asarray(codes), jnp.asarray(weights), num_bins=num_bins
    )
    assert calls == [rung], "histogram engine must engage at the exact budget"
    assert nv == exp_codes.size
    np.testing.assert_array_equal(np.asarray(u)[:nv], exp_codes)
    np.testing.assert_array_equal(np.asarray(s)[:nv], exp_counts)

    calls.clear()
    monkeypatch.setattr(ops, "_HIST_BINS_BUDGET", rung - 1)  # one below
    u2, s2, nv2 = ops.coo_aggregate_counted(
        jnp.asarray(codes), jnp.asarray(weights), num_bins=num_bins
    )
    assert calls == [], "over-budget bin rung must take the sort engine"
    assert nv2 == exp_codes.size
    np.testing.assert_array_equal(np.asarray(u2)[:nv2], exp_codes)
    np.testing.assert_array_equal(np.asarray(s2)[:nv2], exp_counts)


def test_hist_engine_min_rows_boundary(monkeypatch):
    """Streams under the min-rows floor take the sort engine, at it the hist.

    The floor tests the *bucketed* length, so the boundary sits between
    ladder rungs: a stream padding to the rung below ``_HIST_MIN_ROWS``
    sorts, one padding to the floor itself histograms.  Both results must
    match the host oracle bitwise.
    """
    from repro.core.sparse_counts import aggregate_codes

    num_bins = 300
    calls = _hist_calls(monkeypatch)
    rng = np.random.default_rng(7)
    for n, expect_hist in ((ops._HIST_MIN_ROWS, True),
                           (ops._HIST_MIN_ROWS // 2, False)):
        codes = rng.integers(0, num_bins, n).astype(np.int64)
        weights = np.ones(n, np.float32)
        exp_codes, exp_counts = aggregate_codes(codes, weights)
        calls.clear()
        u, s, nv = ops.coo_aggregate_counted(
            jnp.asarray(codes), jnp.asarray(weights), num_bins=num_bins
        )
        assert bool(calls) is expect_hist, (n, calls)
        assert nv == exp_codes.size
        np.testing.assert_array_equal(np.asarray(u)[:nv], exp_codes)
        np.testing.assert_array_equal(np.asarray(s)[:nv], exp_counts)
