"""Set-oriented ScoreManager: batched marginalization + batched-vs-serial
structure-search equivalence over both count backends and kernel impls."""

import numpy as np
import pytest

from repro.core.counts import joint_contingency_table, radix_strides, stacked_family_tables
from repro.core.database import university_db
from repro.core.score_manager import CountCache, ScoreManager
from repro.core.structure import hill_climb, learn_and_join
from repro.kernels import ops

from .bruteforce import random_db

UNIV_RVS = (
    "intelligence(student0)",
    "ranking(student0)",
    "popularity(prof0)",
    "teachingability(prof0)",
)


# ---------------------------------------------------------------------------
# Counts layer: batched marginalization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [3, 11])
def test_sparse_marginal_batch_matches_serial(seed):
    db = random_db(seed)
    joint = joint_contingency_table(db, impl="sparse")
    rvs = joint.rvs
    keeps = [
        (rvs[0],),
        (rvs[1], rvs[0]),
        (rvs[2], rvs[3], rvs[1]),
        (rvs[0],),  # duplicate request is legal
        rvs,        # full-width marginal
    ]
    outs = joint.marginal_batch(list(keeps))
    assert len(outs) == len(keeps)
    for keep, got in zip(keeps, outs):
        ser = joint.marginal(keep)
        assert got.rvs == ser.rvs and got.cards == ser.cards
        np.testing.assert_array_equal(got.codes, ser.codes)
        np.testing.assert_allclose(got.counts, ser.counts)


def test_sparse_marginal_batch_validates():
    db = university_db()
    joint = joint_contingency_table(db, impl="sparse")
    assert joint.marginal_batch([]) == []
    with pytest.raises(KeyError):
        joint.marginal_batch([("nope",)])


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_stacked_family_tables_match_dense_marginals(impl):
    db = university_db()
    joint = joint_contingency_table(db, impl="ref")
    rvs = joint.rvs
    cards = dict(zip(rvs, joint.table.shape))
    flat = np.asarray(joint.table, np.float32).reshape(-1)
    codes = np.flatnonzero(flat).astype(np.int64)
    strides = radix_strides([cards[v] for v in rvs])
    digits = {
        v: ((codes // s) % cards[v]).astype(np.int32) for v, s in zip(rvs, strides)
    }
    fams = [
        (rvs[0], (rvs[1],)),
        (rvs[2], ()),
        (rvs[3], tuple(sorted((rvs[0], rvs[1])))),
    ]
    stacked, mask, metas = stacked_family_tables(
        digits, flat[codes], cards, fams, impl=impl
    )
    stacked, mask = np.asarray(stacked), np.asarray(mask)
    for i, (child, parents) in enumerate(fams):
        _, p_i, c_i = metas[i]
        want = np.asarray(
            joint.marginal(tuple(parents) + (child,)).table
        ).reshape(p_i, c_i)
        np.testing.assert_allclose(stacked[i, :p_i, :c_i], want)
        np.testing.assert_array_equal(mask[i, :c_i], 1.0)
        np.testing.assert_array_equal(mask[i, c_i:], 0.0)
        np.testing.assert_array_equal(stacked[i, p_i:, :], 0.0)


# ---------------------------------------------------------------------------
# ScoreManager service
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["precount", "sparse", "ondemand"])
def test_score_batch_matches_serial_score_family(mode):
    """Every batched FamilyScore matches the serial scores.score_family row."""
    from repro.core.scores import score_family

    db = university_db()
    mgr = ScoreManager(db, mode=mode, impl="ref" if mode != "sparse" else "auto")
    ser = CountCache(db, mode=mode, impl="ref" if mode != "sparse" else "auto")
    fams = [
        (UNIV_RVS[1], (UNIV_RVS[0],)),
        (UNIV_RVS[0], ()),
        (UNIV_RVS[3], (UNIV_RVS[2],)),
        ("salary(prof0,student0)", ("RA(prof0,student0)",)),
    ]
    got = mgr.score_batch(fams, alpha=0.0)
    for (child, parents), fs in zip(fams, got):
        want = score_family(ser, child, tuple(sorted(parents)), 0.0, impl="ref")
        assert fs.child == child
        assert fs.n_params == want.n_params
        np.testing.assert_allclose(fs.loglik, want.loglik, rtol=1e-5)


def test_score_batch_memo_and_order():
    db = university_db()
    mgr = ScoreManager(db, mode="precount", impl="ref")
    f1 = (UNIV_RVS[1], (UNIV_RVS[0],))
    f2 = (UNIV_RVS[0], ())
    out = mgr.score_batch([f1, f2, f1], alpha=0.0)
    assert out[0] is out[2] and out[0].child == f1[0] and out[1].child == f2[0]
    assert (mgr.n_score_batches, mgr.n_scored_families) == (1, 2)
    # parents order canonicalized: permuted request is a memo hit
    mgr.score_batch([(UNIV_RVS[1], (UNIV_RVS[0],))], alpha=0.0)
    assert (mgr.n_score_batches, mgr.n_scored_families) == (1, 2)
    # different alpha is a different score row
    mgr.score_batch([f1], alpha=0.5)
    assert (mgr.n_score_batches, mgr.n_scored_families) == (2, 3)


def test_score_manager_device_resident_matches_host():
    db = university_db()
    host = ScoreManager(db, mode="precount", impl="ref")
    dev = ScoreManager(db, mode="precount", impl="ref", device_resident=True)
    fams = [(UNIV_RVS[1], (UNIV_RVS[0],)), (UNIV_RVS[2], (UNIV_RVS[3],))]
    for a, b in zip(host.score_batch(fams), dev.score_batch(fams)):
        np.testing.assert_allclose(a.loglik, b.loglik, rtol=1e-6)
        assert a.n_params == b.n_params


def test_score_manager_still_serves_cts():
    """ScoreManager keeps the CountCache contract (learn_parameters path)."""
    db = university_db()
    mgr = ScoreManager(db, mode="precount", impl="ref")
    cache = CountCache(db, mode="precount", impl="ref")
    fam = (UNIV_RVS[0], UNIV_RVS[1])
    np.testing.assert_allclose(
        np.asarray(mgr(fam).table), np.asarray(cache(fam).table)
    )


def test_score_batch_groups_and_chunks_under_cell_budget():
    """Mixed-shape batches split by bucketed family shape + cell budget.

    One stack must never be padded to a single worst family's shape times
    the whole batch; with a tiny budget the batch falls back to many small
    launches and every score still matches the serial row.
    """
    from repro.core.counts import set_dense_cell_budget
    from repro.core.scores import score_family

    db = university_db()
    mgr = ScoreManager(db, mode="precount", impl="ref")
    ser = CountCache(db, mode="precount", impl="ref")
    fams = [
        (UNIV_RVS[1], (UNIV_RVS[0],)),
        (UNIV_RVS[0], ()),
        (UNIV_RVS[2], ()),
        (UNIV_RVS[3], (UNIV_RVS[2], UNIV_RVS[0])),  # widest family
    ]
    old = set_dense_cell_budget(8)  # force one launch per family
    try:
        groups = mgr._shape_groups([(c, tuple(sorted(p))) for c, p in fams])
        assert len(groups) >= 3  # shape groups split, wide family isolated
        got = mgr.score_batch(fams)
    finally:
        set_dense_cell_budget(old)
    for (child, parents), fs in zip(fams, got):
        want = score_family(ser, child, tuple(sorted(parents)), 0.0, impl="ref")
        np.testing.assert_allclose(fs.loglik, want.loglik, rtol=1e-5)
        assert fs.n_params == want.n_params


# ---------------------------------------------------------------------------
# Search layer: batched-vs-serial equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "mode,impl",
    [("precount", "ref"), ("precount", "pallas"), ("sparse", "auto")],
)
def test_hill_climb_batched_equals_serial(mode, impl):
    """Identical edge set and total score: batched vs per-candidate scoring."""
    db = university_db()
    ser = CountCache(db, mode=mode, impl=impl if mode != "sparse" else "auto")
    mgr = ScoreManager(db, mode=mode, impl=impl if mode != "sparse" else "auto")
    kw = dict(score="aic", max_parents=2, impl=impl)
    r_ser = hill_climb(UNIV_RVS, ser, **kw)
    r_bat = hill_climb(UNIV_RVS, mgr, **kw)
    assert sorted(r_ser.bn.edges()) == sorted(r_bat.bn.edges())
    np.testing.assert_allclose(r_bat.score, r_ser.score, rtol=1e-5)
    assert r_bat.n_sweeps == r_ser.n_sweeps
    assert mgr.n_score_batches <= r_bat.n_sweeps + 1  # one pass per sweep + init


@pytest.mark.parametrize(
    "mode,impl",
    [("precount", "ref"), ("precount", "pallas"), ("sparse", "auto")],
)
def test_learn_and_join_batched_equals_serial(mode, impl):
    db = university_db()
    ser = CountCache(db, mode=mode, impl=impl if mode != "sparse" else "auto")
    mgr = ScoreManager(db, mode=mode, impl=impl if mode != "sparse" else "auto")
    kw = dict(score="aic", max_parents=2, max_chain=1, impl=impl)
    a = learn_and_join(db, ser, **kw)
    b = learn_and_join(db, mgr, **kw)
    assert sorted(a.bn.edges()) == sorted(b.bn.edges())
    # cross-node score memo: the batched run never re-scores a family
    assert b.n_candidates_scored <= a.n_candidates_scored


def test_batched_path_uses_fewer_kernel_launches():
    """The acceptance criterion: >= 3x fewer device launches per search."""
    db = university_db()
    ser = CountCache(db, mode="precount", impl="ref")
    mgr = ScoreManager(db, mode="precount", impl="ref")
    mgr.batch_min_candidates = 0  # router off: this pins the batched engine
    ops.reset_launch_counts()
    hill_climb(UNIV_RVS, ser, score="aic", impl="ref")
    serial_launches = ops.total_launches()
    ops.reset_launch_counts()
    hill_climb(UNIV_RVS, mgr, score="aic", impl="ref")
    batched_launches = ops.total_launches()
    assert batched_launches * 3 <= serial_launches, (
        serial_launches, batched_launches,
    )


def test_hill_climb_batched_random_db():
    """Batched == serial per backend on a random schema (incl. rel attrs)."""
    from repro.core.schema import KIND_ENTITY_ATTR

    db = random_db(7)
    rvs = tuple(
        v.vid for v in db.catalog.par_rvs if v.kind == KIND_ENTITY_ATTR
    )
    for mode in ("precount", "sparse"):
        impl = "ref" if mode == "precount" else "auto"
        ser = hill_climb(
            rvs, CountCache(db, mode=mode, impl=impl),
            score="aic", max_parents=2, impl=impl,
        )
        bat = hill_climb(
            rvs, ScoreManager(db, mode=mode, impl=impl),
            score="aic", max_parents=2, impl=impl,
        )
        assert sorted(ser.bn.edges()) == sorted(bat.bn.edges()), mode
        np.testing.assert_allclose(bat.score, ser.score, rtol=1e-5)


# ---------------------------------------------------------------------------
# BIC fail-fast (satellite)
# ---------------------------------------------------------------------------


def test_bic_without_groundings_fails_fast():
    db = university_db()
    mgr = ScoreManager(db, mode="precount", impl="ref")
    with pytest.raises(ValueError, match="n_groundings"):
        hill_climb(UNIV_RVS, mgr, score="bic")
    with pytest.raises(ValueError, match="score"):
        hill_climb(UNIV_RVS, mgr, score="bogus")


def test_learn_and_join_bic_end_to_end():
    """learn_and_join supplies n_groundings itself, so BIC just works."""
    db = university_db()
    mgr = ScoreManager(db, mode="precount", impl="ref")
    res = learn_and_join(db, mgr, score="bic", max_parents=2, max_chain=1, impl="ref")
    assert res.bn.is_acyclic()
    assert res.bn.has_edge("RA(prof0,student0)", "salary(prof0,student0)")
