"""Training substrate: optimizer math, schedules, checkpoint atomicity +
bf16 round-trip, fault-tolerant driver, gradient compression properties,
grad-accumulation equivalence, data-pipeline determinism."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, batch_at
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import GradCompressor
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    wsd_schedule,
)
from repro.training.step import init_train_state, make_train_step
from repro.training.trainer import Trainer, TrainerConfig


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3], jnp.float32)}
    st_ = adamw_init(p)
    new_p, st2, _ = adamw_update(p, g, st_, cfg)
    gnp = np.array([0.1, 0.2, -0.3])
    m = 0.1 * gnp
    v = 0.01 * gnp**2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = np.array([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-6)
    assert int(st2["step"]) == 1


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(p, g, adamw_init(p), cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    cos = [float(cosine_schedule(s, warmup=10, total=100)) for s in range(100)]
    assert cos[0] == 0.0 and cos[10] == pytest.approx(1.0, abs=1e-2)
    assert cos[-1] < 0.15
    wsd = [float(wsd_schedule(s, warmup=10, total=100, decay_frac=0.2)) for s in range(100)]
    assert wsd[50] == 1.0  # stable plateau
    assert wsd[-1] < 0.15  # decayed


def test_accum_equals_full_batch():
    """accum_steps=2 must equal the single-shot step (same data, f32)."""
    import dataclasses

    cfg = dataclasses.replace(get_config("granite_8b", smoke=True), dtype="float32")
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
    }
    s1 = make_train_step(cfg, AdamWConfig(lr=1e-2), remat=False, accum_steps=1)
    s2 = make_train_step(cfg, AdamWConfig(lr=1e-2), remat=False, accum_steps=2)
    p1, o1, m1 = s1(*init_train_state(cfg, jax.random.PRNGKey(7)), batch)
    p2, o2, m2 = s2(*init_train_state(cfg, jax.random.PRNGKey(7)), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    # f32 summation-order differences pass through adam's rsqrt; modest tol
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {
        "a": jnp.asarray(np.random.randn(4, 3), jnp.bfloat16),
        "b": {"c": jnp.arange(5, dtype=jnp.int32), "d": jnp.float32(2.5)},
    }
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    mgr.save(7, tree, block=True)
    assert mgr.latest_step() == 7
    step, restored = mgr.restore(None, tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    tree = {"w": jnp.zeros((2,), jnp.float32)}
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, block=True)
    assert mgr.committed_steps() == [3, 4]
    # a directory without COMMITTED marker is ignored
    (tmp_path / "step_00000009").mkdir()
    assert mgr.latest_step() == 4


def test_trainer_fault_recovery(tmp_path):
    cfg = get_config("granite_8b", smoke=True)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=1)
    faults = {7}

    def hook(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError("boom")

    tr = Trainer(cfg, data, TrainerConfig(
        steps=12, ckpt_every=3, log_every=100, ckpt_dir=str(tmp_path)),
        fault_hook=hook)
    res = tr.run(resume=False)
    assert res.restarts == 1
    assert res.final_step == 11
    assert res.losses[-1] < res.losses[0] * 1.2  # still training sanely


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=64))
def test_int8_compression_bounded_error(vals):
    comp = GradCompressor("int8")
    g = {"w": jnp.asarray(np.array(vals, np.float32))}
    state = comp.init(g)
    out, state2 = comp.compress_decompress(g, state)
    scale = max(abs(v) for v in vals) / 127.0
    err = np.abs(np.asarray(out["w"]) - np.array(vals, np.float32))
    assert err.max() <= scale * 0.5 + 1e-6
    # error feedback: residual carries the lost mass
    np.testing.assert_allclose(
        np.asarray(out["w"]) + np.asarray(state2["w"]), np.array(vals, np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_int8_error_feedback_unbiased_over_time():
    """Constant gradient: time-averaged decompressed grads -> true value."""
    comp = GradCompressor("int8")
    g = {"w": jnp.asarray([0.107, -3.33, 9.71], jnp.float32)}
    state = comp.init(g)
    acc = np.zeros(3)
    n = 50
    for _ in range(n):
        out, state = comp.compress_decompress(g, state)
        acc += np.asarray(out["w"])
    np.testing.assert_allclose(acc / n, np.asarray(g["w"]), rtol=2e-2, atol=2e-3)


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=9)
    b1 = batch_at(cfg, 5)
    b2 = batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], batch_at(cfg, 6)["tokens"])
    # labels are next-token shifted
    full1 = np.concatenate([b1["tokens"][:, :1], b1["labels"]], axis=1)
    np.testing.assert_array_equal(full1[:, 1:-1], b1["tokens"][:, 1:])
    # host sharding partitions the batch deterministically
    h0 = batch_at(cfg, 5, host_index=0, host_count=2)
    assert h0["tokens"].shape == (4, 64)


def test_relational_token_stream():
    from repro.core.database import university_db
    from repro.data.pipeline import relational_token_stream

    db = university_db()
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=2, seed=0)
    b = relational_token_stream(db, cfg, 0)
    assert b["tokens"].shape == (2, 32)
    assert b["tokens"].max() < 128 and b["tokens"].min() >= 0
