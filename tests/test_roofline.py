"""Roofline machinery: HLO analyzer trip-count awareness (flops must scale
linearly with scan depth), collective parsing, term computation."""

import pytest

from repro.roofline.analysis import (
    compute_terms,
    model_flops_for,
    parse_collective_bytes,
)
from repro.roofline.hlo import analyze, parse_module


def _compile_depth(L):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.training.optimizer import adamw_init
    from repro.training.step import make_train_step

    cfg = dataclasses.replace(get_config("granite_8b", smoke=True), n_layers=L)
    key = jax.random.PRNGKey(0)
    ps = jax.eval_shape(lambda: init_params(cfg, key))
    os_ = jax.eval_shape(lambda: adamw_init(ps))
    b = {
        "tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
        "labels": jax.ShapeDtypeStruct((2, 64), jnp.int32),
    }
    step = make_train_step(cfg)
    comp = jax.jit(step).lower(ps, os_, b).compile()
    return analyze(comp.as_text())


def test_flops_scale_with_scan_depth():
    """The whole point of the analyzer: XLA counts a scan body once; the
    analyzer must multiply by trip count, so flops(L) is affine in L with a
    positive per-layer slope dominating the base."""
    s2, s4, s8 = _compile_depth(2), _compile_depth(4), _compile_depth(8)
    d1 = s4.flops - s2.flops
    d2 = s8.flops - s4.flops
    assert d1 > 0
    assert d2 == pytest.approx(2 * d1, rel=0.05)
    assert s2.trip_counts, "while trip counts must be detected"
    assert max(s2.trip_counts.values()) == 2
    assert max(s8.trip_counts.values()) == 8


def test_bytes_scale_with_scan_depth():
    s2, s4, s8 = _compile_depth(2), _compile_depth(4), _compile_depth(8)
    d1 = s4.bytes - s2.bytes
    d2 = s8.bytes - s4.bytes
    assert d1 > 0 and d2 == pytest.approx(2 * d1, rel=0.15)


def test_parse_collectives_from_text():
    txt = """
  %ar = f32[256,2048]{1,0} all-reduce(%dot), channel_id=1
  %ag.1 = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
  %rs = (f32[64]{0}, f32[32]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[16]{0} collective-permute(%y), source_target_pairs={{0,1}}
"""
    out = parse_collective_bytes(txt)
    assert out["all-reduce"] == 256 * 2048 * 4 * 2  # x2: RS+AG equivalent
    assert out["all-gather"] == 8 * 128 * 2
    assert out["reduce-scatter"] == 64 * 4 + 32 * 4
    assert out["collective-permute"] == 16 * 4


def test_compute_terms_bottleneck():
    t = compute_terms(197e12, 819e9, 0.0, n_chips=256, model_flops=197e12 * 256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.bottleneck in ("compute", "memory")
    assert t.useful_ratio == pytest.approx(1.0)
    assert t.roofline_fraction == pytest.approx(1.0)
    t2 = compute_terms(1e12, 819e9 * 10, 50e9 * 100, n_chips=256, model_flops=1e12 * 256)
    assert t2.bottleneck == "collective"


def test_model_flops_conventions():
    from repro.configs.base import SHAPES, get_config

    cfg = get_config("phi35_moe")
    train = model_flops_for(cfg, SHAPES["train_4k"])
    dec = model_flops_for(cfg, SHAPES["decode_32k"])
    n_act = cfg.n_active_params()
    assert train == pytest.approx(6.0 * n_act * 4096 * 256)
    assert dec == pytest.approx(2.0 * n_act * 128)
    # MoE active < total
    assert cfg.n_active_params() < cfg.n_params() / 4


def test_parse_module_structure():
    txt = """HloModule test
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %c = s32[] constant(5)
  ROOT %t = (s32[], f32[4]) tuple(%c, %gte)
}
ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4] parameter(0)
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[4] get-tuple-element(%w), index=1
}
"""
    comps = parse_module(txt)
    assert "%body" in comps and "%main" in comps
    assert any(op.opcode == "while" for op in comps["%main"].ops)
