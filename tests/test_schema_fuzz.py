"""Property-based schema fuzzing: adversarial join graphs under the four
differential oracles.

Every generated ``(SchemaSpec, seed)`` draw — self-referencing FKs, parallel
relationships between one entity pair, rings, diamond chains — must satisfy,
over the full par-RV joint:

  1. **brute force**: host ``SparseCT`` == ``tests/bruteforce.brute_force_ct``
     (int64 enumeration of every grounding);
  2. **dense <-> sparse**: ``impl="ref"`` dense CT == the sparse CT's dense
     expansion (the ``DENSE_CELL_BUDGET`` routing seam);
  3. **host <-> device (+ sharded)**: ``DeviceSparseCT.to_host()`` is
     bit-identical (codes AND float32 counts) to the host build, for shard
     counts 1/2/4;
  4. **incremental**: ``sparse_ct_delta`` applied to the live table ==
     a from-scratch rebuild of the mutated database, bit-identical.

Failures print the ``(spec, seed)`` pair plus a ready-to-run
``tools/shrink_schema.py`` command that replays and minimizes the draw.

Tier-1 runs a fast corpus sample (`not slow`); the deep seeded sweep (>= 200
schemas by default, ``REPRO_FUZZ_COUNT``/``REPRO_FUZZ_SEED``/
``REPRO_FUZZ_ARTIFACTS`` knobs) runs under the ``slow`` + ``fuzz`` markers —
see the ``fuzz`` CI job and docs/configuration.md.
"""

import json
import os
from dataclasses import asdict

import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import counts
from repro.core.database import apply_delta, from_labels
from repro.core.schema import make_schema
from repro.core.sparse_counts import (
    DeviceSparseCT,
    SparseCT,
    apply_ct_delta,
    sparse_ct_delta,
)
from repro.data.schema_gen import SchemaSpec, corpus_case, generate_database

from .bruteforce import as_dense_array, brute_force_ct
from .strategies import absent_pair_inserts, fuzz_seeds, schema_specs

#: shard counts the sharded-identity oracle sweeps (1 == the plain build).
_SHARD_COUNTS = (2, 4)


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        val = int(raw)
    except ValueError:
        val = -1
    if val < 0:
        raise ValueError(f"{name} must be a non-negative integer, got {raw!r}")
    return val


def _repro_note(spec: SchemaSpec, seed: int) -> str:
    """The bug-report footer: replay + shrink instructions for one draw."""
    spec_json = json.dumps(asdict(spec))
    return (
        f"\nfailing fuzz draw: seed={seed} spec={spec!r}\n"
        f"replay + minimize:\n"
        f"  python tools/shrink_schema.py --seed {seed} --spec '{spec_json}'"
    )


def _delta_case(db, seed: int):
    """A deterministic valid delta for the incremental oracle: one absent
    pair inserted + row 0 deleted on a seed-chosen relationship (or ``None``
    when the schema offers no legal delta)."""
    rng = np.random.default_rng(seed + 10_000)
    names = [r.name for r in db.schema.relationships]
    if not names:
        return None
    table = names[int(rng.integers(len(names)))]
    ins = absent_pair_inserts(db, table, 1, rng)
    if not ins["fk1"]:
        ins = None
    dele = [0] if db.relationships[table].n_rows else None
    if ins is None and dele is None:
        return None
    return table, ins, dele


def check_oracles(spec: SchemaSpec, seed: int, deep: bool = True) -> None:
    """Run the differential oracles on one draw; raise with repro info.

    ``deep=False`` limits the check to the host-vs-brute-force oracle (the
    cheap subset the adaptive hypothesis search iterates quickly).
    """
    try:
        db = generate_database(spec, seed)
        rvs = tuple(v.vid for v in db.catalog.par_rvs)
        host = counts.contingency_table(db, rvs, impl="sparse")
        assert isinstance(host, SparseCT)

        # oracle 1: int64 brute-force enumeration
        bf = brute_force_ct(db, rvs)
        np.testing.assert_array_equal(as_dense_array(host).astype(np.int64), bf)
        if not deep:
            return

        # oracle 2: dense <-> sparse equivalence
        dense = counts.contingency_table(db, rvs, impl="ref")
        np.testing.assert_array_equal(
            as_dense_array(dense), as_dense_array(host)
        )

        # oracle 3: device bit-identity, incl. sharded 2/4 builds
        dev = counts.contingency_table(
            db, rvs, impl="sparse", device_resident=True
        )
        assert isinstance(dev, DeviceSparseCT)
        got = dev.to_host()
        np.testing.assert_array_equal(got.codes, host.codes)
        np.testing.assert_array_equal(got.counts, host.counts)
        for shards in _SHARD_COUNTS:
            sh = counts.contingency_table(
                db, rvs, impl="sparse", device_resident=True, shards=shards
            ).to_host()
            np.testing.assert_array_equal(sh.codes, host.codes)
            np.testing.assert_array_equal(sh.counts, host.counts)

        # oracle 4: sparse_ct_delta apply == from-scratch rebuild
        case = _delta_case(db, seed)
        if case is not None:
            table, ins, dele = case
            new_db, delta = apply_delta(
                db, table, inserted_rows=ins, deleted_rows=dele
            )
            merged = apply_ct_delta(
                host, sparse_ct_delta(db, delta, rvs, device=False)
            )
            rebuilt = counts.contingency_table(new_db, rvs, impl="sparse")
            np.testing.assert_array_equal(merged.codes, rebuilt.codes)
            np.testing.assert_array_equal(merged.counts, rebuilt.counts)
    except Exception as exc:  # noqa: BLE001 — always attach the repro recipe
        raise AssertionError(_repro_note(spec, seed)) from exc


# ---------------------------------------------------------------------------
# Tier-1: fast corpus sample + adaptive host-oracle property
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", range(6))
def test_fuzz_corpus_sample(case):
    """One draw per named corpus corner through all four oracles."""
    spec, seed = corpus_case(case, base_seed=0)
    check_oracles(spec, seed, deep=True)


@settings(max_examples=8, deadline=None)
@given(spec=schema_specs(), seed=fuzz_seeds(500))
def test_fuzz_host_matches_bruteforce(spec, seed):
    """Adaptive sweep of the cheap oracle (host COO vs brute force)."""
    check_oracles(spec, seed, deep=False)


# ---------------------------------------------------------------------------
# Shrunken regressions: shapes the planner used to reject or misplan
# ---------------------------------------------------------------------------


def _assert_matches_bruteforce(db) -> None:
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    bf = brute_force_ct(db, rvs)
    for impl in ("ref", "sparse"):
        ct = counts.contingency_table(db, rvs, impl=impl)
        np.testing.assert_array_equal(as_dense_array(ct).astype(np.int64), bf)
    dev = counts.contingency_table(db, rvs, impl="sparse", device_resident=True)
    host = counts.contingency_table(db, rvs, impl="sparse")
    np.testing.assert_array_equal(dev.to_host().codes, host.codes)
    np.testing.assert_array_equal(dev.to_host().counts, host.counts)


def test_regression_dual_self_relationships():
    """Two self-relationships on one entity (shrunken from the dual-self-ref
    corpus spec): the join graph has two edges on the e0/e1 fovar pair —
    cyclic — and both relationship leaves share both endpoint entity tables,
    the ``LeafMessageCache``-collision shape called out in the issue."""
    schema = make_schema(
        entities={"e": {"a": ("0", "1")}},
        relationships={
            "r0": (("e", "e"), {}),
            "r1": (("e", "e"), {"w": ("p", "q")}),
        },
    )
    db = from_labels(
        schema,
        {"e": {"a": ["0", "1", "1"]}},
        {"r0": {"fk1": [0, 2], "fk2": [1, 2], "attrs": {}},
         "r1": {"fk1": [1, 0], "fk2": [0, 0], "attrs": {"w": ["p", "q"]}}},
    )
    _assert_matches_bruteforce(db)


def test_regression_three_ring():
    """A 3-entity relationship ring (shrunken from the ring corpus spec):
    every fovar has degree 2, so the old leaf elimination found no leaf."""
    schema = make_schema(
        entities={"e0": {"a0": ("0", "1")},
                  "e1": {"a1": ("0", "1")},
                  "e2": {"a2": ("0", "1")}},
        relationships={
            "r0": (("e0", "e1"), {}),
            "r1": (("e1", "e2"), {}),
            "r2": (("e2", "e0"), {}),
        },
    )
    db = from_labels(
        schema,
        {"e0": {"a0": ["0", "1"]},
         "e1": {"a1": ["1", "0"]},
         "e2": {"a2": ["0", "0"]}},
        {"r0": {"fk1": [0, 1], "fk2": [0, 1], "attrs": {}},
         "r1": {"fk1": [0, 1], "fk2": [1, 0], "attrs": {}},
         "r2": {"fk1": [1], "fk2": [0], "attrs": {}}},
    )
    _assert_matches_bruteforce(db)


def test_cyclic_query_is_marked_in_plan():
    """``plan_conditional`` marks cyclic components instead of raising —
    the contract the sparse/dense/device routers key off."""
    schema = make_schema(
        entities={"a": {"x": ("0", "1")}, "b": {"y": ("0", "1")}},
        relationships={"r1": (("a", "b"), {}), "r2": (("a", "b"), {})},
    )
    db = from_labels(
        schema,
        {"a": {"x": ["0"]}, "b": {"y": ["1"]}},
        {"r1": {"fk1": [0], "fk2": [0], "attrs": {}},
         "r2": {"fk1": [0], "fk2": [0], "attrs": {}}},
    )
    plan = counts.plan_conditional(db, ("x(a0)",), ("r1", "r2"))
    assert plan.cyclic == {0}
    tree = counts.plan_conditional(db, ("x(a0)",), ("r1",))
    assert tree.cyclic == frozenset()


# ---------------------------------------------------------------------------
# The deep seeded sweep (the `fuzz` CI job; >= 200 schemas by default)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.fuzz
def test_fuzz_sweep():
    """Sweep ``REPRO_FUZZ_COUNT`` draws (corpus specs cycled, seeds advancing
    from ``REPRO_FUZZ_SEED``) through all four oracles.  Every failure is
    collected — not fail-fast — so one CI run reports the full divergence
    set; with ``REPRO_FUZZ_ARTIFACTS`` set, the seed list and per-failure
    reproducer specs are written there for artifact upload."""
    base_seed = _env_int("REPRO_FUZZ_SEED", 0)
    count = _env_int("REPRO_FUZZ_COUNT", 240)
    art_dir = os.environ.get("REPRO_FUZZ_ARTIFACTS", "")

    cases = [corpus_case(i, base_seed) for i in range(count)]
    failures: list[dict] = []
    for spec, seed in cases:
        try:
            check_oracles(spec, seed, deep=True)
        except AssertionError as exc:
            failures.append({
                "seed": seed,
                "spec": asdict(spec),
                "error": str(exc.__cause__ or exc),
            })

    if art_dir:
        os.makedirs(art_dir, exist_ok=True)
        with open(os.path.join(art_dir, "seeds.json"), "w") as fh:
            json.dump(
                {"base_seed": base_seed, "count": count,
                 "cases": [{"seed": s, "spec": asdict(sp)} for sp, s in cases],
                 "n_failures": len(failures)},
                fh, indent=1,
            )
        for i, fail in enumerate(failures):
            with open(os.path.join(art_dir, f"repro_{i}.json"), "w") as fh:
                json.dump(fail, fh, indent=1)

    assert not failures, (
        f"{len(failures)}/{count} fuzz draws diverged; first: "
        + _repro_note(SchemaSpec(**failures[0]["spec"]), failures[0]["seed"])
    )
