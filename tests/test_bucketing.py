"""Shape-bucketed execution layer: ladder algebra, identity padding
(padded vs unpadded results bit-identical across bucket boundaries,
including empty inputs and exact-bucket-size edges), compile accounting,
and cache warmth (a second same-bucket build performs zero new XLA
compiles)."""

import math
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.counts import joint_contingency_table
from repro.core.database import university_db
from repro.core.sparse_counts import (
    DeviceSparseCT,
    SparseCT,
    aggregate_codes,
    sparse_family_stats,
)
from repro.kernels import bucketing, ops


@pytest.fixture
def tiny_ladder():
    """Shrink the ladder so single-digit inputs exercise real padding."""
    old = bucketing.set_bucket_ladder(4, 2.0)
    yield
    bucketing.set_bucket_ladder(*old)


# ---------------------------------------------------------------------------
# The ladder itself
# ---------------------------------------------------------------------------


def test_bucket_rows_ladder_properties(tiny_ladder):
    assert bucketing.bucket_rows(0) == 0  # empties never pad
    for n in range(1, 200):
        b = bucketing.bucket_rows(n)
        assert b >= n
        assert bucketing.bucket_rows(b) == b  # rungs are fixed points
        assert b <= bucketing.bucket_rows(n + 1)  # monotone
    # base 4, growth 2: the classic pow2 ladder with a floor
    assert [bucketing.bucket_rows(n) for n in (1, 4, 5, 8, 9)] == [4, 4, 8, 8, 16]


def test_bucket_ladder_fractional_growth():
    old = bucketing.set_bucket_ladder(100, 1.5)
    try:
        rungs = sorted({bucketing.bucket_rows(n) for n in range(1, 1000)})
        assert rungs[0] == 100
        for a, b in zip(rungs, rungs[1:]):
            assert b == max(a + 1, math.ceil(a * 1.5))
    finally:
        bucketing.set_bucket_ladder(*old)


def test_bucket_ladder_validation():
    with pytest.raises(ValueError):
        bucketing.set_bucket_ladder(0, 2.0)
    with pytest.raises(ValueError):
        bucketing.set_bucket_ladder(8, 1.0)  # growth 1 = no bucketing at all
    with pytest.raises(ValueError):
        bucketing.set_donation("yes")


# ---------------------------------------------------------------------------
# coo_aggregate: identity padding across bucket boundaries
# ---------------------------------------------------------------------------


def _agg_host(u, s):
    """Drop the device result's padding/zero cells -> host canonical form."""
    u, s = np.asarray(u), np.asarray(s)
    keep = s != 0.0
    return u[keep], s[keep]


@pytest.mark.parametrize("n", [1, 3, 4, 5, 8, 9, 16])
def test_coo_aggregate_padded_identity(tiny_ladder, n):
    """Bucket-padded aggregation is bit-identical to the host oracle at
    below-/at-/above-boundary sizes of the (4, 2.0) ladder."""
    rng = np.random.default_rng(n)
    codes = rng.integers(0, 6, n).astype(np.int64)
    weights = rng.integers(-3, 4, n).astype(np.float32)  # signed, Möbius-style
    u, s = ops.coo_aggregate(codes, weights)
    assert int(u.shape[0]) == bucketing.bucket_rows(n)  # on the ladder
    got_u, got_s = _agg_host(u, s)
    want_u, want_s = aggregate_codes(codes, weights)
    np.testing.assert_array_equal(got_u, want_u)
    np.testing.assert_array_equal(got_s, want_s)  # bitwise, not close


def test_coo_aggregate_empty(tiny_ladder):
    u, s = ops.coo_aggregate(np.zeros(0, np.int64), np.zeros(0, np.float32))
    assert u.shape == (0,) and s.shape == (0,)


def test_coo_aggregate_ladder_independent():
    """The same stream aggregates to the same cells on any ladder."""
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 50, 37).astype(np.int64)
    weights = np.ones(37, np.float32)
    with_default = _agg_host(*ops.coo_aggregate(codes, weights))
    old = bucketing.set_bucket_ladder(4, 3.0)
    try:
        with_tiny = _agg_host(*ops.coo_aggregate(codes, weights))
    finally:
        bucketing.set_bucket_ladder(*old)
    np.testing.assert_array_equal(with_default[0], with_tiny[0])
    np.testing.assert_array_equal(with_default[1], with_tiny[1])


# ---------------------------------------------------------------------------
# coo_join: bucketed match table vs brute force at boundary sizes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("ns,np_", [(3, 4), (4, 4), (5, 9), (8, 8), (16, 5)])
def test_coo_join_padded_identity(tiny_ladder, impl, ns, np_):
    rng = np.random.default_rng(ns * 31 + np_)
    skeys = np.sort(rng.integers(0, 5, ns)).astype(np.int32)
    pkeys = rng.integers(0, 5, np_).astype(np.int32)
    ia, ib, valid, total = ops.coo_join(
        jnp.asarray(skeys), jnp.asarray(pkeys), impl=impl
    )
    want = [
        (int(m), j) for j, p in enumerate(pkeys) for m in np.flatnonzero(skeys == p)
    ]
    assert total == len(want)
    if total:
        assert int(ia.shape[0]) == bucketing.bucket_rows(total)
        got = list(zip(np.asarray(ia)[:total].tolist(),
                       np.asarray(ib)[:total].tolist()))
        assert got == want
        np.testing.assert_array_equal(
            np.asarray(valid), np.arange(int(ia.shape[0])) < total
        )


# ---------------------------------------------------------------------------
# sparse_family_score: padded stream scores bit-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_cells", [1, 3, 4, 5, 8])
def test_sparse_family_score_padded_identity(tiny_ladder, n_cells):
    """Bucket padding (code 0 / weight 0) leaves fused scores bitwise
    unchanged and matching the float64 host path."""
    rng = np.random.default_rng(n_cells)
    child_card, parent_card = 3, 4
    space = child_card * parent_card
    codes = np.sort(rng.choice(space, size=n_cells, replace=False)).astype(np.int32)
    counts = rng.integers(1, 9, n_cells).astype(np.float32)
    got = float(ops.sparse_family_score(codes, counts, child_card, space, impl="ref"))
    old = bucketing.set_bucket_ladder(1024, 2.0)  # no padding at this size
    try:
        unpadded = float(
            ops.sparse_family_score(codes, counts, child_card, space, impl="ref")
        )
    finally:
        bucketing.set_bucket_ladder(*old)
    assert got == unpadded
    fct = SparseCT(
        ("p", "c"), (parent_card, child_card), codes.astype(np.int64), counts
    )
    want, _ = sparse_family_stats(fct, "c", ("p",))
    assert abs(got - want) <= 1e-12 * max(1.0, abs(want))


# ---------------------------------------------------------------------------
# Device build under a tiny ladder stays bit-identical to the host build
# ---------------------------------------------------------------------------


def test_device_build_bit_identical_under_tiny_ladder(tiny_ladder):
    db = university_db()
    host = joint_contingency_table(db, impl="sparse")
    dev = joint_contingency_table(db, impl="sparse", device_resident=True)
    assert isinstance(host, SparseCT) and isinstance(dev, DeviceSparseCT)
    got = dev.to_host()
    assert got.rvs == host.rvs and got.cards == host.cards
    np.testing.assert_array_equal(got.codes, host.codes)
    np.testing.assert_array_equal(got.counts, host.counts)


# ---------------------------------------------------------------------------
# Compile accounting + cache warmth
# ---------------------------------------------------------------------------


needs_probe = pytest.mark.skipif(
    not bucketing.compile_probe_active(),
    reason="jax.monitoring compile listener unavailable on this JAX",
)


@needs_probe
def test_compile_counter_sees_fresh_compiles():
    ops.reset_compile_counts()
    # a program no other test compiles: unique constant baked into the jaxpr
    @jax.jit
    def fresh(x):
        return x * 7919.25 + 1e-7

    fresh(jnp.arange(33, dtype=jnp.float32)).block_until_ready()
    counts = ops.compile_counts()
    assert counts["compiles"] >= 1
    assert counts["compile_secs"] > 0.0
    ops.reset_compile_counts()
    fresh(jnp.arange(33, dtype=jnp.float32)).block_until_ready()  # cache hit
    assert ops.compile_counts()["compiles"] == 0


@needs_probe
def test_second_build_performs_zero_new_compiles():
    """The cache-warmth contract: rebuilding a same-bucket joint hits only
    already-compiled programs — the compile counter stays at zero."""
    db = university_db()
    joint_contingency_table(db, impl="sparse", device_resident=True)
    ops.reset_compile_counts()
    dev = joint_contingency_table(db, impl="sparse", device_resident=True)
    assert ops.compile_counts()["compiles"] == 0
    assert dev.n_nonzero() > 0  # the warm build still did real work


# ---------------------------------------------------------------------------
# Donation + persistent-cache knobs
# ---------------------------------------------------------------------------


def test_donation_forced_on_padded_path(tiny_ladder):
    """REPRO_DONATE=1 routes padded temporaries through the donating jit;
    results are unchanged (on CPU, XLA ignores the donation and warns)."""
    old = bucketing.set_donation("1")
    try:
        assert bucketing.donate_buffers()
        codes = np.asarray([5, 2, 5], np.int64)
        weights = np.asarray([1.0, 2.0, 3.0], np.float32)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            u, s = ops.coo_aggregate(codes, weights)
        got_u, got_s = _agg_host(u, s)
        np.testing.assert_array_equal(got_u, [2, 5])
        np.testing.assert_array_equal(got_s, [2.0, 4.0])
    finally:
        bucketing.set_donation(old)
    # set_donation returns the previous mode (the restore contract)
    assert bucketing.set_donation("0") == old
    assert bucketing.set_donation(old) == "0"


def test_persistent_cache_knob(tmp_path):
    """enable_persistent_cache points JAX's compilation cache at the dir
    and zeroes the persistence thresholds (REPRO_JAX_CACHE_DIR wiring)."""
    before = jax.config.jax_compilation_cache_dir
    try:
        bucketing.enable_persistent_cache(tmp_path)
        assert jax.config.jax_compilation_cache_dir == str(tmp_path)
        assert jax.config.jax_persistent_cache_min_entry_size_bytes == -1
        assert jax.config.jax_persistent_cache_min_compile_time_secs == 0.0
    finally:
        jax.config.update("jax_compilation_cache_dir", before)
