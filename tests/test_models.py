"""Per-arch smoke tests + decode/forward consistency oracles.

Every assigned architecture instantiates its REDUCED config, runs one
forward/train step on CPU, asserts output shapes and finiteness, and checks
the analytic parameter count matches the real pytree leaf-for-leaf.  The
decode-consistency tests are the strongest correctness check in the suite:
token-by-token decode with ring caches must reproduce the full-sequence
forward pass.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.params import count_params_analytic
from repro.models.transformer import count_params, forward, init_params
from repro.serving.decode import decode_step, init_cache
from repro.training.optimizer import AdamWConfig
from repro.training.step import make_train_step, init_train_state

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["memory"] = jax.random.normal(KEY, (b, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        batch["memory"] = jax.random.normal(KEY, (b, cfg.audio_frames, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    assert count_params(params) == count_params_analytic(cfg), arch
    batch = _batch(cfg)
    logits, _ = forward(params, cfg, batch["tokens"], memory=batch.get("memory"), remat=False)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    params2, opt2, metrics = step(*init_train_state(cfg, KEY), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, 2, 64)
    logits, cache2 = decode_step(params, cfg, cache, jnp.zeros((2, 1), jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"][0]) == 1


@pytest.mark.parametrize("arch", ["qwen3_4b", "granite_8b", "qwen15_32b", "minicpm_2b"])
def test_decode_matches_forward_dense(arch):
    """Token-by-token ring-cache decode == full-sequence forward (f32)."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    params = init_params(cfg, KEY)
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, tokens, remat=False)

    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-4, atol=2e-4
    )


def test_decode_matches_forward_ssm():
    cfg = dataclasses.replace(get_config("mamba2_130m", smoke=True), dtype="float32")
    params = init_params(cfg, KEY)
    b, s = 2, 32  # multiple of smoke ssm_chunk (16)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, tokens, remat=False)
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_hybrid():
    cfg = dataclasses.replace(get_config("hymba_1_5b", smoke=True), dtype="float32")
    params = init_params(cfg, KEY)
    b, s = 1, 16  # within the smoke sliding window (32): ring == full history
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    full_logits, _ = forward(params, cfg, tokens, remat=False)
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, cache, tokens[:, t : t + 1])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_history():
    """With window W, logits at position t must not depend on tokens < t-W+1."""
    from repro.models.layers import attention

    b, s, h, hd = 1, 64, 2, 8
    k1, k2 = jax.random.split(KEY)
    q = jax.random.normal(k1, (b, s, h, hd))
    k = jax.random.normal(k2, (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(9), (b, s, h, hd))
    w = 8
    out1 = attention(q, k, v, causal=True, window=w, chunk=16)
    # perturb keys/values far outside every window of the last position
    k_mod = k.at[:, :16].set(jax.random.normal(jax.random.PRNGKey(10), (b, 16, h, hd)))
    v_mod = v.at[:, :16].set(0.0)
    out2 = attention(q, k_mod, v_mod, causal=True, window=w, chunk=16)
    np.testing.assert_allclose(
        np.asarray(out1[:, 32:]), np.asarray(out2[:, 32:]), rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(np.asarray(out1[:, :16]), np.asarray(out2[:, :16]))


def test_chunked_attention_equals_full():
    from repro.models.layers import attention

    b, s, h, hd = 2, 64, 4, 16
    q = jax.random.normal(KEY, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, 2, hd))
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, 2, hd))
    full = attention(q, k, v, causal=True, chunk=128)   # full path (s<=chunk)
    chunked = attention(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), rtol=2e-5, atol=2e-5)
