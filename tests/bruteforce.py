"""Int64 numpy brute-force oracle for contingency tables.

Enumerates the full cross product of the first-order variables' populations
and counts every joint par-RV assignment — exponential, test-only.

``as_dense_array`` normalizes either count backend (dense tensor or COO
``SparseCT``) to a numpy array so every oracle check can run parametrized
over ``impl in ("ref", "sparse")``.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core.schema import KIND_ENTITY_ATTR, KIND_REL
from repro.core.sparse_counts import SparseCT

#: the impl sweep every dense oracle test also runs with (sparse backend)
CT_IMPLS = ("ref", "sparse")


def as_dense_array(ct) -> np.ndarray:
    """Dense numpy view of a ContingencyTable or SparseCT (same layout)."""
    if isinstance(ct, SparseCT):
        ct = ct.to_dense()
    return np.asarray(ct.table)


def brute_force_ct(db, rvs: tuple[str, ...], *, group_fovar=None,
                   restrict=None) -> np.ndarray:
    cat = db.catalog
    want = [cat[v] for v in rvs]
    restrict = restrict or {}

    fovars: list[str] = []
    for rv in want:
        for f in rv.fovars:
            if f.fid not in fovars:
                fovars.append(f.fid)
    if group_fovar is not None and group_fovar not in fovars:
        fovars.append(group_fovar)
    for f in restrict:
        if f not in fovars:
            fovars.append(f)

    pops = {f: db.entities[cat.fovar(f).entity].n_rows for f in fovars}
    rel_index: dict[str, dict[tuple[int, int], int]] = {}
    for rname, rel in db.relationships.items():
        fk1 = np.asarray(rel.fk1)
        fk2 = np.asarray(rel.fk2)
        rel_index[rname] = {(int(a), int(b)): i for i, (a, b) in enumerate(zip(fk1, fk2))}

    shape = tuple(v.cardinality for v in want)
    if group_fovar is not None:
        shape = (pops[group_fovar],) + shape
    out = np.zeros(shape, np.int64)

    for combo in itertools.product(*(range(pops[f]) for f in fovars)):
        assign = dict(zip(fovars, combo))
        if any(assign[f] != e for f, e in restrict.items()):
            continue
        idx = []
        for rv in want:
            if rv.kind == KIND_ENTITY_ATTR:
                row = assign[rv.fovars[0].fid]
                idx.append(int(np.asarray(db.entities[rv.table].attrs[rv.column])[row]))
            elif rv.kind == KIND_REL:
                key = (assign[rv.fovars[0].fid], assign[rv.fovars[1].fid])
                idx.append(1 if key in rel_index[rv.table] else 0)
            else:  # rel attr
                key = (assign[rv.fovars[0].fid], assign[rv.fovars[1].fid])
                r = rel_index[rv.table].get(key)
                if r is None:
                    idx.append(0)
                else:
                    idx.append(int(np.asarray(db.relationships[rv.table].attrs[rv.column])[r]))
        if group_fovar is not None:
            idx = [assign[group_fovar]] + idx
        out[tuple(idx)] += 1
    return out


def random_db(seed: int, *, n_entities=(3, 4), n_rel_rows=5, self_rel=False):
    """Small random database for property tests."""
    from repro.core.database import from_labels
    from repro.core.schema import make_schema

    rng = np.random.default_rng(seed)
    n1, n2 = n_entities
    schema = make_schema(
        entities={
            "alpha": {"a1": ("x", "y"), "a2": ("p", "q", "r")},
            "beta": {"b1": ("u", "v", "w")},
        },
        relationships={
            "R": (("alpha", "alpha") if self_rel else ("alpha", "beta"),
                  {"ra": ("m", "n")}),
        },
    )
    ents = {
        "alpha": {
            "a1": [("x", "y")[i] for i in rng.integers(0, 2, n1)],
            "a2": [("p", "q", "r")[i] for i in rng.integers(0, 3, n1)],
        },
        "beta": {"b1": [("u", "v", "w")[i] for i in rng.integers(0, 3, n2)]},
    }
    lim2 = n1 if self_rel else n2
    pairs = set()
    while len(pairs) < min(n_rel_rows, n1 * lim2 - (n1 if self_rel else 0)):
        i, j = int(rng.integers(0, n1)), int(rng.integers(0, lim2))
        if self_rel and i == j:
            continue
        pairs.add((i, j))
    pairs = sorted(pairs)
    rels = {
        "R": {
            "fk1": [p[0] for p in pairs],
            "fk2": [p[1] for p in pairs],
            "attrs": {"ra": [("m", "n")[i] for i in rng.integers(0, 2, len(pairs))]},
        }
    }
    return from_labels(schema, ents, rels)
