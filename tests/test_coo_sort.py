"""The fused Pallas COO sort kernel vs its XLA oracle.

``kernels/coo_sort.py`` must reproduce ``ops._coo_aggregate_impl``'s output
bit-for-bit on every stream shape the device build can produce: ascending
unique codes as a prefix, int64-max / zero-count identity padding after,
float32 sums rounded from the same accumulation dtype.  Runs in interpret
mode on CPU (the CI pallas-dispatch leg re-runs it the same way), so the
streams here are deliberately small — the bitonic network is O(log^2 n)
compare-exchange stages and interpret mode executes them op by op.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.kernels import ops
from repro.kernels.coo_sort import coo_sort_aggregate

PAD = np.iinfo(np.int64).max


def _oracle(codes, weights):
    """The XLA sort + segment-sum path, same local x64 scope as dispatch."""
    with enable_x64():
        uniq, sums = ops._coo_aggregate_impl(
            jnp.asarray(codes, jnp.int64), jnp.asarray(weights, jnp.float32)
        )
        return np.asarray(uniq), np.asarray(sums)


def _kernel(codes, weights):
    with enable_x64():
        uniq, sums = coo_sort_aggregate(
            jnp.asarray(codes, jnp.int64),
            jnp.asarray(weights, jnp.float32),
            interpret=True,
            acc=ops.count_acc_dtype(),
        )
        return np.asarray(uniq), np.asarray(sums)


def _assert_matches_oracle(codes, weights):
    ou, os_ = _oracle(codes, weights)
    ku, ks = _kernel(codes, weights)
    np.testing.assert_array_equal(ku, ou)
    np.testing.assert_array_equal(ks, os_)  # bitwise, not allclose


def _stream(n, n_codes, seed=0, hi_bits=False):
    """Integer-count stream with many duplicate codes."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, n_codes, n).astype(np.int64)
    if hi_bits:
        # push codes past 32 bits so the hi/lo split carries real weight
        codes = codes * (1 << 40) + rng.integers(0, 1 << 20, n)
    weights = rng.integers(0, 50, n).astype(np.float32)
    return codes, weights


def test_duplicates_match_oracle():
    _assert_matches_oracle(*_stream(200, 17, seed=1))


def test_all_equal_keys():
    codes = np.full(160, 12345, np.int64)
    weights = np.arange(160, dtype=np.float32)
    ku, ks = _kernel(codes, weights)
    assert ku[0] == 12345 and ks[0] == weights.sum()
    assert (ku[1:] == PAD).all() and (ks[1:] == 0).all()
    _assert_matches_oracle(codes, weights)


def test_already_sorted_and_reversed():
    codes, weights = _stream(150, 40, seed=2)
    order = np.argsort(codes, kind="stable")
    _assert_matches_oracle(codes[order], weights[order])
    _assert_matches_oracle(codes[order][::-1], weights[order][::-1])


def test_empty_stream():
    ku, ks = _kernel(np.zeros(0, np.int64), np.zeros(0, np.float32))
    assert ku.shape == (0,) and ks.shape == (0,)


@pytest.mark.parametrize("n", [1, 127, 128, 129, 255, 256])
def test_rung_boundary_streams(n):
    """Exact power-of-two rungs (no internal padding) and one-off-the-edge
    lengths (max internal padding) — the shapes the bucket ladder feeds."""
    _assert_matches_oracle(*_stream(n, max(2, n // 3), seed=n))


def test_identity_padded_input_keeps_pad_run():
    """Bucket-padded streams (int64-max codes, zero weights) aggregate the
    pad run to a single zero-count cell, exactly like the oracle."""
    codes, weights = _stream(100, 10, seed=3)
    codes = np.concatenate([codes, np.full(28, PAD, np.int64)])
    weights = np.concatenate([weights, np.zeros(28, np.float32)])
    _assert_matches_oracle(codes, weights)
    ku, ks = _kernel(codes, weights)
    assert (ku == PAD).sum() >= 1 and ks[ku == PAD].sum() == 0


def test_int64_hi_lo_split_round_trip():
    """Codes straddling the int32 lane split — low words around the sign
    bias, high words far above 32 bits — survive split + sort + recombine."""
    codes = np.array(
        [0, 1, (1 << 31) - 1, 1 << 31, (1 << 32) - 1, 1 << 32,
         (1 << 40) + 7, (1 << 62) + 5, PAD - 1, 3, 1 << 31, (1 << 40) + 7],
        np.int64,
    )
    weights = np.ones(len(codes), np.float32)
    ku, ks = _kernel(codes, weights)
    uniq, counts = np.unique(codes, return_counts=True)
    np.testing.assert_array_equal(ku[: len(uniq)], uniq)
    np.testing.assert_array_equal(ks[: len(uniq)], counts.astype(np.float32))
    assert (ku[len(uniq):] == PAD).all()
    _assert_matches_oracle(codes, weights)


def test_dispatch_forced_pallas_matches_xla():
    """ops.coo_aggregate under REPRO_SORT_IMPL=pallas (interpret on CPU)
    == the same call under =xla, and the launch counter attributes it."""
    codes, weights = _stream(180, 25, seed=4, hi_bits=True)
    old = ops.set_sort_impl("xla")
    try:
        xu, xs = ops.coo_aggregate(codes, weights)
        ops.set_sort_impl("pallas")
        ops.reset_launch_counts()
        pu, ps = ops.coo_aggregate(codes, weights)
        assert ops.launch_counts().get("coo_sort") == 1
    finally:
        ops.set_sort_impl(old)
    np.testing.assert_array_equal(np.asarray(pu), np.asarray(xu))
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(xs))


def test_dispatch_int32_streams_stay_on_xla():
    """int32 code streams never route to the kernel (it exists for the
    int64 composite keys) even under a forced pallas policy."""
    codes = np.array([3, 1, 3, 2, 1, 3], np.int32)
    weights = np.ones(6, np.float32)
    old = ops.set_sort_impl("pallas")
    try:
        ops.reset_launch_counts()
        uniq, sums = ops.coo_aggregate(codes, weights)
        assert "coo_sort" not in ops.launch_counts()
    finally:
        ops.set_sort_impl(old)
    u = np.asarray(uniq)
    np.testing.assert_array_equal(u[:3], [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(sums)[:3], [2.0, 1.0, 3.0])
