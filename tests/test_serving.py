"""Serving tier: micro-batched block prediction, bitwise-correct and warm.

The service contract (``repro.serving.predict_service``): served
posteriors are *bitwise* equal to ``predict_single_loop`` on the same
model, micro-batches flush on size or deadline, the bounded queue sheds
load loudly, and steady traffic after :meth:`warmup` compiles zero new
XLA programs.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.cpt import learn_parameters
from repro.core.database import university_db
from repro.core.model_store import LearnedModel, load_model, save_model
from repro.core.predict import predict_single_loop
from repro.core.structure import CountCache, learn_and_join
from repro.serving.predict_service import (
    PredictService,
    ServedPrediction,
    ServiceOverloaded,
)

TARGET = "intelligence(student0)"


@pytest.fixture(scope="module")
def learned():
    db = university_db()
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(
        db, cache, score="aic", max_parents=2, max_chain=1, impl="ref"
    )
    factors = learn_parameters(res.bn, cache, alpha=0.1, impl="ref")
    model = LearnedModel(schema=db.schema, bn=res.bn, factors=factors)
    # the single-instance oracle, computed up front so its compiles stay
    # out of every test's warm window
    oracle = predict_single_loop(db, res.bn, factors, TARGET, impl="ref")
    return db, model, np.asarray(oracle.probs), np.asarray(oracle.log_scores)


@pytest.fixture()
def service(learned):
    db, model, _, _ = learned
    svc = PredictService(db, model, TARGET, max_batch=16, flush_ms=2.0, impl="ref")
    svc.warmup()
    yield svc
    svc.close()


# ---------------------------------------------------------------------------
# bitwise correctness
# ---------------------------------------------------------------------------


def test_served_bitwise_equals_single_loop(learned, service):
    _, _, op, ol = learned
    for ids in ([0], [1, 2], [0, 1, 2], [2, 2, 0, 1, 2]):
        r = service.predict(ids)
        assert isinstance(r, ServedPrediction)
        assert np.array_equal(r.probs, op[ids]), ids
        assert np.array_equal(r.log_scores, ol[ids]), ids
        assert r.probs.shape == (len(ids), service.n_y)


def test_batched_with_strangers_still_bitwise(learned, service):
    """A request's rows don't depend on who shares its micro-batch."""
    _, _, op, _ = learned
    futs = [service.submit([i % 3]) for i in range(32)]
    for i, fut in enumerate(futs):
        r = fut.result(timeout=30)
        assert np.array_equal(r.probs, op[[i % 3]])


def test_serves_from_reloaded_artifact(learned, tmp_path):
    db, model, op, _ = learned
    loaded = load_model(save_model(model, tmp_path / "m.npz"))
    with PredictService(db, loaded, TARGET, impl="ref") as svc:
        svc.warmup()
        r = svc.predict([0, 1, 2])
        assert np.array_equal(r.probs, op[[0, 1, 2]])


# ---------------------------------------------------------------------------
# micro-batching behavior
# ---------------------------------------------------------------------------


def test_concurrent_requests_coalesce(learned):
    db, model, op, _ = learned
    svc = PredictService(db, model, TARGET, max_batch=64, flush_ms=20.0, impl="ref")
    svc.warmup()
    try:
        futs = [svc.submit([i % 3]) for i in range(24)]
        for f in futs:
            f.result(timeout=30)
        st = svc.stats()
        assert st["answered"] == 24
        # 24 one-row requests under a generous deadline must NOT run as 24
        # single-row launches — coalescing is the point of the service
        assert st["batches"] < 24
        assert st["rows_per_batch"] > 1.0
    finally:
        svc.close()


def test_flush_on_max_batch_size(learned):
    db, model, _, _ = learned
    # deadline far away: only the size trigger can flush
    svc = PredictService(db, model, TARGET, max_batch=4, flush_ms=5_000.0, impl="ref")
    svc.warmup()
    try:
        futs = [svc.submit([0]) for _ in range(4)]
        for f in futs:
            f.result(timeout=10)  # would hang ~5s if size didn't trigger
        assert svc.stats()["batches"] == 1
    finally:
        svc.close()


def test_flush_on_deadline(learned):
    db, model, op, _ = learned
    svc = PredictService(db, model, TARGET, max_batch=1024, flush_ms=30.0, impl="ref")
    svc.warmup()
    try:
        t0 = time.perf_counter()
        r = svc.predict([1], timeout=10)
        elapsed = time.perf_counter() - t0
        assert np.array_equal(r.probs, op[[1]])
        assert elapsed < 5.0  # the deadline, not max_batch=1024, flushed it
    finally:
        svc.close()


def test_queue_bound_sheds_load(learned):
    db, model, _, _ = learned
    svc = PredictService(db, model, TARGET, queue_size=2, flush_ms=50.0, impl="ref")
    # stall the worker by filling the queue faster than one flush window
    with pytest.raises(ServiceOverloaded):
        for _ in range(200):
            svc.submit([0])
    svc.close()


# ---------------------------------------------------------------------------
# warm-path compile hygiene
# ---------------------------------------------------------------------------


def test_zero_warm_compiles_across_batch_sizes(learned, service):
    for ids in ([0], [0, 1], [0, 1, 2], list(range(3)) * 5):
        service.predict(ids)
    st = service.stats()
    assert st["warm_compiles"] == 0, st


def test_warmup_reports_rungs(learned):
    db, model, _, _ = learned
    svc = PredictService(db, model, TARGET, max_batch=16, impl="ref")
    try:
        info = svc.warmup()
        assert info["rungs"]  # at least one rung compiled
        assert all(r >= 2 for r in info["rungs"])
        # second warmup is a no-op compile-wise: everything already cached
        assert svc.warmup()["compiles"] == 0
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# validation + lifecycle
# ---------------------------------------------------------------------------


def test_rejects_out_of_range_ids(service):
    with pytest.raises(ValueError, match="entity ids"):
        service.predict([service.n_entities])
    with pytest.raises(ValueError, match="entity ids"):
        service.predict([-1])


def test_rejects_empty_request(service):
    with pytest.raises(ValueError, match="non-empty"):
        service.predict([])


def test_rejects_schema_mismatch(learned):
    from repro.data.relational import BENCHMARKS, generate

    db, model, _, _ = learned
    other = generate(BENCHMARKS["uw-cse"].scaled(0.05), seed=0)
    with pytest.raises(ValueError, match="schema"):
        PredictService(other, model, TARGET)


def test_rejects_relationship_target(learned):
    db, model, _, _ = learned
    rel_attrs = [v.vid for v in db.catalog.rel_attrs]
    if not rel_attrs:
        pytest.skip("no relationship attributes in the catalog")
    with pytest.raises(ValueError, match="entity attributes"):
        PredictService(db, model, rel_attrs[0])


def test_submit_after_close_raises(learned):
    db, model, _, _ = learned
    svc = PredictService(db, model, TARGET, impl="ref")
    svc.close()
    svc.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit([0])


def test_submit_returns_future(learned, service):
    fut = service.submit([0])
    assert isinstance(fut, Future)
    fut.result(timeout=30)


def test_thread_safe_submission(learned, service):
    _, _, op, _ = learned
    errors: list[Exception] = []

    def hammer(worker_id):
        try:
            for i in range(16):
                r = service.predict([(worker_id + i) % 3], timeout=30)
                assert np.array_equal(r.probs, op[[(worker_id + i) % 3]])
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors
