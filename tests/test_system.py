"""End-to-end system behaviour: the paper's full pipeline on the running
example and on a scaled benchmark database, plus schema-analyzer contracts."""

import numpy as np
import pytest

from repro.core import (
    CountCache,
    analyze_schema,
    learn_and_join,
    learn_parameters,
    predict_block,
    score_structure,
    university_db,
)
from repro.core.schema import N_A, make_schema
from repro.data.relational import BENCHMARKS, UW_CSE, generate


def test_schema_analyzer_university():
    db = university_db()
    cat = db.catalog
    vids = {v.vid for v in cat.par_rvs}
    assert vids == {
        "intelligence(student0)", "ranking(student0)", "popularity(prof0)",
        "teachingability(prof0)", "RA(prof0,student0)",
        "salary(prof0,student0)", "capability(prof0,student0)",
    }
    sal = cat["salary(prof0,student0)"]
    assert sal.domain[0] == N_A and sal.cardinality == 4
    assert cat["RA(prof0,student0)"].domain == ("F", "T")


def test_schema_analyzer_self_relationship():
    schema = make_schema(
        entities={"person": {"age": ("1", "2")}},
        relationships={"knows": (("person", "person"), {})},
    )
    cat = analyze_schema(schema)
    vids = {v.vid for v in cat.par_rvs}
    # self-relationships duplicate the entity's attribute par-RVs (paper App.)
    assert vids == {"age(person0)", "age(person1)", "knows(person0,person1)"}


def test_benchmark_specs_match_table5():
    """Table V invariants: #relationship tables and #par-RVs per dataset."""
    expect = {
        "movielens": (1, 7), "mutagenesis": (2, 11), "uw-cse": (2, 14),
        "mondial": (2, 18), "hepatitis": (3, 19), "imdb": (3, 17),
    }
    for name, (n_rel, n_rv) in expect.items():
        spec = BENCHMARKS[name]
        assert len(spec.rels) == n_rel, name
        assert spec.n_par_rvs == n_rv, (name, spec.n_par_rvs)


def test_full_pipeline_university():
    db = university_db()
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(db, cache, score="aic", max_parents=2, max_chain=1, impl="ref")
    factors = learn_parameters(res.bn, cache, alpha=0.1, impl="ref")
    scores = score_structure(res.bn, cache, alpha=0.1, impl="ref")
    assert scores.loglik < 0 and scores.n_params > 0
    pred = predict_block(db, res.bn, factors, "intelligence(student0)", impl="ref")
    p = np.asarray(pred.probs)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-5)
    assert p.shape == (3, 3)


@pytest.mark.slow
def test_full_pipeline_benchmark_db():
    """The whole system on a scaled UW-CSE-like DB (self-rel + 2 chains)."""
    db = generate(UW_CSE.scaled(0.5), seed=4)
    cache = CountCache(db, mode="precount", impl="ref")
    assert cache.joint.n_nonzero() > 50
    res = learn_and_join(db, cache, score="bic", max_parents=2, max_chain=2, impl="ref")
    assert res.bn.is_acyclic() and res.bn.n_edges >= 4
    factors = learn_parameters(res.bn, cache, alpha=0.1, impl="ref")
    pred = predict_block(db, res.bn, factors, "position(person0)", impl="ref")
    true = np.asarray(db.entities["person"].attrs["position"])
    import jax.numpy as jnp

    acc = pred.accuracy(jnp.asarray(true))
    base = max(np.bincount(true)) / len(true)
    # planted attribute chains must make the learned model beat chance
    assert acc >= base - 0.05, (acc, base)
