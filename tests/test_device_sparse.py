"""Device-resident COO backend (DeviceSparseCT) + the fused
sparse_family_score kernel: host/device cell equivalence, marginal_batch
edge cases over both backends, bit-comparable totals, kernel-vs-oracle, and
structure-search equivalence of the fused device scoring path."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import counts
from repro.core.counts import joint_contingency_table
from repro.core.database import university_db
from repro.core.score_manager import CountCache, ScoreManager
from repro.core.scores import score_family
from repro.core.sparse_counts import (
    DeviceSparseCT,
    SparseCT,
    TOTAL_ACC_DTYPE,
    aggregate_codes,
    as_host,
    sparse_family_stats,
)
from repro.core.structure import hill_climb, learn_and_join
from repro.kernels import ops

from .bruteforce import random_db

UNIV_RVS = (
    "intelligence(student0)",
    "ranking(student0)",
    "popularity(prof0)",
    "teachingability(prof0)",
)


def _univ_joint(device: bool):
    db = university_db()
    jt = joint_contingency_table(db, impl="sparse", device_resident=device)
    assert isinstance(jt, DeviceSparseCT if device else SparseCT)
    return jt


def _assert_same_cells(host_ct: SparseCT, other) -> None:
    got = as_host(other)
    assert got.rvs == host_ct.rvs and got.cards == host_ct.cards
    np.testing.assert_array_equal(got.codes, host_ct.codes)
    np.testing.assert_allclose(got.counts, host_ct.counts)


# ---------------------------------------------------------------------------
# Residency round-trip + totals
# ---------------------------------------------------------------------------


def test_device_roundtrip_canonical():
    host = _univ_joint(device=False)
    dev = host.to_device()
    back = dev.to_host()
    np.testing.assert_array_equal(back.codes, host.codes)
    np.testing.assert_array_equal(back.counts, host.counts)
    assert back.codes.dtype == np.int64 and back.counts.dtype == np.float32
    assert dev.n_cells == host.n_cells
    assert dev.n_nonzero() == host.n_nonzero()


def test_total_accumulation_dtype_bit_comparable():
    """host/device totals are BIT-identical: one shared accumulation dtype.

    Counts are integer-valued float32, so float64 accumulation
    (TOTAL_ACC_DTYPE) is exact on both backends regardless of reduction
    order — the documented contract behind the shared dtype.
    """
    assert TOTAL_ACC_DTYPE == np.float64
    for seed in (0, 7):
        host = joint_contingency_table(random_db(seed), impl="sparse")
        dev = host.to_device()
        th, td = host.total(), dev.total()
        assert th.dtype == np.float32 and td.dtype == np.float32
        assert th.tobytes() == td.tobytes(), (th, td)


# ---------------------------------------------------------------------------
# Device CT algebra == host CT algebra
# ---------------------------------------------------------------------------


def test_device_marginal_transpose_match_host():
    host = _univ_joint(device=False)
    dev = host.to_device()
    rvs = host.rvs
    for keep in [(rvs[2],), (rvs[3], rvs[1]), (rvs[4], rvs[0], rvs[2])]:
        _assert_same_cells(host.marginal(keep), dev.marginal(keep))
    _assert_same_cells(host.transpose(rvs[::-1]), dev.transpose(rvs[::-1]))


def test_device_contingency_table_flag():
    db = university_db()
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    ct = counts.contingency_table(db, rvs, impl="sparse", device_resident=True)
    assert isinstance(ct, DeviceSparseCT)
    # dense backends are jax arrays already: the flag must be a no-op
    dense = counts.contingency_table(db, rvs[:2], impl="ref", device_resident=True)
    assert isinstance(dense, counts.ContingencyTable)


def test_device_marginal_batch_stays_on_device():
    dev = _univ_joint(device=True)
    ops.reset_launch_counts()
    outs = dev.marginal_batch([(dev.rvs[0],), (dev.rvs[1], dev.rvs[2])])
    assert all(isinstance(o, DeviceSparseCT) for o in outs)
    assert ops.launch_counts().get("coo_aggregate") == 1  # ONE fused sort
    assert ops.launch_counts().get("sorted_segment_sum") is None  # no host agg


# ---------------------------------------------------------------------------
# marginal_batch edge cases, parametrized over host and device backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_empty_keeps_list(device):
    assert _univ_joint(device).marginal_batch([]) == []


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_empty_keep_tuple(device):
    """keep == () is the grand total: a single scalar cell."""
    jt = _univ_joint(device)
    (out,) = jt.marginal_batch([()])
    got = as_host(out)
    assert got.rvs == () and got.cards == ()
    np.testing.assert_array_equal(got.codes, [0])
    np.testing.assert_allclose(got.counts, [float(jt.total())])


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_keep_all_rvs(device):
    """The full-width marginal reproduces the joint cell-for-cell."""
    jt = _univ_joint(device)
    host = as_host(jt)
    (out,) = jt.marginal_batch([jt.rvs])
    _assert_same_cells(host, out)


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_duplicate_keeps_shared_prefix(device):
    """Duplicate keeps and prefix-sharing keeps stay independent slots."""
    jt = _univ_joint(device)
    host = as_host(jt)
    rvs = jt.rvs
    keeps = [
        (rvs[0], rvs[1]),
        (rvs[0], rvs[1]),          # exact duplicate
        (rvs[0], rvs[1], rvs[2]),  # shares the (rvs0, rvs1) prefix
        (rvs[0],),
    ]
    outs = jt.marginal_batch(list(keeps))
    assert len(outs) == len(keeps)
    for keep, out in zip(keeps, outs):
        _assert_same_cells(host.marginal(keep), out)


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_single_nonzero_cell(device):
    """A one-cell table marginalizes to one-cell tables on every keep."""
    ct = SparseCT(
        ("a", "b", "c"), (3, 4, 5),
        np.asarray([2 * 20 + 1 * 5 + 3], np.int64),  # (a=2, b=1, c=3)
        np.asarray([7.0], np.float32),
    )
    jt = ct.to_device() if device else ct
    outs = jt.marginal_batch([("b",), ("c", "a"), (), ("a", "b", "c")])
    for keep, digits in zip(
        [("b",), ("c", "a"), (), ("a", "b", "c")],
        [(1,), (3, 2), (), (2, 1, 3)],
    ):
        got = as_host(outs.pop(0))
        ser = ct.marginal(keep)
        _assert_same_cells(ser, got)
        assert got.n_nonzero() == 1
        np.testing.assert_allclose(got.counts, [7.0])
        # the surviving cell is the digit projection of the original cell
        cards = [ct.card_of(v) for v in keep]
        code = 0
        for d, s in zip(digits, counts.radix_strides(cards)):
            code += d * s
        np.testing.assert_array_equal(got.codes, [code])


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_unknown_rv_raises(device):
    with pytest.raises(KeyError):
        _univ_joint(device).marginal_batch([("nope",)])


@pytest.mark.parametrize("device", [False, True], ids=["host", "device"])
def test_marginal_batch_empty_table(device):
    """A zero-cell table marginalizes to zero-cell tables on every keep."""
    empty = SparseCT(
        ("a", "b"), (2, 3),
        np.zeros(0, np.int64), np.zeros(0, np.float32),
    )
    jt = empty.to_device() if device else empty
    outs = jt.marginal_batch([("a",), ("b", "a"), ()])
    for out in outs:
        got = as_host(out)
        assert got.n_nonzero() == 0
    assert float(jt.total()) == 0.0
    assert as_host(jt.marginal(("b",))).n_nonzero() == 0


def test_host_marginal_batch_device_sort_route():
    """Past the row threshold the host path aggregates via ONE device sort."""
    from repro.core import sparse_counts

    host = _univ_joint(device=False)
    old = sparse_counts._DEVICE_SORT_MIN_ROWS
    sparse_counts._DEVICE_SORT_MIN_ROWS = 1  # force the device route
    try:
        ops.reset_launch_counts()
        outs = host.marginal_batch([(host.rvs[0],), host.rvs])
        assert ops.launch_counts().get("coo_aggregate") == 1
    finally:
        sparse_counts._DEVICE_SORT_MIN_ROWS = old
    for keep, out in zip([(host.rvs[0],), host.rvs], outs):
        _assert_same_cells(host.marginal(keep), out)


# ---------------------------------------------------------------------------
# Fused sparse_family_score kernel: oracle + host ground truth
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_sparse_family_score_kernel_vs_oracle(alpha):
    """Pallas kernel == jnp oracle on a random prepared COO stream."""
    rng = np.random.default_rng(int(alpha * 10) + 3)
    n, b = 3000, 5
    fam = np.sort(rng.integers(0, b, n)).astype(np.int32)
    ctot = rng.integers(1, 9, n).astype(np.float32)
    ptot = ctot + rng.integers(0, 20, n).astype(np.float32)
    cc = rng.integers(2, 7, n).astype(np.float32)
    rep = (rng.random(n) < 0.3).astype(np.float32)
    args = [jnp.asarray(x) for x in (ctot, ptot, cc, rep, fam)]
    from repro.kernels.ref import sparse_family_score_ref
    from repro.kernels.sparse_score import sparse_family_score_pallas

    want = np.asarray(sparse_family_score_ref(*args, b, alpha))
    got = np.asarray(sparse_family_score_pallas(*args, b, alpha, interpret=True))
    assert got.shape == (b,)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_sparse_family_score_matches_host_stats(impl, alpha):
    """Fused batched scorer == sparse_family_stats per family (host truth),
    including duplicate (pre-aggregation) codes and empty families."""
    rng = np.random.default_rng(17)
    metas = [(6, 3), (1, 4), (12, 2), (2, 5)]  # (parent_configs, child_card)
    bounds = np.zeros(len(metas) + 1, np.int64)
    bounds[1:] = np.cumsum([p * c for p, c in metas])
    chunks, weights, want = [], [], []
    for i, (p, c) in enumerate(metas):
        n = 0 if i == 3 else 60  # family 3 has no realized cells
        codes = rng.integers(0, p * c, n).astype(np.int64)
        w = rng.integers(1, 6, n).astype(np.float32)
        chunks.append(codes + bounds[i])
        weights.append(w)
        u, s = aggregate_codes(codes, w)
        fct = SparseCT(("p", "c"), (p, c), u, s)
        ll, _ = sparse_family_stats(fct, "c", ("p",), alpha)
        want.append(ll)
    codes = np.concatenate(chunks).astype(np.int32)
    w = np.concatenate(weights)
    got = np.asarray(
        ops.sparse_family_score_batched(
            jnp.asarray(codes), jnp.asarray(w),
            jnp.asarray(bounds.astype(np.int32)),
            jnp.asarray([c for _, c in metas], np.int32),
            alpha, impl=impl,
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_sparse_family_score_empty_stream(impl):
    """An empty COO stream scores every family to exactly 0.0 (no crash)."""
    got = np.asarray(
        ops.sparse_family_score_batched(
            jnp.asarray([], jnp.int32), jnp.asarray([], jnp.float32),
            jnp.asarray([0, 6, 10], jnp.int32), jnp.asarray([3, 5], jnp.int32),
            0.5, impl=impl,
        )
    )
    np.testing.assert_array_equal(got, [0.0, 0.0])
    single = ops.sparse_family_score(
        jnp.asarray([], jnp.int32), jnp.asarray([], jnp.float32), 3, 12, 0.5,
        impl=impl,
    )
    assert float(single) == 0.0


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_sparse_family_score_single(impl):
    rng = np.random.default_rng(5)
    p, c = 8, 3
    codes = rng.integers(0, p * c, 40).astype(np.int32)
    w = rng.integers(1, 5, 40).astype(np.float32)
    u, s = aggregate_codes(codes.astype(np.int64), w)
    fct = SparseCT(("p", "c"), (p, c), u, s)
    want, _ = sparse_family_stats(fct, "c", ("p",), 0.25)
    got = float(
        ops.sparse_family_score(
            jnp.asarray(codes), jnp.asarray(w), c, p * c, 0.25, impl=impl
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# ScoreManager: fused device scoring == host serial scoring
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha", [0.0, 0.5])
def test_device_score_batch_matches_serial(alpha):
    db = university_db()
    mgr = ScoreManager(db, mode="sparse", device_resident=True)
    ser = CountCache(db, mode="sparse")
    fams = [
        (UNIV_RVS[1], (UNIV_RVS[0],)),
        (UNIV_RVS[0], ()),
        (UNIV_RVS[3], (UNIV_RVS[2],)),
        ("salary(prof0,student0)", ("RA(prof0,student0)",)),
    ]
    got = mgr.score_batch(fams, alpha=alpha)
    for (child, parents), fs in zip(fams, got):
        want = score_family(ser, child, tuple(sorted(parents)), alpha)
        assert fs.child == child
        assert fs.n_params == want.n_params
        np.testing.assert_allclose(fs.loglik, want.loglik, rtol=1e-5, atol=1e-4)


def test_device_score_batch_chunks_match_serial():
    """Forced chunking (tiny row budget) changes launches, not scores."""
    db = university_db()
    mgr = ScoreManager(db, mode="sparse", device_resident=True)
    mgr.batch_min_candidates = 0  # router off: this pins the fused launches
    ser = CountCache(db, mode="sparse")
    fams = [
        (UNIV_RVS[1], (UNIV_RVS[0],)),
        (UNIV_RVS[0], ()),
        (UNIV_RVS[2], ()),
        (UNIV_RVS[3], (UNIV_RVS[2], UNIV_RVS[0])),
    ]
    old = mgr.SPARSE_BATCH_ROW_BUDGET
    mgr.SPARSE_BATCH_ROW_BUDGET = 1  # one family per launch
    try:
        groups = mgr._sparse_groups([(c, tuple(sorted(p))) for c, p in fams])
        assert len(groups) == len(fams)
        ops.reset_launch_counts()
        got = mgr.score_batch(fams)
        assert ops.launch_counts()["sparse_family_score"] == len(fams)
    finally:
        mgr.SPARSE_BATCH_ROW_BUDGET = old
    for (child, parents), fs in zip(fams, got):
        want = score_family(ser, child, tuple(sorted(parents)), 0.0)
        np.testing.assert_allclose(fs.loglik, want.loglik, rtol=1e-5, atol=1e-4)
        assert fs.n_params == want.n_params


@pytest.mark.parametrize("impl", ["auto", "pallas"])
def test_device_hill_climb_equals_serial(impl):
    db = university_db()
    ser = CountCache(db, mode="sparse")
    mgr = ScoreManager(db, mode="sparse", device_resident=True)
    kw = dict(score="aic", max_parents=2, impl=impl)
    r_ser = hill_climb(UNIV_RVS, ser, **kw)
    r_bat = hill_climb(UNIV_RVS, mgr, **kw)
    assert sorted(r_ser.bn.edges()) == sorted(r_bat.bn.edges())
    np.testing.assert_allclose(r_bat.score, r_ser.score, rtol=1e-5)
    assert r_bat.n_sweeps == r_ser.n_sweeps


def test_device_learn_and_join_launches_per_sweep():
    """The acceptance criterion: <= 3 fused launches per sweep, same model."""
    db = university_db()
    ser = CountCache(db, mode="sparse")
    a = learn_and_join(db, ser, score="aic", max_parents=2, max_chain=1)
    mgr = ScoreManager(db, mode="sparse", device_resident=True)
    ops.reset_launch_counts()
    b = learn_and_join(db, mgr, score="aic", max_parents=2, max_chain=1)
    assert sorted(a.bn.edges()) == sorted(b.bn.edges())
    assert ops.total_launches() <= 3 * max(b.n_sweeps, 1), (
        ops.launch_counts(), b.n_sweeps,
    )
    # the fused scorer is the ONLY op the sparse device sweep dispatches
    assert set(ops.launch_counts()) <= {"sparse_family_score", "coo_aggregate"}


def test_device_hill_climb_random_db():
    from repro.core.schema import KIND_ENTITY_ATTR

    db = random_db(7)
    rvs = tuple(v.vid for v in db.catalog.par_rvs if v.kind == KIND_ENTITY_ATTR)
    ser = hill_climb(rvs, CountCache(db, mode="sparse"), score="aic", max_parents=2)
    bat = hill_climb(
        rvs, ScoreManager(db, mode="sparse", device_resident=True),
        score="aic", max_parents=2,
    )
    assert sorted(ser.bn.edges()) == sorted(bat.bn.edges())
    np.testing.assert_allclose(bat.score, ser.score, rtol=1e-5)


def test_device_manager_still_serves_cts():
    """Device manager keeps the CountCache contract (learn_parameters path)."""
    from repro.core.cpt import learn_parameters

    db = university_db()
    mgr = ScoreManager(db, mode="sparse", device_resident=True)
    cache = CountCache(db, mode="sparse")
    fam = (UNIV_RVS[0], UNIV_RVS[1])
    _assert_same_cells(as_host(cache(fam)), mgr(fam))
    res = learn_and_join(db, mgr, score="aic", max_parents=2, max_chain=1)
    factors = learn_parameters(res.bn, mgr, alpha=0.1)
    assert set(factors) == set(res.bn.rvs)
