"""Dependency-light fallback for ``hypothesis``.

When the real ``hypothesis`` package is installed, this module is never
imported and the property tests run as actual hypothesis tests.  When it is
absent (minimal CI images, the bundled toolchain), :func:`install` registers a
shim under ``sys.modules['hypothesis']`` *before* test collection (see
``conftest.py``) so that ``from hypothesis import given, settings, strategies``
keeps working — each ``@given`` test then runs as a fixed-seed parametrized
sweep instead of an adaptive search.

Only the small API surface the suite uses is provided: ``given``, ``settings``
and the ``integers`` / ``booleans`` / ``floats`` / ``lists`` / ``sampled_from``
strategies.  Draws are deterministic per test (seeded from the test name), so
failures reproduce exactly.
"""

from __future__ import annotations

import inspect
import sys
import types
import zlib

import numpy as np
import pytest

#: examples per @given test in shim mode (hypothesis's max_examples is capped
#: to this — a fixed sweep does not shrink, so more draws buy little).
SHIM_MAX_EXAMPLES = 8


class Strategy:
    """A value generator: ``example(rng)`` draws one value."""

    def __init__(self, draw, label: str):
        self._draw = draw
        self.label = label

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):
        return f"Strategy({self.label})"


def integers(min_value: int = 0, max_value: int = 1 << 30) -> Strategy:
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value},{max_value})",
    )


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")


def floats(
    min_value: float = -1e9,
    max_value: float = 1e9,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> Strategy:
    del allow_nan, allow_infinity  # the shim only draws finite values
    return Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value},{max_value})",
    )


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return Strategy(draw, f"lists({elements.label},{min_size},{max_size})")


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))], "sampled_from")


def given(*pos_strategies: Strategy, **kw_strategies: Strategy):
    """Expand into ``pytest.mark.parametrize`` over fixed-seed draws.

    Positional strategies bind to the test function's leading parameters, as
    in real hypothesis.  The number of examples is ``SHIM_MAX_EXAMPLES`` (an
    outer ``@settings(max_examples=N)`` can only lower it — see ``settings``).
    """

    def deco(fn):
        sig_names = [
            p.name
            for p in inspect.signature(fn).parameters.values()
            if p.kind in (p.POSITIONAL_OR_KEYWORD, p.POSITIONAL_ONLY)
        ]
        # Real hypothesis fills positional strategies from the RIGHTMOST
        # parameters (leftmost ones stay free for fixtures/parametrize);
        # match that so both CI modes bind identically.
        pos_names = sig_names[len(sig_names) - len(pos_strategies):] if pos_strategies else []
        names = list(pos_names) + list(kw_strategies)
        strategies_ = list(pos_strategies) + [kw_strategies[k] for k in kw_strategies]
        if len(names) != len(strategies_):
            raise TypeError(f"@given could not bind strategies to {fn.__name__}")
        rng = np.random.default_rng(zlib.adler32(fn.__name__.encode()))
        cases = [
            tuple(s.example(rng) for s in strategies_)
            for _ in range(SHIM_MAX_EXAMPLES)
        ]
        if len(names) == 1:
            cases = [c[0] for c in cases]
        wrapped = pytest.mark.parametrize(",".join(names), cases)(fn)
        wrapped._shim_given = True
        return wrapped

    return deco


def settings(**kwargs):
    """No-op in shim mode (examples are pre-drawn by ``given``)."""
    del kwargs

    def deco(fn):
        return fn

    return deco


def install() -> None:
    """Register this shim as ``hypothesis`` (+``hypothesis.strategies``)."""
    if "hypothesis" in sys.modules:  # real library (or shim) already present
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "lists", "sampled_from"):
        setattr(strategies, name, globals()[name])
    strategies.Strategy = Strategy
    mod.strategies = strategies
    mod.__is_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
