"""Sparse CT backend: dense↔sparse cell equivalence, COO algebra
(marginal/transpose/total/#SS on codes), the Möbius join on codes, the
dense-cell-budget auto-switch, and sparse score/predict consumers."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import counts
from repro.core.counts import CTLike, ContingencyTable
from repro.core.cpt import learn_parameters, mle_factor
from repro.core.database import university_db
from repro.core.predict import predict_block, predict_single_loop
from repro.core.scores import score_family, score_structure
from repro.core.sparse_counts import SparseCT, aggregate_codes, sparse_from_dense
from repro.core.structure import CountCache, learn_and_join

from .bruteforce import as_dense_array, random_db


def _dense_sparse_pair(db, rvs, **kw):
    d = counts.contingency_table(db, rvs, impl="ref", **kw)
    s = counts.contingency_table(db, rvs, impl="sparse", **kw)
    assert isinstance(d, ContingencyTable) and isinstance(s, SparseCT)
    return d, s


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), self_rel=st.booleans())
def test_dense_sparse_equivalence_random_dbs(seed, self_rel):
    """Cell-identical CTs from both backends on random databases."""
    db = random_db(seed, self_rel=self_rel)
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    d, s = _dense_sparse_pair(db, rvs)
    np.testing.assert_array_equal(np.asarray(d.table), as_dense_array(s))
    assert s.n_cells == d.n_cells
    assert s.n_nonzero() == d.n_nonzero()
    assert float(s.total()) == float(d.total())


def test_sparse_canonical_form():
    """Codes strictly increasing, no explicit zeros, counts match layout."""
    db = university_db()
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    s = counts.contingency_table(db, rvs, impl="sparse")
    assert np.all(np.diff(s.codes) > 0)
    assert np.all(s.counts != 0)
    assert s.codes.dtype == np.int64 and s.counts.dtype == np.float32
    # round-trip through the dense backend
    rt = sparse_from_dense(s.to_dense())
    np.testing.assert_array_equal(rt.codes, s.codes)
    np.testing.assert_array_equal(rt.counts, s.counts)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_sparse_marginal_transpose_match_dense(seed):
    db = random_db(seed)
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    d, s = _dense_sparse_pair(db, rvs)
    sub = (rvs[2], rvs[0], rvs[3])
    np.testing.assert_allclose(
        np.asarray(d.marginal(sub).table), as_dense_array(s.marginal(sub))
    )
    order = rvs[::-1]
    np.testing.assert_array_equal(
        np.asarray(d.transpose(order).table), as_dense_array(s.transpose(order))
    )


def test_sparse_grouped_and_restricted():
    db = random_db(11)
    rvs = ("b1(beta0)", "R(alpha0,beta0)", "ra(alpha0,beta0)")
    d, s = _dense_sparse_pair(db, rvs, group_fovar="alpha0")
    np.testing.assert_array_equal(np.asarray(d.table), as_dense_array(s))
    for e in range(db.entities["alpha"].n_rows):
        dr, sr = _dense_sparse_pair(db, rvs, restrict={"alpha0": e})
        np.testing.assert_array_equal(np.asarray(dr.table), as_dense_array(sr))


def test_auto_switch_budget():
    """impl='auto' switches backends exactly at the dense-cell budget."""
    db = university_db()
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    cells = counts.dense_cells_of(db, rvs)
    dense = counts.contingency_table(db, rvs, impl="auto", dense_cell_budget=cells)
    sparse = counts.contingency_table(db, rvs, impl="auto", dense_cell_budget=cells - 1)
    assert isinstance(dense, ContingencyTable) and isinstance(sparse, SparseCT)
    np.testing.assert_array_equal(np.asarray(dense.table), as_dense_array(sparse))
    # the global knob drives the same switch
    old = counts.set_dense_cell_budget(cells - 1)
    try:
        assert isinstance(counts.contingency_table(db, rvs, impl="auto"), SparseCT)
    finally:
        counts.set_dense_cell_budget(old)
    # joint CT obeys the same heuristic instead of raising MemoryError
    jt = counts.joint_contingency_table(db, dense_cell_budget=cells - 1)
    assert isinstance(jt, SparseCT)


def test_ctlike_protocol():
    db = university_db()
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    d, s = _dense_sparse_pair(db, rvs)
    assert isinstance(d, CTLike) and isinstance(s, CTLike)


def test_joint_beyond_dense_cap_builds_sparse():
    """A schema whose joint CT can NEVER be dense still pre-counts sparsely."""
    from repro.core.database import from_labels
    from repro.core.schema import make_schema

    n_attrs, card = 12, 8  # 8^12 * 2 > 2**37 dense cells — over the 2**28 cap
    dom = tuple(str(i) for i in range(card))
    schema = make_schema(
        entities={
            "e": {f"a{i}": dom for i in range(n_attrs)},
            "f": {"b": ("0", "1")},
        },
        relationships={"R": (("e", "f"), {})},
    )
    rng = np.random.default_rng(0)
    ents = {
        "e": {f"a{i}": [dom[j] for j in rng.integers(0, card, 6)] for i in range(n_attrs)},
        "f": {"b": [("0", "1")[j] for j in rng.integers(0, 2, 4)]},
    }
    rels = {"R": {"fk1": [0, 2, 5], "fk2": [1, 3, 0], "attrs": {}}}
    db = from_labels(schema, ents, rels)

    vids = tuple(v.vid for v in db.catalog.par_rvs)
    assert counts.dense_cells_of(db, vids) > 2**28
    with pytest.raises(MemoryError):
        counts.joint_contingency_table(db, impl="ref")
    jt = counts.joint_contingency_table(db)  # auto -> sparse
    assert isinstance(jt, SparseCT)
    assert float(jt.total()) == 6 * 4  # full grounding cross product
    assert jt.n_nonzero() <= 6 * 4    # #SS bounded by realized groundings
    # marginals of the huge joint agree with direct small dense queries
    sub = ("a0(e0)", "a5(e0)", "R(e0,f0)")
    np.testing.assert_allclose(
        as_dense_array(jt.marginal(sub)),
        np.asarray(counts.contingency_table(db, sub, impl="ref").table),
    )


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_sparse_family_scores_match_dense(seed):
    """score_family over nonzero cells == densify + mle_cpt + factor_loglik."""
    db = random_db(seed)
    pre = CountCache(db, mode="precount", impl="ref")
    sp = CountCache(db, mode="sparse")
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    child, parents = rvs[0], (rvs[2], rvs[3])
    for alpha in (0.0, 0.5):
        fd = score_family(pre, child, parents, alpha, impl="ref")
        fs = score_family(sp, child, parents, alpha)
        assert fd.n_params == fs.n_params
        np.testing.assert_allclose(fd.loglik, fs.loglik, rtol=1e-5, atol=1e-4)


def test_sparse_structure_learning_matches_dense_score():
    """LAJ on the sparse cache reaches a structure with the same AIC."""
    db = university_db()
    res_d = learn_and_join(db, CountCache(db, mode="precount", impl="ref"),
                           score="aic", max_parents=2, max_chain=1, impl="ref")
    res_s = learn_and_join(db, CountCache(db, mode="sparse"),
                           score="aic", max_parents=2, max_chain=1)
    scorer = CountCache(db, mode="precount", impl="ref")
    aic_d = score_structure(res_d.bn, scorer, impl="ref").aic
    aic_s = score_structure(res_s.bn, scorer, impl="ref").aic
    np.testing.assert_allclose(aic_d, aic_s, rtol=1e-6)
    # same adjacencies (orientation of score-equivalent edges may differ)
    adj = lambda bn: {frozenset(e) for e in bn.edges()}
    assert adj(res_d.bn) == adj(res_s.bn)


def test_sparse_predict_matches_dense():
    db = university_db()
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(db, cache, score="aic", max_parents=2, max_chain=1, impl="ref")
    factors = learn_parameters(res.bn, cache, alpha=0.1, impl="ref")
    for target in ("intelligence(student0)", "popularity(prof0)"):
        pd = predict_block(db, res.bn, factors, target, impl="ref")
        ps = predict_block(db, res.bn, factors, target, impl="sparse")
        pl = predict_single_loop(db, res.bn, factors, target, impl="sparse")
        np.testing.assert_allclose(
            np.asarray(pd.log_scores), np.asarray(ps.log_scores), atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(ps.log_scores), np.asarray(pl.log_scores), atol=1e-4
        )


def test_mle_factor_accepts_sparse():
    db = university_db()
    sp = CountCache(db, mode="sparse")
    pre = CountCache(db, mode="precount", impl="ref")
    fam = ("RA(prof0,student0)", "salary(prof0,student0)")
    fd = mle_factor(pre(fam), fam[1], fam[:1], 0.2, impl="ref")
    fs = mle_factor(sp(fam), fam[1], fam[:1], 0.2, impl="ref")
    np.testing.assert_allclose(np.asarray(fd.table), np.asarray(fs.table), atol=1e-6)


def test_aggregate_codes_sort_then_segment_sum():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 50, 3000).astype(np.int64)
    w = rng.random(3000).astype(np.float32)
    uniq, sums = aggregate_codes(codes, w)
    assert np.all(np.diff(uniq) > 0)
    expect = np.zeros(50, np.float64)
    np.add.at(expect, codes, w.astype(np.float64))
    np.testing.assert_allclose(sums, expect[uniq], rtol=1e-5)
