# Makes ``tests`` a package so test modules can use relative imports
# (``from .bruteforce import ...``) under pytest's default import mode.
