"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs to launch/dryrun.py only).

If ``hypothesis`` is not installed, a dependency-light shim is registered
before collection so the property-test modules still import; their ``@given``
tests then run as fixed-seed parametrized sweeps (see _hypothesis_compat.py).
"""

try:
    import hypothesis  # noqa: F401  (use the real library when present)
except ModuleNotFoundError:
    from tests import _hypothesis_compat

    _hypothesis_compat.install()

import jax
import pytest

from repro.core import counts as _counts

# Device-build tests use deliberately tiny databases; the
# REPRO_DEVICE_MIN_ROWS crossover would silently host-route every one of
# them (and their DeviceSparseCT type assertions would fail for the wrong
# reason).  Zero the threshold for the whole test session — the routing
# itself is covered by explicit tests that set it and restore.
_counts.set_device_min_rows(0)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
