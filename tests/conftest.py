"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs to launch/dryrun.py only)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
