"""EngineConfig: the kwarg > context > setter > env > default chain.

Every REPRO_* knob in the engine resolves through
:mod:`repro.core.config`; these tests pin the precedence order, context
nesting and thread isolation, fail-loud validation, and the invariant
that no other module reads REPRO_* environment variables directly (the
same check ``tools/check_env_reads.py`` runs in CI).
"""

from __future__ import annotations

import dataclasses
import threading

import pytest

from repro.core import config
from repro.core.config import EngineConfig, current_config, engine_config, resolve


@pytest.fixture(autouse=True)
def _clean_knobs(monkeypatch):
    """Each test starts from built-in defaults: no env, no global overrides."""
    for spec in config._FIELDS.values():
        if spec.env is not None:
            monkeypatch.delenv(spec.env, raising=False)
    saved = dict(config._GLOBAL_OVERRIDES)
    config._GLOBAL_OVERRIDES.clear()
    yield
    config._GLOBAL_OVERRIDES.clear()
    config._GLOBAL_OVERRIDES.update(saved)


# ---------------------------------------------------------------------------
# precedence
# ---------------------------------------------------------------------------


def test_default_wins_when_nothing_set():
    assert resolve("bucket_base") == 128
    assert resolve("incremental") is True
    assert resolve("kernel_impl") == ""


def test_env_beats_default(monkeypatch):
    monkeypatch.setenv("REPRO_BUCKET_BASE", "64")
    assert resolve("bucket_base") == 64


def test_env_is_read_per_call(monkeypatch):
    monkeypatch.setenv("REPRO_BATCH_MIN_CANDIDATES", "3")
    assert resolve("batch_min_candidates") == 3
    monkeypatch.setenv("REPRO_BATCH_MIN_CANDIDATES", "5")
    assert resolve("batch_min_candidates") == 5
    monkeypatch.delenv("REPRO_BATCH_MIN_CANDIDATES")
    assert resolve("batch_min_candidates") == 8


def test_setter_beats_env(monkeypatch):
    monkeypatch.setenv("REPRO_COO_SHARDS", "2")
    old = config.set_override("coo_shards", 4)
    assert old == 2  # setters return the previously-resolved value
    assert resolve("coo_shards") == 4
    config.set_override("coo_shards", None)  # clear -> env visible again
    assert resolve("coo_shards") == 2


def test_context_beats_setter_and_env(monkeypatch):
    monkeypatch.setenv("REPRO_MSG_CACHE", "7")
    config.set_override("msg_cache", 9)
    with engine_config(msg_cache=11):
        assert resolve("msg_cache") == 11
    assert resolve("msg_cache") == 9


def test_kwarg_beats_context():
    with engine_config(device_min_rows=100):
        assert resolve("device_min_rows", 200) == 200
        assert resolve("device_min_rows") == 100


def test_none_kwarg_means_unset():
    with engine_config(device_min_rows=100):
        assert resolve("device_min_rows", None) == 100


def test_context_nesting_innermost_wins():
    with engine_config(bucket_base=64):
        with engine_config(bucket_base=32):
            assert resolve("bucket_base") == 32
        assert resolve("bucket_base") == 64
    assert resolve("bucket_base") == 128


def test_nested_contexts_merge_distinct_fields():
    with engine_config(bucket_base=64):
        with engine_config(coo_shards=2):
            assert resolve("bucket_base") == 64  # outer still visible
            assert resolve("coo_shards") == 2
        assert resolve("coo_shards") == 1


def test_context_yields_snapshot():
    with engine_config(bucket_base=64, incremental=False) as cfg:
        assert isinstance(cfg, EngineConfig)
        assert cfg.bucket_base == 64
        assert cfg.incremental is False
        assert cfg.msg_cache == 128  # untouched fields at their defaults


def test_context_restores_on_exception():
    with pytest.raises(RuntimeError):
        with engine_config(bucket_base=64):
            raise RuntimeError("boom")
    assert resolve("bucket_base") == 128


# ---------------------------------------------------------------------------
# thread / task isolation
# ---------------------------------------------------------------------------


def test_contexts_are_thread_local():
    seen = {}
    barrier = threading.Barrier(2)

    def worker(name, base):
        with engine_config(bucket_base=base):
            barrier.wait(timeout=10)  # both threads inside their contexts
            seen[name] = resolve("bucket_base")
            barrier.wait(timeout=10)

    threads = [
        threading.Thread(target=worker, args=("a", 32)),
        threading.Thread(target=worker, args=("b", 64)),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert seen == {"a": 32, "b": 64}


def test_fresh_thread_sees_no_context():
    out = {}
    with engine_config(bucket_base=64):
        # a thread spawned inside the context does NOT inherit it:
        # contextvars are copied at thread creation only for the main
        # coroutine machinery, not threading.Thread
        t = threading.Thread(target=lambda: out.update(v=resolve("bucket_base")))
        t.start()
        t.join(timeout=30)
    assert out["v"] == 128


# ---------------------------------------------------------------------------
# validation: fail loud, never coerce silently
# ---------------------------------------------------------------------------


def test_unknown_field_rejected():
    with pytest.raises(ValueError, match="unknown engine-config field"):
        with engine_config(no_such_knob=1):
            pass
    with pytest.raises(ValueError, match="unknown engine-config field"):
        resolve("no_such_knob")


def test_bad_env_value_raises(monkeypatch):
    monkeypatch.setenv("REPRO_INCREMENTAL", "maybe")
    with pytest.raises(ValueError, match="REPRO_INCREMENTAL"):
        resolve("incremental")
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "cuda")
    with pytest.raises(ValueError, match="REPRO_KERNEL_IMPL"):
        resolve("kernel_impl")


def test_bad_context_value_raises_on_entry():
    with pytest.raises(ValueError):
        with engine_config(bucket_growth=0.5):  # growth must be > 1
            pass
    with pytest.raises(ValueError):
        with engine_config(donation="2"):
            pass


def test_setters_still_validate():
    from repro.kernels.bucketing import set_bucket_ladder, set_donation

    with pytest.raises(ValueError):
        set_bucket_ladder(base=0)
    with pytest.raises(ValueError):
        set_donation("yes")


# ---------------------------------------------------------------------------
# the EngineConfig snapshot + legacy setter delegation
# ---------------------------------------------------------------------------


def test_engine_config_is_frozen():
    cfg = current_config()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.bucket_base = 1


def test_current_config_reflects_context():
    with engine_config(sort_impl="xla", fused_build=False):
        cfg = current_config()
        assert cfg.sort_impl == "xla"
        assert cfg.fused_build is False
    assert current_config().sort_impl == "auto"


def test_legacy_setters_delegate():
    """set_*() and the read functions see one shared config store."""
    from repro.core.counts import device_min_rows, set_device_min_rows
    from repro.kernels.bucketing import bucket_ladder, set_bucket_ladder

    old = set_bucket_ladder(base=256)
    try:
        assert bucket_ladder()[0] == 256
        assert current_config().bucket_base == 256
    finally:
        set_bucket_ladder(base=old[0], growth=old[1])

    prev = set_device_min_rows(7)
    try:
        assert device_min_rows() == 7
        with engine_config(device_min_rows=3):
            assert device_min_rows() == 3  # context still outranks setter
    finally:
        config.set_override("device_min_rows", None)
        assert device_min_rows() == prev


def test_fields_cover_engine_config():
    assert set(config._FIELDS) == {
        f.name for f in dataclasses.fields(EngineConfig)
    }


# ---------------------------------------------------------------------------
# single-owner invariant: nobody else reads REPRO_* env vars
# ---------------------------------------------------------------------------


def test_no_stray_env_reads():
    """The CI lint, runnable as a plain test: config.py owns every REPRO_*
    environ read (launch/ scripts are grandfathered — they must set
    XLA_FLAGS before jax imports, ahead of any config machinery)."""
    from pathlib import Path
    import subprocess
    import sys

    root = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, str(root / "tools" / "check_env_reads.py")],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
