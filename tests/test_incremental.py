"""Incremental CT maintenance: signed O(Δ) delta propagation.

Pins the tentpole contracts of the live-database path:

  * ``database.apply_delta`` is functional (frozen inputs untouched), emits
    a signed per-table delta stream, and fail-louds on every malformed spec;
  * ``sparse_ct_delta`` + ``apply_ct_delta`` reproduce a from-scratch
    rebuild **bit-identically** (codes AND float32 counts in canonical host
    form) on both residencies, including host-delta-into-device-live merges
    and net-zero insert/delete interleavings;
  * the count/score managers evict exactly the dirty-set entries — families
    disjoint from the touched relationship keep serving from the memo, and
    every re-scored family matches a cold manager bitwise;
  * ``warm_hill_climb`` restarted from the previous graph lands on the cold
    search's model;
  * a warm delta apply (seen shape, settled live rung) compiles **zero**
    XLA programs — delta streams ride the bucket ladder.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.counts import joint_contingency_table
from repro.core.database import apply_delta, university_db
from repro.core.score_manager import (
    CountCache,
    ScoreManager,
    incremental_enabled,
)
from repro.core.sparse_counts import (
    DeviceSparseCT,
    LeafMessageCache,
    SparseCT,
    apply_ct_delta,
    as_host,
    msg_cache_cap,
    sparse_ct_delta,
)
from repro.core.structure import hill_climb, warm_hill_climb
from repro.kernels import bucketing

from .bruteforce import brute_force_ct, random_db
from .strategies import absent_pair_inserts as _absent_pair_inserts
from .strategies import random_rel_inserts as _random_inserts


def _all_rvs(db):
    return tuple(v.vid for v in db.catalog.par_rvs)


def _assert_identical(a, b):
    ha, hb = as_host(a), as_host(b)
    assert ha.rvs == hb.rvs and ha.cards == hb.cards
    np.testing.assert_array_equal(ha.codes, hb.codes)
    np.testing.assert_array_equal(ha.counts, hb.counts)  # bitwise, not close


# ---------------------------------------------------------------------------
# database.apply_delta: the mutation API
# ---------------------------------------------------------------------------


def test_apply_delta_is_functional():
    db = random_db(0)
    n0 = db.relationships["R"].n_rows
    ins = {"fk1": [0], "fk2": [1], "attrs": {"ra": [2]}}
    new_db, delta = apply_delta(db, "R", ins, deleted_rows=[0])
    # the input instance is untouched; the new one reflects the delta
    assert db.relationships["R"].n_rows == n0
    assert new_db.relationships["R"].n_rows == n0  # -1 +1
    assert delta.table == "R"
    assert delta.inserted.n_rows == 1 and delta.deleted.n_rows == 1
    assert delta.n_rows == 2
    # the deleted half carries the removed row's *contents*
    np.testing.assert_array_equal(
        np.asarray(delta.deleted.fk1), np.asarray(db.relationships["R"].fk1)[:1]
    )
    new_db.validate()


def test_apply_delta_validation_errors():
    db = random_db(1)
    with pytest.raises(NotImplementedError):  # entity deltas touch every CT
        apply_delta(db, "alpha", {"fk1": [], "fk2": [], "attrs": {}})
    with pytest.raises(KeyError):
        apply_delta(db, "nope", {"fk1": [0], "fk2": [0], "attrs": {"ra": [1]}})
    with pytest.raises(ValueError):  # attr code 0 is the n/a sentinel
        apply_delta(db, "R", {"fk1": [0], "fk2": [0], "attrs": {"ra": [0]}})
    with pytest.raises(ValueError):  # fk out of the entity population
        apply_delta(db, "R", {"fk1": [99], "fk2": [0], "attrs": {"ra": [1]}})
    with pytest.raises(ValueError):  # unknown attr
        apply_delta(
            db, "R", {"fk1": [0], "fk2": [0], "attrs": {"ra": [1], "zz": [1]}}
        )
    with pytest.raises(ValueError):  # ragged spec
        apply_delta(db, "R", {"fk1": [0, 1], "fk2": [0], "attrs": {"ra": [1]}})
    with pytest.raises(IndexError):  # deleted index past the table
        apply_delta(db, "R", deleted_rows=[db.relationships["R"].n_rows])
    with pytest.raises(ValueError):  # duplicate deleted indices
        apply_delta(db, "R", deleted_rows=[0, 0])


# ---------------------------------------------------------------------------
# Signed ΔCT propagation: bit-identical to a from-scratch rebuild
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_host_delta_matches_rebuild(seed):
    db = random_db(seed)
    joint = joint_contingency_table(db, impl="sparse")
    assert isinstance(joint, SparseCT)
    rng = np.random.default_rng(seed)
    new_db, delta = apply_delta(
        db, "R", _random_inserts(db, "R", 2, rng), deleted_rows=[0]
    )
    dct = sparse_ct_delta(new_db, delta, joint.rvs, device=False)
    merged = apply_ct_delta(joint, dct)
    assert isinstance(merged, SparseCT)
    _assert_identical(merged, joint_contingency_table(new_db, impl="sparse"))


@pytest.mark.parametrize("seed", [2, 5])
def test_device_delta_matches_rebuild(seed):
    db = random_db(seed)
    live = joint_contingency_table(db, impl="sparse", device_resident=True)
    assert isinstance(live, DeviceSparseCT)
    rng = np.random.default_rng(seed)
    new_db, delta = apply_delta(
        db, "R", _random_inserts(db, "R", 3, rng), deleted_rows=[1]
    )
    dct = sparse_ct_delta(new_db, delta, live.rvs, device=True)
    merged = apply_ct_delta(live, dct)
    assert isinstance(merged, DeviceSparseCT)
    oracle = joint_contingency_table(new_db, impl="sparse")
    _assert_identical(merged, oracle)


def test_host_delta_merges_into_device_live():
    db = random_db(4)
    live = joint_contingency_table(db, impl="sparse", device_resident=True)
    rng = np.random.default_rng(4)
    new_db, delta = apply_delta(db, "R", _random_inserts(db, "R", 2, rng))
    # host-built delta (the small-Δ fast path) into a device-resident live
    # table: one rung-padded h2d + one signed aggregate
    dct = sparse_ct_delta(new_db, delta, live.rvs, device=False)
    assert isinstance(dct, SparseCT)
    merged = apply_ct_delta(live, dct)
    assert isinstance(merged, DeviceSparseCT)
    _assert_identical(merged, joint_contingency_table(new_db, impl="sparse"))


def test_chained_deltas_match_rebuild():
    db = random_db(6)
    joint = joint_contingency_table(db, impl="sparse")
    rng = np.random.default_rng(6)
    for step in range(3):
        n = db.relationships["R"].n_rows
        dele = [int(rng.integers(0, n))] if n else None
        db, delta = apply_delta(
            db, "R", _random_inserts(db, "R", 2, rng), deleted_rows=dele
        )
        joint = apply_ct_delta(
            joint, sparse_ct_delta(db, delta, joint.rvs, device=False)
        )
    _assert_identical(joint, joint_contingency_table(db, impl="sparse"))


@pytest.mark.parametrize("seed", [1, 4])
def test_delta_maintained_joint_matches_bruteforce_oracle(seed):
    """Ground truth, not just rebuild-identity: chained *valid* deltas
    (absent pairs only) land exactly on ``brute_force_ct`` of the final db."""
    db = random_db(seed)
    mgr = ScoreManager(db, mode="sparse")
    rvs = _all_rvs(db)
    rng = np.random.default_rng(seed + 100)
    for _ in range(3):
        n = mgr.db.relationships["R"].n_rows
        mgr.apply_delta(
            "R",
            inserted_rows=_absent_pair_inserts(mgr.db, "R", 2, rng),
            deleted_rows=[int(rng.integers(0, n))],
        )
    oracle = brute_force_ct(mgr.db, rvs).astype(np.float64)
    h = as_host(mgr.joint).transpose(rvs)
    dense = np.zeros(int(np.prod(h.cards)))
    dense[h.codes] = h.counts
    np.testing.assert_array_equal(oracle, dense.reshape(tuple(h.cards)))


def test_delta_disjoint_from_query_is_empty():
    """Axes that never join the touched table: ΔCT ≡ 0 with no contraction."""
    db = random_db(8)
    rvs = ("a1(alpha0)", "b1(beta0)")  # entity attrs only — R marginalized out
    rng = np.random.default_rng(8)
    new_db, delta = apply_delta(db, "R", _random_inserts(db, "R", 2, rng))
    dct = sparse_ct_delta(new_db, delta, rvs, device=False)
    assert isinstance(dct, SparseCT) and dct.codes.shape == (0,)
    dev = sparse_ct_delta(new_db, delta, rvs, device=True)
    assert isinstance(dev, DeviceSparseCT) and dev.codes.shape == (0,)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100), k=st.integers(1, 3))
def test_net_zero_interleaving_is_identity(seed, k):
    """Insert k rows, then delete exactly those rows: CT bit-identical.

    The signed merge must cancel the two halves exactly — zero-count cells
    are dropped in canonical host form, so codes AND counts return to the
    pre-delta table bitwise.
    """
    db = random_db(seed % 10)
    joint0 = joint_contingency_table(db, impl="sparse")
    rng = np.random.default_rng(seed)
    n0 = db.relationships["R"].n_rows
    ins = _random_inserts(db, "R", k, rng)
    db1, d1 = apply_delta(db, "R", ins)
    joint1 = apply_ct_delta(
        joint0, sparse_ct_delta(db1, d1, joint0.rvs, device=False)
    )
    # inserted rows land appended at the tail: delete those exact indices
    db2, d2 = apply_delta(db1, "R", deleted_rows=list(range(n0, n0 + k)))
    joint2 = apply_ct_delta(
        joint1, sparse_ct_delta(db2, d2, joint0.rvs, device=False)
    )
    _assert_identical(joint2, joint0)


def test_net_zero_single_call_is_identity():
    """One call deleting a row and re-inserting its contents: identity."""
    db = random_db(9)
    joint0 = joint_contingency_table(db, impl="sparse")
    rel = db.relationships["R"]
    ins = {
        "fk1": np.asarray(rel.fk1)[:1],
        "fk2": np.asarray(rel.fk2)[:1],
        "attrs": {a: np.asarray(c)[:1] for a, c in rel.attrs.items()},
    }
    new_db, delta = apply_delta(db, "R", ins, deleted_rows=[0])
    merged = apply_ct_delta(
        joint0, sparse_ct_delta(new_db, delta, joint0.rvs, device=False)
    )
    _assert_identical(merged, joint0)


# ---------------------------------------------------------------------------
# Manager layer: dirty-set eviction, incremental joint, warm re-search
# ---------------------------------------------------------------------------


def test_count_cache_dirty_set_eviction_and_incremental_joint():
    db = random_db(10)
    cache = CountCache(db, mode="sparse")
    clean_key = tuple(sorted(("a1(alpha0)", "b1(beta0)")))
    dirty_key = tuple(sorted(("a1(alpha0)", "ra(alpha0,beta0)")))
    cache(clean_key)
    cache(dirty_key)
    assert clean_key in cache._memo and dirty_key in cache._memo
    n_mat = cache.n_materializations

    rng = np.random.default_rng(10)
    stats = cache.apply_delta(db.relationships["R"].name,
                              _random_inserts(db, "R", 2, rng))
    assert stats["incremental"] is True
    assert cache.n_delta_applies == 1
    # disjoint marginal survives; anything touching R's vars is evicted
    assert clean_key in cache._memo
    assert dirty_key not in cache._memo
    # incremental maintenance, not a rebuild
    assert cache.n_materializations == n_mat
    _assert_identical(
        cache.joint, joint_contingency_table(cache.db, impl="sparse")
    )
    # the preserved marginal still serves the correct (unchanged) counts
    _assert_identical(cache(clean_key), cache.joint.marginal(clean_key))


def test_incremental_disabled_rebuilds(monkeypatch):
    monkeypatch.setenv("REPRO_INCREMENTAL", "0")
    assert incremental_enabled() is False
    db = random_db(11)
    cache = CountCache(db, mode="sparse")
    n_mat = cache.n_materializations
    rng = np.random.default_rng(11)
    stats = cache.apply_delta("R", _random_inserts(db, "R", 1, rng))
    assert stats["incremental"] is False
    assert cache.n_materializations == n_mat + 1  # full rebuild
    _assert_identical(
        cache.joint, joint_contingency_table(cache.db, impl="sparse")
    )


def test_incremental_env_knob_fails_loud(monkeypatch):
    monkeypatch.setenv("REPRO_INCREMENTAL", "maybe")
    with pytest.raises(ValueError, match="REPRO_INCREMENTAL"):
        incremental_enabled()


def test_score_manager_dirty_refresh_matches_cold():
    db = university_db()
    rvs = _all_rvs(db)
    mgr = ScoreManager(db, mode="sparse")
    prev = hill_climb(rvs, mgr, score="aic", max_parents=2)
    assert mgr._score_memo

    rel = db.relationships["RA"]
    ins = {
        "fk1": [0], "fk2": [0],
        "attrs": {a: [1] for a in rel.attrs},
    }
    stats = mgr.apply_delta("RA", ins)
    # a single-table delta must leave provably-unaffected families served
    assert stats["n_preserved_families"] > 0
    assert stats["n_dirty_families"] > 0
    assert mgr.n_preserved_families == stats["n_preserved_families"]

    cold = ScoreManager(mgr.db, mode="sparse")
    res_warm = warm_hill_climb(prev.bn, mgr, score="aic", max_parents=2)
    res_cold = hill_climb(rvs, cold, score="aic", max_parents=2)
    # same model; the *accumulated* search totals may differ in the last
    # f64 ulp (different move paths), so compare structure + family scores
    assert res_warm.bn.edges() == res_cold.bn.edges()
    assert res_warm.n_sweeps <= res_cold.n_sweeps
    for key, fs in mgr._score_memo.items():
        if key in cold._score_memo:
            cfs = cold._score_memo[key]
            assert (fs.loglik, fs.n_params) == (cfs.loglik, cfs.n_params), key
    # the maintained joint equals the cold manager's rebuilt joint
    _assert_identical(mgr.joint, cold.joint)


# ---------------------------------------------------------------------------
# Compile discipline: warm delta applies ride the bucket ladder
# ---------------------------------------------------------------------------


def test_warm_delta_apply_compiles_nothing():
    if not bucketing.compile_probe_active():
        pytest.skip("no backend compile listener on this JAX")
    db = university_db()
    mgr = CountCache(db, mode="sparse", device_resident=True)
    rng = np.random.default_rng(12)
    table = "RA"
    # cold apply compiles delta rungs; the second may still see a new merge
    # shape if the first grew the live joint across a ladder rung
    mgr.apply_delta(table, _random_inserts(mgr.db, table, 1, rng))
    mgr.apply_delta(table, _random_inserts(mgr.db, table, 1, rng))
    bucketing.reset_compile_counts()
    stats = mgr.apply_delta(table, _random_inserts(mgr.db, table, 1, rng))
    assert stats["incremental"] is True
    assert bucketing.compile_counts()["compiles"] == 0
    _assert_identical(
        mgr.joint, joint_contingency_table(mgr.db, impl="sparse")
    )


# ---------------------------------------------------------------------------
# Leaf-message cache
# ---------------------------------------------------------------------------


def test_leaf_message_cache_fifo_and_counters():
    cache = LeafMessageCache(cap=2)
    built = []

    def mk(v):
        return lambda: built.append(v) or v

    assert cache.get("a", mk(1)) == 1
    assert cache.get("a", mk(99)) == 1  # hit: not rebuilt
    assert cache.get("b", mk(2)) == 2
    assert cache.get("c", mk(3)) == 3  # evicts "a" (FIFO at cap=2)
    assert cache.get("a", mk(4)) == 4  # rebuilt after eviction
    assert built == [1, 2, 3, 4]
    assert cache.hits == 1 and cache.misses == 4
    assert len(cache) == 2


def test_leaf_message_cache_cap_zero_disables():
    cache = LeafMessageCache(cap=0)
    built = []
    for _ in range(3):
        cache.get("k", lambda: built.append(1) or 1)
    assert built == [1, 1, 1] and len(cache) == 0


def test_msg_cache_knob(monkeypatch):
    monkeypatch.delenv("REPRO_MSG_CACHE", raising=False)
    assert msg_cache_cap() == 128
    monkeypatch.setenv("REPRO_MSG_CACHE", "7")
    assert msg_cache_cap() == 7
    monkeypatch.setenv("REPRO_MSG_CACHE", "lots")
    with pytest.raises(ValueError, match="REPRO_MSG_CACHE"):
        msg_cache_cap()
    monkeypatch.setenv("REPRO_MSG_CACHE", "-1")
    with pytest.raises(ValueError, match="REPRO_MSG_CACHE"):
        msg_cache_cap()


def test_message_cache_reused_across_applies():
    db = random_db(13)
    cache = CountCache(db, mode="sparse")
    rng = np.random.default_rng(13)
    cache.apply_delta("R", _random_inserts(cache.db, "R", 1, rng))
    assert cache._msg_cache is not None
    misses0 = cache._msg_cache.misses
    cache.apply_delta("R", _random_inserts(cache.db, "R", 1, rng))
    # second apply re-serves every leaf message: entity tables are immutable
    # across relationship deltas, so only the first apply builds
    assert cache._msg_cache.misses == misses0
    assert cache._msg_cache.hits > 0
    _assert_identical(
        cache.joint, joint_contingency_table(cache.db, impl="sparse")
    )
