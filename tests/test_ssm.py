"""Mamba2 SSD correctness: the chunked dual form vs a naive sequential
recurrence oracle, across chunk sizes (the chunking must be invisible)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.ssm import init_ssm, ssm_block, ssm_decode, init_ssm_state
from repro.models.transformer import _dtype


def _naive_ssd_oracle(p, x_in, cfg):
    """Token-by-token recurrence h_t = exp(dt A) h + dt B x; y = C h + D x,
    sharing the exact projection/conv path with the block implementation."""
    from repro.models.ssm import _causal_conv, _split_proj
    from repro.models.layers import rms_norm

    bsz, l, _ = x_in.shape
    din, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x_in @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x = xbc[..., :din].reshape(bsz, l, h, pdim).astype(jnp.float32)
    bmat = xbc[..., din:din + n].astype(jnp.float32)
    cmat = xbc[..., din + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    hs = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    ys = []
    for t in range(l):
        decay = jnp.exp(dt[:, t] * a)  # (B,H)
        hs = hs * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dt[:, t], bmat[:, t], x[:, t]
        )
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, t], hs)
        ys.append(y + x[:, t] * p["d_skip"][None, :, None])
    y = jnp.stack(ys, axis=1).reshape(bsz, l, din).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["w_out"]


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_ssd_chunked_equals_recurrence(chunk):
    cfg = dataclasses.replace(
        get_config("mamba2_130m", smoke=True), ssm_chunk=chunk, dtype="float32"
    )
    key = jax.random.PRNGKey(0)
    p = init_ssm(key, cfg, _dtype(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    out_chunked = ssm_block(p, x, cfg)
    out_naive = _naive_ssd_oracle(p, x, cfg)
    np.testing.assert_allclose(
        np.asarray(out_chunked), np.asarray(out_naive), rtol=2e-4, atol=2e-4
    )


def test_ssd_decode_equals_block():
    cfg = dataclasses.replace(get_config("mamba2_130m", smoke=True), dtype="float32")
    key = jax.random.PRNGKey(0)
    p = init_ssm(key, cfg, _dtype(cfg))
    b, l = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (b, l, cfg.d_model)) * 0.5
    block_out = ssm_block(p, x, cfg)

    state = init_ssm_state(cfg, b)
    outs = []
    for t in range(l):
        y, state = ssm_decode(p, state, x[:, t:t+1], cfg)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(block_out),
                               rtol=5e-4, atol=5e-4)
