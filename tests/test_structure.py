"""Structure learning: planted-dependency recovery, constraint inheritance,
cache-mode equivalence, score bookkeeping."""

import numpy as np
import pytest

from repro.core.bn import BayesNet
from repro.core.cpt import learn_parameters
from repro.core.database import university_db
from repro.core.scores import score_structure
from repro.core.structure import (
    CountCache,
    SearchConstraints,
    hill_climb,
    learn_and_join,
)
from repro.data.relational import MOVIELENS, generate

from .bruteforce import random_db


def test_bayesnet_ops():
    bn = BayesNet.empty(("a", "b", "c"))
    bn = bn.with_edge("a", "b").with_edge("b", "c")
    assert bn.is_acyclic() and bn.topological_order() == ("a", "b", "c")
    assert not bn.with_edge("c", "a").is_acyclic()
    assert bn.reversed_edge("a", "b").has_edge("b", "a")
    u = bn.union(BayesNet(("c", "d"), {"c": (), "d": ("c",)}))
    assert u.has_edge("a", "b") and u.has_edge("c", "d")


def test_precount_equals_ondemand():
    db = university_db()
    pre = CountCache(db, mode="precount", impl="ref")
    ond = CountCache(db, mode="ondemand", impl="ref")
    for rvs in [
        ("intelligence(student0)", "ranking(student0)"),
        ("RA(prof0,student0)", "salary(prof0,student0)", "popularity(prof0)"),
    ]:
        np.testing.assert_allclose(
            np.asarray(pre(rvs).table), np.asarray(ond(rvs).table)
        )


def test_cache_modes_cell_identical():
    """precount / ondemand / sparse serve cell-identical family CTs."""
    from .bruteforce import as_dense_array

    db = university_db()
    caches = {
        "precount": CountCache(db, mode="precount", impl="ref"),
        "ondemand": CountCache(db, mode="ondemand", impl="ref"),
        "sparse": CountCache(db, mode="sparse"),
        "ondemand-sparse": CountCache(db, mode="ondemand", impl="sparse"),
    }
    families = [
        ("intelligence(student0)", "ranking(student0)"),
        ("RA(prof0,student0)", "salary(prof0,student0)", "popularity(prof0)"),
        ("capability(prof0,student0)", "RA(prof0,student0)"),
    ]
    for rvs in families:
        ref = as_dense_array(caches["precount"](rvs))
        for name, cache in caches.items():
            got = as_dense_array(cache(rvs))
            np.testing.assert_allclose(got, ref, err_msg=f"{name} {rvs}")


def test_cache_counters():
    """n_queries counts calls; n_materializations counts actual CT builds."""
    db = university_db()
    fam = ("intelligence(student0)", "ranking(student0)")

    pre = CountCache(db, mode="precount", impl="ref")
    assert (pre.n_queries, pre.n_materializations) == (0, 1)  # the joint
    pre(fam); pre(fam); pre(tuple(reversed(fam)))
    # marginals of the pre-counted joint are never new materializations
    assert (pre.n_queries, pre.n_materializations) == (3, 1)

    ond = CountCache(db, mode="ondemand", impl="ref")
    assert (ond.n_queries, ond.n_materializations) == (0, 0)  # no joint
    ond(fam); ond(fam); ond(tuple(reversed(fam)))
    # memoized by sorted rv-set: one build serves all three queries
    assert (ond.n_queries, ond.n_materializations) == (3, 1)

    raw = CountCache(db, mode="ondemand", impl="ref", memoize=False)
    raw(fam); raw(fam)
    # the instance-loop baseline re-materializes every query
    assert (raw.n_queries, raw.n_materializations) == (2, 2)

    sp = CountCache(db, mode="sparse")
    assert (sp.n_queries, sp.n_materializations) == (0, 1)  # sparse joint
    sp(fam); sp(fam)
    assert (sp.n_queries, sp.n_materializations) == (2, 1)


def test_hill_climb_finds_planted_dependency():
    """Entity attributes are sampled as a chain attr1 -> attr2 in the
    generator; the climber must pick up that edge (either orientation)."""
    db = generate(MOVIELENS.scaled(0.02), seed=5)
    cache = CountCache(db, mode="precount", impl="ref")
    rvs = ("age(user0)", "gender(user0)", "occupation(user0)")
    res = hill_climb(rvs, cache, score="bic", n_groundings=float(db.total_tuples))
    pairs = {frozenset(e) for e in res.bn.edges()}
    assert frozenset(("age(user0)", "gender(user0)")) in pairs or \
        frozenset(("gender(user0)", "occupation(user0)")) in pairs, res.bn.edges()


def test_constraints_respected():
    db = university_db()
    cache = CountCache(db, mode="precount", impl="ref")
    rvs = ("intelligence(student0)", "ranking(student0)")
    cons = SearchConstraints(
        required=frozenset({("ranking(student0)", "intelligence(student0)")}),
        decided=frozenset({frozenset(rvs)}),
    )
    res = hill_climb(rvs, cache, constraints=cons)
    assert res.bn.has_edge("ranking(student0)", "intelligence(student0)")


def test_learn_and_join_university():
    db = university_db()
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(db, cache, score="aic", max_parents=2, max_chain=1, impl="ref")
    bn = res.bn
    assert bn.is_acyclic()
    # the n/a-pattern edges are structural: R -> each of its attributes
    assert bn.has_edge("RA(prof0,student0)", "salary(prof0,student0)")
    assert bn.has_edge("RA(prof0,student0)", "capability(prof0,student0)")
    # scores decompose: total loglik equals sum of family logliks
    st = score_structure(bn, cache, impl="ref")
    assert st.aic == pytest.approx(st.loglik - st.n_params)
    factors = learn_parameters(bn, cache, impl="ref")
    assert sum(f.n_params for f in factors.values()) == st.n_params


def test_chain2_lattice_runs():
    db = random_db(11)
    cache = CountCache(db, mode="precount", impl="ref")
    res = learn_and_join(db, cache, max_chain=2, max_parents=2, impl="ref")
    assert res.bn.is_acyclic()
    assert res.n_lattice_nodes >= 3
