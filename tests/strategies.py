"""Shared fuzz strategies + random-fixture helpers for the test suite.

Two consumption modes, both supported by every export here:

  * **Strategies** (``schema_specs``, ``fuzz_seeds``) compose only the
    primitive API surface that ``tests/_hypothesis_compat.py`` shims
    (``sampled_from`` / ``integers``), so ``@given`` tests behave the same
    whether the real ``hypothesis`` package is installed (the
    ``tier1-hypothesis`` CI job) or the fixed-seed fallback is active.
  * **Plain helpers** (``fuzz_db``, ``rv_subset``, ``chain_db``,
    ``random_rel_inserts``, ``absent_pair_inserts``) materialize databases,
    RV subsets, and delta specs deterministically from scalars a strategy
    drew — strategies hand around ``(spec, seed)``, never live objects, so
    failing draws stay printable and replayable
    (``tools/shrink_schema.py``).
"""

from __future__ import annotations

import numpy as np
from hypothesis import strategies as st

from repro.core.database import RelationalDatabase, from_labels
from repro.core.schema import make_schema
from repro.data.schema_gen import SPEC_CORPUS, SchemaSpec, generate_database


def schema_specs() -> "st.SearchStrategy | object":
    """Strategy over the named corners of the schema shape space."""
    return st.sampled_from(SPEC_CORPUS)


def fuzz_seeds(max_seed: int = 10_000):
    """Strategy over generator seeds (pair with :func:`schema_specs`)."""
    return st.integers(0, max_seed)


def fuzz_db(spec: SchemaSpec, seed: int) -> RelationalDatabase:
    """Materialize one generated database from a drawn ``(spec, seed)``."""
    return generate_database(spec, seed)


def rv_subset(db: RelationalDatabase, seed: int, k: int = 3) -> tuple[str, ...]:
    """A deterministic size-``<=k`` subset of the catalog's par-RVs."""
    rng = np.random.default_rng(seed)
    vids = [v.vid for v in db.catalog.par_rvs]
    k = min(k, len(vids))
    return tuple(vids[i] for i in sorted(rng.permutation(len(vids))[:k]))


def chain_db(depth: int = 2, card: int = 3, n_rows: int = 7,
             seed: int = 0) -> RelationalDatabase:
    """Entities e0..e<depth> linked by a chain of relationships (with one
    relationship attribute each) — the multi-relationship Möbius workload."""
    rng = np.random.default_rng(seed)
    dom = tuple(str(i) for i in range(card))
    schema = make_schema(
        entities={f"e{k}": {f"a{k}": dom} for k in range(depth + 1)},
        relationships={
            f"r{k}": ((f"e{k}", f"e{k + 1}"), {f"w{k}": ("p", "q")})
            for k in range(depth)
        },
    )
    ents = {
        f"e{k}": {f"a{k}": [dom[j] for j in rng.integers(0, card, n_rows)]}
        for k in range(depth + 1)
    }
    rels = {}
    for k in range(depth):
        pairs = sorted(
            {(int(rng.integers(0, n_rows)), int(rng.integers(0, n_rows)))
             for _ in range(n_rows)}
        )
        rels[f"r{k}"] = {
            "fk1": [p[0] for p in pairs],
            "fk2": [p[1] for p in pairs],
            "attrs": {f"w{k}": [("p", "q")[int(rng.integers(0, 2))] for _ in pairs]},
        }
    return from_labels(schema, ents, rels)


def random_rel_inserts(db: RelationalDatabase, table: str, size: int,
                       rng: np.random.Generator) -> dict:
    """An ``apply_delta`` insert spec with uniform fks/attr codes.  May
    collide with surviving pairs — pair with a delete, or use
    :func:`absent_pair_inserts` when the pair-uniqueness precondition must
    hold unconditionally."""
    decl = next(d for d in db.schema.relationships if d.name == table)
    n1 = db.entities[decl.entities[0]].n_rows
    n2 = db.entities[decl.entities[1]].n_rows
    return {
        "fk1": rng.integers(0, n1, size=size, dtype=np.int32),
        "fk2": rng.integers(0, n2, size=size, dtype=np.int32),
        "attrs": {
            attr: rng.integers(1, len(dom) + 1, size=size, dtype=np.int32)
            for attr, dom in decl.attributes
        },
    }


def absent_pair_inserts(db: RelationalDatabase, table: str, size: int,
                        rng: np.random.Generator) -> dict:
    """Valid inserts: pairs with no surviving row (the apply_delta
    precondition — each pair grounds the relationship at most once)."""
    decl = next(d for d in db.schema.relationships if d.name == table)
    rel = db.relationships[table]
    n1 = db.entities[decl.entities[0]].n_rows
    n2 = db.entities[decl.entities[1]].n_rows
    taken = set(zip(np.asarray(rel.fk1).tolist(), np.asarray(rel.fk2).tolist()))
    free = [(i, j) for i in range(n1) for j in range(n2) if (i, j) not in taken]
    rng.shuffle(free)
    picks = free[:size]
    return {
        "fk1": [p[0] for p in picks],
        "fk2": [p[1] for p in picks],
        "attrs": {
            attr: rng.integers(1, len(dom) + 1, size=len(picks)).tolist()
            for attr, dom in decl.attributes
        },
    }


__all__ = [
    "absent_pair_inserts",
    "chain_db",
    "fuzz_db",
    "fuzz_seeds",
    "random_rel_inserts",
    "rv_subset",
    "schema_specs",
]
