"""Device-side sparse CT builds: the join-tree contraction + Möbius virtual
join as COO code algebra on device.  Pins the bit-identity contract — a
device-built table's ``to_host()`` must match the host builder's codes and
float32 counts exactly on every tricky count-query shape (multi-relationship
Möbius joins, §VI block/``restrict`` paths, empty joins, degenerate trees) —
plus the ``ops.coo_join`` sort-merge kernel vs its oracle and the
zero-host-COO traffic story."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import counts
from repro.core.counts import joint_contingency_table
from repro.core.database import from_labels, university_db
from repro.core.schema import make_schema
from repro.core.score_manager import CountCache, ScoreManager
from repro.core.sparse_counts import DeviceSparseCT, SparseCT
from repro.core.structure import learn_and_join
from repro.kernels import bucketing, ops

from .bruteforce import random_db
from .strategies import chain_db


def _pair(db, rvs, **kw):
    """(host build, device build) of one count query, both sparse."""
    host = counts.contingency_table(db, rvs, impl="sparse", **kw)
    dev = counts.contingency_table(db, rvs, impl="sparse", device_resident=True, **kw)
    assert isinstance(host, SparseCT) and isinstance(dev, DeviceSparseCT)
    return host, dev


def _assert_bit_identical(host: SparseCT, dev: DeviceSparseCT) -> None:
    got = dev.to_host()
    assert got.rvs == host.rvs and got.cards == host.cards
    np.testing.assert_array_equal(got.codes, host.codes)
    np.testing.assert_array_equal(got.counts, host.counts)  # bitwise, not close


def _empty_rel_db():
    schema = make_schema(
        entities={"a": {"x": ("0", "1")}, "b": {"y": ("0", "1", "2")}},
        relationships={"R": (("a", "b"), {"w": ("p", "q")})},
    )
    return from_labels(
        schema,
        {"a": {"x": ["0", "1", "1"]}, "b": {"y": ["2", "0"]}},
        {"R": {"fk1": [], "fk2": [], "attrs": {"w": []}}},
    )


# ---------------------------------------------------------------------------
# ops.coo_join: sort-merge join vs a brute-force pairing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_coo_join_matches_bruteforce(impl, seed):
    rng = np.random.default_rng(seed)
    skeys = np.sort(rng.integers(0, 11, int(rng.integers(1, 60)))).astype(np.int32)
    pkeys = rng.integers(0, 13, int(rng.integers(1, 70))).astype(np.int32)
    ia, ib, valid, total = ops.coo_join(
        jnp.asarray(skeys), jnp.asarray(pkeys), impl=impl
    )
    want = [
        (int(m), j)
        for j, p in enumerate(pkeys)
        for m in np.flatnonzero(skeys == p)
    ]
    assert total == len(want)
    # results come back at the bucketed length with a valid-prefix mask
    assert ia.shape == ib.shape == valid.shape
    assert int(ia.shape[0]) == bucketing.bucket_rows(total)
    np.testing.assert_array_equal(
        np.asarray(valid), np.arange(int(ia.shape[0])) < total
    )
    got = list(zip(
        np.asarray(ia)[:total].tolist(), np.asarray(ib)[:total].tolist()
    ))
    assert got == want  # probe-major order, contiguous match runs


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_coo_join_empty_sides(impl):
    empty = jnp.zeros((0,), jnp.int32)
    some = jnp.asarray([0, 1, 2], jnp.int32)
    for a, b in [(empty, some), (some, empty), (empty, empty)]:
        ia, ib, valid, total = ops.coo_join(a, b, impl=impl)
        assert total == 0 and ia.shape == (0,) and ib.shape == (0,)
        assert valid.shape == (0,)
    # disjoint key ranges: probes present, zero matches
    ia, ib, valid, total = ops.coo_join(some, jnp.asarray([7, 9], jnp.int32), impl=impl)
    assert total == 0


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_coo_join_padded_probes_match_nothing(impl):
    # bucket-padding sentinels on either side never produce pairs: pad
    # probes are masked, pad sorted keys are unreachable for valid probes
    skeys = jnp.asarray([1, 2, 2, ops.PAD_KEY, ops.PAD_KEY], jnp.int32)
    pkeys = jnp.asarray([2, ops.PAD_KEY, 1, ops.PAD_KEY], jnp.int32)
    ia, ib, valid, total = ops.coo_join(skeys, pkeys, impl=impl)
    assert total == 3
    got = list(zip(np.asarray(ia)[:total].tolist(), np.asarray(ib)[:total].tolist()))
    assert got == [(1, 0), (2, 0), (0, 2)]


def test_coo_join_counts_launch_and_scalar_sync():
    ops.reset_launch_counts()
    ops.reset_transfer_counts()
    ops.coo_join(jnp.asarray([0, 0, 1], jnp.int32), jnp.asarray([0, 1], jnp.int32))
    assert ops.launch_counts().get("coo_join") == 1
    assert ops.transfer_bytes()["d2h"] == 8  # the one int64 size sync


# ---------------------------------------------------------------------------
# Build equivalence: device vs host, bit-identical
# ---------------------------------------------------------------------------


def test_device_joint_build_university():
    db = university_db()
    host = joint_contingency_table(db, impl="sparse")
    dev = joint_contingency_table(db, impl="sparse", device_resident=True)
    assert isinstance(dev, DeviceSparseCT)
    _assert_bit_identical(host, dev)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("self_rel", [False, True])
def test_device_build_random_dbs(seed, self_rel):
    db = random_db(seed, self_rel=self_rel)
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    host, dev = _pair(db, rvs)
    _assert_bit_identical(host, dev)


@pytest.mark.parametrize("depth", [2, 3])
def test_device_build_multi_relationship_mobius(depth):
    """Chains of relationships: the Möbius recursion nests ``depth`` signed
    subtraction levels, each with a relationship-attribute n/a embedding."""
    db = chain_db(depth=depth)
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    host, dev = _pair(db, rvs)
    _assert_bit_identical(host, dev)


def test_device_build_group_axis():
    """§VI block access: the ``__group__`` pseudo-axis survives the device
    root contraction with its entity rows intact."""
    db = random_db(11)
    rvs = ("b1(beta0)", "R(alpha0,beta0)", "ra(alpha0,beta0)")
    host, dev = _pair(db, rvs, group_fovar="alpha0")
    _assert_bit_identical(host, dev)


def test_device_build_restrict():
    """§VI single access: counting restricted to one entity row."""
    db = random_db(11)
    rvs = ("b1(beta0)", "R(alpha0,beta0)", "ra(alpha0,beta0)")
    for e in range(db.entities["alpha"].n_rows):
        host, dev = _pair(db, rvs, restrict={"alpha0": e})
        _assert_bit_identical(host, dev)


def test_device_build_empty_join():
    """A relationship with zero tuples: the T branch is empty, all mass sits
    in the Möbius F block (and the rel attribute at its n/a code)."""
    db = _empty_rel_db()
    rvs = tuple(v.vid for v in db.catalog.par_rvs)
    host, dev = _pair(db, rvs)
    assert float(host.total()) > 0  # the F block carries the cross product
    _assert_bit_identical(host, dev)


def test_device_build_degenerate_trees():
    """Single-leaf (one fovar, no relationships) and disconnected-component
    (pure cross product) join trees."""
    db = _empty_rel_db()
    for rvs in [("x(a0)",), ("y(b0)",), ("x(a0)", "y(b0)")]:
        host, dev = _pair(db, rvs)
        _assert_bit_identical(host, dev)


def test_device_build_conditional_only():
    """The conditional contraction (no Möbius level) on its own."""
    from repro.core.sparse_counts import device_sparse_ct_conditional

    db = university_db()
    query = ("intelligence(student0)", "salary(prof0,student0)")
    host = counts.ct_conditional(db, query, ("RA",), impl="sparse")
    dev = device_sparse_ct_conditional(db, query, ("RA",))
    assert isinstance(dev, DeviceSparseCT)
    _assert_bit_identical(host, dev)


# ---------------------------------------------------------------------------
# Canonical form + traffic of the device route
# ---------------------------------------------------------------------------


def test_device_build_canonical_form():
    """Bucket-trimmed pad tail, non-decreasing codes, strict host canonical
    on d2h.  Since the shape-bucketing layer, the device table keeps an
    identity-padding suffix up to its row-ladder rung (int-max codes, zero
    counts) instead of an exact compaction — every consumer treats it as
    absent, and ``to_host()`` restores the strict form."""
    from repro.core.sparse_counts import _PAD_CODE

    db = university_db()
    dev = joint_contingency_table(db, impl="sparse", device_resident=True)
    codes = np.asarray(dev.codes)
    counts = np.asarray(dev.counts)
    assert np.all(np.diff(codes) >= 0)
    # the table length sits on the bucket ladder, valid cells as a prefix
    assert codes.size == bucketing.bucket_rows(codes.size)
    pad = codes == _PAD_CODE
    n_valid = int((~pad).sum())
    assert np.all(~pad[:n_valid]) and np.all(pad[n_valid:])  # pads are a suffix
    assert np.all(counts[pad] == 0.0)
    assert n_valid == 0 or codes[n_valid - 1] < dev.n_cells
    host = dev.to_host()
    assert np.all(np.diff(host.codes) > 0) and np.all(host.counts != 0)


def test_device_build_zero_host_coo_traffic():
    """The tentpole acceptance: the device joint build ships NO bulk COO
    columns across the PCIe — zero h2d bytes, d2h limited to scalar size
    syncs (8 bytes each)."""
    db = university_db()
    ops.reset_transfer_counts()
    dev = joint_contingency_table(db, impl="sparse", device_resident=True)
    tr = ops.transfer_bytes()
    assert tr["h2d"] == 0
    assert 0 < tr["d2h"] <= 8 * 64  # a handful of scalar syncs
    # the PR 3 route (host build + bulk upload) for contrast
    ops.reset_transfer_counts()
    host = joint_contingency_table(db, impl="sparse")
    host.to_device()
    assert ops.transfer_bytes()["h2d"] >= host.codes.nbytes + host.counts.nbytes
    assert dev.to_host().n_nonzero() == host.n_nonzero()


def test_device_built_joint_serves_score_manager():
    """CountCache/ScoreManager threading: a device-*built* joint drives the
    fused scoring path to the same model as the host sparse path."""
    db = university_db()
    mgr = ScoreManager(db, mode="sparse", device_resident=True)
    assert isinstance(mgr.joint, DeviceSparseCT)
    res_dev = learn_and_join(db, mgr, score="aic", max_parents=2, max_chain=1)
    ser = CountCache(db, mode="sparse")
    res_ser = learn_and_join(db, ser, score="aic", max_parents=2, max_chain=1)
    assert sorted(res_dev.bn.edges()) == sorted(res_ser.bn.edges())


def test_device_build_marginals_match_host_build():
    """Marginals of a device-built joint == marginals of the host joint
    (the served-family-CT contract of CountCache)."""
    db = chain_db(depth=2)
    host = joint_contingency_table(db, impl="sparse")
    dev = joint_contingency_table(db, impl="sparse", device_resident=True)
    for keep in [host.rvs[:2], (host.rvs[3], host.rvs[0]), host.rvs[-2:]]:
        hm = host.marginal(tuple(keep))
        dm = dev.marginal(tuple(keep)).to_host()
        np.testing.assert_array_equal(dm.codes, hm.codes)
        np.testing.assert_array_equal(dm.counts, hm.counts)


# ---------------------------------------------------------------------------
# Small-stream crossover routing (REPRO_DEVICE_MIN_ROWS)
# ---------------------------------------------------------------------------


def test_device_min_rows_routes_small_db_to_host():
    """Below the crossover, device_resident=True silently uses the host
    builder: same cells, host SparseCT type, no accounted device launches."""
    db = university_db()
    old = counts.set_device_min_rows(db.total_tuples + 1)
    try:
        ct = joint_contingency_table(db, impl="sparse", device_resident=True)
        assert isinstance(ct, SparseCT) and not isinstance(ct, DeviceSparseCT)
    finally:
        counts.set_device_min_rows(old)
    host = joint_contingency_table(db, impl="sparse")
    np.testing.assert_array_equal(ct.codes, host.codes)
    np.testing.assert_array_equal(ct.counts, host.counts)


def test_device_min_rows_honors_flag_at_threshold():
    """At/above the threshold the device build runs (>= comparison)."""
    db = university_db()
    old = counts.set_device_min_rows(db.total_tuples)
    try:
        ct = joint_contingency_table(db, impl="sparse", device_resident=True)
        assert isinstance(ct, DeviceSparseCT)
    finally:
        counts.set_device_min_rows(old)


def test_device_min_rows_setter_contract():
    old = counts.set_device_min_rows(123)
    try:
        assert counts.device_min_rows() == 123
        with pytest.raises(ValueError):
            counts.set_device_min_rows(-1)
        assert counts.device_min_rows() == 123  # failed set leaves it alone
    finally:
        counts.set_device_min_rows(old)


def test_host_routed_joint_serves_score_manager():
    """ScoreManager(device_resident=True) over a host-routed (small-DB)
    joint still scores — and picks the same model as the device path."""
    db = university_db()
    old = counts.set_device_min_rows(db.total_tuples + 1)
    try:
        mgr = ScoreManager(db, mode="sparse", device_resident=True)
        assert isinstance(mgr.joint, SparseCT)
        res_host = learn_and_join(db, mgr, score="aic", max_parents=2, max_chain=1)
    finally:
        counts.set_device_min_rows(old)
    dev_mgr = ScoreManager(db, mode="sparse", device_resident=True)
    res_dev = learn_and_join(db, dev_mgr, score="aic", max_parents=2, max_chain=1)
    assert sorted(res_host.bn.edges()) == sorted(res_dev.bn.edges())
