"""Incremental maintenance: O(Δ) signed-delta apply vs full joint rebuilds.

The ROADMAP's "live database" item: after a handful of relationship rows
change, the pre-counted device-resident joint should be *maintained*, not
rebuilt.  This leg measures exactly that trade on a ``ScoreManager`` with a
``mode="sparse", device_resident=True`` joint:

  * **rebuild baseline** — a warm from-scratch device build of the current
    joint (what every delta pays with ``REPRO_INCREMENTAL=0``);
  * **delta applies** — :meth:`ScoreManager.apply_delta` with random insert
    batches of 1 / 10^2 / 10^4 rows into the largest relationship table.
    Each size runs cold (pays any new delta-view bucket rungs) then warm;
    the warm pass is compile-counted — the bucket ladder must make repeat
    deltas of a seen shape **zero**-compile (gated for the single-row size
    by ``benchmarks/run.py``).

After *every* apply the maintained joint is checked **bit-identical** in
canonical host form (codes AND float32 counts) against a from-scratch device
rebuild of the mutated database — ``incremental_equal`` is the AND over all
checks and gates the run the same way the scale leg's flags do.  On the
paper-analogue dataset the leg also populates the score memo with a full
``learn_and_join`` first, so the dirty-set refresh counters
(``n_dirty_families`` / ``n_preserved_families``) measure how much scoring
work a single-table delta actually preserves.

Results land under the ``bench_incremental`` key of ``BENCH_structure.json``.
"""

from __future__ import annotations

import numpy as np

from repro.core.counts import joint_contingency_table, set_device_min_rows
from repro.core.score_manager import ScoreManager
from repro.core.sparse_counts import as_host
from repro.core.structure import learn_and_join
from repro.kernels import bucketing

from .common import emit, load, timed

#: CI smoke artifact vs the committed full document (mirrors bench_scale).
SMOKE_PRESETS = ["uw-cse"]
FULL_PRESETS = ["uw-cse", "synth-1m"]

#: Insert-batch sizes per delta apply (the ISSUE's 1 / 10^2 / 10^4 ladder).
DELTA_SIZES = (1, 100, 10_000)


def _equal(oracle_ct, live_ct) -> bool:
    """Bit-identity of the maintained joint against a from-scratch rebuild."""
    h, d = as_host(oracle_ct), as_host(live_ct)
    return (
        h.rvs == d.rvs
        and np.array_equal(np.asarray(h.codes), np.asarray(d.codes))
        and np.array_equal(np.asarray(h.counts), np.asarray(d.counts))
    )


def _random_inserts(db, table: str, size: int, rng) -> dict:
    """A ``database.apply_delta`` insert spec of ``size`` random rows."""
    decl = next(d for d in db.schema.relationships if d.name == table)
    n1 = db.entities[decl.entities[0]].n_rows
    n2 = db.entities[decl.entities[1]].n_rows
    return {
        "fk1": rng.integers(0, n1, size=size, dtype=np.int32),
        "fk2": rng.integers(0, n2, size=size, dtype=np.int32),
        # stored groundings are true: codes in the n/a-augmented [1, |dom|]
        "attrs": {
            attr: rng.integers(1, len(dom) + 1, size=size, dtype=np.int32)
            for attr, dom in decl.attributes
        },
    }


def _device_rebuild(db):
    """From-scratch device joint of ``db`` (the equality oracle)."""
    old = set_device_min_rows(0)
    try:
        return joint_contingency_table(db, impl="sparse", device_resident=True)
    finally:
        set_device_min_rows(old)


def run_incremental(presets: list[str] | None = None) -> dict:
    """Delta-apply vs rebuild on each preset; -> metrics dict.

    Emits ``incremental/<preset>/...`` CSV rows and returns the JSON-ready
    dict ``benchmarks.run`` stores under ``payload["bench_incremental"]``.
    The joint *build* forces the device route (this leg measures maintenance
    of a device-resident joint); the delta applies run under **production**
    routing, so small delta views take the host contraction and only the
    signed merge touches the device — that routing *is* the fast path.
    """
    out: dict[str, dict] = {}
    for name in presets or FULL_PRESETS:
        bdb, _ = timed(load, name)
        table = max(
            bdb.db.relationships,
            key=lambda t: bdb.db.relationships[t].n_rows,
        )
        rng = np.random.default_rng(11)

        old = set_device_min_rows(0)
        try:
            mgr, build_secs = timed(
                ScoreManager, bdb.db, mode="sparse", device_resident=True
            )
            # warm full-rebuild baseline: what REPRO_INCREMENTAL=0 pays on
            # every delta (second run so compile time stays out of it)
            timed(
                joint_contingency_table, mgr.db, impl="sparse",
                device_resident=True,
            )
            _, rebuild_secs = timed(
                joint_contingency_table, mgr.db, impl="sparse",
                device_resident=True,
            )
        finally:
            set_device_min_rows(old)

        metrics: dict = {
            "total_tuples": int(bdb.db.total_tuples),
            "table": table,
            "build_ms": build_secs * 1e3,
            "rebuild_warm_ms": rebuild_secs * 1e3,
        }

        # populate the score memo so the dirty-set refresh has families to
        # preserve (paper-analogue datasets only — the synth star schemas
        # measure raw delta latency, not structure search)
        if not name.startswith("synth"):
            _, learn_secs = timed(
                learn_and_join, mgr.db, mgr, score="aic", max_parents=2
            )
            metrics["learn_ms"] = learn_secs * 1e3

        all_equal = True
        for d in DELTA_SIZES:
            # cold apply: pays any delta-view bucket rungs not yet compiled
            cold_stats, cold_secs = timed(
                mgr.apply_delta, table, _random_inserts(mgr.db, table, d, rng)
            )
            eq_cold = _equal(_device_rebuild(mgr.db), mgr.joint)
            # transition apply: the cold one may have grown the live joint
            # across a ladder rung, so the second still sees a new merge
            # shape — only from the third on is the shape set closed
            mgr.apply_delta(table, _random_inserts(mgr.db, table, d, rng))
            # warm apply of the same delta shape: must be compile-free
            bucketing.reset_compile_counts()
            warm_stats, warm_secs = timed(
                mgr.apply_delta, table, _random_inserts(mgr.db, table, d, rng)
            )
            compiles_warm = bucketing.compile_counts()["compiles"]
            eq_warm = _equal(_device_rebuild(mgr.db), mgr.joint)

            metrics[f"delta{d}_apply_ms_cold"] = cold_secs * 1e3
            metrics[f"delta{d}_apply_ms"] = warm_secs * 1e3
            metrics[f"delta{d}_compiles_warm"] = compiles_warm
            metrics[f"delta{d}_equal"] = eq_cold and eq_warm
            all_equal = all_equal and eq_cold and eq_warm
            if d == DELTA_SIZES[0]:
                # dirty-set refresh split of the first (post-learn) apply
                metrics["n_dirty_families"] = cold_stats["n_dirty_families"]
                metrics["n_preserved_families"] = cold_stats[
                    "n_preserved_families"
                ]
                metrics["delta1_incremental"] = bool(warm_stats["incremental"])

        metrics["incremental_equal"] = all_equal
        metrics["delta1_speedup"] = rebuild_secs / max(
            metrics["delta1_apply_ms"] / 1e3, 1e-9
        )
        if mgr._msg_cache is not None:
            metrics["msg_cache_hits"] = mgr._msg_cache.hits
            metrics["msg_cache_misses"] = mgr._msg_cache.misses

        out[name] = metrics
        emit(
            f"incremental/{name}/rebuild_warm", rebuild_secs,
            f"total_tuples={metrics['total_tuples']};table={table}",
        )
        for d in DELTA_SIZES:
            emit(
                f"incremental/{name}/delta{d}_apply",
                metrics[f"delta{d}_apply_ms"] / 1e3,
                f"cold={metrics[f'delta{d}_apply_ms_cold']:.2f}ms;"
                f"compiles_warm={metrics[f'delta{d}_compiles_warm']};"
                f"equal={metrics[f'delta{d}_equal']}",
            )
        emit(
            f"incremental/{name}/summary",
            metrics["delta1_apply_ms"] / 1e3,
            f"speedup={metrics['delta1_speedup']:.1f}x;"
            f"dirty={metrics['n_dirty_families']};"
            f"preserved={metrics['n_preserved_families']};"
            f"equal={all_equal}",
        )
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--presets", nargs="*", default=None,
                   help=f"presets (default: {FULL_PRESETS})")
    a = p.parse_args(argv)
    print("name,us_per_call,derived")
    run_incremental(a.presets)


if __name__ == "__main__":
    main()
