"""Paper-beyond scale: host vs sharded-device sparse joint builds.

The paper's largest database is ~10^6 tuples; the ``synth-*`` star schemas
(:mod:`repro.data.synth`) push the fact relationship to 10^6..10^7+ rows —
the regime the device COO engine has to *earn*.  This leg builds the same
sparse joint CT three ways and reports the speedup that decides the route:

  * **host** — :func:`repro.core.counts.joint_contingency_table` with
    ``impl="sparse"`` (numpy lexsort + reduceat, float64 accumulate): the
    semantic oracle and the small-N fast path;
  * **device** — the same call with ``device_resident=True``: the COO code
    algebra on device, run cold THEN warm so XLA compile time keeps its own
    key (``device_build_ms_cold``) and the headline
    ``sparse_device_speedup = host_ms / device_build_ms_warm`` is
    steady-state;
  * **sharded device** — ``shards=2`` and ``shards=4``: the fact table
    row-sharded through ``device_sparse_ct_conditional``'s pivot split
    (per-shard contraction, one signed-aggregate merge).

Every leg must be **bit-identical** (codes AND float32 counts) to the host
build; the ``*_equal`` flags gate the numbers the same way the structure
bench's equivalence flags do (``benchmarks/run.py`` fails on any False).
Results land under the ``bench_scale`` key of ``BENCH_structure.json``.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.counts import (
    device_min_rows,
    joint_contingency_table,
    set_device_min_rows,
)
from repro.core.sparse_counts import as_host
from repro.kernels import ops

from .common import emit, load, timed

#: Presets for the CI smoke artifact vs the committed full document vs the
#: weekly slow schedule (see .github/workflows/ci.yml).
SMOKE_PRESETS = ["synth-smoke"]
FULL_PRESETS = ["synth-smoke", "synth-1m"]
WEEKLY_PRESETS = ["synth-smoke", "synth-1m", "synth-4m", "synth-10m"]

#: Shard counts exercised by the sharded legs (each gated bit-identical).
SHARD_COUNTS = (2, 4)


def _equal(host_ct, dev_ct) -> bool:
    """Bit-identity of a device build against the host oracle."""
    h, d = as_host(host_ct), as_host(dev_ct)
    return (
        h.rvs == d.rvs
        and np.array_equal(np.asarray(h.codes), np.asarray(d.codes))
        and np.array_equal(np.asarray(h.counts), np.asarray(d.counts))
    )


def _crossover_rows(out: dict[str, dict]) -> int | None:
    """Log-log interpolated host/device break-even row count.

    Fits ``log(speedup)`` linearly in ``log(total_tuples)`` through the
    measured presets and solves for speedup = 1 — the row count below which
    the host lexsort build wins, i.e. the measured value the
    ``REPRO_DEVICE_MIN_ROWS`` default is calibrated against.  ``None``
    when fewer than two presets ran or all sit on one side of 1x.
    """
    pts = sorted(
        (math.log(m["total_tuples"]), math.log(m["sparse_device_speedup"]))
        for m in out.values()
        if m["total_tuples"] > 0 and m["sparse_device_speedup"] > 0
    )
    if len(pts) < 2:
        return None
    (x0, y0), (x1, y1) = pts[0], pts[-1]
    if y1 == y0 or not (min(y0, y1) < 0.0 < max(y0, y1)):
        return None
    return int(round(math.exp(x0 - y0 * (x1 - x0) / (y1 - y0))))


def run_scale(presets: list[str] | None = None) -> dict:
    """Build the scale presets' sparse joints host/device/sharded; -> metrics.

    Emits ``scale/<preset>/...`` CSV rows and returns the JSON-ready dict
    ``benchmarks.run`` stores under ``payload["bench_scale"]``.  Device legs
    run with the ``REPRO_DEVICE_MIN_ROWS`` crossover forced to 0 (this leg
    *measures* the device path — the routing would host-route the small
    presets); each preset records whether production routing would have
    taken the device path, and the ``_routing`` entry records the active
    threshold next to the crossover interpolated from the measurements.
    """
    old_min_rows = set_device_min_rows(0)
    try:
        out = _run_scale(presets)
    finally:
        set_device_min_rows(old_min_rows)
    # routed flags use the PRODUCTION threshold (restored above), not the 0
    # the measurement legs forced
    for m in out.values():
        m["device_routed"] = m["total_tuples"] >= device_min_rows()
    out["_routing"] = {
        "device_min_rows": device_min_rows(),
        "measured_crossover_rows": _crossover_rows(out),
    }
    return out


def _run_scale(presets: list[str] | None = None) -> dict:
    out: dict[str, dict] = {}
    for name in presets or FULL_PRESETS:
        bdb, gen_secs = timed(load, name)
        db = bdb.db
        n_facts = sum(r.n_rows for r in db.relationships.values())

        # host oracle: second run is the reported number so one-time numpy
        # warmup (BLAS thread pools, allocator growth) stays out of it
        timed(joint_contingency_table, db, impl="sparse")
        host_ct, host_secs = timed(joint_contingency_table, db, impl="sparse")

        ops.reset_compile_counts()
        dev_cold, cold_secs = timed(
            joint_contingency_table, db, impl="sparse", device_resident=True,
        )
        cold_compiles = ops.compile_counts()
        dev_warm, warm_secs = timed(
            joint_contingency_table, db, impl="sparse", device_resident=True,
        )

        metrics = {
            "n_facts": n_facts,
            "total_tuples": int(db.total_tuples),
            "nnz": int(np.asarray(as_host(host_ct).codes).shape[0]),
            "generate_ms": gen_secs * 1e3,
            "host_build_ms": host_secs * 1e3,
            "device_build_ms_cold": cold_secs * 1e3,
            "device_build_ms_warm": warm_secs * 1e3,
            "compiles": cold_compiles["compiles"],
            "sparse_device_speedup": host_secs / max(warm_secs, 1e-9),
            "sparse_device_equal": _equal(host_ct, dev_cold)
            and _equal(host_ct, dev_warm),
        }

        for shards in SHARD_COUNTS:
            # warm sharded build (the cold pass pays the new rungs' compiles)
            timed(
                joint_contingency_table, db, impl="sparse",
                device_resident=True, shards=shards,
            )
            sh_ct, sh_secs = timed(
                joint_contingency_table, db, impl="sparse",
                device_resident=True, shards=shards,
            )
            metrics[f"sharded{shards}_build_ms"] = sh_secs * 1e3
            metrics[f"sharded{shards}_equal"] = _equal(host_ct, sh_ct)

        out[name] = metrics
        emit(
            f"scale/{name}/host_build", host_secs,
            f"n_facts={n_facts};nnz={metrics['nnz']};gen={gen_secs:.2f}s",
        )
        emit(
            f"scale/{name}/device_build", warm_secs,
            f"speedup={metrics['sparse_device_speedup']:.2f}x;"
            f"cold={cold_secs:.3f}s;compiles={metrics['compiles']};"
            f"equal={metrics['sparse_device_equal']}",
        )
        for shards in SHARD_COUNTS:
            emit(
                f"scale/{name}/sharded{shards}_build",
                metrics[f"sharded{shards}_build_ms"] / 1e3,
                f"equal={metrics[f'sharded{shards}_equal']}",
            )
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--presets", nargs="*", default=None,
                   help=f"scale presets (default: {FULL_PRESETS})")
    a = p.parse_args(argv)
    print("name,us_per_call,derived")
    run_scale(a.presets)


if __name__ == "__main__":
    main()
