"""Roofline table from the dry-run JSON cache (results/dryrun/*.json).

Emits one CSV row per (arch x shape x mesh) cell with the three roofline
terms; also used by tools/make_experiments.py to regenerate the
EXPERIMENTS.md §Dry-run / §Roofline tables.
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

from .common import emit

DRYRUN_DIR = Path("results/dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    files = sorted(glob.glob(str(DRYRUN_DIR / "*.json")))
    if not files:
        raise FileNotFoundError(f"no dry-run cache under {DRYRUN_DIR}")
    recs = [json.loads(Path(f).read_text()) for f in files]
    if mesh:
        recs = [r for r in recs if r.get("mesh") == mesh]
    return recs


def run() -> None:
    recs = load_records()
    for r in recs:
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skip":
            emit(f"roofline/{cell}", 0.0, f"skip:{r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            emit(f"roofline/{cell}", 0.0, "ERROR")
            continue
        rf = r["roofline"]
        mem = r.get("memory_analysis", {}).get("peak_bytes_est", 0) / 1e9
        emit(
            f"roofline/{cell}",
            rf["roofline_s"],
            f"bottleneck={rf['bottleneck']};compute_s={rf['compute_s']:.4g};"
            f"memory_s={rf['memory_s']:.4g};collective_s={rf['collective_s']:.4g};"
            f"useful={rf['useful_ratio']:.3f};frac={rf['roofline_fraction']:.4f};"
            f"hbm_gb={mem:.1f}",
        )


def main(argv=None) -> None:
    run()


if __name__ == "__main__":
    main()
