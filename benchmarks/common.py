"""Shared benchmark utilities: dataset instantiation, timing, CSV emission."""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.database import RelationalDatabase
from repro.data.relational import BENCHMARKS, SyntheticSpec, generate
from repro.data.synth import SCALE_PRESETS, ScaleSpec, generate_scale

# Default scales keep a full `python -m benchmarks.run` pass tractable on a
# single CPU core while preserving the paper's cross-dataset ordering
# (MovieLens/IMDb ~10^5-10^6 tuples, the rest at full synthetic scale).
# --paper-scale lifts MovieLens/IMDb to the paper's >10^6-tuple regime.
# The synth-* star schemas (repro.data.synth) run at their preset size.
DEFAULT_SCALES = {
    "movielens": 0.25,
    "mutagenesis": 1.0,
    "uw-cse": 1.0,
    "mondial": 1.0,
    "hepatitis": 1.0,
    "imdb": 0.1,
    **{name: 1.0 for name in SCALE_PRESETS},
}


@dataclass
class BenchDB:
    name: str
    spec: SyntheticSpec | ScaleSpec
    db: RelationalDatabase


_CACHE: dict[tuple[str, float, int], BenchDB] = {}


def load(name: str, scale: float | None = None, seed: int = 7) -> BenchDB:
    """Instantiate a bench database by name (memoized per (name, scale, seed)).

    Names resolve against the paper-analogue catalog
    (``repro.data.relational.BENCHMARKS``) first, then the million-row
    ``synth-*`` star-schema presets (``repro.data.synth.SCALE_PRESETS``) —
    the scale-leg datasets are first-class here, loadable by every bench.
    """
    synth = name not in BENCHMARKS
    spec = SCALE_PRESETS[name] if synth else BENCHMARKS[name]
    s = scale if scale is not None else DEFAULT_SCALES[name]
    key = (name, s, seed)
    if key not in _CACHE:
        scaled = spec.scaled(s)
        gen = generate_scale if synth else generate
        _CACHE[key] = BenchDB(name, scaled, gen(scaled, seed=seed))
    return _CACHE[key]


def emit(name: str, seconds: float, derived: str) -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0
