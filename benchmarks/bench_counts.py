"""Paper Table VI: count manager — sufficient statistics and computing time.

For each benchmark database: build the joint contingency table over all
par-RVs (the paper's pre-counting workload), report #tuples, #sufficient
statistics (realized cells), dense cells, and the SS computing time.  The
BN-compression ratio (#SS / #BN-parameters, discussed with Table VI) is
reported by bench_params once a structure is learned.
"""

from __future__ import annotations

import jax

from repro.core.counts import joint_contingency_table

from .common import emit, load, timed


def _built(ct):
    """Force completion (dense CTs are async jax arrays; sparse are host COO)."""
    if hasattr(ct, "table"):
        jax.block_until_ready(ct.table)
    return ct


def run(datasets: list[str], scale: float | None = None) -> dict[str, dict]:
    out: dict[str, dict] = {}
    for name in datasets:
        bdb = load(name, scale)
        (jt, secs) = timed(
            lambda: _built(joint_contingency_table(bdb.db, impl="auto"))
        )
        # second call re-times the jitted/traced path (steady-state)
        ct, secs2 = timed(
            lambda: joint_contingency_table(bdb.db, impl="auto")
        )
        _built(ct)
        nss = ct.n_nonzero()
        out[name] = {
            "tuples": bdb.db.total_tuples,
            "n_ss": nss,
            "cells": ct.n_cells,
            "seconds": secs,
            "ct": ct,
        }
        emit(
            f"table6/{name}/joint_ct",
            secs,
            f"tuples={bdb.db.total_tuples};SS={nss};cells={ct.n_cells}",
        )
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=list(load.__globals__["DEFAULT_SCALES"]))
    p.add_argument("--scale", type=float, default=None)
    a = p.parse_args(argv)
    run(a.datasets, a.scale)


if __name__ == "__main__":
    main()
