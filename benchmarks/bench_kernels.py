"""Kernel microbenchmarks: production path timing + Pallas validation cost.

On CPU the production dispatch is the jnp oracle (Pallas interpret mode is a
correctness harness, not a fast path); on TPU the same calls hit the Pallas
kernels.  Reported numbers are steady-state (post-jit) per-call times of the
production path at count-manager-realistic shapes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import emit


def _bench(fn, *args, iters: int = 20, **kw) -> float:
    jax.block_until_ready(fn(*args, **kw))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> None:
    rng = np.random.default_rng(0)

    n, bins = 1_000_000, 4096
    keys = jnp.asarray(rng.integers(0, bins, n).astype(np.int32))
    secs = _bench(ops.ct_count, keys, bins)
    emit("kernels/ct_count_1M_4096", secs, f"rows_per_s={n / secs:.3g}")

    w = jnp.asarray(rng.random(n).astype(np.float32))
    secs = _bench(ops.ct_count, keys, bins, w)
    emit("kernels/ct_count_weighted", secs, f"rows_per_s={n / secs:.3g}")

    ct = jnp.asarray(rng.integers(0, 100, (65536, 8)).astype(np.float32))
    secs = _bench(ops.mle_cpt, ct, 0.5)
    emit("kernels/mle_cpt_64k_x8", secs, f"rows_per_s={65536 / secs:.3g}")

    cpt = ops.mle_cpt(ct, 0.5)
    secs = _bench(ops.factor_loglik, ct, cpt)
    emit("kernels/factor_loglik_512k", secs, f"cells_per_s={ct.size / secs:.3g}")

    A = jnp.asarray(rng.random((8192, 1024)).astype(np.float32))
    L = jnp.asarray(rng.standard_normal((1024, 8)).astype(np.float32))
    secs = _bench(ops.block_predict, A, L)
    flops = 2 * 8192 * 1024 * 8
    emit("kernels/block_predict_8kx1kx8", secs, f"gflops={flops / secs / 1e9:.2f}")


def main(argv=None) -> None:
    run()


if __name__ == "__main__":
    main()
