"""Kernel microbenchmarks: production path timing + Pallas validation cost.

On CPU the production dispatch is the jnp oracle (Pallas interpret mode is a
correctness harness, not a fast path); on TPU the same calls hit the Pallas
kernels.  Reported numbers are steady-state (post-jit) per-call times of the
production path at count-manager-realistic shapes.

:func:`run_micro` adds the COO-primitive sweep (sort-aggregate, join probe,
join expansion — the three "kernel endgame" hotspots) as rows-vs-ms curves
with per-call launch counts, recorded under the ``bench_kernels`` key of
``BENCH_structure.json`` and rendered into the README by
``tools/render_bench.py``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels import bucketing, ops

from .common import emit


def _bench(fn, *args, iters: int = 20, **kw) -> float:
    jax.block_until_ready(fn(*args, **kw))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> None:
    rng = np.random.default_rng(0)

    n, bins = 1_000_000, 4096
    keys = jnp.asarray(rng.integers(0, bins, n).astype(np.int32))
    secs = _bench(ops.ct_count, keys, bins)
    emit("kernels/ct_count_1M_4096", secs, f"rows_per_s={n / secs:.3g}")

    w = jnp.asarray(rng.random(n).astype(np.float32))
    secs = _bench(ops.ct_count, keys, bins, w)
    emit("kernels/ct_count_weighted", secs, f"rows_per_s={n / secs:.3g}")

    ct = jnp.asarray(rng.integers(0, 100, (65536, 8)).astype(np.float32))
    secs = _bench(ops.mle_cpt, ct, 0.5)
    emit("kernels/mle_cpt_64k_x8", secs, f"rows_per_s={65536 / secs:.3g}")

    cpt = ops.mle_cpt(ct, 0.5)
    secs = _bench(ops.factor_loglik, ct, cpt)
    emit("kernels/factor_loglik_512k", secs, f"cells_per_s={ct.size / secs:.3g}")

    A = jnp.asarray(rng.random((8192, 1024)).astype(np.float32))
    L = jnp.asarray(rng.standard_normal((1024, 8)).astype(np.float32))
    secs = _bench(ops.block_predict, A, L)
    flops = 2 * 8192 * 1024 * 8
    emit("kernels/block_predict_8kx1kx8", secs, f"gflops={flops / secs / 1e9:.2f}")


#: Row-count sweep of the COO primitive microbenches — ladder rungs, so the
#: timed calls reuse exactly the programs the device build compiles.
MICRO_ROWS = (4096, 65536, 524288)


def run_micro() -> dict:
    """COO primitive sweep: sort-aggregate / join probe / join expansion.

    Times the *production* dispatch of each primitive (on CPU that is the
    XLA sort and the jitted jnp expansion oracle; on TPU the Pallas
    kernels) at bucket-ladder rungs, steady-state per-call.  Returns the
    JSON-ready dict ``benchmarks.run`` stores under
    ``payload["bench_kernels"]``: per primitive, a ``rows -> {ms,
    rows_per_s, launches}`` curve (launches = accounted ops-layer
    dispatches per call — the device-launch proxy the structure bench also
    reports).
    """
    rng = np.random.default_rng(0)
    out: dict[str, dict] = {"sort": {}, "join_probe": {}, "join_expand": {}}

    def curve(kind, n, fn, *args, total_rows=None, launches=1, **kw):
        # launches = compiled-program dispatches per timed call: the jitted
        # probe/expansion phases are one program each by construction; the
        # sort wrapper may add a padding launch on off-rung streams (not
        # here — the sweep sits on exact rungs)
        secs = _bench(fn, *args, **kw)
        rows = total_rows or n
        out[kind][str(n)] = {
            "ms": secs * 1e3,
            "rows_per_s": rows / secs,
            "launches": launches,
        }
        emit(f"kernels/{kind}_{n}", secs, f"rows_per_s={rows / secs:.3g}")

    for n in MICRO_ROWS:
        # sort-aggregate: int64 composite codes with heavy duplication (the
        # canonicalization workload of every build/marginal step)
        codes = (rng.integers(0, max(n // 8, 2), n) * (1 << 32)
                 + rng.integers(0, 1 << 16, n)).astype(np.int64)
        weights = rng.integers(1, 4, n).astype(np.float32)
        curve("sort", n, ops.coo_aggregate, codes, weights)

        # join probe: FK column vs sorted entity-row column (two
        # searchsorted passes + count mask, one fused program).  The x64
        # scope matches production (coo_join traces the int64 pair total)
        # so the timed program is the build's, not a fresh int32 twin.
        sorted_keys = jnp.asarray(np.sort(rng.integers(0, n // 2, n)).astype(np.int32))
        probe_keys = jnp.asarray(rng.integers(0, n // 2, n).astype(np.int32))

        def probe(s, p):
            with enable_x64():
                return ops._coo_join_probe_jit(s, p)

        curve("join_probe", n, probe, sorted_keys, probe_keys)

        # join expansion: match table -> flat gather indices, ~2 matches
        # per probe (the rank/gather kernel or its searchsorted oracle)
        cnt = rng.integers(0, 4, n).astype(np.int32)
        lo = np.concatenate([[0], np.cumsum(cnt)[:-1]]).astype(np.int32)
        total = int(cnt.sum())
        padded = bucketing.bucket_rows(total)
        curve(
            "join_expand", n,
            ops._coo_join_expand_ref_jit,
            jnp.asarray(lo), jnp.asarray(cnt), padded,
            total_rows=total,
        )

    # pallas-vs-oracle sort bit-identity: the acceptance flag next to the
    # host-vs-device and sharded ones (gated by benchmarks.run like every
    # *_equal).  Interpret mode off-TPU, so the stream is small on purpose
    # — identity pad tail included, the exact wrapper-fed layout.
    from repro.kernels.coo_sort import coo_sort_aggregate

    codes = (rng.integers(0, 40, 480) * (1 << 36)
             + rng.integers(0, 1 << 12, 480)).astype(np.int64)
    codes = np.concatenate([codes, np.full(32, np.iinfo(np.int64).max)])
    weights = np.concatenate(
        [rng.integers(1, 9, 480).astype(np.float32), np.zeros(32, np.float32)]
    )
    with enable_x64():
        ku, ks = coo_sort_aggregate(
            jnp.asarray(codes), jnp.asarray(weights),
            interpret=jax.default_backend() != "tpu",
            acc=ops.count_acc_dtype(),
        )
        ou, osum = ops._coo_aggregate_impl(
            jnp.asarray(codes), jnp.asarray(weights)
        )
    out["sort_kernel"] = {
        "pallas_oracle_sort_equal": bool(
            np.array_equal(np.asarray(ku), np.asarray(ou))
            and np.array_equal(np.asarray(ks), np.asarray(osum))
        ),
    }
    emit(
        "kernels/sort_pallas_vs_oracle", 0.0,
        f"equal={out['sort_kernel']['pallas_oracle_sort_equal']}",
    )
    return out


def main(argv=None) -> None:
    run()


if __name__ == "__main__":
    main()
