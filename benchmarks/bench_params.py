"""Paper Table VII: model manager — parameter learning from the joint CT.

Given a learned structure, time the estimation of every family's CPT (MLE
via the count manager's marginals + the mle_cpt kernel) and report #edges,
#parameters, and the BN-compression ratio #SS / #parameters that the paper
highlights with Table VI ("BNs provide very compact summaries").
"""

from __future__ import annotations

import jax

from repro.core.cpt import learn_parameters
from repro.core.structure import CountCache, learn_and_join

from .common import emit, load, timed


def run(datasets: list[str], scale: float | None = None) -> dict:
    out = {}
    for name in datasets:
        bdb = load(name, scale)
        cache = CountCache(bdb.db, mode="precount", impl="auto")
        res = learn_and_join(bdb.db, cache, score="aic", max_parents=2, max_chain=1, impl="auto")
        n_ss = cache.joint.n_nonzero()

        factors, secs = timed(learn_parameters, res.bn, cache, 0.0, impl="auto")
        for f in factors.values():
            jax.block_until_ready(f.table)
        n_par = sum(f.n_params for f in factors.values())
        emit(
            f"table7/{name}/param_learning", secs,
            f"edges={res.bn.n_edges};params={n_par};SS_per_param={n_ss / max(n_par, 1):.1f}",
        )
        out[name] = {"bn": res.bn, "factors": factors, "cache": cache,
                     "n_params": n_par, "seconds": secs}
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*",
                   default=["movielens", "mutagenesis", "uw-cse", "mondial", "hepatitis", "imdb"])
    p.add_argument("--scale", type=float, default=None)
    a = p.parse_args(argv)
    run(a.datasets, a.scale)


if __name__ == "__main__":
    main()
