"""Dense vs sparse CT backends: build time and peak cells vs domain size.

The paper's Table VI point, measured on this reproduction: the dense backend
materializes the full domain cross product, so its cell count explodes as
attribute cardinality and relationship-chain depth grow; the sparse COO
backend stores only realized sufficient statistics (#SS), bounded by the
data.  The sweep scales a chain schema until the dense joint would need
>10^9 cells — configurations only the sparse path can build.

CSV rows:
    sparse/<config>/dense  — dense build (or `oom` when over budget)
    sparse/<config>/sparse — sparse build, with #SS, the dense:SS ratio, and
                             the kernel-launch count of the build
    sparse/<config>/device_marginal_batch — batched GROUP BY of every
                             single-RV marginal on the device-resident COO
                             joint: launch count and accounted host<->device
                             transfer bytes (the device path ships the joint
                             once and pulls only split bounds back)
    sparse/<config>/device_build — the same joint built ON device
                             (``device_resident=True``): join-tree
                             contraction + Möbius join as COO code algebra,
                             with launch count, accounted h2d/d2h bytes
                             (h2d must be 0 — no bulk COO upload) and the
                             upload bytes the device build avoids
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.counts import dense_cells_of, joint_contingency_table
from repro.core.database import from_labels
from repro.core.schema import make_schema
from repro.kernels import ops

from .common import emit, timed

# Dense builds above this many cells are skipped (reported as `oom`); the
# default DENSE_CELL_BUDGET would auto-switch them to sparse anyway.
DENSE_BENCH_CAP = 1 << 24


def chain_db(depth: int, card: int, n_attrs: int, n_rows: int = 40, seed: int = 0):
    """Entities e0..e<depth> (each with ``n_attrs`` card-``card`` attributes)
    linked by a chain of ``depth`` relationships — the deep-chain workload."""
    rng = np.random.default_rng(seed)
    dom = tuple(str(i) for i in range(card))
    entities = {
        f"e{k}": {f"a{k}_{i}": dom for i in range(n_attrs)} for k in range(depth + 1)
    }
    relationships = {
        f"r{k}": ((f"e{k}", f"e{k + 1}"), {}) for k in range(depth)
    }
    schema = make_schema(entities=entities, relationships=relationships)
    ents = {
        f"e{k}": {
            f"a{k}_{i}": [dom[j] for j in rng.integers(0, card, n_rows)]
            for i in range(n_attrs)
        }
        for k in range(depth + 1)
    }
    rels = {}
    for k in range(depth):
        pairs = sorted(
            {(int(rng.integers(0, n_rows)), int(rng.integers(0, n_rows)))
             for _ in range(2 * n_rows)}
        )
        rels[f"r{k}"] = {"fk1": [p[0] for p in pairs], "fk2": [p[1] for p in pairs],
                         "attrs": {}}
    return from_labels(schema, ents, rels)


def run(configs=None) -> list[dict]:
    """Sweep (depth, cardinality, n_attrs); returns the measured rows."""
    from repro.core.counts import set_device_min_rows

    # measure the device build even on configs below the production
    # REPRO_DEVICE_MIN_ROWS crossover (the chain DBs are tiny on purpose)
    old_min_rows = set_device_min_rows(0)
    try:
        return _run(configs)
    finally:
        set_device_min_rows(old_min_rows)


def _run(configs=None) -> list[dict]:
    configs = configs or [
        # scale attribute cardinality at fixed shallow chain
        (1, 4, 2), (1, 8, 2), (1, 16, 2),
        # scale chain depth at fixed cardinality
        (2, 8, 2), (3, 8, 2),
        # the blow-up regime: dense joint > 10^9 cells, sparse still easy
        (2, 16, 3), (3, 16, 3),
    ]
    rows = []
    for depth, card, n_attrs in configs:
        db = chain_db(depth, card, n_attrs)
        vids = tuple(v.vid for v in db.catalog.par_rvs)
        cells = dense_cells_of(db, vids)
        name = f"d{depth}c{card}a{n_attrs}"

        if cells <= DENSE_BENCH_CAP:
            _, dsecs = timed(joint_contingency_table, db, impl="ref")
            emit(f"sparse/{name}/dense", dsecs, f"cells={cells:.3g}")
        else:
            emit(f"sparse/{name}/dense", 0.0, f"oom;cells={cells:.3g}")
            dsecs = math.inf

        ops.reset_launch_counts()
        ct, ssecs = timed(joint_contingency_table, db, impl="sparse")
        build_launches = ops.total_launches()
        nss = ct.n_nonzero()
        emit(
            f"sparse/{name}/sparse",
            ssecs,
            f"SS={nss};cells={cells:.3g};ratio={cells / max(nss, 1):.3g};"
            f"launches={build_launches}",
        )

        # device-resident COO: ship the joint once, batch every single-RV
        # marginal through ONE fused device sort (no host round-trip)
        ops.reset_launch_counts()
        ops.reset_transfer_counts()
        dev = ct.to_device()
        keeps = [(v,) for v in ct.rvs]
        _, msecs = timed(dev.marginal_batch, keeps)
        mb_launches = ops.total_launches()
        transfers = ops.transfer_bytes()
        emit(
            f"sparse/{name}/device_marginal_batch", msecs,
            f"keeps={len(keeps)};launches={mb_launches};"
            f"h2d={transfers['h2d']};d2h={transfers['d2h']}",
        )
        # device-side build: the same joint constructed as COO algebra on
        # the device — zero host-side COO, zero bulk h2d upload
        ops.reset_launch_counts()
        ops.reset_transfer_counts()
        dct, bsecs = timed(
            joint_contingency_table, db, impl="sparse", device_resident=True
        )
        dev_build_launches = ops.total_launches()
        btr = ops.transfer_bytes()
        upload_avoided = ct.codes.nbytes + ct.counts.nbytes
        emit(
            f"sparse/{name}/device_build", bsecs,
            f"SS={dct.n_nonzero()};launches={dev_build_launches};"
            f"h2d={btr['h2d']};d2h={btr['d2h']};upload_avoided={upload_avoided}",
        )
        rows.append(
            {"name": name, "cells": cells, "n_ss": nss,
             "dense_s": dsecs, "sparse_s": ssecs,
             "build_launches": build_launches,
             "device_marginal_batch_s": msecs,
             "device_marginal_batch_launches": mb_launches,
             "h2d_bytes": transfers["h2d"], "d2h_bytes": transfers["d2h"],
             "device_build_s": bsecs,
             "device_build_launches": dev_build_launches,
             "device_build_h2d_bytes": btr["h2d"],
             "device_build_d2h_bytes": btr["d2h"],
             "device_build_upload_avoided_bytes": upload_avoided}
        )
    biggest = max(r["cells"] for r in rows)
    assert biggest > 10**9, "sweep must include a >10^9-dense-cell config"
    return rows


def main(argv: list[str] | None = None) -> None:
    import argparse

    argparse.ArgumentParser().parse_args(argv)
    print("name,us_per_call,derived")
    run()


if __name__ == "__main__":
    main()
