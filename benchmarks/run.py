"""Benchmark driver — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows:
  * table6/*  — Table VI  (count manager: sufficient statistics + time)
  * table7/*  — Table VII (model manager: parameter learning)
  * table9/*  — Table IX  (structure learning, FB vs no-cache baseline)
  * fig9/*    — Figure 9  (block vs single test-set prediction)
  * kernels/* — hot-spot microbenchmarks
  * roofline/*— dry-run-derived roofline terms (needs results/dryrun/*.json)

``--fast`` shrinks datasets for CI; ``--paper-scale`` lifts MovieLens/IMDb to
the paper's >10^6-tuple regime (slow on one CPU core).
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true", help="tiny datasets (CI smoke)")
    p.add_argument("--paper-scale", action="store_true", help="full 10^6-tuple runs")
    p.add_argument("--skip", nargs="*", default=[],
                   help="benches to skip: counts sparse params structure "
                        "predict kernels roofline scale")
    p.add_argument("--json", nargs="?", const="BENCH_structure.json", default=None,
                   metavar="PATH",
                   help="run the batched-vs-serial structure bench plus the "
                        "million-row scale leg and write their "
                        "machine-readable metrics to PATH "
                        "(default BENCH_structure.json)")
    p.add_argument("--smoke", action="store_true",
                   help="with --json: one tiny dataset (CI artifact)")
    p.add_argument("--weekly", action="store_true",
                   help="with --json: extend the scale leg to the 4M/10M "
                        "presets (the scheduled slow run)")
    a = p.parse_args(argv)

    if a.json is not None:
        import json

        from . import (
            bench_incremental,
            bench_kernels,
            bench_scale,
            bench_serve,
            bench_structure,
        )

        datasets = ["uw-cse"] if a.smoke else ["uw-cse", "mutagenesis", "movielens"]
        scale = 0.05 if a.smoke else None
        print("name,us_per_call,derived")
        payload = bench_structure.json_payload(
            datasets, scale, max_chain=1, smoke=a.smoke
        )
        # COO primitive microbenches (sort / join probe / join expansion):
        # rows-vs-ms curves of the kernel-endgame hotspots, per-primitive
        # metric layout, so they keep their own top-level key too.
        payload["bench_kernels"] = bench_kernels.run_micro()
        # The scale leg: host vs (sharded) device sparse joint builds on the
        # synthetic star schemas.  Its per-preset metric keys differ from
        # the structure bench's, so it lives under its own top-level key.
        presets = (
            bench_scale.SMOKE_PRESETS if a.smoke
            else bench_scale.WEEKLY_PRESETS if a.weekly
            else bench_scale.FULL_PRESETS
        )
        payload["bench_scale"] = bench_scale.run_scale(presets)
        # The incremental leg: O(Δ) signed-delta maintenance of the
        # device-resident joint vs warm full rebuilds, every apply gated
        # bit-identical against a from-scratch oracle.
        payload["bench_incremental"] = bench_incremental.run_incremental(
            bench_incremental.SMOKE_PRESETS if a.smoke
            else bench_incremental.FULL_PRESETS
        )
        # The serving leg: model-store round trip + micro-batched online
        # prediction.  Gated bitwise against the single-instance oracle
        # (serve_equal / roundtrip_equal ride the generic _equal scan) and
        # compile-gated below: steady traffic must stay cache-complete.
        payload["bench_serve"] = bench_serve.run_serve(
            bench_serve.SMOKE_PRESETS if a.smoke else bench_serve.FULL_PRESETS,
            scale,
        )
        with open(a.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {a.json}", file=sys.stderr)
        # Equivalence gate: batched-vs-serial (dense and device-sparse)
        # walks must produce the same model, and every scale-leg device /
        # sharded build must be bit-identical to the host oracle.  CI's
        # bench-smoke step fails on any False flag so a scoring or
        # sharded-merge regression cannot land silently.
        failed = [
            f"{name}:{key}"
            for group in (
                "datasets", "bench_scale", "bench_kernels", "bench_incremental",
                "bench_serve",
            )
            for name, metrics in payload[group].items()
            for key, val in sorted(metrics.items())
            if key.endswith("_equal") and val is False
        ]
        if failed:
            print(f"# EQUIVALENCE FAILED: {', '.join(failed)}", file=sys.stderr)
            sys.exit(1)
        # Compile-budget gate (same pattern): the cold device leg's actual
        # XLA compile count must stay under the committed budget, and the
        # warm leg must be cache-complete — a recompile regression (a shape
        # escaping the bucket ladder) fails the PR here, not the next
        # profiling session.
        over = [
            f"{name}:{metrics['compiles']}"
            for name, metrics in payload["datasets"].items()
            if metrics["compiles"] > bench_structure.COMPILE_BUDGET
        ]
        over_warm = [
            f"{name}:warm={metrics['compiles_warm']}"
            for name, metrics in payload["datasets"].items()
            if metrics["compiles_warm"] > bench_structure.WARM_COMPILE_BUDGET
        ]
        # Warm delta applies must be cache-complete: a repeat single-row
        # delta rides the bucket ladder end-to-end, so a nonzero compile
        # count here means a delta-view shape escaped the ladder.
        over_delta = [
            f"{name}:delta1_compiles_warm={metrics['delta1_compiles_warm']}"
            for name, metrics in payload["bench_incremental"].items()
            if metrics["delta1_compiles_warm"] > 0
        ]
        # Warm serving traffic must be cache-complete: after warmup() the
        # service answers every request batch size on already-compiled
        # rung-shaped programs, so a single warm compile means a request
        # shape escaped the bucket ladder.
        over_serve = [
            f"{name}:serve_warm_compiles={metrics['warm_compiles']}"
            for name, metrics in payload["bench_serve"].items()
            if metrics["warm_compiles"] > 0
        ]
        if over or over_warm or over_delta or over_serve:
            print(
                f"# COMPILE BUDGET EXCEEDED: "
                f"{', '.join(over + over_warm + over_delta + over_serve)} "
                f"(budget={bench_structure.COMPILE_BUDGET}, "
                f"warm_budget={bench_structure.WARM_COMPILE_BUDGET}, "
                f"warm_delta_budget=0, serve_warm_budget=0)",
                file=sys.stderr,
            )
            sys.exit(1)
        return

    scale = 0.02 if a.fast else (1.0 if a.paper_scale else None)
    datasets = (
        ["movielens", "mutagenesis", "uw-cse", "hepatitis"]
        if a.fast
        else ["movielens", "mutagenesis", "uw-cse", "mondial", "hepatitis", "imdb"]
    )

    print("name,us_per_call,derived")
    t0 = time.time()

    if "kernels" not in a.skip:
        from . import bench_kernels

        bench_kernels.run()

    if "counts" not in a.skip:
        from . import bench_counts

        bench_counts.run(datasets, scale)

    if "sparse" not in a.skip:
        from . import bench_sparse

        # --fast drops the multi-second deep-chain builds, keeps the >10^9
        # dense-cell demo (which is fast *because* it is sparse)
        cfgs = [(1, 8, 2), (2, 8, 2), (2, 16, 3)] if a.fast else None
        bench_sparse.run(cfgs)

    if "params" not in a.skip:
        from . import bench_params

        bench_params.run(datasets, scale)

    if "structure" not in a.skip:
        from . import bench_structure

        bench_structure.run(datasets, scale)

    if "scale" not in a.skip:
        from . import bench_scale

        bench_scale.run_scale(
            bench_scale.SMOKE_PRESETS if a.fast else bench_scale.FULL_PRESETS
        )

    if "predict" not in a.skip:
        from . import bench_predict

        bench_predict.run(datasets, scale, single_cap=8 if a.fast else 24)

    if "roofline" not in a.skip:
        try:
            from . import bench_roofline

            bench_roofline.run()
        except FileNotFoundError:
            print("roofline/skipped,0.0,no results/dryrun cache — run launch/dryrun.py first",
                  flush=True)

    print(f"# total benchmark wall time: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
