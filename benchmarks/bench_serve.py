"""Serving-tier bench: durable-store reload + micro-batched block prediction.

The paper's §VI case for block access is made offline — score the whole
test set in one grouped query per family.  The serving tier makes the same
claim *online*: requests arriving one at a time are coalesced by the
micro-batcher, padded onto the geometric bucket ladder, and answered by
the very same ``block_predict`` programs the learner compiled — so steady
traffic runs at **zero** warm XLA compiles regardless of request batch
size, and every served posterior is **bitwise** equal to the
single-instance oracle (``predict_single_loop``), not merely close.

Per dataset this leg measures:

  * model-store round trip — save → load → the reloaded CPTs are
    bit-identical (``roundtrip_equal``) and the artifact size is recorded;
  * serving correctness — served probs/log-scores vs the single-instance
    oracle, bitwise (``serve_equal``);
  * latency/throughput — p50/p99 ms and QPS at ≥3 distinct request batch
    sizes riding one warmed service;
  * compile hygiene — ``warm_compiles`` (gated == 0 by ``run.py --json``)
    across all traffic after :meth:`PredictService.warmup`.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.cpt import learn_parameters
from repro.core.model_store import LearnedModel, load_model, save_model
from repro.core.predict import predict_single_loop
from repro.core.structure import CountCache, learn_and_join
from repro.kernels import ops
from repro.serving.predict_service import PredictService

from .common import emit, load

SMOKE_PRESETS = ["uw-cse"]
FULL_PRESETS = ["uw-cse", "mutagenesis", "movielens"]

#: Request batch sizes exercised against one warmed service.  All of them
#: land on the same bucket-ladder rung, which is exactly why the warm
#: compile gate can demand zero across the whole set.
BATCH_SIZES = (1, 4, 16)

#: Requests submitted per batch size (concurrently, so the micro-batcher
#: actually gets to coalesce them).
REQUESTS_PER_SIZE = 32


def _pick_target(db) -> str:
    """First entity-attribute par-RV of the largest entity table."""
    cat = db.catalog
    best = max(db.entities.values(), key=lambda t: t.n_rows)
    for v in cat.entity_attrs:
        if v.table == best.name and v.fovars[0].index == 0:
            return v.vid
    return cat.entity_attrs[0].vid


def run_serve(
    presets: list[str] | None = None,
    scale: float | None = None,
    *,
    single_cap: int = 16,
) -> dict:
    out = {}
    for name in presets or FULL_PRESETS:
        bdb = load(name, scale)
        db = bdb.db
        cache = CountCache(db, mode="precount", impl="auto")
        res = learn_and_join(
            db, cache, score="aic", max_parents=2, max_chain=1, impl="auto"
        )
        factors = learn_parameters(res.bn, cache, alpha=0.1, impl="auto")
        target = _pick_target(db)
        model = LearnedModel(
            schema=db.schema, bn=res.bn, factors=factors,
            meta={"dataset": name, "target": target},
        )

        # -- durable store round trip: the service below runs off the
        #    *reloaded* artifact, so serve_equal transitively covers it too
        with tempfile.TemporaryDirectory() as td:
            path = save_model(model, os.path.join(td, "model.npz"))
            artifact_kb = os.path.getsize(path) / 1024.0
            t0 = time.perf_counter()
            loaded = load_model(path)
            load_ms = (time.perf_counter() - t0) * 1e3
        roundtrip_equal = (
            loaded.schema == model.schema
            and loaded.bn == model.bn
            and all(
                np.array_equal(
                    np.asarray(ops.to_host(loaded.factors[c].table)),
                    np.asarray(ops.to_host(model.factors[c].table)),
                )
                for c in model.factors
            )
        )

        # -- the single-instance oracle (measured BEFORE the service warms
        #    up, so its own compiles stay out of the warm window)
        n_inst = db.entities[db.catalog[target].table].n_rows
        cap = min(single_cap, n_inst)
        oracle = predict_single_loop(
            db, res.bn, factors, target, impl="auto", max_instances=cap
        )
        op = np.asarray(oracle.probs)
        ol = np.asarray(oracle.log_scores)

        svc = PredictService(db, loaded, target, max_batch=64, flush_ms=1.0)
        warm = svc.warmup()

        serve_equal = True
        metrics = {
            "target": target,
            "n_entities": n_inst,
            "artifact_kb": artifact_kb,
            "load_ms": load_ms,
            "roundtrip_equal": bool(roundtrip_equal),
            "warmup_compiles": warm["compiles"],
            "rungs": len(warm["rungs"]),
        }
        for bsize in BATCH_SIZES:
            ids_list = [
                [(i * bsize + j) % cap for j in range(bsize)]
                for i in range(REQUESTS_PER_SIZE)
            ]
            t0 = time.perf_counter()
            futs = [svc.submit(ids) for ids in ids_list]
            results = [f.result(timeout=60) for f in futs]
            wall = time.perf_counter() - t0
            for ids, r in zip(ids_list, results):
                serve_equal = serve_equal and bool(
                    np.array_equal(r.probs, op[ids])
                    and np.array_equal(r.log_scores, ol[ids])
                )
            lats = sorted(r.latency_ms for r in results)
            p50 = lats[len(lats) // 2]
            p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
            qps = len(ids_list) / max(wall, 1e-9)
            metrics[f"b{bsize}_p50_ms"] = p50
            metrics[f"b{bsize}_p99_ms"] = p99
            metrics[f"b{bsize}_qps"] = qps
            emit(
                f"serve/{name}/b{bsize}", wall / len(ids_list),
                f"p50={p50:.2f}ms;p99={p99:.2f}ms;qps={qps:.0f}",
            )

        stats = svc.stats()
        svc.close()
        metrics["serve_equal"] = bool(serve_equal)
        metrics["warm_compiles"] = stats["warm_compiles"]
        metrics["batches"] = stats["batches"]
        metrics["rows_per_batch"] = stats["rows_per_batch"]
        emit(
            f"serve/{name}/summary", 0.0,
            f"warm_compiles={stats['warm_compiles']};"
            f"serve==single:{serve_equal};roundtrip:{roundtrip_equal}",
        )
        out[name] = metrics
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=FULL_PRESETS)
    p.add_argument("--scale", type=float, default=None)
    a = p.parse_args(argv)
    import json
    import sys

    print(json.dumps(run_serve(a.datasets, a.scale), indent=2), file=sys.stderr)


if __name__ == "__main__":
    main()
