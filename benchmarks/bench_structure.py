"""Paper Table IX: end-to-end structure learning time, FB vs no-cache baseline.

FB-Total = learn-and-join with the pre-counted joint CT (the paper's setup);
FB-Count = the count-manager share of that time (joint CT construction).
Baseline = the same search *without* the in-database count services: every
candidate family is re-counted from raw data with no joint CT and no memo —
the algorithmic cost profile of the external-learner class the paper compares
against (RDN/MLN-Boost re-derive statistics per gradient step).  Times are
normalized per par-RV as in Table IX.
"""

from __future__ import annotations

from repro.core.structure import CountCache, learn_and_join

from .common import emit, load, timed

# The no-cache baseline is O(candidates x data scans); restrict it to the
# datasets where that is tolerable on one core, as the paper's baselines
# also failed to terminate on the large sets (N/T entries of Table IX).
BASELINE_OK = {"uw-cse", "mutagenesis", "mondial", "hepatitis"}


def run(datasets: list[str], scale: float | None = None, max_chain: int = 1) -> dict:
    out = {}
    for name in datasets:
        bdb = load(name, scale)
        n_rv = len(bdb.db.catalog.par_rvs)

        cache, count_secs = timed(CountCache, bdb.db, mode="precount", impl="auto")
        res, search_secs = timed(
            learn_and_join, bdb.db, cache, score="aic", max_parents=2,
            max_chain=max_chain, impl="auto",
        )
        total = count_secs + search_secs
        emit(
            f"table9/{name}/fb_total", total,
            f"per_parRV={total / n_rv:.3f}s;count_share={count_secs / total:.2f};edges={res.bn.n_edges}",
        )
        emit(f"table9/{name}/fb_count", count_secs, f"per_parRV={count_secs / n_rv:.3f}s")
        out[name] = {"bn": res.bn, "cache": cache, "fb_total": total, "fb_count": count_secs}

        if name in BASELINE_OK:
            nocache = CountCache(bdb.db, mode="ondemand", impl="auto", memoize=False)
            res_b, base_secs = timed(
                learn_and_join, bdb.db, nocache, score="aic", max_parents=2,
                max_chain=max_chain, impl="auto",
            )
            emit(
                f"table9/{name}/nocache_baseline", base_secs,
                f"per_parRV={base_secs / n_rv:.3f}s;slowdown={base_secs / max(total, 1e-9):.1f}x",
            )
            out[name]["baseline"] = base_secs
        else:
            emit(f"table9/{name}/nocache_baseline", float("nan"), "N/T(skipped-by-cost)")
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*",
                   default=["movielens", "mutagenesis", "uw-cse", "mondial", "hepatitis", "imdb"])
    p.add_argument("--scale", type=float, default=None)
    p.add_argument("--max-chain", type=int, default=1)
    a = p.parse_args(argv)
    run(a.datasets, a.scale, a.max_chain)


if __name__ == "__main__":
    main()
