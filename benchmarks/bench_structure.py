"""Paper Table IX: end-to-end structure learning time, FB vs no-cache baseline.

FB-Total = learn-and-join with the pre-counted joint CT (the paper's setup);
FB-Count = the count-manager share of that time (joint CT construction).
Baseline = the same search *without* the in-database count services: every
candidate family is re-counted from raw data with no joint CT and no memo —
the algorithmic cost profile of the external-learner class the paper compares
against (RDN/MLN-Boost re-derive statistics per gradient step).  Times are
normalized per par-RV as in Table IX.
"""

from __future__ import annotations

import time

from repro.core.scores import score_structure
from repro.core.structure import CountCache, ScoreManager, learn_and_join
from repro.kernels import ops

from .common import emit, load, timed

# The no-cache baseline is O(candidates x data scans); restrict it to the
# datasets where that is tolerable on one core, as the paper's baselines
# also failed to terminate on the large sets (N/T entries of Table IX).
BASELINE_OK = {"uw-cse", "mutagenesis", "mondial", "hepatitis"}

#: CI compile budget: max XLA backend compiles any dataset's cold device
#: leg (build + search, counted by the kernels.bucketing probe) may record
#: before the bench smoke FAILS.  With the build folded into jitted
#: super-programs (sparse_counts), the per-build ladder floor collapsing
#: small-stream shape diversity, and the fused histogram/sort aggregation
#: programs, the cold pass measures ~87 programs on the smoke dataset
#: (build ~45 + search ~42; lower for every later dataset of a run because
#: rungs are shared — was ~230 before the super-program fold).  The budget
#: adds ~40% headroom for backend drift but fails long before a
#: per-join-shape recompile regression (which lands in the thousands) or a
#: de-fusion regression (which lands in the hundreds).  Committed here so
#: a regression fails the PR that caused it, not the next profiling
#: session.
COMPILE_BUDGET = 120

#: Warm-leg compile budget: a second same-shape build + search must hit
#: the jit cache everywhere.  Zero in a healthy run; tiny headroom only
#: for incidental host-side constant programs.
WARM_COMPILE_BUDGET = 8


def run(datasets: list[str], scale: float | None = None, max_chain: int = 1) -> dict:
    out = {}
    for name in datasets:
        bdb = load(name, scale)
        n_rv = len(bdb.db.catalog.par_rvs)

        cache, count_secs = timed(CountCache, bdb.db, mode="precount", impl="auto")
        res, search_secs = timed(
            learn_and_join, bdb.db, cache, score="aic", max_parents=2,
            max_chain=max_chain, impl="auto",
        )
        total = count_secs + search_secs
        emit(
            f"table9/{name}/fb_total", total,
            f"per_parRV={total / n_rv:.3f}s;count_share={count_secs / total:.2f};edges={res.bn.n_edges}",
        )
        emit(f"table9/{name}/fb_count", count_secs, f"per_parRV={count_secs / n_rv:.3f}s")
        out[name] = {"bn": res.bn, "cache": cache, "fb_total": total, "fb_count": count_secs}

        if name in BASELINE_OK:
            nocache = CountCache(bdb.db, mode="ondemand", impl="auto", memoize=False)
            res_b, base_secs = timed(
                learn_and_join, bdb.db, nocache, score="aic", max_parents=2,
                max_chain=max_chain, impl="auto",
            )
            emit(
                f"table9/{name}/nocache_baseline", base_secs,
                f"per_parRV={base_secs / n_rv:.3f}s;slowdown={base_secs / max(total, 1e-9):.1f}x",
            )
            out[name]["baseline"] = base_secs
        else:
            emit(f"table9/{name}/nocache_baseline", float("nan"), "N/T(skipped-by-cost)")
    return out


def run_batched(
    datasets: list[str], scale: float | None = None, max_chain: int = 1
) -> dict:
    """Batched (ScoreManager) vs serial (CountCache) learn-and-join.

    The set-oriented §V-C claim, made machine-readable: same datasets, same
    search, scored per-candidate vs one batch per sweep.  Emits CSV rows and
    returns a JSON-ready metrics dict per dataset — candidates scored/sec,
    per-sweep latency, wall-clock speedup, ops-layer launch counts (the
    device-dispatch proxy) and the sparse joint-build time, plus the
    equivalence checks (identical edges, matching total score) that gate
    the numbers.

    A third leg runs the same search against a **device-resident sparse
    joint** (``ScoreManager(mode="sparse", device_resident=True)``): every
    sweep is scored by the fused ``sparse_family_score`` launch with no
    host sort, and the metrics record its per-sweep launch count (the
    acceptance criterion is <= 3) and the accounted host<->device transfer
    bytes — the whole traffic is the one joint upload plus a (B,) score
    row per batch.  The device leg runs cold THEN warm: the cold pass
    records the actual XLA compile count (``compiles``, gated against
    :data:`COMPILE_BUDGET` by the CI smoke) and the warm pass — fresh
    ScoreManager, warm jit cache — supplies the headline build/search
    timings and ``sparse_device_speedup``, so compile time never leaks
    into the steady-state throughput numbers.

    **Fair accounting.**  The legs walk the same move sequence (the
    ``*_edges_equal`` flags gate that), but their raw candidate counters
    are *per-leg denominators* and must not be compared directly:

      * ``candidates_scored_serial`` counts memo misses of the serial
        climber, whose family memo is **per lattice node** — families
        shared between nodes are re-scored once per node;
      * ``candidates_scored_batched`` counts the ScoreManager's memo
        misses, and that memo is **global across the lattice** — every
        distinct family is counted exactly once (it is the distinct-family
        count of the shared trajectory);
      * sweep counts are likewise per-leg (``n_sweeps_serial`` vs
        ``n_sweeps``, ``sparse_n_sweeps_serial`` vs ``sparse_n_sweeps`` /
        ``sparse_n_sweeps_warm``) — equal final edges do not force equal
        sweep counts, since a leg may spend an extra no-improvement sweep.

    Cross-leg comparisons therefore use equal-work normalizations:
    ``speedup`` / ``sparse_device_speedup`` are wall-clock ratios over the
    same search, and ``speedup_per_sweep`` / ``sparse_device_speedup_per_
    sweep`` divide each leg's seconds by its *own* sweep count first, so a
    sweep-count wobble cannot masquerade as a throughput change.  The
    adaptive batch/serial router's split is reported as
    ``batch_router_serial`` / ``batch_router_batched``.
    """
    from repro.core.counts import set_device_min_rows

    out: dict[str, dict] = {}
    # The device legs MEASURE the device path — force it even on datasets
    # below the REPRO_DEVICE_MIN_ROWS production crossover (uw-cse is), or
    # every device metric would silently re-measure the host builder.
    old_min_rows = set_device_min_rows(0)
    try:
        out.update(_run_batched(datasets, scale, max_chain))
    finally:
        set_device_min_rows(old_min_rows)
    return out


def _run_batched(
    datasets: list[str], scale: float | None = None, max_chain: int = 1
) -> dict:
    out: dict[str, dict] = {}
    for name in datasets:
        bdb = load(name, scale)
        db = bdb.db

        _, sparse_build = timed(CountCache, db, mode="sparse")

        ser_cache, _ = timed(CountCache, db, mode="precount", impl="auto")
        ops.reset_launch_counts()
        res_ser, ser_secs = timed(
            learn_and_join, db, ser_cache, score="aic", max_parents=2,
            max_chain=max_chain, impl="auto",
        )
        ser_launches = ops.total_launches()

        mgr, _ = timed(ScoreManager, db, mode="precount", impl="auto")
        ops.reset_launch_counts()
        res_bat, bat_secs = timed(
            learn_and_join, db, mgr, score="aic", max_parents=2,
            max_chain=max_chain, impl="auto",
        )
        bat_launches = ops.total_launches()

        edges_equal = sorted(res_ser.bn.edges()) == sorted(res_bat.bn.edges())
        aic_ser = score_structure(res_ser.bn, ser_cache, impl="auto").aic
        aic_bat = score_structure(res_bat.bn, ser_cache, impl="auto").aic
        scores_equal = abs(aic_ser - aic_bat) <= 1e-4 * max(1.0, abs(aic_ser))

        # --- device-resident sparse leg (the fused COO scorer) --------------
        sp_ser_cache = CountCache(db, mode="sparse")
        res_sp_ser, sp_ser_secs = timed(
            learn_and_join, db, sp_ser_cache, score="aic", max_parents=2,
            max_chain=max_chain,
        )
        # The joint is BUILT on device (PR 4): bracket the build's own
        # launches and transfer bytes — h2d must stay ~0 (no bulk COO
        # upload; the PR 3 route shipped the whole codes+counts stream) and
        # d2h is a handful of accounted scalar size syncs.  The transfer
        # tally keeps running through the search so the device leg's total
        # traffic story (build + scoring) stays visible; the launch tally
        # restarts after the build so launches/sweep measures scoring only.
        #
        # The leg runs TWICE (PR 5): the cold pass pays whatever XLA
        # compiles the shape-bucket ladder hasn't amortized yet (counted by
        # the ops compile probe — the number the CI compile budget gates),
        # then a warm pass with a fresh ScoreManager (fresh score memo,
        # warm jit cache) measures steady-state throughput.  Headline
        # numbers come from the warm pass so compile time never masquerades
        # as per-sweep cost; cold numbers keep their own keys.
        ops.reset_transfer_counts()
        ops.reset_launch_counts()
        ops.reset_compile_counts()
        mgr_sp, sp_build_cold_secs = timed(
            ScoreManager, db, mode="sparse", device_resident=True
        )
        sp_build_launches = ops.total_launches()
        sp_build_tr = dict(ops.transfer_bytes())
        ops.reset_launch_counts()
        res_sp_dev, sp_dev_cold_secs = timed(
            learn_and_join, db, mgr_sp, score="aic", max_parents=2,
            max_chain=max_chain,
        )
        sp_dev_launches = ops.total_launches()
        sp_transfers = ops.transfer_bytes()
        cold_compiles = ops.compile_counts()
        ops.reset_compile_counts()
        mgr_warm, sp_build_warm_secs = timed(
            ScoreManager, db, mode="sparse", device_resident=True
        )
        res_sp_warm, sp_dev_warm_secs = timed(
            learn_and_join, db, mgr_warm, score="aic", max_parents=2,
            max_chain=max_chain,
        )
        warm_compiles = ops.compile_counts()
        sparse_edges_equal = sorted(res_sp_ser.bn.edges()) == sorted(
            res_sp_dev.bn.edges()
        )
        sparse_warm_edges_equal = sorted(res_sp_warm.bn.edges()) == sorted(
            res_sp_dev.bn.edges()
        )
        aic_sp_ser = score_structure(res_sp_ser.bn, sp_ser_cache).aic
        # the DEVICE-scored AIC of the same families: score_one routes
        # through the fused scorer's memo, so this genuinely compares the
        # fused device scores against the float64 host path within the
        # documented tolerance (see ScoreManager._score_sparse_device)
        aic_sp_dev = sum(
            mgr_sp.score_one(c, tuple(res_sp_dev.bn.parents[c])).aic()
            for c in res_sp_dev.bn.rvs
        )
        sparse_scores_equal = (
            abs(aic_sp_ser - aic_sp_dev) <= 1e-4 * max(1.0, abs(aic_sp_ser))
        )

        metrics = {
            "serial_seconds": ser_secs,
            "batched_seconds": bat_secs,
            "speedup": ser_secs / max(bat_secs, 1e-9),
            "serial_launches": ser_launches,
            "batched_launches": bat_launches,
            "launch_ratio": ser_launches / max(bat_launches, 1),
            # per-leg denominators (NOT directly comparable; see docstring):
            # serial re-scores node-shared families, batched's global memo
            # makes its count the distinct-family count of the shared walk
            "candidates_scored_serial": res_ser.n_candidates_scored,
            "candidates_scored_batched": res_bat.n_candidates_scored,
            "cands_per_sec_serial": res_ser.n_candidates_scored / max(ser_secs, 1e-9),
            "cands_per_sec_batched": res_bat.n_candidates_scored / max(bat_secs, 1e-9),
            "n_sweeps": res_bat.n_sweeps,
            "n_sweeps_serial": res_ser.n_sweeps,
            "sweep_ms_serial": ser_secs / max(res_ser.n_sweeps, 1) * 1e3,
            "sweep_ms_batched": bat_secs / max(res_bat.n_sweeps, 1) * 1e3,
            # equal-work normalization: each leg's seconds over its OWN
            # sweep count, so sweep-count wobble can't fake a speedup
            "speedup_per_sweep": (ser_secs / max(res_ser.n_sweeps, 1))
            / max(bat_secs / max(res_bat.n_sweeps, 1), 1e-9),
            # adaptive batch/serial router split (ScoreManager counters)
            "batch_router_serial": mgr.n_serial_routed,
            "batch_router_batched": mgr.n_batched_routed,
            "sparse_joint_build_ms": sparse_build * 1e3,
            "n_edges": res_bat.bn.n_edges,
            "edges_equal": edges_equal,
            "scores_equal": scores_equal,
            "sparse_serial_seconds": sp_ser_secs,
            # steady-state (warm-cache) numbers are the headline; the cold
            # first-pass keeps its own keys so compile cost stays visible
            "sparse_device_seconds": sp_dev_warm_secs,
            "sparse_device_seconds_cold": sp_dev_cold_secs,
            "sparse_device_speedup": sp_ser_secs / max(sp_dev_warm_secs, 1e-9),
            "sparse_device_speedup_cold": sp_ser_secs / max(sp_dev_cold_secs, 1e-9),
            "sparse_device_launches": sp_dev_launches,
            "sparse_launches_per_sweep": sp_dev_launches
            / max(res_sp_dev.n_sweeps, 1),
            "sparse_device_h2d_bytes": sp_transfers["h2d"],
            "sparse_device_d2h_bytes": sp_transfers["d2h"],
            "sparse_device_build_ms_cold": sp_build_cold_secs * 1e3,
            "sparse_device_build_ms_warm": sp_build_warm_secs * 1e3,
            "sparse_build_launches": sp_build_launches,
            "sparse_build_h2d_bytes": sp_build_tr["h2d"],
            "sparse_build_d2h_bytes": sp_build_tr["d2h"],
            "sparse_n_sweeps": res_sp_dev.n_sweeps,
            "sparse_n_sweeps_serial": res_sp_ser.n_sweeps,
            "sparse_n_sweeps_warm": res_sp_warm.n_sweeps,
            "sparse_device_speedup_per_sweep": (
                sp_ser_secs / max(res_sp_ser.n_sweeps, 1)
            ) / max(sp_dev_warm_secs / max(res_sp_warm.n_sweeps, 1), 1e-9),
            "sparse_edges_equal": sparse_edges_equal,
            "sparse_warm_edges_equal": sparse_warm_edges_equal,
            "sparse_scores_equal": sparse_scores_equal,
            # actual XLA backend compiles of the device leg, counted by the
            # jax.monitoring probe in kernels.bucketing: cold = build +
            # search of the first pass (bounded by the CI compile budget),
            # warm = the second pass (must be ~0: the cache-warmth gate)
            "compiles": cold_compiles["compiles"],
            "compile_secs": cold_compiles["compile_secs"],
            "compiles_warm": warm_compiles["compiles"],
        }
        out[name] = metrics
        emit(
            f"scoremgr/{name}/batched", bat_secs,
            f"speedup={metrics['speedup']:.2f}x;launches={ser_launches}->{bat_launches};"
            f"cands_per_s={metrics['cands_per_sec_batched']:.0f};"
            f"edges_equal={edges_equal};scores_equal={scores_equal}",
        )
        emit(f"scoremgr/{name}/serial", ser_secs,
             f"cands_per_s={metrics['cands_per_sec_serial']:.0f}")
        emit(f"scoremgr/{name}/sparse_joint_build", sparse_build, "mode=sparse")
        emit(
            f"scoremgr/{name}/sparse_device_build", sp_build_warm_secs,
            f"cold={sp_build_cold_secs:.3f}s;compiles={metrics['compiles']};"
            f"launches={sp_build_launches};h2d={sp_build_tr['h2d']};"
            f"d2h={sp_build_tr['d2h']}",
        )
        emit(
            f"scoremgr/{name}/sparse_device", sp_dev_warm_secs,
            f"speedup={metrics['sparse_device_speedup']:.2f}x;"
            f"cold={sp_dev_cold_secs:.3f}s;"
            f"launches_per_sweep={metrics['sparse_launches_per_sweep']:.2f};"
            f"h2d={sp_transfers['h2d']};d2h={sp_transfers['d2h']};"
            f"edges_equal={sparse_edges_equal};scores_equal={sparse_scores_equal}",
        )
    return out


def json_payload(datasets: list[str], scale: float | None, max_chain: int,
                 smoke: bool) -> dict:
    """The BENCH_structure.json document future PRs diff for regressions."""
    import jax

    return {
        "bench": "structure_batched_vs_serial",
        "unix_time": time.time(),
        "backend": jax.default_backend(),
        "smoke": smoke,
        "max_chain": max_chain,
        "scale": scale,
        "datasets": run_batched(datasets, scale, max_chain),
    }


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*",
                   default=["movielens", "mutagenesis", "uw-cse", "mondial", "hepatitis", "imdb"])
    p.add_argument("--scale", type=float, default=None)
    p.add_argument("--max-chain", type=int, default=1)
    a = p.parse_args(argv)
    run(a.datasets, a.scale, a.max_chain)


if __name__ == "__main__":
    main()
