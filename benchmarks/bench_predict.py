"""Paper Figure 9: block vs single-instance test-set prediction.

The block path scores all test entities with one grouped query per family
(one matmul); the single path re-runs a restricted count query per instance.
The paper reports 10-100x block speedups and a timeout for single access on
IMDb.  The single loop is measured on ``--single-cap`` instances and
extrapolated linearly to the full test set (flagged in the output), exactly
because its per-instance cost is what makes it infeasible at scale.
"""

from __future__ import annotations

import jax

from repro.core.cpt import learn_parameters
from repro.core.predict import predict_block, predict_single_loop
from repro.core.structure import CountCache, learn_and_join

from .common import emit, load, timed


def _pick_target(db) -> str:
    """First entity-attribute par-RV of the largest entity table (most instances)."""
    cat = db.catalog
    best = max(db.entities.values(), key=lambda t: t.n_rows)
    for v in cat.entity_attrs:
        if v.table == best.name and v.fovars[0].index == 0:
            return v.vid
    return cat.entity_attrs[0].vid


def run(datasets: list[str], scale: float | None = None, single_cap: int = 24) -> dict:
    out = {}
    for name in datasets:
        bdb = load(name, scale)
        cache = CountCache(bdb.db, mode="precount", impl="auto")
        res = learn_and_join(bdb.db, cache, score="aic", max_parents=2, max_chain=1, impl="auto")
        factors = learn_parameters(res.bn, cache, impl="auto")
        target = _pick_target(bdb.db)
        n_inst = bdb.db.entities[bdb.db.catalog[target].table].n_rows

        pb, block_secs = timed(
            predict_block, bdb.db, res.bn, factors, target, impl="auto"
        )
        jax.block_until_ready(pb.probs)

        cap = min(single_cap, n_inst)
        ps, single_secs = timed(
            predict_single_loop, bdb.db, res.bn, factors, target,
            impl="auto", max_instances=cap,
        )
        jax.block_until_ready(ps.probs)
        per_inst = single_secs / cap
        extrapolated = per_inst * n_inst
        speedup = extrapolated / max(block_secs, 1e-9)

        import numpy as np

        agree = bool(
            np.allclose(
                np.asarray(pb.log_scores[:cap]), np.asarray(ps.log_scores), atol=1e-3
            )
        )
        emit(
            f"fig9/{name}/block", block_secs,
            f"target={target};instances={n_inst}",
        )
        emit(
            f"fig9/{name}/single_extrapolated", extrapolated,
            f"measured_on={cap};speedup={speedup:.1f}x;block==single:{agree}",
        )
        out[name] = {"block": block_secs, "single_extrap": extrapolated,
                     "speedup": speedup, "agree": agree}
    return out


def main(argv: list[str] | None = None) -> None:
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*",
                   default=["movielens", "mutagenesis", "uw-cse", "mondial", "hepatitis", "imdb"])
    p.add_argument("--scale", type=float, default=None)
    p.add_argument("--single-cap", type=int, default=24)
    a = p.parse_args(argv)
    run(a.datasets, a.scale, a.single_cap)


if __name__ == "__main__":
    main()
